"""Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Each variant is (cfg_overrides, opt_cfg, rules). Appends RooflineReports
to results/perf.jsonl with the variant name.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, time, traceback
import jax.numpy as jnp

sys.path.insert(0, "/root/repo/src")
from repro.launch.dryrun import run_cell
from repro.distrib.shardings import ShardingRules
from repro.train.optimizer import AdamWConfig

BF16MOM = AdamWConfig(moment_dtype=jnp.bfloat16)
SP = ShardingRules().override(seq=("model",))

VARIANTS = {
    # (arch, shape): [(variant_name, cfg_overrides, opt_cfg, rules), ...]
    ("granite-moe-3b-a800m", "train_4k"): [
        ("baseline", None, None, None),
        ("grouped16", {"dispatch_groups": 16}, None, None),
        ("grouped16+bf16mom", {"dispatch_groups": 16}, BF16MOM, None),
        ("grouped16+bf16mom+dots", {"dispatch_groups": 16, "remat": "dots"},
         BF16MOM, None),
        ("grouped16+sp", {"dispatch_groups": 16}, None, SP),
    ],
    ("phi3.5-moe-42b-a6.6b", "train_4k"): [
        ("baseline", None, None, None),
        ("grouped16", {"dispatch_groups": 16}, None, None),
        ("grouped16+bf16mom", {"dispatch_groups": 16}, BF16MOM, None),
        ("grouped16+bf16mom+sp", {"dispatch_groups": 16}, BF16MOM, SP),
    ],
    ("qwen1.5-110b", "train_4k"): [
        ("baseline", None, None, None),
        ("dots", {"remat": "dots"}, None, None),
        ("bf16mom", None, BF16MOM, None),
        ("bf16mom+sp", None, BF16MOM, SP),
        ("bf16mom+chunk1024", {"attn_chunk": 1024}, BF16MOM, None),
    ],
}

def main():
    which = sys.argv[1] if len(sys.argv) > 1 else None
    out = open("/root/repo/results/perf.jsonl", "a")
    for (arch, shape), variants in VARIANTS.items():
        if which and which not in arch:
            continue
        for name, ov, opt, rules in variants:
            t0 = time.time()
            try:
                rep = run_cell(arch, shape, False, rules=rules,
                               verbose=False, cfg_overrides=ov,
                               opt_cfg=opt)
                d = rep.to_dict()
                d["variant"] = name
                out.write(json.dumps(d) + "\n")
                out.flush()
                print(f"{arch:22s} {name:26s} comp={rep.compute_s:8.2f} "
                      f"mem={rep.memory_s:8.2f} coll={rep.collective_s:8.2f} "
                      f"dom={rep.dominant:10s} roofline={rep.roofline_fraction:.4f} "
                      f"({time.time()-t0:.0f}s)")
            except Exception as e:
                traceback.print_exc()
                print(f"{arch} {name} FAILED: {e}")
    out.close()

if __name__ == "__main__":
    main()
