"""The paper's §5 experiment, condensed: four pipelines under four
caching settings, showing time/work falling while results stay fixed.

    PYTHONPATH=src python examples/cached_experiment.py
"""
from benchmarks.table2_reproduction import run

rows = run(scale=0.05)
cols = list(rows[0].keys())
widths = [max(len(c), 14) for c in cols]
print("  ".join(c.ljust(w) for c, w in zip(cols, widths)))
for r in rows:
    print("  ".join(str(r[c]).ljust(w) for c, w in zip(cols, widths)))
print("\nNote: identical nDCG columns across settings = the caching "
      "transparency invariant; falling bm25/mono counters = the saved "
      "work (paper Table 2's mechanism).")
