"""Hybrid sparse+dense retrieval through the plan compiler (quickstart
step 10; docs/architecture.md).

The scenario is ``(bm25 % k | dense % k) >> text_loader >> mono``: the
optimizer fuses each ``% k`` into its retriever — BM25's ``num_results``
and the dense stage's per-block kernel k (``kernels/dense_topk``) —
CSE's the shared spine, and the same caches serve offline runs, warming
and online traffic.
"""
import tempfile

from repro.core import ExecutionPlan
from repro.serve import PipelineService
from repro.serve.registry import (build_scenario, run_closed_loop,
                                  warming_frame)

# 1. build the named hybrid scenario (serve/registry.py): synthetic
#    corpus, a BM25 index, a dense index over the Pallas dense_topk
#    stage, and the mono reranker on top of their candidate union
scenario = build_scenario("hybrid", scale=0.02, cutoff=5, num_results=50)

# 2. compile + explain: no residual RankCutoff nodes — both cutoffs are
#    fused into retrieval depth (DenseRetriever shows num_results=5)
cache_dir = tempfile.mkdtemp(prefix="hybrid-dense-")
with ExecutionPlan([scenario.pipeline], cache_dir=cache_dir) as plan:
    print(plan.explain())

    # 3. warm the planner-inserted caches with the scenario's expected
    #    traffic (the closed-loop generator's exact zipf draws), so the
    #    serve epoch below starts hot
    stats = plan.warm(warming_frame(scenario, budget=16))
    print(f"warmed: {stats.cache_misses} entries precomputed, "
          f"{stats.nodes_executed} nodes executed")

# 4. serve the same expression from the same cache directory: the
#    streaming executor coalesces concurrent requests into micro-batches
#    and the warmed caches absorb the repeat traffic
with PipelineService(scenario.pipeline, cache_dir=cache_dir,
                     max_batch=8, max_wait_ms=2.0) as service:
    result = run_closed_loop(service, scenario, n_requests=24,
                             n_clients=3)
    print(f"served {result['requests']} requests at "
          f"{result['throughput_rps']:.1f} rps; "
          f"cache hits={service.stats.cache_hits}")
