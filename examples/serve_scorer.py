"""Serve a small scorer with batched requests + ScorerCache.

    PYTHONPATH=src python examples/serve_scorer.py
"""
from repro.launch.serve import main

stats = main(["--requests", "400", "--n-queries", "16",
              "--max-batch", "64"])
print("cache makes repeat traffic cheap: p50 includes hot requests; "
      "run with --no-cache to compare.")
