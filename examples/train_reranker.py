"""End-to-end driver: train a neural reranker, then evaluate it inside
a cached pipeline against the BM25 baseline.

    PYTHONPATH=src python examples/train_reranker.py [--steps 300]

The training substrate is the same stack the big configs use
(make_train_step -> AdamW + schedules; checkpointing via
repro.distrib) — dimensioned down to CPU.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Experiment
from repro.ir import InvertedIndex, TextLoader, msmarco_like
from repro.models.common import init_params
from repro.models.cross_encoder import (EncoderConfig, MonoScorer,
                                        encoder_param_specs, encoder_score)
from repro.train import AdamWConfig, linear_warmup_cosine, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
args = ap.parse_args()

dataset = msmarco_like(1, scale=0.1)
index = InvertedIndex.build(dataset.get_corpus_iter())
bm25 = index.bm25(num_results=50)
loader = TextLoader(dataset.text_map())
cfg = EncoderConfig(n_layers=2, d_model=64, n_heads=4, d_ff=128,
                    vocab_size=8192, max_len=32)

# ---- build (query, doc, label) training pairs from qrels + BM25 negatives
scorer = MonoScorer(cfg)
qrels = dataset.get_qrels()
text = dataset.text_map()
topics = dataset.get_topics()
q_text = dict(zip(topics["qid"].tolist(), topics["query"].tolist()))
pos = [(q_text[q], text[d]) for q, d in
       zip(qrels["qid"].tolist(), qrels["docno"].tolist())]
rng = np.random.default_rng(0)
docnos = dataset.docs["docno"].tolist()
neg = [(q_text[q], text[docnos[rng.integers(len(docnos))]])
       for q in qrels["qid"].tolist()]
pairs = pos + neg
labels = np.array([1.0] * len(pos) + [0.0] * len(neg), np.float32)
toks = np.stack([scorer.tokenizer.encode_pair(q, t, cfg.max_len)
                 for q, t in pairs])

# ---- train with the shared substrate
params = init_params(encoder_param_specs(cfg), jax.random.key(0))


def loss_fn(p, batch):
    logits = encoder_score(p, batch["toks"], cfg)
    y = batch["y"]
    z = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y
                    + jnp.log1p(jnp.exp(-jnp.abs(z))))


step_fn, init_opt = make_train_step(
    loss_fn, AdamWConfig(lr=3e-3, weight_decay=0.01),
    lr_schedule=lambda s: linear_warmup_cosine(s, warmup=20,
                                               total=args.steps))
jitted = jax.jit(step_fn, donate_argnums=(0, 1))
opt = init_opt(params)
B = 64
for step in range(args.steps):
    idx = rng.integers(0, len(pairs), B)
    batch = {"toks": jnp.asarray(toks[idx]), "y": jnp.asarray(labels[idx])}
    params, opt, m = jitted(params, opt, batch)
    if step % 50 == 0 or step == args.steps - 1:
        print(f"step {step:4d} loss {float(m['loss']):.4f}")

# ---- drop the trained weights into the pipeline stage and evaluate
scorer.params = params
res = Experiment(
    [bm25 % 10, bm25 % 50 >> loader >> scorer % 10],
    topics, qrels, ["nDCG@10", "MAP"],
    names=["bm25", "bm25 >> trained-mono"], baseline=0,
    precompute_prefix=True)
print(res)
