"""Quickstart: declarative IR pipelines, experiments, precompute, caches.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.caching import RetrieverCache, ScorerCache, auto_cache
from repro.core import Experiment
from repro.ir import InvertedIndex, TextLoader, msmarco_like
from repro.models.cross_encoder import EncoderConfig, MonoScorer

# 1. a corpus + topics + qrels (synthetic MSMARCO-v1-scaled)
dataset = msmarco_like(1, scale=0.1)

# 2. index it; build a BM25 retriever (Q -> R)
index = InvertedIndex.build(dataset.get_corpus_iter())
bm25 = index.bm25(num_results=100)

# 3. the paper's operator language: compose a retrieve-and-rerank pipeline
mono = MonoScorer(EncoderConfig(n_layers=2, d_model=64, n_heads=4,
                                d_ff=128, vocab_size=8192, max_len=32))
loader = TextLoader(dataset.text_map())
pipeline = bm25 % 20 >> loader >> mono
print("pipeline:", pipeline)

# 4. a declarative experiment over four rank cutoffs — ONE bm25 pass
#    thanks to prefix precomputation (paper §3)
res = Experiment(
    [bm25 % k >> loader >> mono for k in (5, 10, 20, 50)],
    dataset.get_topics(), dataset.get_qrels(),
    ["nDCG@10", "MAP", "R@50"],
    names=[f"k={k}" for k in (5, 10, 20, 50)],
    precompute_prefix=True,          # <---- the paper's §3 feature
    baseline=0,
)
print(res)
print("precompute saved stage invocations:",
      res.precompute.stage_invocations_saved)

# 5. explicit caching (paper §4): wrap the scorer, re-run for free
with ScorerCache(None, mono) as cached_mono:
    cached = bm25 % 20 >> loader >> cached_mono
    cached(dataset.get_topics())
    cached(dataset.get_topics())     # <- all values cached
    print("scorer cache:", cached_mono.stats)

# 6. or let the framework pick the right cache family from transformer
#    metadata (the paper's §6 future work, implemented here)
c = auto_cache(bm25)
print("auto_cache(bm25) ->", type(c).__name__)
c.close()

# 7. the unified planner: lower a pipeline set into ONE shared DAG —
#    sharing recurses into binary operators (a is executed once below,
#    even though stages_of sees `a + b` and `a ** b` as opaque), and
#    the planner inserts the §4 caches itself when given a cache_dir
from repro.core import ExecutionPlan

a, b = bm25 % 20, index.bm25(num_results=100, k1=2.0) % 20
with ExecutionPlan([a + b, a ** b, a]) as plan:
    outs, stats = plan.run(dataset.get_topics())
    print("plan:", stats)

# 8. the plan is a compiled artifact: explain() shows the optimized DAG
#    — per-node fingerprints, inserted cache families, and which
#    optimizer pass (normalize / cse / pushdown / cache-prune) touched
#    each node.  `b + a` below shares the `a + b` node via commutative
#    normalization + CSE, and the lone `% 5` fuses into the retriever's
#    num_results via cutoff pushdown.
with ExecutionPlan([a + b, b + a,
                    index.bm25(num_results=500, b=0.8) % 5]) as plan:
    print(plan.explain())

# 9. online serving: the SAME pipeline expression, compiled once and
#    stood up as a service — concurrent submissions coalesce into
#    micro-batches (flush on max_batch or max_wait_ms), requests
#    sharing a query execute it once, and planner caches make repeat
#    traffic cheap per request (the paper's Table-2 mechanism, online)
from repro.serve import PipelineService

with PipelineService(pipeline, cache_backend="memory",
                     max_batch=16, max_wait_ms=2.0) as service:
    topics = dataset.get_topics()
    futures = [service.submit(qid, query)          # async submission
               for qid, query in zip(topics["qid"].tolist(),
                                     topics["query"].tolist())]
    futures += [service.submit(topics["qid"][0],   # repeat traffic: hits
                               topics["query"][0])]
    for fut in futures:
        fut.result()
    print("service:", service.stats.summary())
    print(service.explain())                       # plan tree + online
                                                   # p50/p99 per node

# 10. hybrid sparse+dense retrieval: `(bm25 % k | dense % k)` fans out
#     over the inverted index AND the Pallas dense_topk kernel stage
#     (kernels/dense_topk), with both cutoffs fused into retrieval
#     depth by the optimizer — the full walkthrough (explain, cache
#     warming, then serving from the warmed store) lives in
#     examples/hybrid_dense.py and docs/architecture.md.
