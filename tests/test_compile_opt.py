"""pipeline.compile() rewriting (paper §3's conceptual->logical map)."""
import pytest

from repro.core import ColFrame, Identity, RankCutoff, stages_of
from repro.core.compile_opt import compile_pipeline
from repro.ir import InvertedIndex, msmarco_like

CORPUS = msmarco_like(1, scale=0.04)
INDEX = InvertedIndex.build(CORPUS.get_corpus_iter())
TOPICS = CORPUS.get_topics()


def test_cutoff_pushdown_into_retriever():
    bm25 = INDEX.bm25(num_results=1000)
    compiled = compile_pipeline(bm25 % 10)
    stages = stages_of(compiled)
    assert len(stages) == 1
    assert stages[0].num_results == 10
    # semantics preserved
    a = (bm25 % 10)(TOPICS)
    b = compiled(TOPICS)
    assert a.equals(b, cols=["qid", "docno", "score", "rank"])
    # the original object is untouched (clone, not mutation)
    assert bm25.num_results == 1000


def test_cutoff_fusion_and_identity_elision():
    bm25 = INDEX.bm25(num_results=100)
    p = bm25 >> Identity() % 20 % 5        # -> bm25 % 20 % 5 w/ identity
    compiled = compile_pipeline(p)
    # identity dropped, cutoffs fused, then pushed into the retriever
    stages = stages_of(compiled)
    assert len(stages) == 1 and stages[0].num_results == 5
    a = p(TOPICS)
    b = compiled(TOPICS)
    assert a.equals(b, cols=["qid", "docno", "score", "rank"])


def test_no_pushdown_across_score_changing_stage():
    from repro.core import GenericTransformer, add_ranks
    bm25 = INDEX.bm25(num_results=50)
    boost = GenericTransformer(
        lambda r: add_ranks(r.assign(score=-r["score"])), "negate")
    p = bm25 >> boost % 5
    compiled = compile_pipeline(p)
    # cutoff must stay AFTER the score change
    assert len(stages_of(compiled)) == 3
    a = p(TOPICS)
    b = compiled(TOPICS)
    assert a.equals(b, cols=["qid", "docno", "score", "rank"])


def test_pushdown_larger_cutoff_noop():
    bm25 = INDEX.bm25(num_results=10)
    compiled = compile_pipeline(bm25 % 100)   # cutoff beyond num_results
    assert len(stages_of(compiled)) == 2      # kept as-is (no-op anyway)


def test_compile_composes_with_precompute():
    """compile each pipeline first, then share the (compiled) prefix."""
    from repro.core import longest_common_prefix
    bm25 = INDEX.bm25(num_results=100)
    pipes = [compile_pipeline(bm25 % 20 >> Identity()),
             compile_pipeline(bm25 % 20)]
    # both compile to the same single pushed-down retriever
    assert len(longest_common_prefix(pipes)) == 1
