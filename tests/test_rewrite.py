"""Plan-compiler optimizer (core/ir.py + core/rewrite.py + core/plan.py).

Covers the pass pipeline — algebraic normalization of commutative
operators, cross-pipeline CSE beyond prefixes, RankCutoff pushdown into
retriever depth, cache-aware pruning behind warm manifests — the
``optimize=`` knob, ``explain()`` and its ``repro plan explain``
round-trip, and the hard invariant: ``optimize="all"`` and
``optimize="none"`` produce bit-identical per-qid results under both
the sequential and the sharded executor (property-tested).
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ColFrame, ExecutionPlan, Experiment,
                        GenericTransformer, OPTIMIZER_PASSES, RankCutoff,
                        Transformer, add_ranks)

QUERIES = ColFrame({"qid": ["q1", "q2", "q3"],
                    "query": ["alpha", "beta", "gamma"]})

SORT = ["qid", "docno"]


class CutRetriever(Transformer):
    """Deterministic retriever with an absorbable depth knob: scores
    strictly decrease with the doc index, so the top-k is a prefix of
    the top-n for any n >= k (the contract ``with_cutoff`` needs)."""

    key_columns = ("qid", "query")
    one_to_many = True

    def __init__(self, name, n=6, base=100.0):
        self.name, self.n, self.base = name, int(n), float(base)

    def signature(self):
        return ("CutRetriever", self.name, self.n, self.base)

    def with_cutoff(self, k):
        return self if int(k) >= self.n \
            else CutRetriever(self.name, int(k), self.base)

    def transform(self, inp):
        rows = [{"qid": q, "query": t, "docno": f"{self.name}_d{i:02d}",
                 "score": self.base - i, "rank": i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(self.n)]
        return ColFrame.from_dicts(rows) if rows else inp.head(0)


class Counting(GenericTransformer):
    def __init__(self, name, fn=None, **kw):
        self.calls = 0

        def wrapped(inp, _fn=fn):
            self.calls += 1
            return _fn(inp) if _fn else inp
        super().__init__(wrapped, name, **kw)


def make_retriever(name, n=4, base=10.0):
    def fn(inp):
        rows = [{"qid": q, "query": t, "docno": f"{name}_d{i}",
                 "score": base - i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(n)]
        return add_ranks(ColFrame.from_dicts(rows))
    return Counting(name, fn, one_to_many=True, key_columns=("qid", "query"))


def docno_scorer(name, mult=1.0, rank_preserving=False):
    """Deterministic score from the docno (works on score-less frames,
    e.g. SetUnion output)."""
    def fn(inp, _m=mult):
        scores = np.array([float(ord(d[-1]) + len(d)) * _m
                           for d in inp["docno"].tolist()])
        return add_ranks(inp.assign(score=scores))
    return Counting(name, fn, rank_preserving=rank_preserving)


def boost(name="boost", factor=2.0):
    """Strictly monotone per-row score map — rank-preserving."""
    def fn(inp, _f=factor):
        return add_ranks(inp.assign(score=inp["score"] * _f))
    return Counting(name, fn, rank_preserving=True)


def assert_bit_identical(outs_a, outs_b):
    assert len(outs_a) == len(outs_b)
    for got, want in zip(outs_a, outs_b):
        cols = [c for c in ("qid", "docno", "score", "rank")
                if c in want.columns and c in got.columns]
        by = [c for c in SORT if c in want.columns]
        g = got.sort_values(by) if by else got
        w = want.sort_values(by) if by else want
        assert g.equals(w, cols=cols, rtol=0, atol=0), \
            "optimizer changed results"


def run_both(pipelines, queries=QUERIES, **run_kw):
    outs_opt, stats_opt = ExecutionPlan(pipelines, optimize="all").run(
        queries, **run_kw)
    outs_ref, stats_ref = ExecutionPlan(pipelines, optimize="none").run(
        queries, **run_kw)
    assert_bit_identical(outs_opt, outs_ref)
    assert stats_opt.nodes_executed <= stats_ref.nodes_executed
    return stats_opt, stats_ref


# ---------------------------------------------------------------------------
# normalization + CSE
# ---------------------------------------------------------------------------

def test_commutative_normalization_shares_nodes():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    stats_opt, stats_ref = run_both([a + b, b + a])
    # a, b and ONE combine node; unoptimized runs all six
    assert stats_opt.nodes_planned == 3
    assert stats_ref.nodes_planned == 6
    a.calls = b.calls = 0
    ExecutionPlan([a + b, b + a]).run(QUERIES)
    assert a.calls == 1 and b.calls == 1


def test_set_union_commutes_but_concat_does_not():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    assert ExecutionPlan([a | b, b | a]).n_nodes() == 3
    # ^ and & are order-sensitive: no merge
    assert ExecutionPlan([a ^ b, b ^ a]).n_nodes() == 4
    assert ExecutionPlan([a & b, b & a]).n_nodes() == 4
    run_both([a ^ b, b ^ a])
    run_both([a & b, b & a])


def test_cse_merges_non_prefix_subtrees():
    """The tentpole claim: an identical subtree *under* different
    operator contexts — not a stage-list prefix — executes once."""
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    rr = docno_scorer("rr")
    pipelines = [(a | b) >> rr, (b | a) >> rr >> boost("post"),
                 ((a | b) >> rr) % 3]
    stats_opt, _ = run_both(pipelines)
    a.calls = b.calls = rr.calls = 0
    ExecutionPlan(pipelines).run(QUERIES)
    assert a.calls == 1 and b.calls == 1
    assert rr.calls == 1                 # shared through |, >> and %


def test_experiment_shares_non_prefix_subtree():
    """Acceptance criterion: an Experiment over >=3 pipelines sharing a
    non-prefix subtree (the same reranker over two differently-ordered
    unioned retrievers) executes that subtree once."""
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    rr = docno_scorer("rr")
    systems = [(a | b) >> rr, (b | a) >> rr >> boost("post"),
               ((a | b) >> rr) % 3]
    qrels = ColFrame({"qid": ["q1", "q2", "q3"],
                      "docno": ["A_d0", "B_d1", "A_d2"],
                      "label": [1, 1, 1]})
    base = Experiment(systems, QUERIES, qrels, ["nDCG@10", "MAP"])
    a.calls = b.calls = rr.calls = 0
    planned = Experiment(systems, QUERIES, qrels, ["nDCG@10", "MAP"],
                         precompute_prefix=True, precompute_mode="plan")
    assert rr.calls == 1
    assert a.calls == 1 and b.calls == 1
    for n1, n2 in zip(base.names, planned.names):
        for m in ("nDCG@10", "MAP"):
            assert base.means[n1][m] == pytest.approx(planned.means[n2][m])


# ---------------------------------------------------------------------------
# RankCutoff pushdown
# ---------------------------------------------------------------------------

def _retriever_nodes(plan, cls=CutRetriever):
    return [n for n in plan.graph.nodes
            if n.kind == "stage" and isinstance(n.stage, cls)]


def test_pushdown_absorbs_cutoff_into_retriever():
    r = CutRetriever("R", n=8)
    plan = ExecutionPlan([r % 3 >> boost()])
    nodes = _retriever_nodes(plan)
    assert len(nodes) == 1
    assert nodes[0].stage.n == 3         # retriever-level depth assertion
    assert not any(isinstance(n.stage, RankCutoff) for n in plan.graph.nodes)
    stats_opt, _ = run_both([CutRetriever("R", n=8) % 3 >> boost("b2")])
    assert stats_opt.cutoffs_pushed == 1
    assert stats_opt.nodes_eliminated >= 1


def test_pushdown_through_rank_preserving_chain():
    r = CutRetriever("R", n=8)
    plan = ExecutionPlan([r >> boost("b1") >> boost("b2") % 4])
    nodes = _retriever_nodes(plan)
    assert nodes[0].stage.n == 4         # climbed through both boosts
    run_both([CutRetriever("R", n=8) >> boost("c1") >> boost("c2") % 4])


def test_pushdown_moves_cutoff_below_chain_without_absorber():
    """No absorber below the chain (the retriever lacks with_cutoff):
    the cutoff still moves below rank-preserving stages so they only
    process k rows."""
    a = make_retriever("A", n=8)         # GenericTransformer: no with_cutoff
    plan = ExecutionPlan([a >> boost("b") % 3])
    cut_nodes = [n for n in plan.graph.nodes
                 if isinstance(n.stage, RankCutoff)]
    assert len(cut_nodes) == 1
    # the cutoff's input is now the retriever, not the boost
    assert cut_nodes[0].inputs[0].stage is a
    assert sum(p.cutoffs_pushed for p in plan.pass_stats
               if p.name == "pushdown") == 1
    run_both([make_retriever("A2", n=8) >> boost("b2") % 3])


def test_pushdown_declined_on_shared_retriever():
    r = CutRetriever("R", n=8)
    plan = ExecutionPlan([r % 3, r])     # r itself is a terminal
    nodes = _retriever_nodes(plan)
    assert len(nodes) == 1 and nodes[0].stage.n == 8
    stats_opt, _ = run_both([CutRetriever("R", n=8) % 3,
                             CutRetriever("R", n=8)])
    assert stats_opt.cutoffs_pushed == 0


def test_stacked_cutoffs_fuse_to_min():
    r = CutRetriever("R", n=9)
    plan = ExecutionPlan([r % 5 % 3])
    nodes = _retriever_nodes(plan)
    assert nodes[0].stage.n == 3
    assert not any(isinstance(n.stage, RankCutoff) for n in plan.graph.nodes)
    run_both([CutRetriever("R", n=9) % 5 % 3])


def test_pushdown_bm25_num_results():
    """Retriever-level num_results assertion on the real BM25 stage."""
    from repro.ir import InvertedIndex, BM25Retriever
    docs = [{"docno": f"d{i}", "text": f"term{i % 7} shared tok{i}"}
            for i in range(40)]
    index = InvertedIndex.build(iter(docs))
    topics = ColFrame({"qid": ["q1", "q2"],
                       "query": ["shared term1", "shared term2"]})
    bm25 = index.bm25(num_results=25)
    pipes = [bm25 % 5 >> boost("bb")]
    plan = ExecutionPlan(pipes)
    nodes = _retriever_nodes(plan, BM25Retriever)
    assert len(nodes) == 1 and nodes[0].stage.num_results == 5
    outs_opt, _ = plan.run(topics)
    outs_ref, _ = ExecutionPlan(
        [index.bm25(num_results=25) % 5 >> boost("bb2")],
        optimize="none").run(topics)
    assert_bit_identical(outs_opt, outs_ref)


# ---------------------------------------------------------------------------
# cache-aware pruning
# ---------------------------------------------------------------------------

def _annotator(calls):
    def fn(inp):
        calls["ann"] += 1
        return inp.assign(prio=np.ones(len(inp)))
    return GenericTransformer(fn, "annotate", augment_only=True)


def _cached_retr_pipes(calls):
    def retr_fn(inp):
        rows = [{"qid": q, "query": t, "docno": f"d{i}", "score": 9.0 - i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(3)]
        return add_ranks(ColFrame.from_dicts(rows))
    retr = GenericTransformer(retr_fn, "R", one_to_many=True,
                              key_columns=("qid", "query"))
    return [_annotator(calls) >> retr % 2]


def test_cache_prune_skips_warm_upstream_chain(tmp_path):
    calls = {"ann": 0}
    pipes = _cached_retr_pipes(calls)
    with ExecutionPlan(pipes, cache_dir=str(tmp_path)) as cold:
        outs1, s1 = cold.run(QUERIES)
        assert s1.nodes_pruned == 0 and calls["ann"] == 1
    # a fresh plan consults the now-warm manifest and defers the chain
    with ExecutionPlan(pipes, cache_dir=str(tmp_path)) as warm:
        prune = next(p for p in warm.pass_stats if p.name == "cache-prune")
        assert prune.nodes_marked_prunable == 1
        outs2, s2 = warm.run(QUERIES)
        assert s2.nodes_pruned == 1
        assert calls["ann"] == 1         # annotate never ran warm
        assert s2.cache_hits == len(QUERIES)
        assert_bit_identical(outs2, outs1)
        # sharded execution prunes too
        outs3, s3 = warm.run(QUERIES, n_shards=2, max_workers=2)
        assert s3.nodes_pruned == 1 and calls["ann"] == 1
        assert_bit_identical(outs3, outs1)
        # unseen queries miss the probe: the chain runs, results correct
        fresh = ColFrame({"qid": ["q9"], "query": ["omega"]})
        outs4, s4 = warm.run(fresh)
        assert calls["ann"] == 2 and s4.nodes_pruned == 0
    naive = _cached_retr_pipes({"ann": 0})[0](fresh)
    assert_bit_identical(outs4, [naive])


def test_cache_prune_requires_augment_only(tmp_path):
    """A query-REWRITING upstream stage must never be deferred — its
    output changes the cache keys."""
    calls = {"rw": 0}

    def rw_fn(inp):
        calls["rw"] += 1
        return inp.assign(query=np.array(
            [q + "!" for q in inp["query"].tolist()], dtype=object))
    rewrite = GenericTransformer(rw_fn, "rewrite")   # not augment_only

    def retr_fn(inp):
        rows = [{"qid": q, "query": t, "docno": f"d{len(t)}", "score": 1.0}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())]
        return add_ranks(ColFrame.from_dicts(rows))
    retr = GenericTransformer(retr_fn, "R2", one_to_many=True,
                              key_columns=("qid", "query"))
    pipes = [rewrite >> retr]
    with ExecutionPlan(pipes, cache_dir=str(tmp_path)) as cold:
        cold.run(QUERIES)
    with ExecutionPlan(pipes, cache_dir=str(tmp_path)) as warm:
        marked = sum(p.nodes_marked_prunable for p in warm.pass_stats)
        assert marked == 0
        _, s = warm.run(QUERIES)
        assert s.nodes_pruned == 0 and calls["rw"] == 2


# ---------------------------------------------------------------------------
# the optimize= knob
# ---------------------------------------------------------------------------

def test_optimize_none_is_the_naive_forest():
    A = make_retriever("A")
    B = Counting("B", lambda inp: add_ranks(
        inp.assign(score=inp["score"] * 2.0)))
    pipelines = [A, A >> B]
    plan = ExecutionPlan(pipelines, optimize="none")
    assert plan.n_nodes() == 3           # A, A, B — no sharing at all
    A.calls = B.calls = 0
    _, stats = plan.run(QUERIES)
    assert stats.nodes_executed == 3 and A.calls == 2
    assert stats.optimizer_passes == [] and stats.pass_times_s == {}
    assert ExecutionPlan(pipelines).n_nodes() == 2


def test_optimize_accepts_pass_subset():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    # cse without normalize: structural twins merge, commuted ones don't
    plan = ExecutionPlan([a + b, b + a], optimize=["cse"])
    assert plan.n_nodes() == 4           # a, b, a+b, b+a
    assert [p.name for p in plan.pass_stats] == ["cse"]
    outs, _ = plan.run(QUERIES)
    ref, _ = ExecutionPlan([a + b, b + a], optimize="none").run(QUERIES)
    assert_bit_identical(outs, ref)


def test_optimize_rejects_unknown_passes():
    a = make_retriever("A")
    with pytest.raises(ValueError, match="optimize must be"):
        ExecutionPlan([a], optimize="fastest")
    with pytest.raises(ValueError, match="unknown optimizer pass"):
        ExecutionPlan([a], optimize=["cse", "bogus"])
    assert set(OPTIMIZER_PASSES) == {"normalize", "cse", "pushdown",
                                     "operand-order", "cache-place",
                                     "cache-prune", "autotune"}


def test_plan_stats_carry_optimizer_accounting():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    _, stats = ExecutionPlan([a + b, b + a, a % 3]).run(QUERIES)
    assert stats.optimizer_passes == ["normalize", "cse", "pushdown",
                                      "operand-order"]
    assert set(stats.pass_times_s) == {"normalize", "cse", "pushdown",
                                       "operand-order"}
    assert all(t >= 0 for t in stats.pass_times_s.values())
    assert stats.nodes_eliminated > 0
    assert "eliminated=" in str(stats)


# ---------------------------------------------------------------------------
# explain() and the CLI round-trip
# ---------------------------------------------------------------------------

def test_explain_lists_every_node_and_pass():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    plan = ExecutionPlan([a + b, b + a])
    text = plan.explain()
    assert "passes=['normalize', 'cse', 'pushdown', 'operand-order']" in text
    assert "shared, see above" in text   # the merged combine
    for node in plan.graph.nodes:
        if node.kind != "source":
            assert f"#{node.id} " in text
    fps = plan.node_fingerprints()
    assert any(fps[n.id][:12] in text for n in plan.graph.nodes
               if n.kind != "source")


def test_explain_roundtrips_through_cli(tmp_path, capsys):
    from repro.cli import main
    a = make_retriever("A")
    pipes = [a % 3, a % 2]
    with ExecutionPlan(pipes, cache_dir=str(tmp_path)) as plan:
        plan.run(QUERIES)
        expected = plan.explain()
        plan_id = plan.to_record()["plan_id"]
    assert main(["plan", "explain", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert out.strip() == expected.strip()
    # id-prefix selection
    assert main(["plan", "explain", str(tmp_path),
                 "--plan", plan_id[:8]]) == 0
    assert capsys.readouterr().out.strip() == expected.strip()
    # --json is parseable and carries the same structure
    assert main(["plan", "explain", str(tmp_path), "--json"]) == 0
    docs = json.loads(capsys.readouterr().out)
    assert docs[0]["plan_id"] == plan_id
    assert {n["label"] for n in docs[0]["nodes"]} == \
        {n.label for n in plan.graph.nodes if n.kind != "source"}
    # cache dirs recorded in the manifest resolve via repro cache ls
    assert main(["cache", "ls", str(tmp_path), "--json"]) == 0
    ls = json.loads(capsys.readouterr().out)
    assert ls["plans"][0]["plan_id"] == plan_id


def test_explain_cli_errors_without_plans(tmp_path, capsys):
    from repro.cli import main
    assert main(["plan", "explain", str(tmp_path)]) == 1
    assert "no recorded plan manifests" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# the hard invariant, property-tested (hypothesis or the fallback shim)
# ---------------------------------------------------------------------------

def _build_pipes(seqs, ops, cutoffs):
    retr = {c: CutRetriever(c, n=5 + ord(c) % 3, base=40.0 + ord(c))
            for c in "ABCD"}
    rerank = {c: GenericTransformer(
        lambda inp, _c=c: add_ranks(
            inp.assign(score=inp["score"] * (1.0 + ord(_c) / 100.0))),
        f"re{c}", rank_preserving=True) for c in "ABCD"}
    pipes = []
    rtyped = []                          # score-bearing: valid under +/**/^/%
    for seq in seqs:
        p = retr[seq[0]]
        for c in seq[1:]:
            p = p >> rerank[c]
        pipes.append(p)
        rtyped.append(p)
    for i, op in enumerate(ops):
        left = rtyped[i % len(rtyped)]
        right = rtyped[(i + 1) % len(rtyped)]
        if op == "+":
            pipes.append(left + right)
            pipes.append(right + left)   # commuted twin for normalize+cse
            rtyped.extend(pipes[-2:])
        elif op == "|":                  # drops scores: terminal-only
            pipes.append(left | right)
            pipes.append(right | left)
        elif op == "**":
            pipes.append(left ** right)
            rtyped.append(pipes[-1])
        elif op == "^":
            pipes.append(left ^ right)
            rtyped.append(pipes[-1])
    for i, k in enumerate(cutoffs):
        pipes.append(rtyped[i % len(rtyped)] % k)
    return pipes


@given(st.lists(st.lists(st.sampled_from("ABCD"), min_size=1, max_size=3),
                min_size=1, max_size=4),
       st.lists(st.sampled_from(["+", "|", "**", "^"]),
                min_size=0, max_size=2),
       st.lists(st.integers(min_value=1, max_value=7),
                min_size=0, max_size=2))
@settings(max_examples=15, deadline=None)
def test_property_optimized_bit_identical_sequential(seqs, ops, cutoffs):
    """Random pipeline algebras: optimize='all' == optimize='none',
    bit-for-bit per qid, under the sequential executor."""
    run_both(_build_pipes(seqs, ops, cutoffs))


@given(st.lists(st.lists(st.sampled_from("ABCD"), min_size=1, max_size=3),
                min_size=1, max_size=3),
       st.lists(st.sampled_from(["+", "|", "**", "^"]),
                min_size=0, max_size=2),
       st.lists(st.integers(min_value=1, max_value=7),
                min_size=0, max_size=2),
       st.integers(min_value=2, max_value=3))
@settings(max_examples=10, deadline=None)
def test_property_optimized_bit_identical_sharded(seqs, ops, cutoffs,
                                                  n_shards):
    """Same invariant under the sharded wavefront executor."""
    run_both(_build_pipes(seqs, ops, cutoffs),
             n_shards=n_shards, max_workers=3)


def test_metadata_flags_lift_onto_ir_nodes():
    r = CutRetriever("R", n=4)
    chain = r >> GenericTransformer(lambda inp: inp, "aug",
                                    augment_only=True) \
        >> GenericTransformer(
            lambda inp: add_ranks(inp.assign(score=inp["score"])),
            "rp", rank_preserving=True)
    plan = ExecutionPlan([chain], optimize="none")
    aug = next(n for n in plan.graph.nodes
               if n.kind == "stage" and "aug" in n.label)
    rp = next(n for n in plan.graph.nodes
              if n.kind == "stage" and "rp" in n.label)
    assert aug.augment_only and not aug.rank_preserving
    assert rp.rank_preserving and not rp.augment_only
    retr_node = next(n for n in plan.graph.nodes
                     if isinstance(n.stage, CutRetriever))
    assert retr_node.relation == "R" and retr_node.shardable


def test_cache_prune_never_defers_key_column_producers(tmp_path):
    """Regression: an augment-only stage that *produces* one of the
    downstream cache's key columns (a query attacher) must not be
    deferred — the probe frame would lack the key — and even when it
    is undeclared, ``serve_from_store`` must treat the missing column
    as a miss instead of crashing."""
    calls = {"att": 0}

    def att_fn(inp):
        calls["att"] += 1
        return inp.assign(query=np.array(
            ["terms " + q for q in inp["qid"].tolist()], dtype=object))
    attach = GenericTransformer(att_fn, "attach", augment_only=True,
                                value_columns=("query",))

    def retr_fn(inp):
        rows = [{"qid": q, "query": t, "docno": f"d{i}", "score": 5.0 - i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(2)]
        return add_ranks(ColFrame.from_dicts(rows))
    retr = GenericTransformer(retr_fn, "R3", one_to_many=True,
                              key_columns=("qid", "query"))
    topics = ColFrame({"qid": ["q1", "q2"]})   # no query column yet
    pipes = [attach >> retr]
    with ExecutionPlan(pipes, cache_dir=str(tmp_path)) as cold:
        outs1, _ = cold.run(topics)
    with ExecutionPlan(pipes, cache_dir=str(tmp_path)) as warm:
        assert sum(p.nodes_marked_prunable for p in warm.pass_stats) == 0
        outs2, s2 = warm.run(topics)       # must not raise
        assert s2.nodes_pruned == 0 and s2.cache_hits == len(topics)
        assert calls["att"] == 2
        assert_bit_identical(outs2, outs1)
    # the dynamic guard alone: probing with a key-less frame is a miss
    from repro.caching import RetrieverCache
    cache = RetrieverCache(None, retr)
    try:
        assert cache.serve_from_store(topics) is None
    finally:
        cache.close()


def test_cse_reruns_after_pushdown_merges_fused_twins():
    """Regression: `r(n=8) % 3` fused by pushdown becomes structurally
    identical to a literal `r(n=3)` — a post-pushdown CSE round must
    merge them so the shared subtree still executes once."""
    pipes = [CutRetriever("R", n=8) % 3 >> boost("pb"),
             CutRetriever("R", n=3) >> boost("pb")]
    plan = ExecutionPlan(pipes)
    assert plan.n_nodes() == 2           # one fused retriever + one boost
    _, stats = plan.run(QUERIES)
    assert stats.nodes_executed == 2
    assert stats.optimizer_passes == ["normalize", "cse", "pushdown",
                                      "normalize", "cse", "operand-order"]
    assert set(stats.pass_times_s) == {"normalize", "cse", "pushdown",
                                       "operand-order"}
    run_both([CutRetriever("R", n=8) % 3 >> boost("pb2"),
              CutRetriever("R", n=3) >> boost("pb2")])
