import numpy as np
import pytest

from repro.core import (ColFrame, Compose, GenericTransformer, Identity,
                        RankCutoff, add_ranks, longest_common_prefix,
                        pipeline_hash, stages_of)


def make_retriever(name, n=10, base=100.0):
    def fn(q):
        rows = []
        for qid in q["qid"].tolist():
            for i in range(n):
                rows.append({"qid": qid, "docno": f"{name}_d{i}",
                             "score": base - i})
        return add_ranks(ColFrame.from_dicts(rows))
    return GenericTransformer(fn, name, one_to_many=True, params=(n,))


QUERIES = ColFrame({"qid": ["q1", "q2"], "query": ["a b", "c d"]})


def test_compose_flattens_and_equality():
    A, B = make_retriever("A"), make_retriever("B")
    p1 = A >> B >> Identity()
    assert len(stages_of(p1)) == 3
    p2 = A >> (B >> Identity())
    assert p1 == p2
    assert pipeline_hash(p1) == pipeline_hash(p2)
    assert (A % 5).signature() == (A % 5).signature()
    assert (A % 5) != (A % 6)


def test_rank_cutoff():
    A = make_retriever("A", n=10)
    res = (A % 3)(QUERIES)
    assert len(res) == 6
    assert res["rank"].max() == 2


def test_linear_combine_and_scalar_product():
    A, B = make_retriever("A", 5), make_retriever("A", 5, base=10.0)
    combined = (A + B)(QUERIES)
    # same docnos -> scores sum
    a, b = A(QUERIES), B(QUERIES)
    expect = a["score"][0] + b["score"][0]
    top = combined.sort_values(["qid", "rank"])
    assert top["score"][0] == expect
    scaled = (A * 2.0)(QUERIES)
    assert scaled["score"].max() == a["score"].max() * 2.0


def test_set_union_intersection():
    A, B = make_retriever("A", 5), make_retriever("B", 5)
    uni = (A | B)(QUERIES)
    assert len(uni) == 20       # disjoint docnos, 10 per query
    inter = (A & B)(QUERIES)
    assert len(inter) == 0
    same = (A & A)(QUERIES)
    assert len(same) == 10


def test_concatenate_puts_right_below_left():
    A, B = make_retriever("A", 3), make_retriever("B", 3)
    both = (A ^ B)(QUERIES)
    ranked = both.sort_values(["qid", "rank"])
    per_q = ranked.group_indices(["qid"])
    for _, idx in per_q.items():
        docs = [str(d) for d in ranked["docno"][idx]]
        assert all(d.startswith("A") for d in docs[:3])
        assert all(d.startswith("B") for d in docs[3:])


def test_feature_union():
    A, B = make_retriever("A", 4), make_retriever("A", 4, base=50.0)
    feats = (A ** B)(QUERIES)
    assert "features" in feats.columns
    assert len(feats["features"][0]) == 2


def test_add_ranks_stable_and_descending():
    f = ColFrame({"qid": ["q"] * 4, "docno": list("abcd"),
                  "score": [2.0, 3.0, 1.0, 3.0]})
    r = add_ranks(f)
    ranked = r.sort_values(["rank"])
    assert ranked["score"].tolist() == [3.0, 3.0, 2.0, 1.0]
    # tie broken by docno for determinism
    assert ranked["docno"].tolist()[:2] == ["b", "d"]


def test_input_type_checking():
    cut = RankCutoff(5)
    with pytest.raises(TypeError):
        cut(ColFrame({"qid": ["q"], "query": ["text"]}))
