"""Per-architecture smoke tests: a REDUCED config of the same family
runs one forward/train step on CPU with finite outputs + right shapes.
The FULL configs are exercised only via the dry-run (abstract lowering).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, all_cells


def test_registry_has_all_ten_archs_and_40_cells():
    assert len(ARCHS) == 10
    cells = all_cells()
    assert len(cells) == 40
    fams = {a.family for a in ARCHS.values()}
    assert fams == {"lm", "gnn", "recsys"}


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
def test_arch_smoke(arch_name):
    arch = ARCHS[arch_name]
    small, run = arch.smoke()
    out = run()
    for k, v in out.items():
        arr = jnp.asarray(v)
        assert not bool(jnp.isnan(arr).any()), f"{arch_name}/{k} has NaN"
        assert not bool(jnp.isinf(arr).any()), f"{arch_name}/{k} has Inf"
    if arch.family == "lm":
        assert out["logits"].ndim == 3
        assert out["logits"].shape[-1] == small.padded_vocab
        assert float(out["loss"]) > 0
    elif arch.family == "gnn":
        assert out["logits"].shape[-1] == small.n_classes
    else:
        assert float(out["loss"]) > 0


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
def test_cells_constructible(arch_name):
    """Every (arch × shape) builds a Cell with consistent abstract args
    (no lowering here — that's the dry-run's job)."""
    arch = ARCHS[arch_name]
    for shape in arch.shape_names():
        cell = arch.cell(shape)
        assert len(cell.abstract_args) == len(cell.arg_spec_trees)
        leaves = jax.tree.leaves(cell.abstract_args)
        assert all(hasattr(l, "shape") for l in leaves)


def test_exact_published_configs():
    g = ARCHS["granite-moe-3b-a800m"].config
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab_size, g.n_experts, g.top_k) == \
        (32, 1536, 24, 8, 512, 49155, 40, 8)
    p = ARCHS["phi3.5-moe-42b-a6.6b"].config
    assert (p.n_layers, p.d_model, p.n_experts, p.top_k) == (32, 4096, 16, 2)
    q3 = ARCHS["qwen3-14b"].config
    assert q3.qk_norm and q3.head_dim == 128 and q3.vocab_size == 151936
    s = ARCHS["smollm-360m"].config
    assert (s.n_heads, s.n_kv_heads, s.d_ff) == (15, 5, 2560)
    q1 = ARCHS["qwen1.5-110b"].config
    assert q1.qkv_bias and q1.n_layers == 80 and q1.d_ff == 49152
    gc = ARCHS["gcn-cora"].config
    assert gc.n_layers == 2 and gc.d_hidden == 16
    d = ARCHS["dlrm-rm2"].config
    assert d.embed_dim == 64 and len(d.vocab_sizes) == 26
    assert d.bot_mlp == (512, 256, 64) and d.top_mlp == (512, 512, 256, 1)
    dc = ARCHS["dcn-v2"].config
    assert dc.embed_dim == 16 and dc.n_cross_layers == 3
    m = ARCHS["mind"].config
    assert m.n_interests == 4 and m.capsule_iters == 3
    tt = ARCHS["two-tower-retrieval"].config
    assert tt.embed_dim == 256 and tt.tower_mlp == (1024, 512, 256)
