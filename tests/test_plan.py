"""ExecutionPlan: one shared DAG for a pipeline set (core/plan.py).

Covers the cache-transparency invariant (plan execution == naive
per-pipeline execution) across every operator of the §2.1 algebra,
sharing through binary operator nodes (the §6 limitation the stage-list
trie cannot resolve), planner-inserted memoization with hit accounting,
and the §6 ablation regression (A; A»B; A»B»C executes B exactly once).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ColFrame, ExecutionPlan, GenericTransformer,
                        add_ranks, plan_size, run_with_trie)


class CountingStage(GenericTransformer):
    def __init__(self, name, fn=None, **kw):
        self.calls = 0

        def wrapped(inp, _fn=fn):
            self.calls += 1
            return _fn(inp) if _fn else inp
        super().__init__(wrapped, name, **kw)


def make_retriever(name, n=6, base=10.0):
    def fn(inp):
        rows = []
        for qid in inp["qid"].tolist():
            for i in range(n):
                rows.append({"qid": qid, "docno": f"{name}_d{i}",
                             "score": base - i})
        return add_ranks(ColFrame.from_dicts(rows))
    return CountingStage(name, fn)


def boost_fn(inp):
    return add_ranks(inp.assign(score=inp["score"] * 2.0))


def shift_fn(inp):
    return add_ranks(inp.assign(score=inp["score"] + 1.0))


QUERIES = ColFrame({"qid": ["q1", "q2", "q3"],
                    "query": ["alpha", "beta", "gamma"]})

SORT = ["qid", "docno"]


def assert_equivalent(pipelines, queries=QUERIES, run_kw=None, **plan_kw):
    naive = [p(queries) for p in pipelines]
    with ExecutionPlan(pipelines, **plan_kw) as plan:
        outs, stats = plan.run(queries, **(run_kw or {}))
    assert len(outs) == len(naive)
    for got, want in zip(outs, naive):
        g = got.sort_values(SORT)
        w = want.sort_values(SORT)
        cols = [c for c in ("qid", "docno", "score", "rank")
                if c in want.columns]
        assert g.equals(w, cols=cols, rtol=0, atol=0), \
            f"plan diverged from naive for {pipelines}"
    return stats


def test_plan_equivalence_all_operator_types():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    boost = CountingStage("boost", boost_fn)
    shift = CountingStage("shift", shift_fn)
    pipelines = [
        a,                              # bare stage
        a >> boost,                     # compose
        a % 3,                          # rank cutoff
        a + b,                          # linear combine
        a ** b,                         # feature union
        a | b,                          # set union
        a & a,                          # set intersection
        a ^ b,                          # concatenate
        a * 0.5,                        # scalar product
        (a + b) % 4 >> shift,           # nested mix
        ((a * 2.0) + (b >> boost)) % 5,
    ]
    stats = assert_equivalent(pipelines)
    assert stats.nodes_executed == stats.nodes_planned
    assert stats.nodes_total == sum(plan_size(p) for p in pipelines)
    assert stats.stage_invocations_saved > 0


def test_shared_retriever_under_binary_operators_runs_once():
    """The tentpole claim: a retriever shared under ``a + b`` and
    ``a ** c`` executes once — stages_of-based sharing cannot see it."""
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    c = make_retriever("C", base=6.0)
    pipelines = [a + b, a ** c, a % 3, a]
    assert_equivalent(pipelines)   # re-runs naive first
    a.calls = b.calls = c.calls = 0
    outs, stats = ExecutionPlan(pipelines).run(QUERIES)
    assert a.calls == 1
    assert b.calls == 1
    assert c.calls == 1
    # nodes: A, B, C, A+B, A**C, A%3  — naive would run 3+3+2+1=9
    assert stats.nodes_planned == 6
    assert stats.nodes_executed == 6
    assert stats.nodes_total == 9
    assert stats.stage_invocations_saved == 3


def test_section6_ablation_executes_B_once():
    """Regression for the paper-§6 case ``A; A»B; A»B»C``."""
    A = make_retriever("A")
    B = CountingStage("B", boost_fn)
    C = CountingStage("C", shift_fn)
    pipelines = [A, A >> B, A >> B >> C]
    assert_equivalent(pipelines)
    A.calls = B.calls = C.calls = 0
    _, stats = ExecutionPlan(pipelines).run(QUERIES)
    assert A.calls == 1
    assert B.calls == 1          # LCP-only precomputation runs B twice
    assert C.calls == 1
    assert stats.nodes_executed == 3
    assert stats.nodes_total == 6
    # the thin wrapper reports identical accounting
    _, trie_stats = run_with_trie(pipelines, QUERIES)
    assert trie_stats.nodes_executed == 3
    assert trie_stats.nodes_total == 6


def test_same_stage_under_different_prefixes_not_merged():
    """Correctness guard: node identity is (prefix, stage), not stage."""
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    boost = CountingStage("boost", boost_fn)
    pipelines = [a >> boost, b >> boost]
    stats = assert_equivalent(pipelines)
    assert stats.nodes_planned == 4      # a, b, and TWO boost nodes


def test_planner_inserted_cache_hits_on_second_run(tmp_path):
    def retr_fn(inp):
        rows = []
        for qid, query in zip(inp["qid"].tolist(), inp["query"].tolist()):
            for i in range(4):
                rows.append({"qid": qid, "query": query,
                             "docno": f"d{i}", "score": 9.0 - i})
        return add_ranks(ColFrame.from_dicts(rows))
    retr = CountingStage("R", retr_fn,
                         one_to_many=True, key_columns=("qid", "query"))
    boost = CountingStage("boost", boost_fn)   # no metadata -> uncached
    pipelines = [retr % 3, retr >> boost]
    naive = [p(QUERIES) for p in pipelines]
    retr.calls = 0

    with ExecutionPlan(pipelines, cache_dir=str(tmp_path)) as plan:
        cached = [n for n in plan.nodes.values() if n.cache is not None]
        assert len(cached) == 1          # only the retriever is cacheable
        outs1, stats1 = plan.run(QUERIES)
        assert stats1.cache_hits == 0
        assert stats1.cache_misses == len(QUERIES)
        outs2, stats2 = plan.run(QUERIES)
        assert stats2.cache_hits == len(QUERIES)
        assert stats2.cache_misses == 0
    assert retr.calls == 1               # second run served from cache
    for got, want in zip(outs2, naive):
        assert got.sort_values(SORT).equals(
            want.sort_values(SORT), cols=["qid", "docno", "score", "rank"])

    # a fresh plan against the same cache_dir is hot from the start
    with ExecutionPlan(pipelines, cache_dir=str(tmp_path)) as plan2:
        _, stats3 = plan2.run(QUERIES)
        assert stats3.cache_hits == len(QUERIES)
    assert retr.calls == 1


def test_cache_paths_stable_across_processes(tmp_path):
    """Node cache directories must not depend on the per-process hash
    salt — a fresh interpreter pointed at the same cache_dir must hit."""
    import os
    import subprocess
    import sys
    script = (
        "import sys\n"
        "from repro.core import ColFrame, ExecutionPlan, "
        "GenericTransformer, add_ranks\n"
        "def retr(inp):\n"
        "    rows = [{'qid': q, 'query': t, 'docno': f'd{i}', "
        "'score': 5.0 - i}\n"
        "            for q, t in zip(inp['qid'].tolist(), "
        "inp['query'].tolist()) for i in range(3)]\n"
        "    return add_ranks(ColFrame.from_dicts(rows))\n"
        "a = GenericTransformer(retr, 'A', one_to_many=True, "
        "key_columns=('qid', 'query'))\n"
        "Q = ColFrame({'qid': ['q1'], 'query': ['x']})\n"
        "with ExecutionPlan([a % 2], cache_dir=sys.argv[1]) as plan:\n"
        "    _, stats = plan.run(Q)\n"
        "    print(stats.cache_hits, stats.cache_misses)\n")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(root, "src")}
    outs = []
    for _ in range(2):
        p = subprocess.run([sys.executable, "-c", script, str(tmp_path)],
                           capture_output=True, text=True, env=env,
                           timeout=120)
        assert p.returncode == 0, p.stderr[-1000:]
        outs.append(p.stdout.split())
    assert outs[0] == ["0", "1"]         # cold
    assert outs[1] == ["1", "0"]         # second process hits


def test_pluggable_memo_factory():
    seen = []

    def factory(stage, path):
        seen.append(repr(stage))
        return None

    a = make_retriever("A")
    ExecutionPlan([a % 3], memo_factory=factory)
    assert len(seen) == 2                # a and the RankCutoff node


def test_plan_stats_carry_node_times():
    a = make_retriever("A")
    _, stats = ExecutionPlan([a % 3, a % 5]).run(QUERIES)
    assert set(stats.node_times_s) == {repr(a), repr(
        (a % 3).stages[1]), repr((a % 5).stages[1])}
    assert all(t >= 0 for t in stats.node_times_s.values())
    assert stats.wall_time_s > 0


def test_plan_batching_matches_unbatched():
    a = make_retriever("A", n=4)
    boost = CountingStage("boost", boost_fn)
    pipelines = [a >> boost, a % 2]
    big = ColFrame({"qid": [f"q{i}" for i in range(9)],
                    "query": [f"t{i}" for i in range(9)]})
    full, _ = ExecutionPlan(pipelines).run(big)
    batched, _ = ExecutionPlan(pipelines).run(big, batch_size=2)
    for f, b in zip(full, batched):
        assert f.sort_values(SORT).equals(b.sort_values(SORT),
                                          cols=["qid", "docno", "score"])


def test_experiment_plan_mode(tmp_path):
    from repro.core import Experiment, PlanStats
    qrels = ColFrame({"qid": ["q1", "q2", "q3"],
                      "docno": ["A_d0", "A_d1", "B_d0"],
                      "label": [1, 1, 1]})
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    systems = [a % 3, a + b, a ** b]
    naive = Experiment(systems, QUERIES, qrels, ["nDCG@10", "MAP"])
    planned = Experiment(systems, QUERIES, qrels, ["nDCG@10", "MAP"],
                         precompute_prefix=True, precompute_mode="plan",
                         cache_dir=str(tmp_path))
    for n1, n2 in zip(naive.names, planned.names):
        for m in ("nDCG@10", "MAP"):
            assert naive.means[n1][m] == pytest.approx(planned.means[n2][m])
    assert isinstance(planned.precompute, PlanStats)
    assert planned.precompute.nodes_executed < planned.precompute.nodes_total


def _random_pipes(seqs, ops):
    retrievers = {c: make_retriever(c, base=ord(c) * 1.0) for c in "ABCD"}
    rerank = {c: GenericTransformer(
        lambda inp, _c=c: add_ranks(
            inp.assign(score=inp["score"] + ord(_c))), f"re{c}")
        for c in "ABCD"}
    pipes = []
    for seq in seqs:
        p = retrievers[seq[0]]
        for c in seq[1:]:
            p = p >> rerank[c]
        pipes.append(p)
    for i, op in enumerate(ops):
        l, r = pipes[i % len(pipes)], pipes[(i + 1) % len(pipes)]
        if op == "+":
            pipes.append(l + r)
        elif op == "**":
            pipes.append(l ** r)
        elif op == "^":
            pipes.append(l ^ r)
        else:
            pipes.append(l % 3)
    return pipes


@given(st.lists(st.lists(st.sampled_from("ABCD"), min_size=1, max_size=4),
                min_size=2, max_size=5),
       st.lists(st.sampled_from(["+", "**", "^", ">>"]),
                min_size=0, max_size=3))
@settings(max_examples=25, deadline=None)
def test_property_plan_equals_naive(seqs, ops):
    """Random pipeline sets: chains of rerankers over shared retrievers,
    optionally merged pairwise by binary operators."""
    assert_equivalent(_random_pipes(seqs, ops))


# ---------------------------------------------------------------------------
# concurrent sharded executor
# ---------------------------------------------------------------------------

def test_sharded_run_matches_sequential_all_operator_types():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    boost = CountingStage("boost", boost_fn)
    shift = CountingStage("shift", shift_fn)
    pipelines = [
        a, a >> boost, a % 3, a + b, a ** b, a | b, a & a, a ^ b,
        a * 0.5, (a + b) % 4 >> shift, ((a * 2.0) + (b >> boost)) % 5,
    ]
    stats = assert_equivalent(pipelines,
                              run_kw=dict(n_shards=2, max_workers=4))
    assert stats.n_shards == 2
    assert stats.n_workers == 4
    assert stats.nodes_executed == stats.nodes_planned


@given(st.lists(st.lists(st.sampled_from("ABCD"), min_size=1, max_size=4),
                min_size=2, max_size=4),
       st.lists(st.sampled_from(["+", "**", "^", ">>"]),
                min_size=0, max_size=3),
       st.integers(min_value=2, max_value=4))
@settings(max_examples=15, deadline=None)
def test_property_sharded_plan_equals_naive(seqs, ops, n_shards):
    """The acceptance-criteria property: ``run(..., n_shards>1)`` equals
    sequential/naive execution on every operator shape."""
    assert_equivalent(_random_pipes(seqs, ops),
                      run_kw=dict(n_shards=n_shards, max_workers=4))


def test_sharded_stats_carry_shard_times_and_occupancy():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    _, stats = ExecutionPlan([a + b, a % 3]).run(
        QUERIES, n_shards=3, max_workers=2)
    assert stats.n_shards == len(stats.shard_times_s) == 3
    assert all(t >= 0 for t in stats.shard_times_s)
    assert 0.0 < stats.occupancy <= 1.0
    assert stats.wall_time_s > 0
    assert "shards=3" in str(stats)


def test_max_workers_alone_enables_branch_parallelism():
    """Branch-level concurrency without sharding: n_shards defaults to
    max_workers, and a single-row frame degenerates to one shard."""
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    one = ColFrame({"qid": ["q1"], "query": ["alpha"]})
    naive = (a + b)(one)
    outs, stats = ExecutionPlan([a + b]).run(one, max_workers=4)
    assert stats.n_shards == 1 and stats.n_workers == 4
    assert outs[0].sort_values(SORT).equals(
        naive.sort_values(SORT), cols=["qid", "docno", "score", "rank"])


def test_sharding_keeps_qid_groups_whole():
    """R-type inputs with several rows per qid: shard cuts only at qid
    boundaries, so per-qid operators see whole groups."""
    rows = [{"qid": f"q{i}", "query": f"t{i}", "docno": f"d{j}",
             "score": float(10 - j)}
            for i in range(5) for j in range(4)]
    results = add_ranks(ColFrame.from_dicts(rows))
    cut = GenericTransformer(
        lambda inp: inp.mask(inp["rank"] < 2), "cut2")
    from repro.core import Identity
    pipelines = [Identity() >> cut]
    naive = [p(results) for p in pipelines]
    outs, stats = ExecutionPlan(pipelines).run(
        results, n_shards=3, max_workers=3)
    assert stats.n_shards == 3
    assert outs[0].sort_values(SORT).equals(
        naive[0].sort_values(SORT), cols=["qid", "docno", "score", "rank"])


def test_unshardable_stage_falls_back_to_one_shard():
    """A stage declaring shardable=False (cross-query statistics) must
    not see a partitioned frame — results would silently change."""
    a = make_retriever("A")
    norm = GenericTransformer(
        lambda inp: add_ranks(inp.assign(
            score=inp["score"] - float(inp["score"].max()))),
        "global_norm", shardable=False)
    pipelines = [a >> norm]
    naive = [p(QUERIES) for p in pipelines]
    outs, stats = ExecutionPlan(pipelines).run(
        QUERIES, n_shards=3, max_workers=3)
    assert stats.n_shards == 1
    assert outs[0].sort_values(SORT).equals(
        naive[0].sort_values(SORT), cols=["qid", "docno", "score", "rank"],
        rtol=0, atol=0)
    # batch_size partitions the frame exactly like sharding would;
    # an unshardable stage must see it whole there too
    outs_b, _ = ExecutionPlan(pipelines).run(QUERIES, batch_size=1)
    assert outs_b[0].sort_values(SORT).equals(
        naive[0].sort_values(SORT), cols=["qid", "docno", "score", "rank"],
        rtol=0, atol=0)


def test_hand_wrapped_cache_preserves_unshardable(tmp_path):
    """A CacheTransformer wrapping a shardable=False stage must delegate
    the declaration — otherwise sharding silently changes results."""
    from repro.caching import KeyValueCache
    norm = GenericTransformer(
        lambda inp: add_ranks(inp.assign(
            score=inp["score"] - float(inp["score"].max()))),
        "global_norm", shardable=False,
        key_columns=("qid", "docno"), value_columns=("score",))
    cached = KeyValueCache(str(tmp_path), norm,
                           key=("qid", "docno"), value=("score",))
    assert cached.shardable is False
    a = make_retriever("A")
    pipelines = [a >> cached]
    naive = [(a >> norm)(QUERIES)]
    outs, stats = ExecutionPlan(pipelines).run(
        QUERIES, n_shards=3, max_workers=3)
    assert stats.n_shards == 1
    assert outs[0].sort_values(SORT).equals(
        naive[0].sort_values(SORT), cols=["qid", "docno", "score"],
        rtol=0, atol=0)
    cached.close()


def test_experiment_forwards_shards_in_lcp_and_trie_modes():
    a = make_retriever("A")
    b = make_retriever("B", base=8.0)
    from repro.core import Experiment
    qrels = ColFrame({"qid": ["q1"], "docno": ["A_d0"], "label": [1]})
    base = Experiment([a % 3, a + b], QUERIES, qrels, ["MAP"])
    for mode in ("lcp", "trie"):
        res = Experiment([a % 3, a + b], QUERIES, qrels, ["MAP"],
                         precompute_prefix=True, precompute_mode=mode,
                         n_shards=3, max_workers=3)
        if mode == "trie":               # trie returns PlanStats directly
            assert res.precompute.n_shards == 3
        for n1, n2 in zip(base.names, res.names):
            assert base.means[n1]["MAP"] == pytest.approx(
                res.means[n2]["MAP"])


def test_non_contiguous_qids_fall_back_to_one_shard():
    frame = add_ranks(ColFrame({
        "qid": ["q1", "q2", "q1"], "query": ["a", "b", "a"],
        "docno": ["d1", "d1", "d2"], "score": [3.0, 2.0, 1.0]}))
    boost = CountingStage("boost", boost_fn)
    from repro.core import Identity
    outs, stats = ExecutionPlan([Identity() >> boost]).run(
        frame, n_shards=4, max_workers=2)
    assert stats.n_shards == 1          # cannot cut without splitting q1
    naive = boost(frame)
    assert outs[0].sort_values(SORT).equals(
        naive.sort_values(SORT), cols=["qid", "docno", "score", "rank"])


def test_sharded_run_with_cache_dir_hits_on_second_run(tmp_path):
    def retr_fn(inp):
        rows = []
        for qid, query in zip(inp["qid"].tolist(), inp["query"].tolist()):
            for i in range(4):
                rows.append({"qid": qid, "query": query,
                             "docno": f"d{i}", "score": 9.0 - i})
        return add_ranks(ColFrame.from_dicts(rows))
    retr = CountingStage("R", retr_fn,
                         one_to_many=True, key_columns=("qid", "query"))
    pipelines = [retr % 3, retr % 2]
    with ExecutionPlan(pipelines, cache_dir=str(tmp_path),
                       cache_backend="pickle") as plan:
        _, s1 = plan.run(QUERIES, n_shards=3, max_workers=3)
        assert s1.cache_misses == len(QUERIES)
        outs, s2 = plan.run(QUERIES, n_shards=3, max_workers=3)
        assert s2.cache_hits == len(QUERIES)
        assert s2.cache_misses == 0
    naive = [p(QUERIES) for p in pipelines]
    for got, want in zip(outs, naive):
        assert got.sort_values(SORT).equals(
            want.sort_values(SORT), cols=["qid", "docno", "score", "rank"])


def test_plan_cache_backend_memory_without_cache_dir():
    """cache_backend="memory" alone enables in-process memoization."""
    def retr_fn(inp):
        rows = [{"qid": q, "query": t, "docno": "d0", "score": 1.0}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())]
        return add_ranks(ColFrame.from_dicts(rows))
    retr = CountingStage("R", retr_fn,
                         one_to_many=True, key_columns=("qid", "query"))
    with ExecutionPlan([retr % 1], cache_backend="memory") as plan:
        cached = [n for n in plan.nodes.values() if n.cache is not None]
        assert len(cached) == 1
        assert cached[0].cache.backend.name == "memory"
        plan.run(QUERIES)
        plan.run(QUERIES)
    assert retr.calls == 1              # second run served from memory


_CONCURRENT_PLAN_SCRIPT = """
import sys
from repro.core import ColFrame, ExecutionPlan, GenericTransformer, add_ranks

cache_dir, backend, log_path = sys.argv[1:4]

def retr(inp):
    with open(log_path, "a") as f:            # O_APPEND: atomic small writes
        for q in inp["qid"].tolist():
            f.write(q + "\\n")
    rows = [{"qid": q, "query": t, "docno": f"d{i}", "score": 5.0 - i}
            for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
            for i in range(3)]
    return add_ranks(ColFrame.from_dicts(rows))

a = GenericTransformer(retr, "A", one_to_many=True,
                       key_columns=("qid", "query"))
Q = ColFrame({"qid": [f"q{i}" for i in range(6)],
              "query": [f"t{i}" for i in range(6)]})
with ExecutionPlan([a % 2], cache_dir=cache_dir,
                   cache_backend=backend) as plan:
    outs, stats = plan.run(Q, n_shards=3, max_workers=3)
assert len(outs[0]) == 12, len(outs[0])
"""


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["pickle", "dbm", "sqlite"])
def test_concurrent_processes_share_plan_cache_dir(tmp_path, backend):
    """Two concurrent interpreters run the same sharded plan against one
    cache_dir through each backend: the file-locked miss path computes
    every entry exactly once across both processes *and* all shards."""
    import os
    import subprocess
    import sys
    log = tmp_path / "computed.log"
    log.touch()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(root, "src")}
    procs = [subprocess.Popen(
        [sys.executable, "-c", _CONCURRENT_PLAN_SCRIPT,
         str(tmp_path / "cache"), backend, str(log)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env)
        for _ in range(2)]
    for p in procs:
        _, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()[-2000:]
    computed = log.read_text().split()
    assert sorted(computed) == sorted(f"q{i}" for i in range(6)), \
        f"{backend}: entries computed more than once: {computed}"

