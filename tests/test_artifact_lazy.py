"""Dedicated coverage for caching/artifact.py and caching/lazy.py.

The Artifact layer (paper §4.5) packages cache directories into a local
hub (the network transport to HF/Zenodo is the only stubbed part); Lazy
defers transformer construction until a cache actually misses.
"""
import json
import os

import numpy as np
import pytest

from repro.caching import KeyValueCache, Lazy
from repro.caching.artifact import (Artifact, from_hub, hub_dir,
                                    install_artifact_methods, to_hub)
from repro.caching.base import resolve_transformer
from repro.core import ColFrame, GenericTransformer
from repro.ir import QueryExpander

QUERIES = ColFrame({"qid": ["q1", "q2"], "query": ["alpha beta", "gamma"]})


@pytest.fixture
def hub(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_HUB", str(tmp_path / "hub"))
    return tmp_path


# -- artifact hub -------------------------------------------------------------

def test_hub_dir_honours_env(hub):
    d = hub_dir()
    assert d == str(hub / "hub") and os.path.isdir(d)


def test_to_hub_writes_tarball_and_metadata(hub, tmp_path):
    src = str(tmp_path / "kv")
    with KeyValueCache(src, QueryExpander(2), key=("qid", "query"),
                       value=("query",)) as kv:
        kv(QUERIES)
        kv._temporary = False
        dest = to_hub(kv, "grp/expansions")
    assert os.path.exists(os.path.join(dest, "artifact.tar"))
    with open(os.path.join(dest, "metadata.json")) as f:
        meta = json.load(f)
    assert meta["artifact_type"] == "KeyValueCache"
    assert meta["module"] == "repro.caching.kv"
    assert meta["format_version"] == 1 and meta["created"] > 0


def test_hub_roundtrip_preserves_entries_and_manifest(hub, tmp_path):
    src = str(tmp_path / "kv")
    t = QueryExpander(2)
    with KeyValueCache(src, t, key=("qid", "query"), value=("query",),
                       fingerprint=t.fingerprint()) as kv:
        kv(QUERIES)
        kv._temporary = False
        kv.to_hf("grp/expansions")       # grafted Artifact method
    local = from_hub("grp/expansions")
    # the manifest travelled with the directory -> provenance survives
    from repro.caching import CacheManifest
    m = CacheManifest.load(local)
    assert m.fingerprint == t.fingerprint()
    with KeyValueCache(local, t, key=("qid", "query"), value=("query",),
                       fingerprint=t.fingerprint()) as kv2:
        out = kv2(QUERIES)
        assert kv2.stats.hits == len(QUERIES)
        assert out["query"][0] == "alpha beta alpha"


def test_from_hub_missing_artifact_raises(hub):
    with pytest.raises(FileNotFoundError, match="not found in hub"):
        from_hub("nobody/nothing")


def test_to_hub_requires_a_directory(hub):
    class Pathless:
        pass
    with pytest.raises(ValueError, match="no directory"):
        to_hub(Pathless(), "grp/x")


def test_artifact_from_hf_constructs_class(hub, tmp_path):
    src = str(tmp_path / "kv")
    with KeyValueCache(src, QueryExpander(2), key=("qid", "query"),
                       value=("query",)) as kv:
        kv(QUERIES)
        kv._temporary = False
        kv.to_zenodo("12345")
    cache = Artifact.from_zenodo("12345", KeyValueCache,
                                 key=("qid", "query"), value=("query",))
    try:
        assert isinstance(cache, KeyValueCache)
        assert cache(QUERIES)["query"][0] == "alpha beta alpha"
        assert cache.stats.hits == len(QUERIES)
    finally:
        cache.close()
    # without cls, from_* return the local path
    assert os.path.isdir(Artifact.from_zenodo("12345"))


def test_install_artifact_methods_grafts():
    class Custom:
        pass
    install_artifact_methods(Custom)
    assert callable(Custom.to_hf) and callable(Custom.to_zenodo)


# -- lazy ---------------------------------------------------------------------

def test_lazy_defers_and_constructs_once():
    built = []

    def factory():
        built.append(1)
        return GenericTransformer(lambda x: x.assign(
            query=np.array([q + "!" for q in x["query"].tolist()],
                           dtype=object)), "bang")

    lazy = Lazy(factory, name="bang")
    assert not lazy.constructed and built == []
    assert lazy.signature() == ("Lazy", "bang")       # placeholder identity
    out = lazy(QUERIES)
    assert out["query"][0] == "alpha beta!"
    assert lazy.constructed and lazy.construction_count == 1
    lazy(QUERIES)
    lazy._resolve_lazy()
    assert lazy.construction_count == 1               # at most once
    # after construction the signature is the instance's
    assert lazy.signature() == ("GenericTransformer", "bang")


def test_resolve_transformer_passthrough_and_lazy():
    assert resolve_transformer(None) is None
    t = GenericTransformer(lambda x: x, "id")
    assert resolve_transformer(t) is t
    lazy = Lazy(lambda: t)
    assert resolve_transformer(lazy) is t
    assert lazy.constructed


def test_unconstructed_lazy_skips_fingerprint_derivation(tmp_path):
    """auto-deriving a fingerprint from an unconstructed Lazy would (a)
    force construction and (b) record the placeholder signature; the
    derivation helper must decline instead."""
    from repro.caching import derive_fingerprint
    t = GenericTransformer(lambda x: x, "id")
    lazy = Lazy(lambda: t)
    assert derive_fingerprint(lazy) is None
    assert not lazy.constructed
    lazy._resolve_lazy()
    assert derive_fingerprint(lazy) == t.fingerprint()
