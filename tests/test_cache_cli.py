"""`repro cache` CLI (cli/cache.py): ls / verify / gc / export / import.

Most tests drive `repro.cli.main` in-process for speed; one slow test
exercises the real `python -m repro.cli` entry point.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.caching import CacheManifest, RetrieverCache
from repro.cli import main
from repro.core import ColFrame, ExecutionPlan, GenericTransformer, add_ranks
from repro.ir import QueryExpander

QUERIES = ColFrame({"qid": ["q1", "q2", "q3"],
                    "query": ["alpha beta", "gamma delta", "epsilon zeta"]})


def make_retriever(name, n=4, base=10.0):
    def fn(inp):
        rows = [{"qid": q, "query": t, "docno": f"{name}_d{i}",
                 "score": base - i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(n)]
        return add_ranks(ColFrame.from_dicts(rows))
    return GenericTransformer(fn, name, one_to_many=True,
                              key_columns=("qid", "query"))


@pytest.fixture
def cache_root(tmp_path):
    """A planner-populated cache root: a KeyValueCache node (sqlite), a
    RetrieverCache node (dbm), and a plan manifest."""
    root = tmp_path / "cache"
    a = make_retriever("A")
    with ExecutionPlan([QueryExpander(2) >> a, a],
                       cache_dir=str(root)) as plan:
        plan.run(QUERIES)
    return root


def _node_dirs(root):
    return sorted(d for d in os.listdir(root) if d != "plans")


# -- ls -----------------------------------------------------------------------

def test_ls_reports_dirs_and_plans(cache_root, capsys):
    assert main(["cache", "ls", str(cache_root), "--json"]) == 0
    info = json.loads(capsys.readouterr().out)
    assert len(info["dirs"]) == 3            # expander + A-under-qe + A
    families = {d["family"] for d in info["dirs"]}
    assert families == {"KeyValueCache", "RetrieverCache"}
    assert all(d["entry_count"] == len(QUERIES) for d in info["dirs"])
    assert all(d["fingerprint"] for d in info["dirs"])
    assert len(info["plans"]) == 1
    assert info["plans"][0]["n_nodes"] == 3
    assert info["plans"][0]["n_runs"] == 1


def test_ls_single_dir(cache_root, capsys):
    node = os.path.join(str(cache_root), _node_dirs(cache_root)[0])
    assert main(["cache", "ls", node, "--json"]) == 0
    info = json.loads(capsys.readouterr().out)
    assert len(info["dirs"]) == 1 and info["dirs"][0]["dir"] == "."


# -- verify -------------------------------------------------------------------

def test_verify_clean_root_exits_zero(cache_root, capsys):
    assert main(["cache", "verify", str(cache_root)]) == 0
    out = capsys.readouterr().out
    assert "0 failure(s)" in out


def test_verify_detects_hand_corrupted_manifest(cache_root, capsys):
    """Acceptance: `repro cache verify` detects a hand-corrupted
    manifest (the checksum no longer matches the edited body)."""
    node = _node_dirs(cache_root)[0]
    mpath = os.path.join(str(cache_root), node, "manifest.json")
    with open(mpath) as f:
        text = f.read()
    with open(mpath, "w") as f:
        f.write(text.replace('"entry_count": 3', '"entry_count": 999'))
    assert main(["cache", "verify", str(cache_root)]) == 1
    out = capsys.readouterr().out
    assert "checksum mismatch" in out and f"FAIL {node}" in out


def test_verify_detects_missing_store(cache_root, capsys):
    """A manifest whose recorded entries have no backing store fails."""
    info_rc = None
    for node in _node_dirs(cache_root):
        d = os.path.join(str(cache_root), node)
        m = CacheManifest.load(d)
        if m.backend == "sqlite":
            os.remove(os.path.join(d, "cache.sqlite3"))
            info_rc = node
    assert info_rc is not None
    assert main(["cache", "verify", str(cache_root)]) == 1
    assert "entry count mismatch" in capsys.readouterr().out


def test_verify_detects_plan_dir_fingerprint_divergence(cache_root, capsys):
    node = _node_dirs(cache_root)[0]
    d = os.path.join(str(cache_root), node)
    m = CacheManifest.load(d)
    m.fingerprint = "f" * 16
    m.save(d)                                # valid checksum, wrong fp
    assert main(["cache", "verify", str(cache_root)]) == 1
    assert "plan fingerprint" in capsys.readouterr().out


# -- gc -----------------------------------------------------------------------

def test_gc_dry_run_then_delete_old_dirs(cache_root, capsys):
    n_before = len(_node_dirs(cache_root))
    assert main(["cache", "gc", str(cache_root), "--older-than", "0s"]) == 0
    assert "would remove" in capsys.readouterr().out
    assert len(_node_dirs(cache_root)) == n_before       # dry run
    assert main(["cache", "gc", str(cache_root), "--older-than", "0s",
                 "--yes"]) == 0
    assert _node_dirs(cache_root) == []
    # fresh dirs survive a 1-week threshold
    assert main(["cache", "gc", str(cache_root), "--older-than", "7d",
                 "--yes"]) == 0


def test_gc_orphaned_removes_unreferenced_only(cache_root, capsys):
    stray = cache_root / "stray-dir"
    stray.mkdir()
    CacheManifest.new(family="KeyValueCache", backend="sqlite").save(
        str(stray))
    referenced = _node_dirs(cache_root)
    assert main(["cache", "gc", str(cache_root), "--orphaned",
                 "--yes"]) == 0
    left = _node_dirs(cache_root)
    assert "stray-dir" not in left
    assert left == [d for d in referenced if d != "stray-dir"]


def test_gc_requires_a_selector(cache_root):
    with pytest.raises(SystemExit):
        main(["cache", "gc", str(cache_root)])


# -- export / import ----------------------------------------------------------

def _retriever_node(cache_root):
    for node in _node_dirs(cache_root):
        d = os.path.join(str(cache_root), node)
        if CacheManifest.load(d).family == "RetrieverCache":
            return d
    raise AssertionError("no RetrieverCache node found")


def test_export_import_roundtrip_cross_backend(cache_root, tmp_path,
                                               capsys):
    """Entries export backend-agnostically: a dbm RetrieverCache node
    re-imports into a sqlite store and serves the same hits."""
    src = _retriever_node(cache_root)
    art = str(tmp_path / "node.tar")
    dest = str(tmp_path / "imported")
    assert main(["cache", "export", src, art]) == 0
    assert "entries mode" in capsys.readouterr().out
    assert main(["cache", "import", art, dest, "--backend", "sqlite"]) == 0
    m = CacheManifest.load(dest)
    assert m.backend == "sqlite" and m.entry_count == len(QUERIES)
    assert m.fingerprint == CacheManifest.load(src).fingerprint
    # the imported dir serves the cached queries with no transformer
    with RetrieverCache(dest, None, backend="sqlite") as rc:
        out = rc(QUERIES)
        assert rc.stats.hits == len(QUERIES) and rc.stats.misses == 0
        assert len(out) == len(QUERIES) * 4
    assert main(["cache", "verify", dest]) == 0


def test_import_refuses_fingerprint_mismatch(cache_root, tmp_path, capsys):
    dirs = [os.path.join(str(cache_root), d) for d in
            _node_dirs(cache_root)]
    art_a, art_b = str(tmp_path / "a.tar"), str(tmp_path / "b.tar")
    dest = str(tmp_path / "imported")
    assert main(["cache", "export", dirs[0], art_a]) == 0
    assert main(["cache", "export", dirs[1], art_b]) == 0
    assert main(["cache", "import", art_a, dest]) == 0
    with pytest.raises(SystemExit, match="fingerprint mismatch"):
        main(["cache", "import", art_b, dest])
    capsys.readouterr()
    assert main(["cache", "import", art_b, dest, "--force"]) == 0


def test_export_raw_mode_for_pickle_backend(tmp_path, capsys):
    """Backends that cannot enumerate keys export raw store files and
    re-import them verbatim."""
    from repro.caching import KeyValueCache
    src, dest = str(tmp_path / "src"), str(tmp_path / "dest")
    t = QueryExpander(2)
    with KeyValueCache(src, t, key=("qid", "query"), value=("query",),
                       backend="pickle",
                       fingerprint=t.fingerprint()) as kv:
        kv(QUERIES)
    art = str(tmp_path / "raw.tar")
    assert main(["cache", "export", src, art]) == 0
    assert "raw mode" in capsys.readouterr().out
    assert main(["cache", "import", art, dest]) == 0
    with KeyValueCache(dest, t, key=("qid", "query"), value=("query",),
                       backend="pickle",
                       fingerprint=t.fingerprint()) as kv:
        kv(QUERIES)
        assert kv.stats.hits == len(QUERIES)


def test_export_requires_manifest(tmp_path):
    plain = tmp_path / "plain"
    plain.mkdir()
    with pytest.raises(SystemExit, match="manifest"):
        main(["cache", "export", str(plain), str(tmp_path / "x.tar")])


# -- the real entry point -----------------------------------------------------

@pytest.mark.slow
def test_python_m_repro_cli_verify(cache_root):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(root, "src"),
           "REPRO_PROVENANCE_HASH": "host"}
    p = subprocess.run([sys.executable, "-m", "repro.cli", "cache",
                        "verify", str(cache_root)],
                       capture_output=True, text=True, env=env, timeout=180)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "0 failure(s)" in p.stdout


# -- --json scripting contract (stable key order, unchanged exit codes) ------

def _assert_stable_json(raw: str):
    """Output must be pure JSON with recursively sorted keys, so shell
    pipelines can diff two invocations without canonicalizing first."""
    doc = json.loads(raw)
    assert raw.strip() == json.dumps(doc, indent=2, sort_keys=True)
    return doc


def test_ls_json_is_stable_and_pure(cache_root, capsys):
    assert main(["cache", "ls", str(cache_root), "--json"]) == 0
    doc = _assert_stable_json(capsys.readouterr().out)
    assert set(doc) == {"root", "dirs", "plans"}
    # repeated invocations are byte-identical (modulo nothing)
    assert main(["cache", "ls", str(cache_root), "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["dirs"] == doc["dirs"]


def test_verify_json_keeps_exit_codes(cache_root, capsys):
    assert main(["cache", "verify", str(cache_root), "--json"]) == 0
    doc = _assert_stable_json(capsys.readouterr().out)
    assert doc["failed"] == 0 and doc["checked"] >= 4
    assert all(r["problems"] == [] for r in doc["report"])
    # corrupt one manifest: exit code flips to 1, report names the dir
    node = _node_dirs(cache_root)[0]
    mpath = os.path.join(str(cache_root), node, "manifest.json")
    with open(mpath) as f:
        text = f.read()
    with open(mpath, "w") as f:
        f.write(text.replace('"entry_count": 3', '"entry_count": 999'))
    assert main(["cache", "verify", str(cache_root), "--json"]) == 1
    doc = _assert_stable_json(capsys.readouterr().out)
    assert doc["failed"] == 1
    bad = [r for r in doc["report"] if r["problems"]]
    assert bad[0]["dir"] == node


def test_plan_explain_json_is_stable(cache_root, capsys):
    assert main(["plan", "explain", str(cache_root), "--json"]) == 0
    docs = _assert_stable_json(capsys.readouterr().out)
    assert len(docs) == 1 and docs[0]["nodes"]
