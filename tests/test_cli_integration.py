"""Subprocess integration: the deliverable CLIs actually run.

The dry-run MUST run in its own process (it forces 512 placeholder
devices before JAX init); these tests exercise the real commands.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


@pytest.mark.slow
def test_dryrun_cli_single_cell(tmp_path):
    out = tmp_path / "cell.jsonl"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "gcn-cora", "--shape", "molecule",
         "--out", str(out), "--quiet"],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(out.read_text().strip().splitlines()[-1])
    assert rec["arch"] == "gcn-cora" and rec["mesh"] == "16x16"
    assert rec["hlo_flops"] > 0 and rec["est_step_s"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_train_cli_runs_and_learns(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "smollm-360m", "--steps", "30", "--batch", "4",
         "--seq", "32", "--ckpt-dir", str(tmp_path / "ck"),
         "--ckpt-every", "15"],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "loss" in proc.stdout
    # a committed checkpoint exists
    assert any(d.startswith("step_")
               for d in os.listdir(tmp_path / "ck"))
