"""Dense retrieval as a compiler-native node (ir/dense.py + the plan
stack): pushdown fusion into the kernel's per-block k, hybrid
sparse+dense bit-identity under both schedulers, cold→warm planner
caching, and the query-embedding memo."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ColFrame, ExecutionPlan
from repro.ir import InvertedIndex, TextLoader, msmarco_like
from repro.ir.dense import DenseEncoder, DenseIndex, DenseRetriever
from repro.models.cross_encoder import EncoderConfig, MonoScorer

CORPUS = msmarco_like(1, scale=0.02)
CE = EncoderConfig(name="dense-ce", n_layers=1, d_model=32, n_heads=2,
                   d_ff=64, vocab_size=2048, max_len=16)
MONO = EncoderConfig(name="mono-ce", n_layers=1, d_model=32, n_heads=2,
                     d_ff=64, vocab_size=2048, max_len=16)


@pytest.fixture(scope="module")
def dense_index():
    return DenseIndex(DenseEncoder(CE)).index(CORPUS.get_corpus_iter())


@pytest.fixture(scope="module")
def bm25():
    return InvertedIndex.build(CORPUS.get_corpus_iter()).bm25(
        num_results=100)


def _hybrid(bm25, dense_index, k=10, num_results=100):
    dense = dense_index.retriever(num_results=num_results)
    return ((bm25 % k | dense % k)
            >> TextLoader(CORPUS.text_map()) >> MonoScorer(MONO))


def _dense_nodes(plan):
    return [n for n in plan.graph.nodes
            if isinstance(n.stage, DenseRetriever)]


def _cutoff_nodes(plan):
    return [n for n in plan.graph.nodes
            if n.stage is not None
            and type(n.stage).__name__ == "RankCutoff"]


def assert_bit_identical(outs_a, outs_b):
    assert len(outs_a) == len(outs_b)
    for got, want in zip(outs_a, outs_b):
        cols = [c for c in ("qid", "docno", "score", "rank")
                if c in want.columns and c in got.columns]
        by = [c for c in ("qid", "docno") if c in want.columns]
        g = got.sort_values(by) if by else got
        w = want.sort_values(by) if by else want
        assert g.equals(w, cols=cols, rtol=0, atol=0), \
            "optimizer changed results"


# -- pushdown fusion ----------------------------------------------------------

def test_pushdown_fuses_cutoff_into_dense_k(dense_index):
    plan = ExecutionPlan([dense_index.retriever(num_results=100) % 7])
    nodes = _dense_nodes(plan)
    assert len(nodes) == 1
    assert nodes[0].stage.num_results == 7
    assert not _cutoff_nodes(plan)


def test_pushdown_fuses_both_hybrid_branches(bm25, dense_index):
    plan = ExecutionPlan([_hybrid(bm25, dense_index, k=10)])
    assert not _cutoff_nodes(plan)
    (dn,) = _dense_nodes(plan)
    assert dn.stage.num_results == 10
    assert any(getattr(n.stage, "num_results", None) == 10
               for n in plan.graph.nodes
               if type(n.stage).__name__ == "BM25Retriever")


def test_with_cutoff_is_prefix_of_deeper_run(dense_index):
    """The soundness condition pushdown relies on: top-k is a prefix of
    top-n under the deterministic (score desc, docno idx asc) order."""
    topics = CORPUS.get_topics().head(8)
    deep = dense_index.retriever(num_results=20)(topics)
    shallow = dense_index.retriever(num_results=20).with_cutoff(6)(topics)
    prefix = deep.take(np.where(deep["rank"] < 6)[0])
    assert shallow.sort_values(["qid", "rank"]).equals(
        prefix.sort_values(["qid", "rank"]),
        cols=["qid", "docno", "rank", "score"], rtol=0, atol=0)


def test_hybrid_explain_has_fused_dense_no_cutoff(tmp_path, capsys,
                                                  bm25, dense_index):
    """`repro plan explain` over the hybrid plan's manifest shows the
    cutoff fused into the dense node (no residual RankCutoff)."""
    from repro.cli import main
    with ExecutionPlan([_hybrid(bm25, dense_index, k=10)],
                       cache_dir=str(tmp_path)) as plan:
        expected = plan.explain()
    assert main(["plan", "explain", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert out.strip() == expected.strip()
    assert "DenseRetriever('dense-ce', 7, 180, 10)" in out
    # no node renders a RankCutoff stage (the token only appears inside
    # structural signatures of downstream operators, if at all)
    record_nodes = plan.to_record()["nodes"]
    assert all(not n["label"].startswith("RankCutoff")
               for n in record_nodes)


# -- the hard invariant, dense edition ---------------------------------------

def _run_both(pipelines, queries, **run_kw):
    outs_opt, stats_opt = ExecutionPlan(pipelines, optimize="all").run(
        queries, **run_kw)
    outs_ref, stats_ref = ExecutionPlan(pipelines, optimize="none").run(
        queries, **run_kw)
    assert_bit_identical(outs_opt, outs_ref)
    assert stats_opt.nodes_executed <= stats_ref.nodes_executed
    return stats_opt


def test_hybrid_bit_identical_sequential(bm25, dense_index):
    _run_both([_hybrid(bm25, dense_index, k=5)],
              CORPUS.get_topics().head(6))


def test_hybrid_bit_identical_sharded(bm25, dense_index):
    _run_both([_hybrid(bm25, dense_index, k=5)],
              CORPUS.get_topics().head(6), n_shards=2, max_workers=2)


_SHARED = {}


def _shared():
    """Module-level lazy singletons for the property test (the
    hypothesis fallback shim can't draw pytest fixtures)."""
    if not _SHARED:
        _SHARED["bm25"] = InvertedIndex.build(
            CORPUS.get_corpus_iter()).bm25(num_results=100)
        _SHARED["dense"] = DenseIndex(DenseEncoder(CE)).index(
            CORPUS.get_corpus_iter())
    return _SHARED["bm25"], _SHARED["dense"]


@given(k=st.integers(1, 12), sharded=st.booleans())
@settings(max_examples=6, deadline=None)
def test_hybrid_bit_identical_property(k, sharded):
    bm25, dense_index = _shared()
    kw = {"n_shards": 2, "max_workers": 2} if sharded else {}
    _run_both([_hybrid(bm25, dense_index, k=k)],
              CORPUS.get_topics().head(4), **kw)


# -- planner-inserted caching -------------------------------------------------

def test_dense_cold_warm_restart_zero_misses(tmp_path, dense_index):
    topics = CORPUS.get_topics().head(8)
    pipe = dense_index.retriever(num_results=100) % 5
    with ExecutionPlan([pipe], cache_dir=str(tmp_path)) as plan:
        _, cold = plan.run(topics)
    assert cold.cache_misses > 0
    # fresh process restart, same cache dir: all hits, zero misses
    with ExecutionPlan([pipe], cache_dir=str(tmp_path)) as plan2:
        outs, warm = plan2.run(topics)
    assert warm.cache_misses == 0
    assert warm.cache_hits == len(topics)
    assert len(outs[0]) == 5 * len(topics)


def test_dense_fingerprint_tracks_corpus_and_backend(dense_index):
    r = dense_index.retriever(num_results=5)
    fp = r.fingerprint()
    assert fp == dense_index.retriever(num_results=5).fingerprint()
    other = DenseIndex(dense_index.encoder).index(
        list(CORPUS.get_corpus_iter())[:50])
    assert other.retriever(num_results=5).fingerprint() != fp
    assert dense_index.retriever(
        num_results=5, backend="pallas").fingerprint() != fp


# -- query-embedding memo -----------------------------------------------------

def test_dense_encodes_each_unique_query_once():
    """Two dense nodes that survive CSE as distinct (different retrieval
    depths) still encode each unique query once — the re-encoding fix."""
    index = DenseIndex(DenseEncoder(CE)).index(CORPUS.get_corpus_iter())
    topics = CORPUS.get_topics().head(6)
    plan = ExecutionPlan([index.retriever(num_results=3),
                          index.retriever(num_results=8)])
    labels = sorted(n.label for n in _dense_nodes(plan))
    assert len(labels) == 2              # distinct signatures, no CSE
    base = index.encoder.encoded_texts
    _, stats = plan.run(topics)
    # both nodes executed (the savings came from the memo, not CSE) ...
    for lbl in labels:
        assert stats.node_exec_counts[lbl] == 1
    # ... yet the backbone saw each unique query exactly once
    assert index.encoder.encoded_texts - base == len(topics)
    # and a second run over the same traffic encodes nothing
    plan.run(topics)
    assert index.encoder.encoded_texts - base == len(topics)


def test_dense_kernel_backend_matches_xla(dense_index):
    topics = CORPUS.get_topics().head(4)
    a = dense_index.retriever(num_results=7)(topics)
    b = dense_index.retriever(num_results=7, backend="pallas")(topics)
    assert a.sort_values(["qid", "rank"]).equals(
        b.sort_values(["qid", "rank"]), cols=["qid", "docno", "rank"])
    np.testing.assert_allclose(
        a.sort_values(["qid", "rank"])["score"],
        b.sort_values(["qid", "rank"])["score"], atol=2e-5)
