"""DenseRetriever (neural first-stage + RetrieverCache) and the
step-keyed data pipeline contracts."""
import numpy as np
import pytest

from repro.caching import RetrieverCache
from repro.data.pipeline import (StepKeyedDataset, gcn_sampled,
                                 lm_synthetic, recsys_synthetic)
from repro.ir import msmarco_like
from repro.ir.dense import DenseEncoder, DenseIndex
from repro.models.cross_encoder import EncoderConfig

CORPUS = msmarco_like(1, scale=0.02)
CE = EncoderConfig(name="dense-ce", n_layers=1, d_model=32, n_heads=2,
                   d_ff=64, vocab_size=2048, max_len=16)


@pytest.fixture(scope="module")
def dense_index():
    return DenseIndex(DenseEncoder(CE)).index(CORPUS.get_corpus_iter())


def test_dense_retriever_shapes_and_ranks(dense_index):
    retr = dense_index.retriever(num_results=10)
    out = retr(CORPUS.get_topics())
    assert len(out) == 10 * len(CORPUS.get_topics())
    for (_,), idx in out.group_indices(["qid"]).items():
        scores = out["score"][idx][np.argsort(out["rank"][idx])]
        assert all(scores[i] >= scores[i + 1] - 1e-6
                   for i in range(len(scores) - 1))


def test_dense_retriever_deterministic_and_cacheable(dense_index):
    """The paper §4.3 flow with a NEURAL retriever: cache round-trips."""
    retr = dense_index.retriever(num_results=5)
    a = retr(CORPUS.get_topics())
    with RetrieverCache(None, retr) as rc:
        cold = rc(CORPUS.get_topics())
        hot = rc(CORPUS.get_topics())
        assert rc.stats.hits == len(CORPUS.get_topics())
        assert cold.equals(a, cols=["qid", "docno", "rank"])
        assert hot.equals(a, cols=["qid", "docno", "rank"])


def test_dense_embeddings_normalized(dense_index):
    norms = np.linalg.norm(dense_index.matrix, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)


# -- data pipeline ------------------------------------------------------------

def test_step_keyed_random_access_determinism():
    ds = StepKeyedDataset(lm_synthetic(1000, 32), global_batch=16, seed=3)
    b1, b2 = ds.batch(7), ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(8)["tokens"], b1["tokens"])


def test_sharded_slices_compose_to_global():
    ds = StepKeyedDataset(lm_synthetic(1000, 16), global_batch=32, seed=0)
    full = ds.batch(5)
    parts = [ds.shard(i, 4).batch(5)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])


def test_recsys_generator_schemas():
    from repro.configs import ARCHS
    for name in ("dlrm-rm2", "mind", "two-tower-retrieval"):
        cfg = ARCHS[name].config
        gen = recsys_synthetic(cfg)
        ds = StepKeyedDataset(gen, global_batch=8, seed=1)
        b = ds.batch(0)
        if cfg.kind in ("dlrm", "dcn"):
            assert b["sparse"].shape == (8, cfg.n_sparse)
            assert (b["sparse"].max(axis=0)
                    < np.array(cfg.vocab_sizes)).all()
        elif cfg.kind == "mind":
            assert b["hist_ids"].shape == (8, cfg.hist_len)
        else:
            assert b["user_ids"].shape == (8,)


def test_gcn_sampled_generator():
    from repro.models.gcn import NeighborSampler
    rng = np.random.default_rng(0)
    N, E = 100, 500
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    sampler = NeighborSampler.from_edges(N, src, dst)
    feats = rng.normal(size=(N, 8)).astype(np.float32)
    labels = rng.integers(0, 4, N).astype(np.int32)
    gen = gcn_sampled(sampler, feats, labels, (5, 3))
    ds = StepKeyedDataset(gen, global_batch=8, seed=0)
    b = ds.batch(0)
    assert b["feats_hop2"].shape == (8, 5, 3, 8)
    b2 = ds.batch(0)
    np.testing.assert_array_equal(b["feats_hop1"], b2["feats_hop1"])
