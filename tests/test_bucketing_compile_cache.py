"""TPU adaptations: bucketed miss execution + CompileCache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.caching import BucketedRunner, CompileCache, bucket_size, \
    pad_batch


def test_bucket_size_powers_of_two():
    assert bucket_size(1) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(1000) == 1024


@given(st.integers(1, 5000))
@settings(max_examples=100, deadline=None)
def test_property_bucket_bounds(n):
    b = bucket_size(n)
    assert b >= min(n, 8)
    assert b & (b - 1) == 0          # power of two
    assert b < 2 * max(n, 8)


def test_pad_batch_repeats_row0():
    a = np.arange(6).reshape(3, 2)
    p = pad_batch(a, 5)
    assert p.shape == (5, 2)
    assert (p[3:] == a[0]).all()


def test_bucketed_runner_bounded_shapes_and_exact_results():
    compiled_shapes = []
    @jax.jit
    def fn(x):
        compiled_shapes.append(x.shape)
        return x.sum(axis=1)
    runner = BucketedRunner(lambda x: fn(jnp.asarray(x)), floor=8,
                            max_bucket=64)
    rng = np.random.default_rng(0)
    sizes = [3, 7, 9, 17, 33, 63, 64, 65, 129, 5, 31]
    for n in sizes:
        x = rng.normal(size=(n, 4)).astype(np.float32)
        out = runner(x)
        assert out.shape == (n,)
        np.testing.assert_allclose(out, x.sum(1), rtol=1e-5, atol=1e-6)
    # O(log max_bucket) distinct compiled shapes
    assert len(set(runner.shapes_issued)) <= 5


def test_compile_cache_reuses_executables():
    cc = CompileCache()
    def f(x):
        return x * 2 + 1
    x = jnp.ones((16, 8))
    y1 = cc.call("f", f, x)
    y2 = cc.call("f", f, x)
    assert cc.stats.compile_misses == 1
    assert cc.stats.compile_hits == 1
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
    # different shape -> new compile
    cc.call("f", f, jnp.ones((32, 8)))
    assert cc.stats.compile_misses == 2
    # same shapes under a different name -> separate entry
    cc.call("g", f, x)
    assert cc.stats.compile_misses == 3
