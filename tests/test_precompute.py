"""Prefix precomputation (paper §3): LCP Eq.2 + the cache-transparency
invariant (precomputation changes time, never results), + the
beyond-paper trie (resolves the §6 ablation limitation)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ColFrame, GenericTransformer, Identity, add_ranks,
                        longest_common_prefix, run_with_precompute,
                        run_with_trie, split_on_prefix, stages_of)


class CountingStage(GenericTransformer):
    """Transformer that counts invocations (for sharing assertions)."""

    def __init__(self, name, fn=None, **kw):
        self.calls = 0
        def wrapped(inp, _fn=fn):
            self.calls += 1
            return _fn(inp) if _fn else inp
        super().__init__(wrapped, name, **kw)


def retr_fn(inp):
    rows = []
    for qid in inp["qid"].tolist():
        for i in range(6):
            rows.append({"qid": qid, "docno": f"d{i}", "score": 10.0 - i})
    return add_ranks(ColFrame.from_dicts(rows))


def boost_fn(inp):
    return add_ranks(inp.assign(score=inp["score"] * 2.0))


def shift_fn(inp):
    return add_ranks(inp.assign(score=inp["score"] + 1.0))


QUERIES = ColFrame({"qid": ["q1", "q2", "q3"],
                    "query": ["alpha", "beta", "gamma"]})


def test_lcp_matches_eq2():
    A = GenericTransformer(retr_fn, "A")
    B = GenericTransformer(boost_fn, "B")
    C = GenericTransformer(shift_fn, "C")
    assert len(longest_common_prefix([A >> B, A >> C])) == 1
    assert len(longest_common_prefix([A >> B >> C, A >> B])) == 2
    assert len(longest_common_prefix([A >> B, C >> B])) == 0
    assert len(longest_common_prefix([A % 5, A % 3])) == 1   # shared A
    assert longest_common_prefix([]) == ()


def test_split_on_prefix():
    A = GenericTransformer(retr_fn, "A")
    B = GenericTransformer(boost_fn, "B")
    p = A >> B
    rest = split_on_prefix(p, 1)
    assert stages_of(rest)[0] == B
    ident = split_on_prefix(p, 2)
    assert isinstance(ident, Identity)


def test_precompute_transparency_invariant():
    """Outputs with precomputation == outputs without (paper's implicit
    contract; the whole point of §3)."""
    A = CountingStage("A", retr_fn)
    B = CountingStage("B", boost_fn)
    C = CountingStage("C", shift_fn)
    pipes = [A >> B, A >> C, A >> B >> C]
    naive = [p(QUERIES) for p in pipes]
    calls_naive = A.calls
    outs, stats = run_with_precompute(pipes, QUERIES)
    assert A.calls == calls_naive + 1          # A ran once more, not 3x
    for got, want in zip(outs, naive):
        assert got.equals(want, cols=["qid", "docno", "score", "rank"])
    assert stats.prefix_len == 1
    assert stats.stage_invocations_saved == 2


def test_trie_dominates_lcp_on_ablation_case():
    """Paper §6: A; A»B; A»B»C — LCP precomputes only A, the trie also
    shares A»B."""
    A = CountingStage("A", retr_fn)
    B = CountingStage("B", boost_fn)
    C = CountingStage("C", shift_fn)
    pipes = [A, A >> B, A >> B >> C]
    naive = [p(QUERIES) for p in pipes]
    A.calls = B.calls = C.calls = 0
    outs, stats = run_with_trie(pipes, QUERIES)
    assert A.calls == 1
    assert B.calls == 1           # LCP-only would call B twice
    assert C.calls == 1
    for got, want in zip(outs, naive):
        assert got.equals(want, cols=["qid", "docno", "score", "rank"])
    assert stats.nodes_executed == 3
    assert stats.nodes_total == 6


@given(st.lists(st.lists(st.sampled_from("ABCD"), min_size=1, max_size=4),
                min_size=2, max_size=5))
@settings(max_examples=40, deadline=None)
def test_property_lcp_is_common_prefix(seqs):
    stages = {c: GenericTransformer(lambda x: x, c) for c in "ABCD"}
    pipes = []
    for seq in seqs:
        p = stages[seq[0]]
        for c in seq[1:]:
            p = p >> stages[c]
        pipes.append(p)
    prefix = longest_common_prefix(pipes)
    k = len(prefix)
    # prefix property: every pipeline starts with it
    for seq in seqs:
        assert len(seq) >= k
        assert all(stages[seq[j]] == prefix[j] for j in range(k))
    # maximality: no longer common prefix exists
    if all(len(s) > k for s in seqs):
        first = seqs[0][k]
        assert any(s[k] != first for s in seqs[1:])


@given(st.lists(st.lists(st.sampled_from("AB"), min_size=1, max_size=3),
                min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_property_trie_executes_each_distinct_prefix_once(seqs):
    calls = []
    def mk(c):
        def fn(x, _c=c):
            return x
        t = GenericTransformer(fn, c)
        orig = t.transform
        def counting(inp, _t=t, _orig=orig):
            calls.append(_t.name)
            return _orig(inp)
        t.transform = counting
        return t
    stages = {c: mk(c) for c in "AB"}
    pipes = []
    for seq in seqs:
        p = stages[seq[0]]
        for c in seq[1:]:
            p = p >> stages[c]
        pipes.append(p)
    outs, stats = run_with_trie(pipes, QUERIES)
    distinct_prefixes = {tuple(s[:i + 1]) for s in seqs
                         for i in range(len(s))}
    assert stats.nodes_executed == len(distinct_prefixes)
    assert len(calls) == len(distinct_prefixes)
