"""End-to-end behaviour: the paper's §5 experiment at reduced scale.

Settings: (1) no caching, (2) prefix precomputation, (3) cold
ScorerCache on the Mono scorer, (4) hot ScorerCache.  The invariant the
paper implies but never states: all four settings produce IDENTICAL
evaluation tables; caching changes time, not results.  Work counters
must be monotone non-increasing (1) >= (2) >= (3) >= (4).
"""
import numpy as np
import pytest

from repro.caching import ScorerCache
from repro.core import ColFrame, Experiment
from repro.ir import InvertedIndex, TextLoader, msmarco_like
from repro.models.cross_encoder import DuoScorer, EncoderConfig, MonoScorer
from repro.serve import ScoringService

CORPUS = msmarco_like(1, scale=0.05)
INDEX = InvertedIndex.build(CORPUS.get_corpus_iter())
CE = EncoderConfig(n_layers=1, d_model=32, n_heads=2, d_ff=64,
                   vocab_size=4096, max_len=32)
CUTS = (3, 5, 8)
MEASURES = ["nDCG@10", "MAP"]


def build_pipelines(mono, duo):
    bm25 = INDEX.bm25(num_results=20)
    loader = TextLoader(CORPUS.text_map())
    return [bm25 % k >> loader >> mono % 3 >> duo for k in CUTS]


def run_setting(mono_wrapper=None, precompute=False):
    mono = MonoScorer(CE)
    duo = DuoScorer(CE, max_docs=3)
    stage = mono_wrapper(mono) if mono_wrapper else mono
    bm25 = INDEX.bm25(num_results=20)
    loader = TextLoader(CORPUS.text_map())
    systems = [bm25 % k >> loader >> stage % 3 >> duo for k in CUTS]
    res = Experiment(systems, CORPUS.get_topics(), CORPUS.get_qrels(),
                     MEASURES, precompute_prefix=precompute,
                     names=[f"k={k}" for k in CUTS])
    return res, mono, duo


def test_table2_invariant_results_identical_and_work_monotone():
    r1, mono1, _ = run_setting()                                # (1)
    r2, mono2, _ = run_setting(precompute=True)                 # (2)
    cache = ScorerCache(None)                                   # shared
    def wrap(m):
        cache._transformer_raw = m
        return cache
    r3, mono3, _ = run_setting(mono_wrapper=wrap,
                               precompute=True)                 # (3) cold
    r4, mono4, _ = run_setting(mono_wrapper=wrap,
                               precompute=True)                 # (4) hot
    cache.close()

    # Invariant A: all settings give the same evaluation table
    for name in r1.names:
        for m in MEASURES:
            v = r1.means[name][m]
            assert r2.means[name][m] == pytest.approx(v, abs=1e-9)
            assert r3.means[name][m] == pytest.approx(v, abs=1e-9)
            assert r4.means[name][m] == pytest.approx(v, abs=1e-9)

    # Invariant B: monotone non-increasing scorer work
    assert mono2.invocations <= mono1.invocations
    assert mono3.invocations <= mono2.invocations
    assert mono4.invocations <= mono3.invocations
    assert mono4.invocations == 0        # hot cache: zero re-scoring


def test_indexing_pipeline_end_to_end():
    """Paper §4.1 flow: expensive doc transform cached once, two indexes
    built from the cache."""
    from repro.caching import IndexerCache, KeyValueCache
    from repro.ir import QueryExpander

    calls = {"n": 0}
    def expand(frame):
        calls["n"] += len(frame)
        texts = [t + " expanded" for t in frame["text"].tolist()]
        return frame.assign(text=np.array(texts, dtype=object))
    from repro.core import GenericTransformer
    doc_rewriter = GenericTransformer(expand, "doc2query",
                                      key_columns=("docno",),
                                      value_columns=("text",))
    with KeyValueCache(None, doc_rewriter, key="docno",
                       value="text") as cache:
        idx1 = InvertedIndex()
        (cache >> idx1.indexer()).index(CORPUS.get_corpus_iter())
        n_after_first = calls["n"]
        idx2 = InvertedIndex()
        (cache >> idx2.indexer()).index(CORPUS.get_corpus_iter())
        assert calls["n"] == n_after_first      # second index = all hits
        assert idx1.n_docs == idx2.n_docs == len(CORPUS.docs)
        assert "expanded" in list(idx1.postings.keys())


def test_pipeline_service_with_cached_scorer():
    """The §4.2 single-scorer service on its modern surface: a
    ScorerCache-wrapped MonoScorer behind PipelineService (what the
    ScoringService deprecation points at)."""
    from repro.serve import PipelineService
    mono = MonoScorer(CE)
    cache = ScorerCache(None, mono)
    svc = PipelineService(cache, max_batch=32, max_wait_ms=0.0,
                          max_workers=1)
    docs = CORPUS.docs
    rows = [{"qid": f"q{i % 4}", "query": f"query text {i % 4}",
             "docno": str(docs["docno"][i]), "text": str(docs["text"][i]),
             "score": 0.0, "rank": 0} for i in range(40)]
    out1 = svc.search(rows)
    assert len(out1) == 40
    out2 = svc.search(rows)             # identical requests: all hits now
    assert len(out2) == 40
    assert out2.equals(out1)            # caching changes time, not results
    s = svc.stats.summary()
    assert s["hit_rate"] >= 0.5
    svc.close()
    cache.close()


def test_scoring_service_deprecated_but_compatible():
    """The legacy front-end still works (one more release) but warns."""
    mono = MonoScorer(CE)
    with pytest.warns(DeprecationWarning, match="PipelineService"):
        svc = ScoringService(mono, max_batch=32)
    docs = CORPUS.docs
    for i in range(8):
        svc.submit(f"q{i % 2}", f"query text {i % 2}",
                   str(docs["docno"][i]), str(docs["text"][i]))
    assert len(svc.flush()) == 8
    svc.close()
