"""auto_cache + typecheck_pipeline (paper §6 future work, implemented)."""
import pytest

from repro.caching import (KeyValueCache, RetrieverCache, ScorerCache,
                           UncacheableError, auto_cache, typecheck_pipeline)
from repro.core import ColFrame, GenericTransformer
from repro.ir import InvertedIndex, QueryExpander, msmarco_like
from repro.models.cross_encoder import DuoScorer, EncoderConfig, MonoScorer

CORPUS = msmarco_like(1, scale=0.03)
INDEX = InvertedIndex.build(CORPUS.get_corpus_iter())
CE = EncoderConfig(n_layers=1, d_model=32, n_heads=2, d_ff=64,
                   vocab_size=2048, max_len=16)


def test_auto_cache_picks_retriever_cache():
    c = auto_cache(INDEX.bm25())
    assert isinstance(c, RetrieverCache)
    c.close()


def test_auto_cache_picks_scorer_cache():
    c = auto_cache(MonoScorer(CE))
    assert isinstance(c, ScorerCache)
    c.close()


def test_auto_cache_picks_kv_cache():
    c = auto_cache(QueryExpander(2))
    assert isinstance(c, KeyValueCache)
    c.close()


def test_auto_cache_refuses_pairwise_scorer():
    """The paper-§5 DuoT5 caveat, enforced by metadata."""
    with pytest.raises(UncacheableError, match="cacheable=False"):
        auto_cache(DuoScorer(CE))


def test_auto_cache_refuses_nondeterministic():
    t = GenericTransformer(lambda x: x, "rng", deterministic=False,
                           key_columns=("qid",), value_columns=("query",))
    with pytest.raises(UncacheableError, match="deterministic"):
        auto_cache(t)


def test_auto_cache_refuses_missing_metadata():
    t = GenericTransformer(lambda x: x, "opaque")
    with pytest.raises(UncacheableError, match="key/value"):
        auto_cache(t)


def test_typecheck_pipeline_catches_missing_text():
    """MonoScorer needs a text column; raw BM25 output provides it via
    its query/docno/text contract only after a TextLoader."""
    bm25 = INDEX.bm25()
    scorer = MonoScorer(CE)
    bad = bm25 >> scorer
    errors = typecheck_pipeline(bad)
    assert errors and "text" in errors[0][1]
    from repro.ir import TextLoader
    good = bm25 >> TextLoader(CORPUS.text_map()) >> scorer
    assert typecheck_pipeline(good) == []
