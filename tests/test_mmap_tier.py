"""MmapTier (caching/mmap_tier.py): packed read-only snapshot over a
disk backend — selector plumbing, write-shadowing, miss-rate-triggered
refresh, storage-identity staleness relaxation, and observational
equivalence with the bare disk backend under random operation sequences
(property-tested, including across a close/reopen cycle — the same
harness as tests/test_tiered.py)."""
import os
import tempfile

import pytest
from hypothesis import given, settings, strategies as st

from repro.caching import (BACKENDS, KeyValueCache, MmapTier,
                           backend_store_exists, open_backend,
                           registered_selectors, select_backend, split_mmap,
                           storage_identity)
from repro.caching.base import StaleCacheError
from repro.caching.mmap_tier import PACK_FILE
from repro.core import ColFrame, GenericTransformer

import numpy as np

#: every disk tier mmap may compose over (pickle cannot enumerate)
DISK_BACKENDS = ["dbm", "sqlite"]


# -- selector plumbing --------------------------------------------------------

def test_split_mmap_selector():
    assert split_mmap("mmap") == "sqlite"                # default disk
    assert split_mmap("mmap:dbm") == "dbm"
    assert split_mmap("sqlite") is None                  # not mmap
    with pytest.raises(ValueError, match="persistent"):
        split_mmap("mmap:memory")                        # no store to pack
    with pytest.raises(ValueError, match="enumerate"):
        split_mmap("mmap:pickle")                        # hashed keys only
    with pytest.raises(ValueError, match="mmap"):
        split_mmap("mmap:redis")


def test_select_backend_normalizes_and_validates():
    assert select_backend("mmap") == "mmap:sqlite"
    assert select_backend("mmap:dbm") == "mmap:dbm"
    assert select_backend("tiered") == "tiered:sqlite"
    assert select_backend(None) == "sqlite"
    assert select_backend(None, default="dbm") == "dbm"
    with pytest.raises(ValueError) as e:
        select_backend("bogus")
    # the unknown-selector error spells out every registered selector,
    # combinator forms included
    for name in registered_selectors():
        assert repr(name) in str(e.value)


def test_registered_selectors_cover_registry_and_combinators():
    names = registered_selectors()
    for base in BACKENDS:
        assert base in names
    assert "tiered:pickle" in names                      # tiered takes any
    assert "mmap:sqlite" in names and "mmap:dbm" in names
    assert "mmap:pickle" not in names                    # ... mmap does not
    assert "mmap" not in BACKENDS                        # combinator, not entry


def test_storage_identity_strips_combinators():
    assert storage_identity("mmap:sqlite") == "sqlite"
    assert storage_identity("tiered:dbm") == "dbm"
    assert storage_identity("sqlite") == "sqlite"
    assert storage_identity("bogus") == "bogus"          # caller validates
    assert storage_identity(None) is None


def test_open_backend_mmap(tmp_path):
    b = open_backend("mmap:dbm", str(tmp_path))
    assert isinstance(b, MmapTier)
    assert b.name == "mmap:dbm"
    assert b.disk.name == "dbm"
    assert b.persistent
    b.close()
    b.close()                                            # idempotent
    b2 = open_backend("mmap", str(tmp_path / "x"))
    assert b2.disk.name == "sqlite"
    b2.close()


def test_backend_store_exists_dispatches_on_disk_tier(tmp_path):
    assert not backend_store_exists("mmap:sqlite", str(tmp_path))
    b = open_backend("mmap:sqlite", str(tmp_path))
    b.put(b"k", b"v")
    b.close()
    assert backend_store_exists("mmap:sqlite", str(tmp_path))
    assert backend_store_exists("sqlite", str(tmp_path))


# -- tier semantics -----------------------------------------------------------

def test_snapshot_serves_warmed_entries(tmp_path):
    bare = open_backend("sqlite", str(tmp_path))
    bare.put_many([(b"k1", b"v1"), (b"k2", b"v2")])
    bare.close()
    t = open_backend("mmap:sqlite", str(tmp_path))
    assert os.path.exists(os.path.join(str(tmp_path), PACK_FILE))
    assert t._snap.get(b"k1") == b"v1"                   # packed at open
    assert t.get_many([b"k1", b"k2", b"nope"]) == [b"v1", b"v2", None]
    t.close()


def test_writes_go_to_disk_and_are_shadowed(tmp_path):
    t = open_backend("mmap:sqlite", str(tmp_path))
    t.put_many([(b"a", b"1")])
    assert t._snap.get(b"a") is None                     # snapshot lags ...
    assert t.get(b"a") == b"1"                           # ... reads don't
    assert t.disk.get(b"a") == b"1"
    t.refresh()
    assert t._snap.get(b"a") == b"1"                     # repack catches up
    t.close()
    bare = open_backend("sqlite", str(tmp_path))         # reopen WITHOUT tier
    assert bare.get(b"a") == b"1"
    bare.close()


def test_delete_shadows_until_refresh(tmp_path):
    t = open_backend("mmap:sqlite", str(tmp_path))
    t.put(b"k", b"v")
    t.refresh()                                          # snapshot has k
    assert t.delete_many([b"k", b"missing"]) == 1
    assert t.get(b"k") is None                           # not resurrected
    assert t.get_many([b"k"]) == [None]
    assert len(t) == 0
    t.close()


def test_foreign_writes_found_via_fall_through_then_trigger_refresh(tmp_path):
    """A key written by another process is served from disk (snapshot
    miss) and counts toward the refresh trigger."""
    t = MmapTier(str(tmp_path), disk="sqlite", refresh_after=3)
    foreign = open_backend("sqlite", str(tmp_path))      # same store files
    foreign.put_many([(b"f%d" % i, b"v%d" % i) for i in range(4)])
    refreshes0 = t.refreshes
    assert t.get(b"f0") == b"v0"                         # disk fall-through
    assert t.get(b"f1") == b"v1"
    assert t.get(b"f2") == b"v2"                         # 3rd find: repack
    assert t.refreshes == refreshes0 + 1
    assert t._snap.get(b"f3") == b"v3"                   # snapshot caught up
    foreign.close()
    t.close()


def test_misses_do_not_trigger_refresh(tmp_path):
    t = MmapTier(str(tmp_path), disk="sqlite", refresh_after=1)
    refreshes0 = t.refreshes
    assert t.get(b"nope") is None                        # true miss
    assert t.get_many([b"also-nope"]) == [None]
    assert t.refreshes == refreshes0                     # no pointless repack
    t.close()


def test_parity_views_delegate_to_disk(tmp_path):
    t = open_backend("mmap:sqlite", str(tmp_path))
    pairs = [(b"k%d" % i, b"v%d" % i) for i in range(5)]
    t.put_many(pairs)
    assert sorted(t.items()) == sorted(pairs)
    assert sorted(t.entry_stats()) == \
        sorted((k, len(v)) for k, v in pairs)
    assert t.stat_entries([b"k0", b"nope"]) == [2, None]
    t.close()


def test_lock_delegates_to_disk_and_allows_nested_puts(tmp_path):
    """The compute-once critical section must be able to write while
    held (the kv miss path runs put_many inside lock())."""
    t = open_backend("mmap:sqlite", str(tmp_path))
    with t.lock():
        with t.lock():                                   # re-entrant
            t.put(b"k", b"v")
    assert t.get(b"k") == b"v"
    t.close()


# -- cache families over the mmap selector ------------------------------------

def _expander():
    return GenericTransformer(
        lambda inp: inp.assign(query=np.array(
            [q + "!" for q in inp["query"].tolist()], dtype=object)),
        "expander", key_columns=("qid", "query"), value_columns=("query",))


TOPICS = ColFrame({"qid": [f"q{i}" for i in range(6)],
                   "query": [f"terms {i}" for i in range(6)]})


def test_kv_cache_over_mmap_backend(tmp_path):
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="mmap:sqlite") as kv:
        assert kv._manifest.backend == "mmap:sqlite"
        cold = kv(TOPICS)
        assert kv.stats.misses == len(TOPICS)
        hot = kv(TOPICS)
        assert kv.stats.hits == len(TOPICS)
        direct = _expander()(TOPICS)
        assert cold.equals(direct) and hot.equals(direct)
    # a fresh open over the same dir replays from the packed snapshot
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="mmap:sqlite") as kv2:
        assert kv2(TOPICS).equals(_expander()(TOPICS))
        assert kv2.stats.misses == 0


def test_storage_identity_relaxes_manifest_staleness(tmp_path):
    """Combinators are pure accelerators over the same store files, so
    warming with ``sqlite`` and serving with ``mmap:sqlite`` (the fleet
    deployment pattern) is NOT a backend mismatch — but a different
    disk store still is."""
    t = _expander()
    with KeyValueCache(str(tmp_path), t, key=("qid", "query"),
                       value=("query",), backend="sqlite") as kv:
        kv(TOPICS)
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="mmap:sqlite") as kv2:
        assert kv2(TOPICS).equals(_expander()(TOPICS))
        assert kv2.stats.misses == 0                     # warm, not stale
    with pytest.raises(StaleCacheError, match="backend"):
        KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                      value=("query",), backend="dbm")


# -- observational equivalence (property test) --------------------------------

_OPS = st.lists(
    st.tuples(st.integers(0, 3),          # 0/1: put, 2: delete, 3: get
              st.integers(0, 9),          # key id (small space -> collisions)
              st.integers(0, 99)),        # value id
    min_size=1, max_size=40)


def _apply(backend, ops):
    """Drive one op sequence, returning every observable result."""
    seen = []
    for op, k, v in ops:
        key = b"key-%d" % k
        if op in (0, 1):
            backend.put_many([(key, b"val-%d" % v)])
        elif op == 2:
            seen.append(("del", backend.delete_many([key])))
        else:
            seen.append(("get", backend.get(key)))
    keys = [b"key-%d" % i for i in range(10)]
    seen.append(("get_many", backend.get_many(keys)))
    seen.append(("len", len(backend)))
    return seen


@given(ops=_OPS)
@settings(max_examples=15, deadline=None)
def test_mmap_observationally_equivalent_to_bare_disk(ops):
    """For any put/get/delete sequence, an MmapTier over disk backend X
    is indistinguishable from X alone — including after a close/reopen
    cycle (the snapshot must add speed, never state).  A tiny
    ``refresh_after`` maximizes mid-sequence repacks."""
    for disk in DISK_BACKENDS:
        _check_equivalence(disk, ops)


def _check_equivalence(disk, ops):
    with tempfile.TemporaryDirectory(prefix="mmap-prop-") as tmp:
        p_mmap = os.path.join(tmp, "mmap")
        p_bare = os.path.join(tmp, "bare")
        os.makedirs(p_mmap)
        t = MmapTier(p_mmap, disk=disk, refresh_after=2)
        b = open_backend(disk, p_bare)
        try:
            assert _apply(t, ops) == _apply(b, ops)
        finally:
            t.close()
            b.close()
        # reopen both: the surviving state must match too
        t2 = MmapTier(p_mmap, disk=disk, refresh_after=2)
        b2 = open_backend(disk, p_bare)
        try:
            keys = [b"key-%d" % i for i in range(10)]
            assert t2.get_many(keys) == b2.get_many(keys)
            assert len(t2) == len(b2)
            assert _apply(t2, ops) == _apply(b2, ops)
        finally:
            t2.close()
            b2.close()
