"""Explicit caches (paper §4): transparency invariant, hit/miss
accounting, persistence, temporary-mode cleanup, miss→raise, Lazy,
Artifact sharing, determinism verification."""
import os
import tempfile

import numpy as np
import pytest

from repro.caching import (CacheMissError, DenseScorerCache, IndexerCache,
                           KeyValueCache, Lazy, RetrieverCache, ScorerCache,
                           from_hub, to_hub)
from repro.core import ColFrame, GenericTransformer, add_ranks
from repro.ir import InvertedIndex, QueryExpander, msmarco_like

CORPUS = msmarco_like(1, scale=0.04)
INDEX = InvertedIndex.build(CORPUS.get_corpus_iter())
TOPICS = CORPUS.get_topics()


class CountingScorer(GenericTransformer):
    def __init__(self):
        self.calls = 0
        def fn(inp):
            self.calls += len(inp)
            s = np.array([float(len(str(d)) % 7) + float(str(q)[-1] == "1")
                          for q, d in zip(inp["query"].tolist(),
                                          inp["docno"].tolist())])
            return inp.assign(score=s)
        super().__init__(fn, "counting_scorer",
                         key_columns=("query", "docno"),
                         value_columns=("score",))


@pytest.fixture
def results():
    return INDEX.bm25(num_results=20)(TOPICS)


# -- KeyValueCache -----------------------------------------------------------

def test_kv_cache_hot_cold_and_transparency():
    qe = QueryExpander(2)
    with KeyValueCache(None, qe, key=("qid", "query"),
                       value=("query",)) as kv:
        cold = kv(TOPICS)
        assert kv.stats.misses == len(TOPICS)
        hot = kv(TOPICS)
        assert kv.stats.hits == len(TOPICS)
        direct = qe(TOPICS)
        assert cold.equals(direct) and hot.equals(direct)


def test_kv_cache_persists_across_instances(tmp_path):
    qe = QueryExpander(2)
    p = str(tmp_path / "kv")
    with KeyValueCache(p, qe, key=("qid", "query"), value=("query",)) as kv:
        kv(TOPICS)
    with KeyValueCache(p, qe, key=("qid", "query"), value=("query",)) as kv2:
        kv2(TOPICS)
        assert kv2.stats.hits == len(TOPICS)
        assert kv2.stats.misses == 0


def test_kv_cache_rejects_non_rowwise():
    bad = GenericTransformer(lambda inp: inp.head(1), "bad")
    with KeyValueCache(None, bad, key=("qid",), value=("query",)) as kv:
        with pytest.raises(ValueError, match="row-wise"):
            kv(TOPICS)


def test_temporary_cache_cleanup():
    qe = QueryExpander(2)
    kv = KeyValueCache(None, qe, key=("qid",), value=("query",))
    path = kv.path
    assert os.path.isdir(path)
    kv.close()
    assert not os.path.isdir(path)


# -- ScorerCache -------------------------------------------------------------

def test_scorer_cache_shares_across_retrievers(results):
    """Paper §4.2: 'Will only compute scores for docnos that were not
    returned by bm25' — the second pipeline reuses overlapping pairs."""
    scorer = CountingScorer()
    with ScorerCache(None, scorer) as sc:
        out1 = sc(results)
        calls_after_first = scorer.calls
        sc(results)                          # fully cached
        assert scorer.calls == calls_after_first
        # overlapping but different candidate set
        shallow = INDEX.bm25(num_results=10)(TOPICS)
        sc(shallow)
        assert scorer.calls == calls_after_first   # subset => no new work
        assert "rank" in out1.columns
        direct = add_ranks(scorer(results))
        assert out1.equals(direct, cols=["qid", "docno", "score", "rank"])


def test_scorer_cache_reassigns_ranks(results):
    scorer = CountingScorer()
    with ScorerCache(None, scorer) as sc:
        out = sc(results)
        for (_,), idx in out.group_indices(["qid"]).items():
            ranks = sorted(out["rank"][idx].tolist())
            assert ranks == list(range(len(idx)))


def test_dense_scorer_cache_matches_sqlite(results):
    s1, s2 = CountingScorer(), CountingScorer()
    with ScorerCache(None, s1) as sc, \
         DenseScorerCache(None, s2,
                          docnos=CORPUS.docs["docno"].tolist()) as dc:
        a = sc(results)
        b = dc(results)
        assert a.equals(b, cols=["qid", "docno", "score", "rank"])
        b2 = dc(results)
        assert s2.calls == len(results)       # second pass fully cached
        assert b2.equals(b)


def test_dense_scorer_cache_grows_rows(results):
    s = CountingScorer()
    with DenseScorerCache(None, s, docnos=CORPUS.docs["docno"].tolist()) \
            as dc:
        dc.GROW = 2
        dc(results)        # > 2 distinct queries forces growth
        assert len(dc._query_rows) == len(set(TOPICS["qid"].tolist()))


# -- RetrieverCache ----------------------------------------------------------

def test_retriever_cache_round_trip():
    bm25 = INDEX.bm25(num_results=15)
    with RetrieverCache(None, bm25) as rc:
        cold = rc(TOPICS)
        hot = rc(TOPICS)
        assert rc.stats.hits == len(TOPICS)
        direct = bm25(TOPICS)
        assert cold.equals(direct, cols=["qid", "docno", "score", "rank"])
        assert hot.equals(direct, cols=["qid", "docno", "score", "rank"])


def test_retriever_cache_partial_hits():
    bm25 = INDEX.bm25(num_results=5)
    with RetrieverCache(None, bm25) as rc:
        rc(TOPICS.head(3))
        rc(TOPICS)
        assert rc.stats.hits == 3
        assert rc.stats.misses == len(TOPICS) + 0


# -- IndexerCache ------------------------------------------------------------

def test_indexer_cache_preserves_order_and_forward_index():
    with IndexerCache(None) as ic:
        ic.index(CORPUS.get_corpus_iter())
        replay = list(ic)
        orig = list(CORPUS.get_corpus_iter())
        assert [r["docno"] for r in replay] == [r["docno"] for r in orig]
        some = orig[7]
        assert ic.get(some["docno"])["text"] == some["text"]
        # build a real index from the cached stream (paper §4.4 usage)
        idx2 = InvertedIndex.build(ic)
        assert idx2.n_docs == len(orig)


def test_indexer_cache_as_text_loader():
    with IndexerCache(None) as ic:
        ic.index(CORPUS.get_corpus_iter())
        frame = ColFrame({"qid": ["q"], "docno":
                          [CORPUS.docs["docno"][0]]})
        out = ic(frame)
        assert out["text"][0] == CORPUS.docs["text"][0]


# -- miss -> raise, Lazy ------------------------------------------------------

def test_cache_miss_error_without_transformer(results):
    with ScorerCache(None) as sc:
        with pytest.raises(CacheMissError):
            sc(results)


def test_lazy_constructs_once_and_only_when_needed(results):
    built = []
    def factory():
        built.append(1)
        return CountingScorer()
    lazy = Lazy(factory, name="lazy_scorer")
    with ScorerCache(None, lazy) as sc:
        assert not lazy.constructed
        sc(results)
        assert lazy.constructed and len(built) == 1
        sc(results)
        assert len(built) == 1


def test_lazy_never_constructed_on_full_hit(results):
    scorer = CountingScorer()
    with ScorerCache(None, scorer) as warm:
        warm(results)
        path = warm.path
        warm._temporary = False      # keep dir for the second instance
    built = []
    lazy = Lazy(lambda: (built.append(1), CountingScorer())[1])
    with ScorerCache(path, lazy) as sc:
        sc(results)
        assert built == []           # hot cache -> model never built
    import shutil
    shutil.rmtree(path, ignore_errors=True)


# -- determinism verification (beyond paper §6) -------------------------------

def test_verify_mode_catches_nondeterminism(results):
    calls = {"n": 0}
    def fn(inp):
        calls["n"] += 1
        s = np.arange(len(inp), dtype=np.float64) + calls["n"] * 100
        return inp.assign(score=s)
    flaky = GenericTransformer(fn, "flaky", key_columns=("query", "docno"),
                               value_columns=("score",))
    with ScorerCache(None, flaky, verify_fraction=1.0) as sc:
        sc(results)
        with pytest.raises(AssertionError, match="determinism"):
            sc(results)


# -- Artifact API --------------------------------------------------------------

def test_artifact_hub_roundtrip(tmp_path, results, monkeypatch):
    monkeypatch.setenv("REPRO_HUB", str(tmp_path / "hub"))
    scorer = CountingScorer()
    with ScorerCache(None, scorer) as sc:
        sc(results)
        sc.to_hf("grp/scores")
    local = from_hub("grp/scores")
    fresh = CountingScorer()
    with ScorerCache(local, fresh) as sc2:
        sc2(results)
        assert fresh.calls == 0            # fully served from the artifact
        assert sc2.stats.hit_rate == 1.0
