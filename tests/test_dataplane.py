"""Asynchronous cache data plane (caching/dataplane.py, caching/codecs.py):
vectorized key building bit-identical to the scalar reference, columnar
codec roundtrips and per-directory negotiation, staging-map pop-once /
in-flight-wait semantics, write-behind overlay durability (readable
before flush, durable after, recompute-never-corrupt after a SIGKILL
inside the pre-flush window), and query-keyed prefetch preserving
per-qid bit-identity and honest hit/miss accounting under all three
executors."""
import os
import signal
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.caching import (KV_CODEC, RETRIEVER_CODEC, CacheManifest,
                           KeyValueCache, RetrieverCache, StagingMap,
                           StaleCacheError, WriteBehindWriter, scalar_key,
                           vector_keys)
from repro.caching.codecs import (decode_columnar_frame, decode_kv_batch,
                                  decode_kv_value, encode_columnar_frame,
                                  encode_kv_value)
from repro.core import ColFrame, ExecutionPlan, GenericTransformer, add_ranks

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SUBPROC_ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO_ROOT, "src")}

QUERIES = ColFrame({"qid": ["q1", "q2", "q3"],
                    "query": ["alpha", "beta", "gamma"]})
SORT = ["qid", "docno"]


class CountingStage(GenericTransformer):
    def __init__(self, name, fn=None, **kw):
        self.calls = 0

        def wrapped(inp, _fn=fn):
            self.calls += 1
            return _fn(inp) if _fn else inp
        super().__init__(wrapped, name, **kw)


def make_cacheable_retriever(name="R", n=4):
    def retr_fn(inp):
        rows = []
        for qid, query in zip(inp["qid"].tolist(), inp["query"].tolist()):
            for i in range(n):
                rows.append({"qid": qid, "query": query,
                             "docno": f"{name}_d{i}",
                             "score": 9.0 - i + 0.125 * len(query)})
        return add_ranks(ColFrame.from_dicts(rows))
    return CountingStage(name, retr_fn,
                         one_to_many=True, key_columns=("qid", "query"))


# -- vectorized key building (satellite: _keys_of hot path) -------------------

_COL_KINDS = st.sampled_from(["int", "float", "str"])


def _column_for(kind, n, rng_seed):
    rng = np.random.default_rng(rng_seed)
    if kind == "int":
        return rng.integers(-10**9, 10**9, size=n).astype(np.int64)
    if kind == "float":
        vals = rng.standard_normal(n) * 1e3
        vals[rng.random(n) < 0.1] = 0.0
        return vals.astype(np.float64)
    lens = rng.integers(0, 12, size=n)
    col = np.empty(n, dtype=object)
    col[:] = ["".join(chr(97 + int(c)) for c in rng.integers(0, 26, size=l))
              for l in lens]
    return col


@settings(max_examples=40, deadline=None)
@given(kinds=st.lists(_COL_KINDS, min_size=1, max_size=3),
       n=st.integers(min_value=1, max_value=64),
       seed=st.integers(min_value=0, max_value=2**16))
def test_vector_keys_match_scalar_reference(kinds, n, seed):
    """The vectorized digest must be bit-identical to the scalar
    reference for every row — this is what keeps warm dirs warm."""
    cols = [_column_for(k, n, seed + i) for i, k in enumerate(kinds)]
    vec = vector_keys(cols)
    assert len(vec) == n and all(len(k) == 16 * len(cols) for k in vec)
    for r in range(n):
        values = [c[r] for c in cols]
        dkinds = [np.asarray(c).dtype.kind if c.dtype != object else "O"
                  for c in cols]
        assert vec[r] == scalar_key(values, dkinds)


def test_vector_keys_batch_composition_independent():
    """A row's key must not depend on what other rows share the batch
    (masked per-position fold) — otherwise re-batching would miss."""
    qids = np.array(["q1", "q22", "q333", "q4444"], dtype=object)
    scores = np.array([1.5, -2.0, 0.0, 1e12])
    full = vector_keys([qids, scores])
    for i in range(4):
        alone = vector_keys([qids[i:i + 1], scores[i:i + 1]])
        assert alone[0] == full[i]
    # and distinct rows get distinct keys
    assert len(set(full)) == 4


def test_vector_keys_cross_scalar_fallback_boundary():
    """Batches wider than the vector width fall back to per-row scalar
    digests — both paths must produce the same bytes."""
    from repro.caching import codecs
    n = 32
    col = np.arange(n).astype(np.int64)
    wide = vector_keys([col])
    try:
        codecs._MAX_VECTOR_WIDTH, saved = 8, codecs._MAX_VECTOR_WIDTH
        narrow = vector_keys([col])
    finally:
        codecs._MAX_VECTOR_WIDTH = saved
    assert wide == narrow


# -- value codecs -------------------------------------------------------------

def test_kv_value_codec_roundtrips():
    for vals in [(1.5,), (0.0, -3.25, 1e-300), ("text", 2.0), (None,),
                 (np.float64(7.125), np.int64(3))]:
        got = decode_kv_value(encode_kv_value(vals))
        assert len(got) == len(vals)
        for g, v in zip(got, vals):
            if isinstance(v, (float, np.floating, int, np.integer)) \
                    and not isinstance(v, bool):
                assert float(g) == float(v)      # exact: bit-identity
            else:
                assert g == v


def test_kv_batch_decode_all_float_fast_path():
    blobs = [encode_kv_value((1.5, -2.25)), encode_kv_value((0.0, 1e9))]
    mat = decode_kv_batch(blobs, 2)
    assert mat is not None and mat.shape == (2, 2)
    assert mat.tolist() == [[1.5, -2.25], [0.0, 1e9]]
    # one pickled value disables the fast path (None, not garbage)
    assert decode_kv_batch([blobs[0], encode_kv_value(("s", 1.0))], 2) is None
    assert decode_kv_batch(blobs, 3) is None     # column-count mismatch


def test_columnar_frame_roundtrip_bit_identity():
    n = 7
    cols = [
        ("qid", np.array(["q1"] * n, dtype=object)),
        ("docno", np.array([f"d{i}" for i in range(n)], dtype=object)),
        ("score", np.linspace(-1.0, 1.0, n) * np.pi),
        ("rank", np.arange(n, dtype=np.int64)),
    ]
    out = decode_columnar_frame(encode_columnar_frame(cols, n))
    assert set(out) == {"qid", "docno", "score", "rank"}
    # floats roundtrip bit-for-bit (float64 preserved, no f32 cast)
    assert out["score"].tobytes() == cols[2][1].tobytes()
    assert out["rank"].tolist() == list(range(n))
    assert out["docno"].tolist() == [f"d{i}" for i in range(n)]


# -- codec negotiation via the manifest ---------------------------------------

def _strip_codec(dirpath):
    m = CacheManifest.load(dirpath)
    m.codec = None
    m.save(dirpath)


def test_fresh_dir_records_codec(tmp_path):
    c = KeyValueCache(str(tmp_path / "kv"), lambda f: f.assign(
        out=f["text"].astype(object)), key="text", value="out")
    assert c.codec == KV_CODEC
    c.close()
    assert CacheManifest.load(str(tmp_path / "kv")).codec == KV_CODEC
    r = RetrieverCache(str(tmp_path / "ret"), make_cacheable_retriever())
    assert r.codec == RETRIEVER_CODEC
    r.close()


def test_legacy_dir_without_codec_stays_warm_on_pickle(tmp_path):
    """A directory whose manifest predates the codec field keeps its
    pickled keys/values forever — reopening must hit, not re-key."""
    path = str(tmp_path / "kv")
    upper = GenericTransformer(
        lambda f: f.assign(out=np.array(
            [t.upper() for t in f["text"].tolist()], dtype=object)), "U")
    frame = ColFrame({"text": ["a", "b", "c"]})
    c1 = KeyValueCache(path, upper, key="text", value="out")
    c1.close()
    _strip_codec(path)                   # simulate a pre-codec build
    c2 = KeyValueCache(path, upper, key="text", value="out")
    assert c2.codec is None
    c2.transform(frame)
    assert c2.stats.misses == 3
    c2.close()
    c3 = KeyValueCache(path, upper, key="text", value="out")
    assert c3.codec is None              # negotiation sticks to legacy
    out = c3.transform(frame)
    assert c3.stats.hits == 3 and c3.stats.misses == 0
    assert out["out"].tolist() == ["A", "B", "C"]
    c3.close()


def test_unknown_codec_is_stale(tmp_path):
    path = str(tmp_path / "kv")
    KeyValueCache(path, lambda f: f, key="text", value="text").close()
    m = CacheManifest.load(path)
    m.codec = "kv-quantum-42"            # from a future build
    m.save(path)
    with pytest.raises(StaleCacheError, match="codec"):
        KeyValueCache(path, lambda f: f, key="text", value="text")
    # recompute policy wipes and renegotiates the current codec
    c = KeyValueCache(path, lambda f: f, key="text", value="text",
                      on_stale="recompute")
    assert c.codec == KV_CODEC
    c.close()


# -- staging map --------------------------------------------------------------

def test_staging_map_pop_once_and_none_misses():
    s = StagingMap()
    s.deposit([(b"k1", b"v1"), (b"k2", None)])
    assert len(s) == 2
    got = s.pop_many([b"k1", b"k2", b"k3"])
    assert got == {b"k1": b"v1", b"k2": None}    # staged miss is a result
    assert s.pop_many([b"k1"]) == {}             # consumed at most once
    s.deposit([(b"k4", b"v4")])
    s.discard()
    assert s.pop_many([b"k4"]) == {}


def test_staging_map_covered_dedups_inflight():
    from concurrent.futures import Future
    s = StagingMap()
    s.deposit([(b"a", b"1")])
    fut = Future()
    s.track(fut, [b"b"])
    assert s.covered([b"a", b"b", b"c"]) == [b"c"]
    fut.set_result(None)                 # done callback untracks
    assert s.covered([b"b"]) == [b"b"]


def test_staging_map_pop_waits_for_inflight_fetch():
    from concurrent.futures import Future
    s = StagingMap()
    fut = Future()
    s.track(fut, [b"k"])

    def land():
        time.sleep(0.05)
        s.deposit([(b"k", b"v")])
        fut.set_result(None)

    t = threading.Thread(target=land)
    t.start()
    try:
        assert s.pop_many([b"k"]) == {b"k": b"v"}   # waited, no re-read
    finally:
        t.join()


# -- write-behind writer ------------------------------------------------------

class _RecordingStore:
    def __init__(self, fail_times=0):
        self.rows = {}
        self.calls = 0
        self.fail_times = fail_times

    def put_many(self, items):
        self.calls += 1
        if self.fail_times > 0:
            self.fail_times -= 1
            raise OSError("transient store failure")
        self.rows.update(items)


def test_write_behind_overlay_readable_until_durable(monkeypatch):
    monkeypatch.setenv("REPRO_WRITE_BEHIND_HOLD", "1")
    store = _RecordingStore()
    w = WriteBehindWriter(store.put_many)
    w.put([(b"k1", b"v1"), (b"k2", b"v2")])
    assert w.pending == 2 and store.rows == {}     # held: nothing durable
    assert w.overlay_many([b"k1", b"k3"]) == {b"k1": b"v1"}
    assert w.barrier() is None and store.rows == {}   # barrier honors HOLD
    w.flush()
    assert store.rows == {b"k1": b"v1", b"k2": b"v2"}
    assert w.pending == 0 and w.overlay_many([b"k1"]) == {}
    w.close()
    with pytest.raises(RuntimeError):
        w.put([(b"k3", b"v3")])


def test_write_behind_failed_flush_keeps_entries_pending(monkeypatch):
    monkeypatch.setenv("REPRO_WRITE_BEHIND_HOLD", "1")
    store = _RecordingStore(fail_times=1)
    w = WriteBehindWriter(store.put_many)
    w.put([(b"k", b"v")])
    with pytest.raises(OSError):
        w.flush()
    # the entry stays readable and re-flushable — never silently lost
    assert w.pending == 1 and w.overlay_many([b"k"]) == {b"k": b"v"}
    w.flush()
    assert store.rows == {b"k": b"v"}


def test_write_behind_last_value_wins_and_order_preserved():
    store = _RecordingStore()
    w = WriteBehindWriter(store.put_many)
    w._hold = True                       # deterministic pending state
    w.put([(b"k", b"v1")])
    w.put([(b"k", b"v2"), (b"j", b"w")])
    assert w.pending == 2                # rewrite coalesced in place
    w.flush()
    assert store.rows == {b"k": b"v2", b"j": b"w"}


def test_kv_cache_async_writes_threads_compute_exactly_once(tmp_path):
    calls = []

    def upper(f):
        calls.extend(f["text"].tolist())
        return f.assign(out=np.array(
            [t.upper() for t in f["text"].tolist()], dtype=object))

    c = KeyValueCache(str(tmp_path / "kv"), GenericTransformer(upper, "U"),
                      key="text", value="out", async_writes=True)
    frame = ColFrame({"text": [f"t{i}" for i in range(8)]})
    outs = [None] * 4

    def run(slot):
        outs[slot] = c.transform(frame)

    threads = [threading.Thread(target=run, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(calls) == sorted(f"t{i}" for i in range(8))   # once each
    for o in outs:
        assert o["out"].tolist() == [f"T{i}" for i in range(8)]
    c.close()
    warm = KeyValueCache(str(tmp_path / "kv"), GenericTransformer(upper, "U"),
                         key="text", value="out")
    warm.transform(frame)
    assert warm.stats.hits == 8          # every write became durable
    warm.close()


# -- prefetch: bit-identity + attribution across executors --------------------

def _run_plan(tmp_path, *, prefetch, run_kw=None):
    retr = make_cacheable_retriever()
    boost = CountingStage("boost", lambda f: add_ranks(
        f.assign(score=f["score"] * 2.0)))
    pipelines = [retr % 3, retr >> boost]
    with ExecutionPlan(pipelines, cache_dir=str(tmp_path),
                       prefetch=prefetch) as plan:
        outs, stats = plan.run(QUERIES, **(run_kw or {}))
    return outs, stats


@pytest.mark.parametrize("run_kw", [
    pytest.param(None, id="sequential"),
    pytest.param({"n_shards": 3, "max_workers": 3}, id="concurrent"),
])
def test_prefetch_bit_identity_and_attribution(tmp_path, run_kw):
    """Warm runs with prefetch on vs off must be bit-identical per qid
    and report identical hit/miss counts; prefetched hits attribute to
    the consuming node (CacheStats.prefetched ≤ hits, > 0 when on)."""
    cold_outs, cold = _run_plan(tmp_path, prefetch=True, run_kw=run_kw)
    assert cold.cache_misses == len(QUERIES) and cold.cache_hits == 0
    assert cold.cache_prefetched == 0    # misses are never "prefetched"

    on_outs, on = _run_plan(tmp_path, prefetch=True, run_kw=run_kw)
    off_outs, off = _run_plan(tmp_path, prefetch=False, run_kw=run_kw)
    assert on.cache_hits == off.cache_hits == len(QUERIES)
    assert on.cache_misses == off.cache_misses == 0
    assert on.cache_prefetched > 0       # staged entries actually served
    assert on.cache_prefetched <= on.cache_hits
    assert off.cache_prefetched == 0
    for got, want, base in zip(on_outs, off_outs, cold_outs):
        cols = ["qid", "docno", "score", "rank"]
        assert got.sort_values(SORT).equals(
            want.sort_values(SORT), cols=cols, rtol=0, atol=0)
        assert got.sort_values(SORT).equals(
            base.sort_values(SORT), cols=cols, rtol=0, atol=0)


def test_prefetch_streaming_service_bit_identity(tmp_path):
    """The streaming executor (PipelineService) prefetches at submit
    time; warm results must match the offline run bit for bit and the
    service's plan stats must attribute the prefetched hits."""
    from repro.serve import PipelineService
    retr = make_cacheable_retriever()
    pipeline = retr % 3
    offline = pipeline(QUERIES)
    with ExecutionPlan([pipeline], cache_dir=str(tmp_path)) as plan:
        plan.run(QUERIES)                # warm the store

    results = {}
    for prefetch in (True, False):
        svc = PipelineService(pipeline, cache_dir=str(tmp_path),
                              prefetch=prefetch, max_wait_ms=0.0)
        try:
            results[prefetch] = svc.search(QUERIES)
            stats = svc.plan_stats()
            if prefetch:
                assert stats.cache_prefetched > 0
            else:
                assert stats.cache_prefetched == 0
        finally:
            svc.close()
    cols = ["qid", "docno", "score", "rank"]
    for frame in results.values():
        assert frame.sort_values(SORT).equals(
            offline.sort_values(SORT), cols=cols, rtol=0, atol=0)


def test_prefetch_kill_switch(tmp_path, monkeypatch):
    _run_plan(tmp_path, prefetch=True)   # cold
    monkeypatch.setenv("REPRO_PREFETCH", "0")
    _, stats = _run_plan(tmp_path, prefetch=True)
    assert stats.cache_hits == len(QUERIES)
    assert stats.cache_prefetched == 0   # env veto beats the plan kwarg


# -- crash consistency (satellite: SIGKILL before flush) ----------------------

_CRASH_SCRIPT = textwrap.dedent("""\
    import sys, time
    from repro.core import ColFrame, ExecutionPlan, GenericTransformer, \\
        add_ranks

    def retr(inp):
        rows = [{"qid": q, "query": t, "docno": f"d{i}", "score": 5.0 - i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(3)]
        return add_ranks(ColFrame.from_dicts(rows))

    a = GenericTransformer(retr, "A", one_to_many=True,
                           key_columns=("qid", "query"))
    Q = ColFrame({"qid": ["q1", "q2"], "query": ["x", "y"]})
    if sys.argv[2] == "crash":
        plan = ExecutionPlan([a % 2], cache_dir=sys.argv[1])
        _, stats = plan.run(Q)
        assert stats.cache_misses == 2, stats.cache_misses
        print("READY", flush=True)
        time.sleep(60)                   # killed here — before any flush
    else:
        with ExecutionPlan([a % 2], cache_dir=sys.argv[1]) as plan:
            _, s1 = plan.run(Q)
            _, s2 = plan.run(Q)
        print(s1.cache_hits, s1.cache_misses,
              s2.cache_hits, s2.cache_misses)
""")


def test_sigkill_before_flush_recomputes_never_corrupts(tmp_path):
    """Kill a worker inside the pre-flush window (REPRO_WRITE_BEHIND_HOLD
    keeps every put pending): the store must verify clean, the entries
    recompute on reopen, and nothing double-counts."""
    from repro.cli import main as cli_main
    env = {**SUBPROC_ENV, "REPRO_WRITE_BEHIND_HOLD": "1"}
    proc = subprocess.Popen(
        [sys.executable, "-c", _CRASH_SCRIPT, str(tmp_path), "crash"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)
    try:
        line = proc.stdout.readline()
        assert line.strip() == "READY", (line, proc.stderr.read())
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
    # 1) the directory is verifiable — crash lost entries, corrupted none
    assert cli_main(["cache", "verify", str(tmp_path)]) == 0
    # 2) a fresh process recomputes exactly the lost entries, then hits
    p = subprocess.run(
        [sys.executable, "-c", _CRASH_SCRIPT, str(tmp_path), "reopen"],
        capture_output=True, text=True, env=SUBPROC_ENV, timeout=120)
    assert p.returncode == 0, p.stderr[-2000:]
    assert p.stdout.split() == ["0", "2", "2", "0"]
    assert cli_main(["cache", "verify", str(tmp_path)]) == 0


@pytest.mark.slow
def test_fleet_worker_sigkill_leaves_store_verifiable(tmp_path):
    """Fleet variant: SIGKILL one worker mid-service, finish the run on
    the survivors, and the shared cache directory still verifies."""
    from repro.cli import main as cli_main
    from repro.serve import FleetService, ServeConfig
    cfg = ServeConfig(pipeline="bm25", scale=0.02, cutoff=5, num_results=10,
                      seed=0, max_batch=4, max_wait_ms=0.0, exec_workers=1,
                      warm_start=False, workers=2, cache_dir=str(tmp_path))
    scenario = cfg.build_scenario()
    qids = [str(q) for q in scenario.topics["qid"].tolist()]
    queries = scenario.topics["query"].tolist()
    with FleetService(cfg) as svc:
        first = [svc.submit(q, t) for q, t in zip(qids[:3], queries[:3])]
        assert all(f.result(120) is not None for f in first)
        svc.kill_worker()                # chaos: pending writes die with it
        rest = [svc.submit(q, t) for q, t in zip(qids[3:6], queries[3:6])]
        assert all(f.result(120) is not None for f in rest)
        svc.drain()                      # survivors flush + refresh manifests
    assert cli_main(["cache", "verify", str(tmp_path)]) == 0
