"""Declarative experiments (paper §2.2) + §3 precompute integration."""
import numpy as np
import pytest

from repro.core import ColFrame, Experiment, GenericTransformer, add_ranks
from repro.ir import InvertedIndex, msmarco_like

CORPUS = msmarco_like(1, scale=0.04)
INDEX = InvertedIndex.build(CORPUS.get_corpus_iter())
BM25 = INDEX.bm25(num_results=50)


def test_experiment_basic_table():
    res = Experiment([BM25 % 10, BM25 % 30],
                     CORPUS.get_topics(), CORPUS.get_qrels(),
                     ["nDCG@10", "MAP", "R@30"])
    assert len(res.names) == 2
    for n in res.names:
        assert 0 <= res.means[n]["nDCG@10"] <= 1
    # deeper cutoff can only improve recall
    assert res.means[res.names[1]]["R@30"] >= \
        res.means[res.names[0]]["R@30"] - 1e-12


def test_experiment_precompute_matches_naive():
    systems = [BM25 % k for k in (5, 10, 20)]
    naive = Experiment(systems, CORPUS.get_topics(), CORPUS.get_qrels(),
                       ["nDCG@10", "MAP"])
    pre = Experiment(systems, CORPUS.get_topics(), CORPUS.get_qrels(),
                     ["nDCG@10", "MAP"], precompute_prefix=True)
    trie = Experiment(systems, CORPUS.get_topics(), CORPUS.get_qrels(),
                      ["nDCG@10", "MAP"], precompute_prefix=True,
                      precompute_mode="trie")
    for n1, n2, n3 in zip(naive.names, pre.names, trie.names):
        for m in ("nDCG@10", "MAP"):
            assert naive.means[n1][m] == pytest.approx(pre.means[n2][m])
            assert naive.means[n1][m] == pytest.approx(trie.means[n3][m])
    assert pre.precompute.prefix_len == 1
    assert pre.precompute.stage_invocations_saved == 2


def test_significance_machinery():
    topics, qrels = CORPUS.get_topics(), CORPUS.get_qrels()
    res = Experiment([BM25 % 10, BM25 % 10, BM25 % 2],
                     topics, qrels, ["nDCG@10"], baseline=0,
                     names=["base", "same", "worse"], correction="holm")
    # identical system vs itself: p == 1
    assert res.pvalues["same"]["nDCG@10"] == pytest.approx(1.0)
    assert 0.0 <= res.pvalues["worse"]["nDCG@10"] <= 1.0
    # corrected p >= raw p
    assert res.corrected_pvalues["worse"]["nDCG@10"] >= \
        res.pvalues["worse"]["nDCG@10"] - 1e-12


def test_batch_size_does_not_change_results():
    sys_ = [BM25 % 10]
    full = Experiment(sys_, CORPUS.get_topics(), CORPUS.get_qrels(),
                      ["MAP"])
    batched = Experiment(sys_, CORPUS.get_topics(), CORPUS.get_qrels(),
                         ["MAP"], batch_size=7)
    assert full.means[full.names[0]]["MAP"] == \
        pytest.approx(batched.means[batched.names[0]]["MAP"])


def test_ttest_against_scipy():
    from repro.core.experiment import _paired_ttest, _betainc
    from scipy import stats
    rng = np.random.default_rng(0)
    for _ in range(5):
        a = rng.normal(size=20)
        b = a + rng.normal(scale=0.3, size=20) + 0.1
        ours = _paired_ttest(a, b)
        ref = stats.ttest_rel(a, b).pvalue
        assert ours == pytest.approx(ref, rel=1e-6)
    # the stdlib fallback agrees with scipy's betainc
    from scipy import special
    for (aa, bb, xx) in [(5, 0.5, 0.3), (9.5, 0.5, 0.8), (2, 2, 0.5)]:
        assert _betainc(aa, bb, xx) == pytest.approx(
            special.betainc(aa, bb, xx), rel=1e-6)


def test_correction_methods():
    from repro.core.experiment import _correct
    ps = [0.01, 0.04, 0.03]
    bonf = _correct(ps, "bonferroni")
    assert bonf == pytest.approx([0.03, 0.12, 0.09])
    holm = _correct(ps, "holm")
    assert holm[0] == pytest.approx(0.03)
    assert all(h <= b + 1e-12 for h, b in zip(holm, bonf))
