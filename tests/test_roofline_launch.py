"""Launch-layer units that run WITHOUT the 512-device env: the roofline
HLO parser, model-FLOPs formulas, mesh factory contracts, and the
grouped-MoE / repeat-KV optimized paths' numerics."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   RooflineReport, derive_terms,
                                   apply_layer_correction,
                                   gnn_model_flops, lm_model_flops,
                                   parse_collective_bytes,
                                   recsys_model_flops)


HLO = """
ENTRY main {
  %p0 = bf16[1024,512]{1,0} parameter(0)
  %ag = bf16[16384,512]{1,0} all-gather(%p0), dimensions={0}
  %ar.1 = f32[256,128]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[16,128]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = (bf16[8,64]{1,0}, bf16[8,64]{1,0}) all-to-all(%z, %w)
  %cp-start = bf16[32,32]{1,0} collective-permute-start(%q)
  %cp-done = bf16[32,32]{1,0} collective-permute-done(%cp-start)
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_parse_collective_bytes():
    out = parse_collective_bytes(HLO)
    assert out["all-gather"] == 16384 * 512 * 2
    assert out["all-reduce"] == 256 * 128 * 4
    assert out["reduce-scatter"] == 16 * 128 * 4
    assert out["all-to-all"] == 2 * 8 * 64 * 2
    assert out["collective-permute"] == 32 * 32 * 2   # -done not counted
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_derive_terms_and_dominant():
    rep = RooflineReport(arch="a", shape="s", mesh="16x16", n_devices=256,
                         kind="train", hlo_flops=PEAK_FLOPS,
                         hlo_bytes=HBM_BW * 10,
                         collective_bytes=ICI_BW * 2,
                         model_flops_global=PEAK_FLOPS * 256)
    derive_terms(rep)
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(10.0)
    assert rep.collective_s == pytest.approx(2.0)
    assert rep.dominant == "memory"
    assert rep.roofline_fraction == pytest.approx(0.1)
    assert rep.useful_ratio == pytest.approx(1.0)


def test_layer_correction_math():
    rep = RooflineReport(arch="a", shape="s", mesh="m", n_devices=256,
                         kind="train", hlo_flops=10.0, hlo_bytes=20.0,
                         collective_bytes=2.0,
                         collective_breakdown={"all-gather": 2,
                                               "total": 2},
                         model_flops_global=1.0)
    probe = RooflineReport(arch="a", shape="s", mesh="m", n_devices=256,
                           kind="probe", hlo_flops=3.0, hlo_bytes=4.0,
                           collective_bytes=1.0,
                           collective_breakdown={"all-gather": 1,
                                                 "total": 1})
    apply_layer_correction(rep, probe, n_layers=5)
    assert rep.hlo_flops == 10.0 + 4 * 3.0
    assert rep.hlo_bytes == 20.0 + 4 * 4.0
    assert rep.collective_bytes == 2.0 + 4 * 1.0
    assert rep.collective_breakdown["all-gather"] == 2 + 4


def test_model_flops_formulas():
    from repro.configs import ARCHS
    q = ARCHS["qwen1.5-110b"].config
    f_train = lm_model_flops(q, 4096, 256, "train")
    f_prefill = lm_model_flops(q, 4096, 256, "prefill")
    assert f_train == pytest.approx(3 * f_prefill)
    # MoE counts ACTIVE params only
    phi = ARCHS["phi3.5-moe-42b-a6.6b"].config
    from repro.models.lm import active_params, num_params
    f_phi = lm_model_flops(phi, 4096, 256, "train")
    assert f_phi == pytest.approx(6 * active_params(phi) * 256 * 4096)
    assert f_phi < 6 * num_params(phi) * 256 * 4096 * 0.3
    # decode is tiny vs train
    assert lm_model_flops(q, 32768, 128, "decode") < f_train / 100
    # gnn / recsys formulas positive and train > serve
    g = ARCHS["gcn-cora"].config
    from repro.configs.base import GNN_SHAPES, RECSYS_SHAPES
    assert gnn_model_flops(g, GNN_SHAPES["ogb_products"]) > \
        gnn_model_flops(g, GNN_SHAPES["full_graph_sm"])
    d = ARCHS["dlrm-rm2"].config
    assert recsys_model_flops(d, RECSYS_SHAPES["train_batch"]) > \
        recsys_model_flops(d, RECSYS_SHAPES["serve_p99"])


def test_mesh_factory_contract():
    """Importing mesh.py must not initialize devices; shapes/axes match
    the assignment. (We can't build the real 512-device mesh here —
    tests run with 1 CPU device by design.)"""
    import inspect

    from repro.launch import mesh as mesh_mod
    src = inspect.getsource(mesh_mod)
    assert "make_production_mesh" in src
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src


# -- optimized-path numerics (the §Perf variants stay correct) ---------------

TINY_MOE = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab_size=512, vocab_pad_multiple=128,
                n_experts=8, top_k=2, capacity_factor=8.0, remat="none",
                dtype=jnp.float32)


def test_grouped_dispatch_matches_flat():
    from repro.models import lm as LM
    from repro.models.common import init_params
    cfg = LM.LMConfig(**TINY_MOE)
    params = init_params(LM.param_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, 512)
    flat, _ = LM.forward(params, toks, cfg)
    for g in (2, 4):
        grouped, _ = LM.forward(params, toks,
                                replace(cfg, dispatch_groups=g))
        np.testing.assert_allclose(np.asarray(flat), np.asarray(grouped),
                                   atol=2e-4)


def test_grouped_dispatch_gradients_flow():
    from repro.models import lm as LM
    from repro.models.common import init_params
    cfg = replace(LM.LMConfig(**TINY_MOE), dispatch_groups=4)
    params = init_params(LM.param_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, 512)
    g = jax.grad(lambda p: LM.causal_lm_loss(
        p, {"tokens": toks, "labels": toks}, cfg))(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
    assert float(jnp.abs(g["layers"]["w1"]).max()) > 0


def test_repeat_kv_matches_factored_gqa():
    from repro.models import lm as LM
    from repro.models.common import init_params
    cfg = LM.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=512,
                      vocab_pad_multiple=128, remat="none",
                      dtype=jnp.float32)
    params = init_params(LM.param_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, 512)
    a, _ = LM.forward(params, toks, cfg)
    b, _ = LM.forward(params, toks, replace(cfg, gqa_repeat_kv=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    # decode path too
    lg1, c1 = LM.prefill(params, toks, cfg)
    c1 = jax.tree.map(lambda c: jnp.pad(
        c, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))), c1)
    d1, _ = LM.decode_one(params, c1, toks[:, -1], jnp.int32(24), cfg)
    d2, _ = LM.decode_one(params, c1, toks[:, -1], jnp.int32(24),
                          replace(cfg, gqa_repeat_kv=True))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)


def test_bf16_moments_converge():
    from repro.train import AdamWConfig, train_loop
    X = jnp.array(np.random.default_rng(0).normal(size=(64, 4)),
                  jnp.float32)
    w_true = jnp.array([1.0, -2.0, 3.0, 0.5])
    Y = X @ w_true[:, None]
    loss = lambda p, b: jnp.mean((b["x"] @ p["w"][:, None] - b["y"]) ** 2)
    p, _, _ = train_loop({"w": jnp.zeros(4)}, lambda s: {"x": X, "y": Y},
                         loss, n_steps=300,
                         opt_cfg=AdamWConfig(lr=0.05, weight_decay=0.0,
                                             moment_dtype=jnp.bfloat16))
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(w_true),
                               atol=0.15)
