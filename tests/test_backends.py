"""Pluggable cache backends (caching/backends.py): protocol conformance,
persistence, file-locked atomic writes, compute-once under concurrency,
and CacheTransformer lifecycle (close idempotency, __del__ guard)."""
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.caching import (BACKENDS, KeyValueCache, MemoryLRUBackend,
                           RetrieverCache, ScorerCache, atomic_write_bytes,
                           auto_cache, open_backend)
from repro.core import ColFrame, GenericTransformer, add_ranks

DISK_BACKENDS = ["pickle", "dbm", "sqlite"]
ALL_BACKENDS = ["memory"] + DISK_BACKENDS


# -- protocol conformance ----------------------------------------------------

@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_backend_roundtrip_and_len(name, tmp_path):
    b = open_backend(name, str(tmp_path))
    assert len(b) == 0
    b.put_many([(b"k1", b"v1"), (b"k2", b"v2")])
    assert b.get_many([b"k1", b"missing", b"k2"]) == [b"v1", None, b"v2"]
    assert b.get(b"k1") == b"v1" and b.get(b"nope") is None
    assert len(b) == 2
    b.put(b"k1", b"v1b")                 # overwrite, not a new entry
    assert b.get(b"k1") == b"v1b"
    assert len(b) == 2
    b.close()
    b.close()                            # idempotent


@pytest.mark.parametrize("name", DISK_BACKENDS)
def test_backend_persists_across_instances(name, tmp_path):
    b = open_backend(name, str(tmp_path))
    b.put(b"key", b"value")
    b.close()
    b2 = open_backend(name, str(tmp_path))
    assert b2.persistent
    assert b2.get(b"key") == b"value"
    b2.close()


def test_memory_backend_lru_eviction():
    b = MemoryLRUBackend(capacity=2)
    b.put(b"a", b"1")
    b.put(b"b", b"2")
    assert b.get(b"a") == b"1"           # refresh a
    b.put(b"c", b"3")                    # evicts b (least recent)
    assert b.get(b"b") is None
    assert b.get(b"a") == b"1" and b.get(b"c") == b"3"
    assert len(b) == 2


def test_open_backend_rejects_unknown(tmp_path):
    with pytest.raises(ValueError, match="unknown cache backend"):
        open_backend("redis", str(tmp_path))
    inst = MemoryLRUBackend()
    assert open_backend(inst, None) is inst          # instances pass through
    assert set(BACKENDS) == {"memory", "pickle", "dbm", "sqlite"}


def test_atomic_write_bytes(tmp_path):
    p = str(tmp_path / "blob.bin")
    atomic_write_bytes(p, b"one")
    atomic_write_bytes(p, b"two")
    with open(p, "rb") as f:
        assert f.read() == b"two"
    # no temp litter left behind
    assert [f for f in os.listdir(tmp_path) if f.startswith(".tmp-")] == []


def test_legacy_store_filenames_stay_warm(tmp_path):
    """Directories written by the pre-backend cache families
    (kv.sqlite3 / retriever.db) must be picked up, not recomputed."""
    import sqlite3
    legacy_sql = tmp_path / "sql"
    legacy_sql.mkdir()
    db = sqlite3.connect(str(legacy_sql / "kv.sqlite3"))
    db.executescript("CREATE TABLE IF NOT EXISTS kv ("
                     "key BLOB PRIMARY KEY, value BLOB NOT NULL"
                     ") WITHOUT ROWID;")
    db.execute("INSERT INTO kv VALUES (?, ?)", (b"k", b"v"))
    db.commit()
    db.close()
    b = open_backend("sqlite", str(legacy_sql))
    assert b.get(b"k") == b"v"
    b.close()

    import dbm
    legacy_dbm = tmp_path / "dbm"
    legacy_dbm.mkdir()
    d = dbm.open(str(legacy_dbm / "retriever.db"), "c")
    d[b"k"] = b"v"
    d.close()
    b2 = open_backend("dbm", str(legacy_dbm))
    assert b2.get(b"k") == b"v"
    b2.close()


def test_filelock_failed_acquire_does_not_deadlock(tmp_path):
    """If taking the inter-process lock fails, the in-process lock must
    be rolled back so other threads see the error, not a hang."""
    from repro.caching import FileLock
    missing_dir = str(tmp_path / "nope" / ".lock")   # os.open -> ENOENT
    lk = FileLock(missing_dir)
    with pytest.raises(OSError):
        lk.acquire()
    acquired = []

    def try_lock():
        real = FileLock(str(tmp_path / ".lock"))
        lk._tlock.acquire(timeout=5) and lk._tlock.release()
        acquired.append(True)
        real.acquire()
        real.release()

    t = threading.Thread(target=try_lock)
    t.start()
    t.join(timeout=10)
    assert acquired, "thread lock leaked by failed FileLock.acquire"
    assert not lk.held()


def test_dbm_reads_concurrent_under_shared_flock(tmp_path):
    """Two threads reading a dbm backend proceed without exclusive
    serialization, and reads inside lock() (compute-once recheck) do
    not deadlock against the held exclusive lock."""
    b = open_backend("dbm", str(tmp_path))
    b.put_many([(f"k{i}".encode(), f"v{i}".encode()) for i in range(4)])
    with b.lock():                       # recheck path: read while held
        assert b.get(b"k1") == b"v1"
    results = []

    def reader():
        results.append(b.get_many([b"k0", b"k3"]))

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert results == [[b"v0", b"v3"]] * 2
    b.close()


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_backend_lock_reentrant(name, tmp_path):
    b = open_backend(name, str(tmp_path))
    with b.lock():
        with b.lock():                   # re-entrant for nested miss paths
            b.put(b"k", b"v")
    assert b.get(b"k") == b"v"
    b.close()


# -- cache families over each backend ----------------------------------------

def _expander():
    return GenericTransformer(
        lambda inp: inp.assign(query=np.array(
            [q + "!" for q in inp["query"].tolist()], dtype=object)),
        "expander", key_columns=("qid", "query"), value_columns=("query",))


TOPICS = ColFrame({"qid": [f"q{i}" for i in range(8)],
                   "query": [f"terms {i}" for i in range(8)]})


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_kv_cache_over_backend(name, tmp_path):
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend=name) as kv:
        cold = kv(TOPICS)
        assert kv.stats.misses == len(TOPICS)
        hot = kv(TOPICS)
        assert kv.stats.hits == len(TOPICS)
        direct = _expander()(TOPICS)
        assert cold.equals(direct) and hot.equals(direct)


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_retriever_cache_over_backend(name, tmp_path):
    def retr_fn(inp):
        rows = [{"qid": q, "query": t, "docno": f"d{i}", "score": 9.0 - i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(4)]
        return add_ranks(ColFrame.from_dicts(rows))
    retr = GenericTransformer(retr_fn, "retr", one_to_many=True,
                              key_columns=("qid", "query"))
    with RetrieverCache(str(tmp_path), retr, backend=name) as rc:
        cold = rc(TOPICS)
        hot = rc(TOPICS)
        assert rc.stats.hits == len(TOPICS)
        direct = retr(TOPICS)
        cols = ["qid", "docno", "score", "rank"]
        assert cold.equals(direct, cols=cols)
        assert hot.equals(direct, cols=cols)


def test_auto_cache_backend_selector(tmp_path):
    c = auto_cache(_expander(), str(tmp_path), backend="pickle")
    assert isinstance(c, KeyValueCache)
    assert c.backend.name == "pickle"
    c.close()
    s = auto_cache(GenericTransformer(lambda x: x, "scorer",
                                      key_columns=("query", "docno"),
                                      value_columns=("score",)),
                   backend="memory")
    assert isinstance(s, ScorerCache)
    assert s.backend.name == "memory"
    s.close()


# -- compute-once under concurrent threads -----------------------------------

class CountingExpander(GenericTransformer):
    """Row-wise transformer that counts computed rows thread-safely."""

    def __init__(self):
        self.computed = []
        self._lock = threading.Lock()

        def fn(inp):
            with self._lock:
                self.computed.extend(inp["qid"].tolist())
            return inp.assign(query=np.array(
                [q + "!" for q in inp["query"].tolist()], dtype=object))
        super().__init__(fn, "counting_expander",
                         key_columns=("qid", "query"),
                         value_columns=("query",))


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_two_threads_share_cache_compute_exactly_once(name, tmp_path):
    """Two threads race the same key set through one cache directory —
    the locked recheck-then-compute miss path must compute each entry
    exactly once, whichever thread wins the lock."""
    counter = CountingExpander()
    if name == "memory":
        # memory backends do not share state across instances; share one
        shared = open_backend("memory", None)
        caches = [KeyValueCache(None, counter, key=("qid", "query"),
                                value=("query",), backend=shared)
                  for _ in range(2)]
    else:
        caches = [KeyValueCache(str(tmp_path), counter,
                                key=("qid", "query"), value=("query",),
                                backend=name)
                  for _ in range(2)]
    outs = [None, None]
    errs = []

    def worker(i):
        try:
            outs[i] = caches[i](TOPICS)
        except Exception as e:                       # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert sorted(counter.computed) == sorted(TOPICS["qid"].tolist()), \
        f"{name}: entries recomputed — computed {len(counter.computed)} " \
        f"rows for {len(TOPICS)} unique keys"
    direct = _expander()(TOPICS)
    for out in outs:
        assert out is not None and out.equals(direct)
    for c in caches:
        c.close()


# -- compute-once across processes (shared cache dir) -------------------------

_PROC_SCRIPT = """
import sys
import numpy as np
from repro.caching import KeyValueCache
from repro.core import ColFrame, GenericTransformer

cache_dir, backend, log_path = sys.argv[1:4]

def fn(inp):
    with open(log_path, "a") as f:           # O_APPEND: atomic small writes
        for q in inp["qid"].tolist():
            f.write(q + "\\n")
    return inp.assign(query=np.array(
        [q + "!" for q in inp["query"].tolist()], dtype=object))

t = GenericTransformer(fn, "counting_expander",
                       key_columns=("qid", "query"),
                       value_columns=("query",))
topics = ColFrame({"qid": [f"q{i}" for i in range(8)],
                   "query": [f"terms {i}" for i in range(8)]})
with KeyValueCache(cache_dir, t, key=("qid", "query"), value=("query",),
                   backend=backend) as kv:
    out = kv(topics)
assert out["query"][0] == "terms 0!"
"""


@pytest.mark.slow
@pytest.mark.parametrize("name", DISK_BACKENDS)
def test_two_processes_share_cache_dir_compute_exactly_once(name, tmp_path):
    """Two interpreters pointed at one cache directory, started
    concurrently: the inter-process file lock serializes the miss path,
    so every entry is computed exactly once across both."""
    log = tmp_path / "computed.log"
    log.touch()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(root, "src")}
    procs = [subprocess.Popen(
        [sys.executable, "-c", _PROC_SCRIPT,
         str(tmp_path / "cache"), name, str(log)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env)
        for _ in range(2)]
    for p in procs:
        _, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()[-2000:]
    computed = log.read_text().split()
    assert sorted(computed) == sorted(f"q{i}" for i in range(8)), \
        f"{name}: keys computed more than once across processes: {computed}"


# -- CacheTransformer lifecycle (close idempotency, __del__ guard) ------------

def test_close_is_idempotent_and_del_safe():
    kv = KeyValueCache(None, _expander(), key=("qid", "query"),
                       value=("query",))
    path = kv.path
    kv(TOPICS)
    assert os.path.isdir(path)
    kv.close()
    assert not os.path.isdir(path)       # temp dir cleaned up
    kv.close()                           # second close is a no-op
    kv.__del__()                         # finalizer after close: no raise
    assert not os.path.isdir(path)


def test_del_closes_unclosed_cache(tmp_path):
    kv = KeyValueCache(None, _expander(), key=("qid", "query"),
                       value=("query",), backend="pickle")
    path = kv.path
    kv(TOPICS)
    kv.__del__()                         # acts as close() pre-shutdown
    assert not os.path.isdir(path)


def test_backend_close_idempotent_through_cache(tmp_path):
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="sqlite") as kv:
        kv(TOPICS)
        b = kv.backend
    b.close()                            # backend already closed by cache


# -- open_backend diagnostics (helpful errors) --------------------------------

def test_open_backend_unknown_name_lists_registered_backends(tmp_path):
    """The error for a typo'd selector must spell out every registered
    backend so the fix is copy-pasteable."""
    with pytest.raises(ValueError) as ei:
        open_backend("sqlite3", str(tmp_path))       # classic typo
    msg = str(ei.value)
    assert "'sqlite3'" in msg
    for name in BACKENDS:
        assert repr(name) in msg
    assert "CacheBackend instance" in msg            # custom-store hint


def test_open_backend_rejects_non_string_selector(tmp_path):
    with pytest.raises(TypeError, match="registry name"):
        open_backend(42, str(tmp_path))


def test_resolve_backend_name():
    from repro.caching import resolve_backend_name
    assert resolve_backend_name(None, "dbm") == "dbm"
    assert resolve_backend_name("pickle", "dbm") == "pickle"
    assert resolve_backend_name(MemoryLRUBackend(), "dbm") == "memory"
    # unknown selectors list every registered selector, combinator
    # forms (tiered:<disk> / mmap:<disk>) included
    with pytest.raises(ValueError, match="registered selectors"):
        resolve_backend_name("redis", "dbm")
    with pytest.raises(ValueError, match="mmap:sqlite"):
        resolve_backend_name("redis", "dbm")


# -- entry enumeration (drives `repro cache export`) --------------------------

@pytest.mark.parametrize("name", ["memory", "dbm", "sqlite"])
def test_backend_items_enumerates_all_entries(name, tmp_path):
    b = open_backend(name, str(tmp_path))
    pairs = [(f"k{i}".encode(), f"v{i}".encode()) for i in range(5)]
    b.put_many(pairs)
    assert sorted(b.items()) == sorted(pairs)
    b.close()


def test_pickle_backend_items_unsupported(tmp_path):
    """Keys are stored hashed; enumeration must refuse loudly (export
    falls back to raw-file mode for this backend)."""
    b = open_backend("pickle", str(tmp_path))
    b.put(b"k", b"v")
    with pytest.raises(NotImplementedError, match="raw files"):
        b.items()
    b.close()


# -- duplicate keys in one lookup batch ---------------------------------------

@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_get_many_resolves_every_duplicate_occurrence(name, tmp_path):
    """Regression: a micro-batch coalescing concurrent requests for the
    same hot query hands get_many duplicate keys — every occurrence
    must resolve (the sqlite backend used to fill only one slot per
    unique key, turning repeat traffic into spurious misses and
    recomputation)."""
    b = open_backend(name, str(tmp_path))
    b.put_many([(b"a", b"1"), (b"b", b"2")])
    assert b.get_many([b"a", b"a", b"b", b"nope", b"a", b"b"]) == \
        [b"1", b"1", b"2", None, b"1", b"2"]
    b.close()


# -- eviction-facing protocol (delete_many / entry_stats / stat_entries) ------

@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_backend_delete_many(name, tmp_path):
    b = open_backend(name, str(tmp_path))
    b.put_many([(f"k{i}".encode(), f"v{i}".encode()) for i in range(4)])
    assert b.delete_many([b"k0", b"k2", b"missing"]) == 2
    assert b.get_many([b"k0", b"k1", b"k2", b"k3"]) == \
        [None, b"v1", None, b"v3"]
    assert len(b) == 2
    b.close()


@pytest.mark.parametrize("name", ["memory", "dbm", "sqlite"])
def test_backend_entry_stats_and_stat_entries(name, tmp_path):
    b = open_backend(name, str(tmp_path))
    b.put_many([(b"k1", b"v"), (b"k2", b"vv")])
    assert sorted(b.entry_stats()) == [(b"k1", 1), (b"k2", 2)]
    assert b.stat_entries([b"k2", b"nope", b"k1"]) == [2, None, 1]
    b.close()


def test_pickle_entry_stats_unsupported_but_stat_entries_works(tmp_path):
    b = open_backend("pickle", str(tmp_path))
    b.put(b"k", b"val")
    with pytest.raises(NotImplementedError):
        b.entry_stats()
    assert b.stat_entries([b"k", b"nope"]) == [3, None]
    b.close()
