"""Train runtime + distribution: optimizer, microbatching, compression,
checkpoint atomicity/elasticity, fault-tolerant restart, stragglers,
sharding rules."""
import os
import tempfile
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distrib import (Checkpointer, CompressionConfig, Preemption,
                           RestartableLoop, ShardingRules, StragglerPolicy,
                           latest_step, restore_checkpoint, save_checkpoint,
                           wire_bytes)
from repro.train import (AdamWConfig, adamw_init, adamw_update,
                         linear_warmup_cosine, make_train_step, train_loop)

RNG = np.random.default_rng(0)


# -- optimizer ---------------------------------------------------------------

def _linreg_setup():
    X = jnp.array(RNG.normal(size=(64, 4)), jnp.float32)
    w_true = jnp.array([1.0, -2.0, 3.0, 0.5])
    Y = X @ w_true[:, None]
    loss = lambda p, b: jnp.mean((b["x"] @ p["w"][:, None] - b["y"]) ** 2)
    return {"w": jnp.zeros(4, jnp.float32)}, {"x": X, "y": Y}, loss, w_true


def test_adamw_converges_linreg():
    params, batch, loss, w_true = _linreg_setup()
    p, _, hist = train_loop(params, lambda s: batch, loss, n_steps=300,
                            opt_cfg=AdamWConfig(lr=0.05, weight_decay=0.0))
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(w_true),
                               atol=0.05)


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    params = {"w": jnp.ones(3)}
    grads = {"w": jnp.full(3, 1e6)}
    state = adamw_init(params)
    new, _, m = adamw_update(params, grads, state, cfg)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.abs(new["w"] - params["w"]).max()) < 1.1   # clipped


def test_microbatch_equals_full_batch():
    params, batch, loss, _ = _linreg_setup()
    s1, init1 = make_train_step(loss, AdamWConfig(lr=0.01,
                                                  weight_decay=0.0))
    s4, init4 = make_train_step(loss, AdamWConfig(lr=0.01,
                                                  weight_decay=0.0),
                                microbatches=4)
    p1, o1 = dict(params), init1(params)
    p4, o4 = dict(params), init4(params)
    for _ in range(5):
        p1, o1, _ = jax.jit(s1)(p1, o1, batch)
        p4, o4, _ = jax.jit(s4)(p4, o4, batch)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               atol=1e-5)


def test_schedule_shape():
    s0 = float(linear_warmup_cosine(0, warmup=10, total=100))
    s10 = float(linear_warmup_cosine(10, warmup=10, total=100))
    s100 = float(linear_warmup_cosine(100, warmup=10, total=100,
                                      floor=0.1))
    assert s0 == 0.0 and s10 == pytest.approx(1.0)
    assert s100 == pytest.approx(0.1)


# -- compression ----------------------------------------------------------------

def test_int8_compression_with_ef_still_converges():
    params, batch, loss, w_true = _linreg_setup()
    step, init = make_train_step(
        loss, AdamWConfig(lr=0.05, weight_decay=0.0),
        compression=CompressionConfig(method="int8"))
    p, o = params, init(params)
    for _ in range(300):
        p, o, _ = jax.jit(step)(p, o, batch)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(w_true),
                               atol=0.1)


def test_error_feedback_bookkeeping():
    from repro.distrib import compress_grads, init_ef_state
    g = {"w": jnp.array([1.0, -0.5, 0.25, 1e-4], jnp.float32)}
    ef = init_ef_state(g)
    cfg = CompressionConfig(method="int8", error_feedback=True)
    sent, ef2 = compress_grads(g, ef, cfg)
    # EF invariant: sent + error == target
    np.testing.assert_allclose(
        np.asarray(sent["w"] + ef2["w"]), np.asarray(g["w"]), rtol=1e-6)


def test_wire_bytes_accounting():
    params = {"a": jnp.zeros((100,)), "b": jnp.zeros((28,))}
    assert wire_bytes(params, CompressionConfig("none")) == 128 * 4
    assert wire_bytes(params, CompressionConfig("int8")) == 128
    assert wire_bytes(params, CompressionConfig(
        "topk", topk_fraction=0.25)) == 32 * 8


def test_topk_compression_sparsity():
    from repro.distrib import compress_grads, init_ef_state
    g = {"w": jnp.array(RNG.normal(size=256), jnp.float32)}
    cfg = CompressionConfig(method="topk", topk_fraction=0.1,
                            error_feedback=False)
    sent, _ = compress_grads(g, init_ef_state(g), cfg)
    nz = int((sent["w"] != 0).sum())
    assert nz <= 26 + 5        # ~top 10% (ties may add a few)


# -- checkpointing ----------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 3, tree)
    save_checkpoint(str(tmp_path), 7, jax.tree.map(lambda x: x * 2, tree))
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 2)
    assert restored["b"]["c"].dtype == jnp.bfloat16
    restored3, _ = restore_checkpoint(str(tmp_path), like, step=3)
    np.testing.assert_allclose(np.asarray(restored3["a"]),
                               np.asarray(tree["a"]))


def test_checkpoint_commit_is_atomic(tmp_path):
    # a stale .tmp dir from a "crashed" save must be invisible
    os.makedirs(tmp_path / ".tmp-99-123")
    tree = {"a": jnp.zeros(2)}
    save_checkpoint(str(tmp_path), 1, tree)
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(4, dtype=jnp.float32)}
    for s in (1, 2, 3, 4):
        ck.save_async(s, jax.tree.map(lambda x: x + s, tree))
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]
    restored, step = ck.restore(tree)
    assert step == 4
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) + 4)


def test_elastic_restore_reshards(tmp_path):
    """Manifest is mesh-agnostic: restore onto a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = restore_checkpoint(str(tmp_path), tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]))


# -- fault tolerance -----------------------------------------------------------------

def test_restart_reproduces_uninterrupted_run(tmp_path):
    params, batch, loss, _ = _linreg_setup()
    step, init = make_train_step(loss, AdamWConfig(lr=0.01,
                                                   weight_decay=0.0))
    def sfn(state, b):
        p, o = state
        p, o, m = jax.jit(step)(p, o, b)
        return (p, o), m
    batch_fn = lambda s: batch
    ref = RestartableLoop(sfn, batch_fn,
                          Checkpointer(str(tmp_path / "a"), keep=2),
                          ckpt_every=4).run((params, init(params)), 17)
    loop = RestartableLoop(sfn, batch_fn,
                           Checkpointer(str(tmp_path / "b"), keep=2),
                           ckpt_every=4)
    out = loop.run((params, init(params)), 17,
                   fail_at={6: 0, 13: 1, 16: 2})
    assert loop.restarts == 3
    assert bool(jnp.all(ref[0]["w"] == out[0]["w"]))     # bit-equal


def test_straggler_policy_flags_and_evicts():
    sp = StragglerPolicy(deadline_factor=2.0, evict_after=2)
    assert sp.observe(0, 1.0) == "ok"
    assert sp.observe(1, 1.05) == "ok"
    assert sp.observe(2, 5.0) == "straggle"
    assert sp.observe(3, 5.0) == "evict"
    assert sp.evicted
    # healthy steps don't poison the EWMA baseline
    assert sp._ewma < 1.5


# -- sharding rules -------------------------------------------------------------------

def fake_mesh(shape, names):
    return SimpleNamespace(axis_names=names,
                           devices=SimpleNamespace(shape=shape))


def test_rules_basic_mapping():
    r = ShardingRules()
    mesh = fake_mesh((16, 16), ("data", "model"))
    assert str(r.spec_for((49408, 960), ("vocab", "d_model"), mesh)) == \
        "PartitionSpec('model', 'data')"
    # heads indivisible -> pruned, head_dim never sharded
    spec = r.spec_for((32, 960, 15, 64),
                      ("layers", "d_model", "heads", "head_dim"), mesh)
    assert spec == jax.sharding.PartitionSpec(None, "data")


def test_rules_axis_used_once():
    r = ShardingRules()
    mesh = fake_mesh((16, 16), ("data", "model"))
    # MoE w1 [L, E, D, F]: E takes model, F must NOT reuse it
    spec = r.spec_for((32, 16, 4096, 6400),
                      ("layers", "experts", "d_model", "d_ff"), mesh)
    parts = [p for p in spec if p is not None]
    assert parts == ["model", "data"]


def test_rules_joint_axes_and_pruning():
    r = ShardingRules()
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = r.spec_for((1024, 64), ("table_rows", "table_dim"), mesh)
    assert spec[0] == ("data", "model")
    # batch over (pod, data); indivisible batch drops trailing axes
    spec = r.spec_for((256, 4096), ("batch", "seq"), mesh)
    assert spec[0] == ("pod", "data")
    spec = r.spec_for((2, 4096), ("batch", "seq"), mesh)
    assert spec == jax.sharding.PartitionSpec("pod")


def test_rules_override():
    r = ShardingRules().override(d_ff=())
    mesh = fake_mesh((16, 16), ("data", "model"))
    spec = r.spec_for((960, 2560), ("d_model", "d_ff"), mesh)
    assert spec == jax.sharding.PartitionSpec("data")
