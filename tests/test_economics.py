"""Cache economics (caching/economics.py): budgets, access stats, the
LRU/TTL eviction pass, close-time enforcement, offline `repro cache
evict` / speculative `repro cache warm`, and the entry_count-refresh
regression (manifests must stay truthful against a still-open
backend)."""
import json
import os
import time

import numpy as np
import pytest

from repro.caching import (AccessStats, CacheBudget, CacheManifest,
                           DenseScorerCache, KeyValueCache, enforce_dir,
                           evict_entries, open_backend, warm_scenario)
from repro.caching import provenance as prov
from repro.cli import main
from repro.core import ColFrame, ExecutionPlan, GenericTransformer

# ruff: noqa: E402
from repro.caching.economics import open_family_for_dir


def _expander():
    return GenericTransformer(
        lambda inp: inp.assign(query=np.array(
            [q + "!" for q in inp["query"].tolist()], dtype=object)),
        "expander", key_columns=("qid", "query"), value_columns=("query",))


def _topics(n=8):
    return ColFrame({"qid": [f"q{i}" for i in range(n)],
                     "query": [f"terms {i}" for i in range(n)]})


# -- CacheBudget --------------------------------------------------------------

def test_budget_coerce():
    assert CacheBudget.coerce(None).empty()
    assert CacheBudget.coerce(5) == CacheBudget(max_entries=5)
    b = CacheBudget(max_bytes=1024)
    assert CacheBudget.coerce(b) is b
    assert CacheBudget.coerce({"max_entries": 3, "ttl_seconds": 60.0}) == \
        CacheBudget(max_entries=3, ttl_seconds=60.0)
    with pytest.raises(TypeError, match="bool"):
        CacheBudget.coerce(True)
    with pytest.raises(ValueError, match="unknown cache budget"):
        CacheBudget.coerce({"max_rows": 3})
    with pytest.raises(TypeError, match="CacheBudget"):
        CacheBudget.coerce("3")


def test_budget_manifest_roundtrip(tmp_path):
    m = CacheManifest.new(family="KeyValueCache", backend="sqlite",
                          fingerprint="aa" * 8)
    assert not m.has_budget()
    budget = CacheBudget(max_entries=10, ttl_seconds=3600.0)
    assert budget.record_in(m)                   # changed
    assert not budget.record_in(m)               # idempotent
    m.save(str(tmp_path))
    loaded = CacheManifest.load(str(tmp_path))
    assert loaded.format_version == prov.MANIFEST_VERSION
    assert loaded.has_budget()
    assert CacheBudget.from_manifest(loaded) == budget


def test_v1_manifest_adopts_v2_schema(tmp_path):
    """A pre-economics (v1) manifest loads with an empty budget and is
    upgraded in place the next time it is saved."""
    m = CacheManifest.new(family="KeyValueCache", backend="sqlite",
                          fingerprint="bb" * 8)
    doc = m.body()
    doc["format_version"] = 1
    for k in ("max_entries", "max_bytes", "ttl_seconds"):
        del doc[k]
    doc["checksum"] = prov._body_checksum(doc)
    with open(os.path.join(tmp_path, "manifest.json"), "w") as f:
        json.dump(doc, f)
    loaded = CacheManifest.load(str(tmp_path))
    assert loaded.format_version == 1
    assert not loaded.has_budget()
    assert CacheBudget.from_manifest(loaded).empty()
    loaded.save(str(tmp_path))                   # upgrade-on-write
    assert CacheManifest.load(str(tmp_path)).format_version == \
        prov.MANIFEST_VERSION


# -- AccessStats --------------------------------------------------------------

def test_access_stats_merge_forget_persist(tmp_path):
    a = AccessStats()
    a.merge_pending({b"k1": [100.0, 2], b"k2": [50.0, 1]})
    a.merge_pending({b"k1": [80.0, 3]})          # older ts, more hits
    assert a.last_used(b"k1") == 100.0           # later timestamp wins
    assert a.hits(b"k1") == 5                    # hit counts add
    assert a.total_hits() == 6
    a.save(str(tmp_path))
    b = AccessStats.load(str(tmp_path))
    assert b.last_used(b"k1") == 100.0 and b.hits(b"k2") == 1
    assert sorted(b.keys_bytes()) == [b"k1", b"k2"]
    b.forget([b"k1", b"unknown"])
    assert len(b) == 1 and b.last_used(b"k1", -1.0) == -1.0


def test_access_stats_corrupt_file_loads_empty(tmp_path):
    with open(AccessStats.path_of(str(tmp_path)), "w") as f:
        f.write("{not json")
    assert len(AccessStats.load(str(tmp_path))) == 0


# -- evict_entries (the pass itself, deterministic inputs) --------------------

def _filled_backend(tmp_path, n=6):
    b = open_backend("sqlite", str(tmp_path))
    b.put_many([(b"k%d" % i, b"v" * (i + 1)) for i in range(n)])
    return b


def test_evict_lru_order_and_entry_budget(tmp_path):
    b = _filled_backend(tmp_path)
    access = AccessStats()
    # recency: k3 and k5 most recent; the rest in key order at t=10
    access.merge_pending({b"k%d" % i: [10.0, 1] for i in range(6)})
    access.merge_pending({b"k3": [99.0, 1], b"k5": [98.0, 1]})
    access.save(str(tmp_path))
    rep = evict_entries(b, str(tmp_path), CacheBudget(max_entries=2),
                        access=access, now=100.0)
    assert rep["evicted"] == 4 and rep["entries_after"] == 2
    assert rep["expired"] == 0 and rep["unevictable"] == 0
    assert b.get(b"k3") and b.get(b"k5")         # most recent survive
    assert b.get(b"k0") is None
    # the sidecar forgot the victims
    assert sorted(AccessStats.load(str(tmp_path)).keys_bytes()) == \
        [b"k3", b"k5"]
    b.close()


def test_evict_ttl_before_lru(tmp_path):
    b = _filled_backend(tmp_path)
    access = AccessStats()
    access.merge_pending({b"k%d" % i: [float(i * 10), 1] for i in range(6)})
    # ttl 25s at now=60: k0 (t=0), k1 (t=10), k2 (t=20), k3 (t=30 > 35? no)
    rep = evict_entries(b, str(tmp_path), CacheBudget(ttl_seconds=25.0),
                        access=access, now=60.0)
    assert rep["expired"] == 4                   # t in {0,10,20,30} <= 35
    assert rep["evicted"] == 4 and rep["entries_after"] == 2
    assert b.get(b"k4") and b.get(b"k5")
    b.close()


def test_evict_byte_budget(tmp_path):
    b = _filled_backend(tmp_path)                # sizes 1..6, total 21
    access = AccessStats()
    access.merge_pending({b"k%d" % i: [float(i), 1] for i in range(6)})
    rep = evict_entries(b, str(tmp_path), CacheBudget(max_bytes=12),
                        access=access, now=100.0)
    assert rep["bytes_after"] <= 12
    assert not rep["bytes_approximate"]
    assert b.get(b"k5") == b"v" * 6              # most recent survives
    b.close()


def test_evict_unknown_entries_age_as_the_directory(tmp_path):
    """Entries the sidecar never saw must be evictable (treated as old
    as created_at), not immortal."""
    b = _filled_backend(tmp_path)
    access = AccessStats()
    access.merge_pending({b"k5": [50.0, 1]})     # only k5 is known
    rep = evict_entries(b, str(tmp_path), CacheBudget(max_entries=1),
                        access=access, created_at=1.0, now=100.0)
    assert rep["evicted"] == 5
    assert b.get(b"k5") == b"v" * 6
    b.close()


def test_evict_pickle_fallback_uses_sidecar_pool(tmp_path):
    """Backends that cannot enumerate (pickle) evict from the sidecar's
    key set; unknown entries are reported unevictable."""
    b = open_backend("pickle", str(tmp_path))
    b.put_many([(b"k%d" % i, b"v%d" % i) for i in range(4)])
    access = AccessStats()
    access.merge_pending({b"k0": [1.0, 1], b"k1": [2.0, 1]})  # 2 of 4 known
    rep = evict_entries(b, str(tmp_path), CacheBudget(max_entries=1),
                        access=access, now=100.0)
    assert rep["bytes_approximate"]
    assert rep["evicted"] == 2                   # only the known ones
    assert rep["entries_after"] == 2 and rep["unevictable"] == 1
    assert b.get(b"k0") is None and b.get(b"k1") is None
    b.close()


# -- family-level eviction + the entry_count-refresh regression ---------------

def test_kv_evict_refreshes_manifest_before_close(tmp_path):
    """THE PR-6 bugfix: after evict() the on-disk manifest must reflect
    the new entry count immediately (verify runs against still-open
    backends), not only at close()."""
    kv = KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="sqlite")
    kv(_topics(8))
    rep = kv.evict(3)
    assert rep["entries_after"] == 3 and len(kv.backend) == 3
    # manifest refreshed NOW, while the cache is still open
    assert CacheManifest.load(str(tmp_path)).entry_count == 3
    assert main(["cache", "verify", str(tmp_path)]) == 0
    kv.close()


def test_close_enforces_constructor_budget(tmp_path):
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="sqlite", budget=3) as kv:
        kv(_topics(8))
        assert len(kv.backend) == 8              # not enforced mid-run
    m = CacheManifest.load(str(tmp_path))
    assert m.entry_count == 3 and m.max_entries == 3
    b = open_backend("sqlite", str(tmp_path))
    assert len(b) == 3
    b.close()


def test_close_enforces_recorded_budget_without_constructor(tmp_path):
    """The budget outlives the process that configured it: a later
    opener without budget= still enforces what the manifest records."""
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="sqlite", budget=4) as kv:
        kv(_topics(4))
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="sqlite") as kv2:
        assert kv2.budget == CacheBudget(max_entries=4)
        kv2(_topics(8))                          # 4 hits + 4 new = 8 entries
    b = open_backend("sqlite", str(tmp_path))
    assert len(b) == 4
    b.close()


def test_evict_without_budget_is_skipped_and_memory_raises(tmp_path):
    kv = KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="sqlite")
    kv(_topics(4))
    assert "skipped" in kv.evict()
    assert kv.evict(2)["entries_after"] == 2
    kv.close()


def test_dense_evict_nans_rows_and_reuses_row_slots(tmp_path):
    scorer = GenericTransformer(
        lambda inp: inp.assign(score=np.arange(len(inp), dtype=np.float64)),
        "scorer", key_columns=("query", "docno"), value_columns=("score",))
    docnos = [f"d{i}" for i in range(4)]

    def frame(queries):
        rows = [{"qid": q, "query": q, "docno": d, "score": 0.0, "rank": 0}
                for q in queries for d in docnos]
        return ColFrame.from_dicts(rows)

    dc = DenseScorerCache(str(tmp_path), scorer, docnos=docnos)
    dc(frame(["qa", "qb", "qc"]))
    assert len(dc) == 12                         # 3 queries x 4 docs
    rep = dc.evict({"max_entries": 4})           # keep one query row
    assert rep["entries_after"] == 4
    assert CacheManifest.load(str(tmp_path)).entry_count == 4
    # the freed row indices are reused, not appended past the matrix
    dc(frame(["qd"]))
    assert len(dc) == 8
    assert max(dc._query_rows.values()) <= 2
    out = dc(frame(["qd"]))                      # replay is a pure hit
    assert dc.stats.misses == 12 + 4             # qa..qc cold + qd, no more
    assert dc.stats.hits == 4                    # the qd replay
    assert np.all(np.asarray(out["score"]) == np.arange(4, dtype=float))
    dc.close()


# -- offline enforcement (enforce_dir / repro cache evict) --------------------

def test_enforce_dir_offline(tmp_path):
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="sqlite") as kv:
        kv(_topics(8))
    assert enforce_dir(str(tmp_path))["skipped"].startswith("no budget")
    rep = enforce_dir(str(tmp_path), 2)
    assert rep["entries_after"] == 2
    assert CacheManifest.load(str(tmp_path)).entry_count == 2
    assert enforce_dir(str(tmp_path / "nope")) == {"skipped": "no manifest"}


def test_open_family_for_dir_reconstructs_from_manifest(tmp_path):
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="dbm") as kv:
        kv(_topics(3))
    m = CacheManifest.load(str(tmp_path))
    fam = open_family_for_dir(str(tmp_path), m)
    assert isinstance(fam, KeyValueCache)
    assert len(fam.backend) == 3
    fam.close()


# -- the CLI lifecycle: warm -> ls -> evict -> verify -------------------------

@pytest.fixture(scope="module")
def warmed_root(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("warmed") / "cache")
    rep = warm_scenario("bm25", root, scale=0.02, requests=64, seed=0)
    return root, rep


def test_warm_scenario_precomputes_cold_dir(warmed_root):
    root, rep = warmed_root
    assert rep["queries_warmed"] > 0
    assert rep["cache_misses"] > 0 and rep["cache_hits"] == 0
    # idempotent: a second warm is all hits
    rep2 = warm_scenario("bm25", root, scale=0.02, requests=64, seed=0)
    assert rep2["cache_misses"] == 0
    assert rep2["cache_hits"] == rep["cache_misses"]


def test_warm_budget_caps_queries(tmp_path):
    rep = warm_scenario("bm25", str(tmp_path / "c"), scale=0.02,
                        requests=64, budget=5, seed=0)
    assert rep["queries_warmed"] == 5


def test_cli_ls_sort_and_budget_utilization(warmed_root, capsys):
    root, _ = warmed_root
    assert main(["cache", "ls", root, "--json", "--sort", "hits"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert set(doc) == {"root", "dirs", "plans"}
    assert doc["dirs"]
    hits = [d["hits"] for d in doc["dirs"]]
    assert hits == sorted(hits, reverse=True)
    assert all(d["budget_utilization"] is None for d in doc["dirs"])
    assert main(["cache", "ls", root, "--json", "--sort", "size"]) == 0
    doc = json.loads(capsys.readouterr().out)
    sizes = [d["size_bytes"] for d in doc["dirs"]]
    assert sizes == sorted(sizes, reverse=True)


def test_cli_evict_records_and_enforces(warmed_root, capsys):
    root, rep = warmed_root
    budget = max(1, rep["queries_warmed"] // 2)
    assert main(["cache", "evict", root, "--budget", str(budget),
                 "--record", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert any(r.get("evicted", 0) > 0 for r in doc["dirs"])
    assert main(["cache", "ls", root, "--json"]) == 0
    ls = json.loads(capsys.readouterr().out)
    for d in ls["dirs"]:
        assert d["entry_count"] <= budget
        assert d["max_entries"] == budget        # --record persisted it
        assert d["budget_utilization"]["entries"] <= 1.0
    assert main(["cache", "verify", root]) == 0


def test_cli_evict_ttl_and_size_args(tmp_path, capsys):
    with KeyValueCache(str(tmp_path / "d"), _expander(),
                       key=("qid", "query"), value=("query",),
                       backend="sqlite") as kv:
        kv(_topics(4))
    time.sleep(0.01)
    assert main(["cache", "evict", str(tmp_path), "--ttl", "0s",
                 "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    (rec,) = doc["dirs"]
    assert rec["evicted"] == 4 and rec["entries_after"] == 0


# -- plan-level warm (ExecutionPlan.warm / run_warm) --------------------------

def _plan_pipeline():
    def retr_fn(inp):
        from repro.core import add_ranks
        rows = [{"qid": q, "query": t, "docno": f"d{i}", "score": 9.0 - i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(3)]
        return add_ranks(ColFrame.from_dicts(rows))
    return GenericTransformer(retr_fn, "retr", one_to_many=True,
                              key_columns=("qid", "query"))


def test_plan_warm_populates_and_chunk_equivalence(tmp_path):
    topics = _topics(9)
    with ExecutionPlan([_plan_pipeline()],
                       cache_dir=str(tmp_path / "whole")) as p1:
        s1 = p1.warm(topics)
    with ExecutionPlan([_plan_pipeline()],
                       cache_dir=str(tmp_path / "chunked")) as p2:
        s2 = p2.warm(topics, chunk_rows=4)
    assert s1.cache_misses == s2.cache_misses == 9
    # identical stored state either way
    def keys(d):
        (sub,) = [x for x in os.listdir(d) if x != "plans"]
        b = open_backend("dbm", os.path.join(str(d), sub))
        try:
            return sorted(k for k, _ in b.items())
        finally:
            b.close()
    assert keys(tmp_path / "whole") == keys(tmp_path / "chunked")
    # a warmed plan replays without recomputation
    with ExecutionPlan([_plan_pipeline()],
                       cache_dir=str(tmp_path / "whole")) as p3:
        s3 = p3.warm(topics)
    assert s3.cache_misses == 0 and s3.cache_hits == 9


def test_plan_cache_budget_flows_to_families(tmp_path):
    topics = _topics(8)
    with ExecutionPlan([_plan_pipeline()], cache_dir=str(tmp_path),
                       cache_budget=3) as p:
        p.warm(topics)
    (sub,) = [x for x in os.listdir(tmp_path) if x != "plans"]
    m = CacheManifest.load(os.path.join(str(tmp_path), sub))
    assert m.max_entries == 3
    assert m.entry_count == 3                    # enforced at close
