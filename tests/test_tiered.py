"""TieredBackend (caching/tiered.py): memory-LRU front over a disk
backend — selector plumbing, write-through puts, promote-on-hit reads,
parity views, and observational equivalence with the bare disk backend
under random operation sequences (property-tested, including across a
close/reopen cycle)."""
import os
import tempfile

import pytest
from hypothesis import given, settings, strategies as st

from repro.caching import (BACKENDS, KeyValueCache, MemoryLRUBackend,
                           TieredBackend, backend_store_exists,
                           open_backend, resolve_backend_name, split_tiered)
from repro.core import ColFrame, GenericTransformer

import numpy as np

DISK_BACKENDS = ["pickle", "dbm", "sqlite"]


# -- selector plumbing --------------------------------------------------------

def test_split_tiered_selector():
    assert split_tiered("tiered") == "sqlite"            # default disk
    assert split_tiered("tiered:dbm") == "dbm"
    assert split_tiered("sqlite") is None                # not tiered
    with pytest.raises(ValueError, match="persistent"):
        split_tiered("tiered:memory")                    # front over front
    with pytest.raises(ValueError, match="tiered"):
        split_tiered("tiered:redis")


def test_resolve_backend_name_normalizes_tiered():
    assert resolve_backend_name("tiered", "sqlite") == "tiered:sqlite"
    assert resolve_backend_name("tiered:dbm", "sqlite") == "tiered:dbm"


def test_tiered_not_a_registry_entry():
    """The combinator composes registered backends; it is not itself
    one (the registry stays exactly the four base stores)."""
    assert "tiered" not in BACKENDS


def test_open_backend_tiered(tmp_path):
    b = open_backend("tiered:dbm", str(tmp_path))
    assert isinstance(b, TieredBackend)
    assert b.name == "tiered:dbm"
    assert b.disk.name == "dbm"
    assert b.persistent
    b.close()
    b.close()                                            # idempotent
    b2 = open_backend("tiered", str(tmp_path / "x"))
    assert b2.disk.name == "sqlite"
    b2.close()


def test_backend_store_exists_dispatches_on_disk_tier(tmp_path):
    assert not backend_store_exists("tiered:sqlite", str(tmp_path))
    b = open_backend("tiered:sqlite", str(tmp_path))
    b.put(b"k", b"v")
    b.close()
    assert backend_store_exists("tiered:sqlite", str(tmp_path))
    assert backend_store_exists("sqlite", str(tmp_path))


# -- tier semantics -----------------------------------------------------------

def test_write_through_and_persistence(tmp_path):
    b = open_backend("tiered:sqlite", str(tmp_path))
    b.put_many([(b"k1", b"v1"), (b"k2", b"v2")])
    assert b.front.get(b"k1") == b"v1"                   # front has it now
    assert b.disk.get(b"k1") == b"v1"                    # ... and so does disk
    b.close()
    bare = open_backend("sqlite", str(tmp_path))         # reopen WITHOUT front
    assert bare.get_many([b"k1", b"k2"]) == [b"v1", b"v2"]
    bare.close()


def test_promote_on_hit(tmp_path):
    bare = open_backend("sqlite", str(tmp_path))
    bare.put(b"k", b"v")
    bare.close()
    t = open_backend("tiered:sqlite", str(tmp_path))
    assert t.front.get(b"k") is None                     # cold front
    assert t.get(b"k") == b"v"                           # disk hit ...
    assert t.front.get(b"k") == b"v"                     # ... promoted
    t.close()


def test_get_many_promotes_and_preserves_duplicates(tmp_path):
    bare = open_backend("sqlite", str(tmp_path))
    bare.put_many([(b"a", b"1"), (b"b", b"2")])
    bare.close()
    t = open_backend("tiered:sqlite", str(tmp_path))
    assert t.get_many([b"a", b"b", b"a", b"nope", b"a"]) == \
        [b"1", b"2", b"1", None, b"1"]
    assert t.front.get(b"a") == b"1" and t.front.get(b"b") == b"2"
    # second lookup is served entirely from the front
    assert t.get_many([b"a", b"b"]) == [b"1", b"2"]
    t.close()


def test_front_capacity_bounds_memory_not_disk(tmp_path):
    t = TieredBackend(str(tmp_path), disk="sqlite", front_capacity=2)
    t.put_many([(b"a", b"1"), (b"b", b"2"), (b"c", b"3")])
    assert len(t) == 3                                   # disk keeps all
    assert len(t.front) == 2                             # front is bounded
    assert t.get_many([b"a", b"b", b"c"]) == [b"1", b"2", b"3"]
    t.close()


def test_delete_many_hits_both_tiers(tmp_path):
    t = open_backend("tiered:sqlite", str(tmp_path))
    t.put_many([(b"a", b"1"), (b"b", b"2")])
    assert t.delete_many([b"a", b"missing"]) == 1
    assert t.get(b"a") is None                           # not resurrected
    assert t.front.get(b"a") is None
    assert len(t) == 1
    t.close()


def test_parity_views_delegate_to_disk(tmp_path):
    t = open_backend("tiered:sqlite", str(tmp_path))
    pairs = [(b"k%d" % i, b"v%d" % i) for i in range(5)]
    t.put_many(pairs)
    assert sorted(t.items()) == sorted(pairs)
    assert sorted(t.entry_stats()) == \
        sorted((k, len(v)) for k, v in pairs)
    assert t.stat_entries([b"k0", b"nope"]) == [2, None]
    t.close()


def test_lock_delegates_to_disk_and_allows_nested_puts(tmp_path):
    """The compute-once critical section must be able to write while
    held (the kv miss path runs put_many inside lock())."""
    t = open_backend("tiered:sqlite", str(tmp_path))
    with t.lock():
        with t.lock():                                   # re-entrant
            t.put(b"k", b"v")
    assert t.get(b"k") == b"v"
    t.close()


# -- cache families over the tiered selector ----------------------------------

def _expander():
    return GenericTransformer(
        lambda inp: inp.assign(query=np.array(
            [q + "!" for q in inp["query"].tolist()], dtype=object)),
        "expander", key_columns=("qid", "query"), value_columns=("query",))


TOPICS = ColFrame({"qid": [f"q{i}" for i in range(6)],
                   "query": [f"terms {i}" for i in range(6)]})


def test_kv_cache_over_tiered_backend(tmp_path):
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="tiered:sqlite") as kv:
        assert kv._manifest.backend == "tiered:sqlite"
        cold = kv(TOPICS)
        assert kv.stats.misses == len(TOPICS)
        hot = kv(TOPICS)
        assert kv.stats.hits == len(TOPICS)
        direct = _expander()(TOPICS)
        assert cold.equals(direct) and hot.equals(direct)
    # a fresh open over the same dir replays from the disk tier
    with KeyValueCache(str(tmp_path), _expander(), key=("qid", "query"),
                       value=("query",), backend="tiered:sqlite") as kv2:
        assert kv2(TOPICS).equals(_expander()(TOPICS))
        assert kv2.stats.misses == 0


# -- observational equivalence (property test) --------------------------------

_OPS = st.lists(
    st.tuples(st.integers(0, 3),          # 0/1: put, 2: delete, 3: get
              st.integers(0, 9),          # key id (small space -> collisions)
              st.integers(0, 99)),        # value id
    min_size=1, max_size=40)


def _apply(backend, ops):
    """Drive one op sequence, returning every observable result."""
    seen = []
    for op, k, v in ops:
        key = b"key-%d" % k
        if op in (0, 1):
            backend.put_many([(key, b"val-%d" % v)])
        elif op == 2:
            seen.append(("del", backend.delete_many([key])))
        else:
            seen.append(("get", backend.get(key)))
    keys = [b"key-%d" % i for i in range(10)]
    seen.append(("get_many", backend.get_many(keys)))
    seen.append(("len", len(backend)))
    return seen


@given(ops=_OPS)
@settings(max_examples=15, deadline=None)
def test_tiered_observationally_equivalent_to_bare_disk(ops):
    """For any put/get/delete sequence, a TieredBackend over disk
    backend X is indistinguishable from X alone — including after a
    close/reopen cycle (the front tier must add speed, never state)."""
    for disk in DISK_BACKENDS:
        _check_equivalence(disk, ops)


def _check_equivalence(disk, ops):
    with tempfile.TemporaryDirectory(prefix="tiered-prop-") as tmp:
        p_tiered = os.path.join(tmp, "tiered")
        p_bare = os.path.join(tmp, "bare")
        t = open_backend(f"tiered:{disk}", p_tiered)
        b = open_backend(disk, p_bare)
        try:
            assert _apply(t, ops) == _apply(b, ops)
        finally:
            t.close()
            b.close()
        # reopen both: the surviving state must match too
        t2 = open_backend(f"tiered:{disk}", p_tiered)
        b2 = open_backend(disk, p_bare)
        try:
            keys = [b"key-%d" % i for i in range(10)]
            assert t2.get_many(keys) == b2.get_many(keys)
            assert len(t2) == len(b2)
            assert _apply(t2, ops) == _apply(b2, ops)
        finally:
            t2.close()
            b2.close()
