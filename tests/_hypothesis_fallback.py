"""Minimal stand-in for ``hypothesis`` on bare interpreters.

The tier-1 suite must collect (and meaningfully run) without optional
dependencies.  When the real ``hypothesis`` package is unavailable,
``conftest.py`` installs this module as ``sys.modules["hypothesis"]``:
``@given`` then draws a fixed number of pseudo-random examples from a
seeded RNG instead of doing real property search.  Only the strategy
surface the test suite uses is implemented (integers, floats, text,
lists, tuples, sampled_from, permutations).

This is a *fallback*, not a replacement — install ``hypothesis`` (the
``test`` extra in pyproject.toml) to get shrinking and real coverage.
"""
from __future__ import annotations

import functools
import inspect
import random
import string
import types
from typing import Any, Callable, List, Sequence

__all__ = ["given", "settings", "strategies", "assume", "make_module"]

_MAX_EXAMPLES_CAP = 20  # keep the fallback fast in CI


class _Assumption(Exception):
    pass


def assume(condition: Any) -> bool:
    if not condition:
        raise _Assumption()
    return True


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_with(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "_Strategy":
        def draw(rng: random.Random):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Assumption()
        return _Strategy(draw)


def integers(min_value: int = -(2 ** 16), max_value: int = 2 ** 16) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float = -1e6, max_value: float = 1e6,
           **_: Any) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def text(alphabet: Sequence[str] = string.ascii_lowercase,
         min_size: int = 0, max_size: int = 10) -> _Strategy:
    chars = list(alphabet)

    def draw(rng: random.Random) -> str:
        n = rng.randint(min_size, max_size)
        return "".join(rng.choice(chars) for _ in range(n))
    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10, **_: Any) -> _Strategy:
    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements.example_with(rng) for _ in range(n)]
    return _Strategy(draw)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(
        lambda rng: tuple(e.example_with(rng) for e in elements))


def sampled_from(options: Sequence[Any]) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rng: rng.choice(opts))


def permutations(values: Sequence[Any]) -> _Strategy:
    def draw(rng: random.Random) -> List[Any]:
        out = list(values)
        rng.shuffle(out)
        return out
    return _Strategy(draw)


def just(value: Any) -> _Strategy:
    return _Strategy(lambda rng: value)


def one_of(*strats: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: rng.choice(strats).example_with(rng))


def given(*strats: _Strategy, **kw_strats: _Strategy):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = (getattr(wrapper, "_max_examples", None)
                 or getattr(fn, "_max_examples", None)
                 or _MAX_EXAMPLES_CAP)
            rng = random.Random(0)
            for _ in range(min(n, _MAX_EXAMPLES_CAP)):
                drawn = [s.example_with(rng) for s in strats]
                drawn_kw = {k: s.example_with(rng)
                            for k, s in kw_strats.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except _Assumption:
                    continue
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # pytest must not mistake the drawn parameters for fixtures
        wrapper.__signature__ = inspect.Signature()
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper
    return decorate


def settings(max_examples: int = None, **_: Any):
    def decorate(fn):
        if max_examples:
            fn._max_examples = max_examples
        return fn
    return decorate


class HealthCheck:  # referenced by settings(suppress_health_check=...)
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


def make_module() -> types.ModuleType:
    """Build importable ``hypothesis`` + ``hypothesis.strategies`` modules."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.__is_repro_fallback__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "text", "lists",
                 "tuples", "sampled_from", "permutations", "just", "one_of"):
        setattr(st_mod, name, globals()[name])
    hyp.strategies = st_mod
    return hyp
