import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ColFrame, evaluate, parse_measure


QRELS = ColFrame({"qid": ["q1", "q1", "q2"],
                  "docno": ["d1", "d2", "d9"],
                  "label": [2, 1, 1]})


def results(rows):
    return ColFrame.from_dicts(
        [{"qid": q, "docno": d, "score": s, "rank": r}
         for q, d, s, r in rows])


def test_parse_measure():
    m = parse_measure("nDCG@10")
    assert m.k == 10 and m.name == "nDCG@10"
    assert parse_measure("MAP").k is None
    with pytest.raises(ValueError):
        parse_measure("XYZ@3")


def test_perfect_ranking_scores_one():
    res = results([("q1", "d1", 3.0, 0), ("q1", "d2", 2.0, 1),
                   ("q2", "d9", 1.0, 0)])
    pq = evaluate(res, QRELS, ["nDCG@10", "MAP", "MRR", "P@1", "R@10"])
    assert pq["nDCG@10"]["q1"] == pytest.approx(1.0)
    assert pq["MAP"]["q1"] == pytest.approx(1.0)
    assert pq["MRR"]["q2"] == pytest.approx(1.0)
    assert pq["P@1"]["q1"] == pytest.approx(1.0)
    assert pq["R@10"]["q2"] == pytest.approx(1.0)


def test_known_ndcg_value():
    # relevant doc (label 2) at rank 1 (0-based), nothing else
    res = results([("q1", "dX", 2.0, 0), ("q1", "d1", 1.0, 1)])
    pq = evaluate(res, QRELS, ["nDCG@10"])
    dcg = (2 ** 2 - 1) / math.log2(3)
    idcg = (2 ** 2 - 1) / math.log2(2) + (2 ** 1 - 1) / math.log2(3)
    assert pq["nDCG@10"]["q1"] == pytest.approx(dcg / idcg)


def test_unretrieved_query_scores_zero():
    res = results([("q1", "d1", 1.0, 0)])
    pq = evaluate(res, QRELS, ["MAP", "nDCG@10"])
    assert pq["MAP"]["q2"] == 0.0
    assert "q2" in pq["nDCG@10"]


def test_rr_position():
    res = results([("q2", "dA", 3.0, 0), ("q2", "dB", 2.0, 1),
                   ("q2", "d9", 1.0, 2)])
    pq = evaluate(res, QRELS, ["MRR"])
    assert pq["MRR"]["q2"] == pytest.approx(1.0 / 3.0)


@given(st.permutations(["d1", "d2", "dA", "dB", "dC"]))
@settings(max_examples=40, deadline=None)
def test_property_measures_bounded_and_monotone(perm):
    res = results([("q1", d, float(10 - i), i) for i, d in enumerate(perm)])
    pq = evaluate(res, QRELS, ["nDCG@5", "MAP", "MRR", "P@5", "R@5"])
    for m, per_q in pq.items():
        for v in per_q.values():
            assert 0.0 <= v <= 1.0
    # putting d1 (the best doc) first can never hurt nDCG vs this perm
    best_first = ["d1"] + [d for d in perm if d != "d1"]
    res2 = results([("q1", d, float(10 - i), i)
                    for i, d in enumerate(best_first)])
    pq2 = evaluate(res2, QRELS, ["nDCG@5"])
    assert pq2["nDCG@5"]["q1"] >= pq["nDCG@5"]["q1"] - 1e-12
