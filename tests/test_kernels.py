"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the container is CPU-only; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.bm25_block import bm25_block_op, bm25_block_ref
from repro.kernels.cachekey_hash import cachekey_hash_op, cachekey_hash_ref
from repro.kernels.cachekey_hash.ops import host_cachekey
from repro.kernels.dense_topk import dense_topk_op, dense_topk_ref
from repro.kernels.embedding_bag import embedding_bag_op, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_op



# -- flash attention -----------------------------------------------------------

FLASH_SWEEP = [
    # B, H, K, Sq, Sk, hd, causal, dtype
    (1, 2, 2, 64, 64, 32, True, jnp.float32),
    (2, 4, 2, 128, 128, 64, True, jnp.float32),
    (1, 8, 1, 128, 128, 64, True, jnp.float32),     # MQA
    (2, 4, 4, 96, 96, 32, True, jnp.float32),       # unaligned -> pad
    (1, 2, 2, 64, 256, 64, True, jnp.float32),      # cross Sq != Sk
    (1, 4, 2, 128, 128, 64, False, jnp.float32),
    (1, 2, 2, 128, 128, 128, True, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,K,Sq,Sk,hd,causal,dtype", FLASH_SWEEP)
def test_flash_attention_sweep(B, H, K, Sq, Sk, hd, causal, dtype):
    RNG = np.random.default_rng(B * 1000 + Sq)
    q = jnp.array(RNG.normal(size=(B, H, Sq, hd)), dtype)
    k = jnp.array(RNG.normal(size=(B, K, Sk, hd)), dtype)
    v = jnp.array(RNG.normal(size=(B, K, Sk, hd)), dtype)
    out = flash_attention_op(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_block_shape_invariance():
    RNG = np.random.default_rng(1)
    q = jnp.array(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.array(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.array(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
    outs = [flash_attention_op(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(64, 64), (128, 128), (128, 64), (64, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-5)


@given(st.integers(1, 3), st.sampled_from([1, 2, 4]),
       st.sampled_from([64, 128]), st.sampled_from([32, 64]))
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(B, K, S, hd):
    RNG = np.random.default_rng(B * 7919 + K * 131 + S + hd)
    H = K * 2
    q = jnp.array(RNG.normal(size=(B, H, S, hd)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, K, S, hd)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, K, S, hd)), jnp.float32)
    out = flash_attention_op(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# -- embedding bag -------------------------------------------------------------

EB_SWEEP = [
    # V, d, B, L, weighted, combiner, dtype
    (64, 32, 4, 5, True, "sum", jnp.float32),
    (128, 48, 8, 3, False, "sum", jnp.float32),
    (1000, 64, 16, 10, True, "mean", jnp.float32),
    (64, 128, 2, 7, True, "sum", jnp.bfloat16),
    (32, 16, 1, 1, False, "mean", jnp.float32),
]


@pytest.mark.parametrize("V,d,B,L,weighted,combiner,dtype", EB_SWEEP)
def test_embedding_bag_sweep(V, d, B, L, weighted, combiner, dtype):
    RNG = np.random.default_rng(V + d * 3 + B + L)
    tab = jnp.array(RNG.normal(size=(V, d)), dtype)
    ids = jnp.array(RNG.integers(0, V, (B, L)), jnp.int32)
    w = jnp.array(RNG.random((B, L)), dtype) if weighted else None
    out = embedding_bag_op(tab, ids, w, combiner=combiner)
    ref = embedding_bag_ref(tab, ids, w, combiner=combiner)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_embedding_bag_duplicate_ids_accumulate():
    tab = jnp.eye(8, dtype=jnp.float32)
    ids = jnp.array([[3, 3, 3]], jnp.int32)
    out = embedding_bag_op(tab, ids)
    assert float(out[0, 3]) == pytest.approx(3.0)


# -- cachekey hash --------------------------------------------------------------

@pytest.mark.parametrize("N,L", [(1, 1), (10, 7), (256, 16), (300, 64)])
def test_cachekey_hash_sweep(N, L):
    RNG = np.random.default_rng(N * 100 + L)
    toks = jnp.array(RNG.integers(0, 2 ** 31 - 1, (N, L)), jnp.int32)
    out = cachekey_hash_op(toks)
    ref = cachekey_hash_ref(toks)
    assert bool((out == ref).all())


def test_cachekey_hash_host_device_digest_identical():
    RNG = np.random.default_rng(3)
    toks = jnp.array(RNG.integers(0, 2 ** 31 - 1, (5, 9)), jnp.int32)
    out = np.asarray(cachekey_hash_op(toks))
    for i in range(5):
        host = host_cachekey(np.asarray(toks[i]))
        dev = (int(out[i, 0]).to_bytes(4, "little")
               + int(out[i, 1]).to_bytes(4, "little"))
        assert host == dev


def test_cachekey_hash_sensitivity():
    """One-token change flips the digest (avalanche sanity)."""
    RNG = np.random.default_rng(4)
    toks = jnp.array(RNG.integers(0, 1000, (1, 12)), jnp.int32)
    a = np.asarray(cachekey_hash_op(toks))
    b = np.asarray(cachekey_hash_op(toks.at[0, 5].add(1)))
    assert (a != b).any()


# -- dense topk ----------------------------------------------------------------

DENSE_SWEEP = [
    # Q, N, d, k, dtype — aligned, ragged final blocks, ragged features
    (8, 256, 32, 10, jnp.float32),
    (5, 300, 33, 7, jnp.float32),        # ragged everything -> pad+mask
    (16, 1024, 64, 100, jnp.float32),
    (3, 130, 128, 130, jnp.float32),     # k == N, one ragged doc block
    (8, 512, 64, 16, jnp.bfloat16),
    (1, 8, 16, 3, jnp.float32),          # corpus smaller than one block
]


@pytest.mark.parametrize("Q,N,d,k,dtype", DENSE_SWEEP)
def test_dense_topk_sweep(Q, N, d, k, dtype):
    RNG = np.random.default_rng(Q * 131 + N + d + k)
    q = jnp.array(RNG.normal(size=(Q, d)), dtype)
    c = jnp.array(RNG.normal(size=(N, d)), dtype)
    vals, idxs = dense_topk_op(q, c, k=k)
    rv, ri = dense_topk_ref(q, c, k=k)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=tol)
    np.testing.assert_array_equal(np.asarray(idxs), np.asarray(ri))


def test_dense_topk_tie_break_is_lower_index():
    """Duplicate corpus rows score identically; the kernel and the
    oracle both emit the lower doc index first — the total order that
    makes RankCutoff fusion sound."""
    RNG = np.random.default_rng(11)
    q = jnp.array(RNG.normal(size=(4, 32)), jnp.float32)
    base = jnp.array(RNG.normal(size=(20, 32)), jnp.float32)
    c = jnp.concatenate([base, base])            # every doc duplicated
    vals, idxs = dense_topk_op(q, c, k=40)
    rv, ri = dense_topk_ref(q, c, k=40)
    np.testing.assert_array_equal(np.asarray(idxs), np.asarray(ri))
    arr = np.asarray(idxs)
    for row in arr:
        pos = {int(dd): p for p, dd in enumerate(row)}
        for dd in range(20):
            assert pos[dd] < pos[dd + 20]


def test_dense_topk_block_shape_invariance():
    RNG = np.random.default_rng(2)
    q = jnp.array(RNG.normal(size=(8, 64)), jnp.float32)
    c = jnp.array(RNG.normal(size=(512, 64)), jnp.float32)
    outs = [dense_topk_op(q, c, k=20, block_q=bq, block_d=bd)
            for bq, bd in [(8, 128), (8, 256), (4, 128)]]
    for v, i in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0][0]), np.asarray(v),
                                   atol=2e-5)
        np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                      np.asarray(i))


def test_dense_topk_k_clamps_to_corpus():
    RNG = np.random.default_rng(3)
    q = jnp.array(RNG.normal(size=(2, 16)), jnp.float32)
    c = jnp.array(RNG.normal(size=(6, 16)), jnp.float32)
    vals, idxs = dense_topk_op(q, c, k=50)
    assert vals.shape == (2, 6)
    assert sorted(np.asarray(idxs)[0].tolist()) == list(range(6))


# -- bm25 block -------------------------------------------------------------------

@pytest.mark.parametrize("T,D", [(8, 128), (20, 150), (64, 512), (5, 40)])
def test_bm25_block_sweep(T, D):
    RNG = np.random.default_rng(T * 31 + D)
    tf = jnp.array(RNG.poisson(0.3, (T, D)), jnp.float32)
    idf = jnp.array(RNG.random(T) * 5, jnp.float32)
    dl = jnp.array(RNG.integers(20, 100, D), jnp.float32)
    out = bm25_block_op(tf, idf, dl, avg_dl=55.0)
    ref = bm25_block_ref(tf, idf, dl, avg_dl=55.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_bm25_block_matches_inverted_index():
    """The kernel reproduces the host BM25 scores on a real query."""
    from repro.ir import InvertedIndex, msmarco_like
    corpus = msmarco_like(1, scale=0.02)
    idx = InvertedIndex.build(corpus.get_corpus_iter())
    bm25 = idx.bm25(num_results=30)
    query = corpus.topics["query"][0]
    terms = [t for t in idx.tokenizer.tokenize(query) if t in idx.postings]
    D = idx.n_docs
    tf = np.zeros((len(terms), D), np.float32)
    idf = np.array([idx.idf(t) for t in terms], np.float32)
    for ti, t in enumerate(terms):
        ids, tfs = idx.postings[t]
        tf[ti, ids] = tfs
    kernel_scores = np.asarray(bm25_block_op(
        jnp.array(tf), jnp.array(idf), jnp.array(idx.doc_len),
        k1=bm25.k1, b=bm25.b, avg_dl=idx.avg_dl))
    ids, scores = bm25.score_query(query)
    np.testing.assert_allclose(kernel_scores[ids], scores, rtol=1e-4)
