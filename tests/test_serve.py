"""Serve-layer tests: PipelineService, the streaming executor, the
bounded latency reservoir, per-call cache accounting, and the
single-key read-through fast path.

The acceptance invariants of the online mode:

* scores served through ``PipelineService`` are bit-identical per qid
  to an offline ``ExecutionPlan.run`` of the same pipeline, including
  under >=4 concurrent client threads;
* N in-flight requests sharing a query execute the retrieval stage
  once per unique query (coalescing), verified via node-execution
  counts;
* micro-batches flush on ``max_batch`` (size) and on ``max_wait_ms``
  (timeout);
* a warm cache directory serves a repeat stream without a single miss.
"""
import threading
import time

import numpy as np
import pytest

from repro.caching.kv import KeyValueCache
from repro.caching.retriever import RetrieverCache
from repro.core import ColFrame, ExecutionPlan, GenericTransformer
from repro.core.executor import Reservoir
from repro.core.pipeline import add_ranks
from repro.ir import InvertedIndex, TextLoader, msmarco_like
from repro.serve import PipelineService, build_scenario, run_closed_loop

CORPUS = msmarco_like(1, scale=0.02)
INDEX = InvertedIndex.build(CORPUS.get_corpus_iter())
TOPICS = CORPUS.get_topics()


def np_reranker():
    """Deterministic numpy pointwise reranker: row-local, bit-exact
    under any batching — lets equivalence tests assert exact equality
    (MonoScorer-based serving is covered by benchmarks/system tests)."""
    def fn(frame):
        if len(frame) == 0:
            return frame
        scores = np.array(
            [((hash((q, d)) % 100003) / 1000.0)
             for q, d in zip(frame["query"].tolist(),
                             frame["docno"].tolist())], dtype=np.float64)
        return add_ranks(frame.assign(score=scores))
    return GenericTransformer(
        fn, "np_rerank", key_columns=("query", "docno"),
        value_columns=("score",))


def two_stage():
    return (INDEX.bm25(num_results=50) % 10
            >> TextLoader(CORPUS.text_map()) >> np_reranker())


def per_qid(frame):
    return {str(k[0]): frame.take(idx)
            for k, idx in frame.group_indices(["qid"]).items()}


# ---------------------------------------------------------------------------
# equivalence: served == offline, concurrent clients
# ---------------------------------------------------------------------------

def test_served_scores_bit_identical_to_offline_concurrent():
    pipeline = two_stage()
    offline, _ = ExecutionPlan([pipeline]).run(TOPICS)
    ref = per_qid(offline[0])

    svc = PipelineService(pipeline, max_batch=8, max_wait_ms=20,
                          max_workers=4)
    results = {}
    lock = threading.Lock()
    qids = TOPICS["qid"].tolist()
    queries = TOPICS["query"].tolist()

    def client(cid):
        # overlapping slices: several clients serve the same queries
        for i in range(cid, len(qids), 2):
            out = svc.submit(qids[i], queries[i]).result(60)
            with lock:
                results[str(qids[i])] = out

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.close()

    assert set(results) == set(ref)
    for qid, out in results.items():
        exp = ref[qid].sort_values(["docno"])
        got = out.sort_values(["docno"])
        assert got["docno"].tolist() == exp["docno"].tolist()
        assert np.array_equal(
            np.asarray(got["score"], dtype=np.float64),
            np.asarray(exp["score"], dtype=np.float64))      # bit-identical
        assert np.array_equal(got["rank"], exp["rank"])


def test_search_matches_offline_whole_frame():
    pipeline = two_stage()
    offline, _ = ExecutionPlan([pipeline]).run(TOPICS)
    with PipelineService(pipeline, max_wait_ms=0) as svc:
        served = svc.search(TOPICS)
    exp, got = per_qid(offline[0]), per_qid(served)
    assert set(exp) == set(got)
    for qid in exp:
        a = exp[qid].sort_values(["docno"])
        b = got[qid].sort_values(["docno"])
        assert np.array_equal(
            np.asarray(a["score"], dtype=np.float64),
            np.asarray(b["score"], dtype=np.float64))


# ---------------------------------------------------------------------------
# coalescing: a shared query retrieves once
# ---------------------------------------------------------------------------

def test_shared_query_executes_retrieval_once():
    calls = {"n": 0}
    inner = INDEX.bm25(num_results=20)

    def counted(frame):
        calls["n"] += len(frame)
        return inner(frame)

    retriever = GenericTransformer(counted, "counted_bm25",
                                   key_columns=("qid", "query"),
                                   one_to_many=True)
    svc = PipelineService(retriever, max_batch=6, max_wait_ms=2000,
                          max_workers=2)
    # 6 concurrent submissions of the SAME query fill one batch window
    futs = [svc.submit("q0", "shared query text") for _ in range(6)]
    outs = [f.result(60) for f in futs]
    stats = svc.plan_stats()
    svc.close()

    assert all(len(o) == len(outs[0]) for o in outs)
    assert calls["n"] == 1               # one unique row executed
    # node-execution counts agree: one micro-batch, one execution
    assert stats.node_exec_counts == \
        {"GenericTransformer('counted_bm25',)": 1}
    assert stats.online["rows_in"] == 6
    assert stats.online["rows_executed"] == 1


def test_conflicting_qid_rows_do_not_coalesce():
    scorer = np_reranker()
    svc = PipelineService(scorer, max_batch=4, max_wait_ms=500,
                          max_workers=2)
    rowa = {"qid": "q0", "query": "qq", "docno": "d1", "text": "ta",
            "score": 0.0, "rank": 0}
    rowb = {"qid": "q0", "query": "qq", "docno": "d2", "text": "tb",
            "score": 0.0, "rank": 0}
    fa = svc._exec.submit([rowa])
    fb = svc._exec.submit([rowb])
    a, b = fa.result(60), fb.result(60)
    svc.close()
    # same qid, different rows: each request keeps ITS row's result
    assert a["docno"].tolist() == ["d1"]
    assert b["docno"].tolist() == ["d2"]


# ---------------------------------------------------------------------------
# micro-batch flush triggers
# ---------------------------------------------------------------------------

def test_flush_trigger_size():
    svc = PipelineService(two_stage(), max_batch=4, max_wait_ms=30_000,
                          max_workers=2)
    qids = TOPICS["qid"].tolist()[:4]
    queries = TOPICS["query"].tolist()[:4]
    t0 = time.perf_counter()
    futs = [svc.submit(q, t) for q, t in zip(qids, queries)]
    for f in futs:
        f.result(60)                     # resolves long before the 30s window
    dt = time.perf_counter() - t0
    s = svc.online_stats
    assert s.flush_size >= 1 and s.flush_timeout == 0
    assert dt < 10
    svc.close()


def test_flush_trigger_timeout():
    svc = PipelineService(two_stage(), max_batch=100, max_wait_ms=50,
                          max_workers=2)
    futs = [svc.submit(TOPICS["qid"][i], TOPICS["query"][i])
            for i in range(2)]
    for f in futs:
        f.result(60)
    s = svc.online_stats
    assert s.flush_timeout >= 1 and s.flush_size == 0
    svc.close()


def test_explicit_flush_dispatches_immediately():
    svc = PipelineService(two_stage(), max_batch=100, max_wait_ms=30_000,
                          max_workers=2)
    fut = svc.submit(TOPICS["qid"][0], TOPICS["query"][0])
    svc.flush()
    fut.result(60)
    assert svc.online_stats.flush_forced >= 1
    svc.close()


# ---------------------------------------------------------------------------
# cold vs warm cache
# ---------------------------------------------------------------------------

def test_cold_then_warm_hit_rates(tmp_path):
    pipeline = two_stage()
    qids = TOPICS["qid"].tolist()[:8]
    queries = TOPICS["query"].tolist()[:8]

    svc1 = PipelineService(pipeline, cache_dir=str(tmp_path),
                           max_batch=4, max_wait_ms=5)
    r1 = [svc1.submit(q, t).result(60) for q, t in zip(qids, queries)]
    cold = svc1.stats
    assert cold.cache_misses > 0
    svc1.close()

    # a NEW service over the same directory: manifests re-validated at
    # start, stores adopted warm — the repeat stream never misses
    svc2 = PipelineService(pipeline, cache_dir=str(tmp_path),
                           max_batch=4, max_wait_ms=5)
    r2 = [svc2.submit(q, t).result(60) for q, t in zip(qids, queries)]
    warm = svc2.stats
    assert warm.cache_hits > 0 and warm.cache_misses == 0
    assert warm.summary()["hit_rate"] == 1.0
    svc2.close()

    for a, b in zip(r1, r2):
        sa = a.sort_values(["docno"])
        sb = b.sort_values(["docno"])
        assert np.array_equal(np.asarray(sa["score"], dtype=np.float64),
                              np.asarray(sb["score"], dtype=np.float64))


# ---------------------------------------------------------------------------
# satellite: bounded latency reservoir + thread-safe stats
# ---------------------------------------------------------------------------

def test_reservoir_bounded_and_stable():
    r = Reservoir(capacity=128, seed=0)
    for i in range(10_000):
        r.add(float(i % 100))
    assert len(r) == 128                 # memory bounded
    assert r.count == 10_000
    # percentiles of a uniform 0..99 stream stay near truth
    assert 30 <= r.percentile(50) <= 70
    assert r.percentile(99) >= 80


def test_service_stats_thread_safe():
    from repro.serve import ServiceStats
    stats = ServiceStats(reservoir_capacity=64)

    def hammer():
        for _ in range(500):
            stats.record_batch(n_requests=1, latencies_ms=[1.0])
            stats.add_cache_counts(2, 1)

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert stats.requests == 4000
    assert stats.batches == 4000
    assert stats.cache_hits == 8000 and stats.cache_misses == 4000
    assert len(stats.latencies) == 64    # bounded despite 4000 samples


# ---------------------------------------------------------------------------
# satellite: per-call hit/miss counts (not shared-counter deltas)
# ---------------------------------------------------------------------------

def test_per_call_counts_under_concurrency():
    seen = []

    def echo(frame):
        return frame.assign(out=np.asarray(
            [s.upper() for s in frame["text"].tolist()], dtype=object))

    t = GenericTransformer(echo, "upper", key_columns=("text",),
                           value_columns=("out",))
    cache = KeyValueCache(None, t, key="text", value="out")
    frames = [ColFrame({"text": [f"w{i}-{j}" for j in range(5)]})
              for i in range(4)]
    # warm one frame so hits and misses interleave across threads
    cache(frames[0])
    lock = threading.Lock()

    def call(i):
        out, hits, misses = cache.call_with_counts(frames[i])
        with lock:
            seen.append((i, hits, misses))

    threads = [threading.Thread(target=call, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    by_frame = dict((i, (h, m)) for i, h, m in seen)
    assert by_frame[0] == (5, 0)         # fully warm frame: all hits
    for i in (1, 2, 3):
        h, m = by_frame[i]
        assert h + m == 5 and m == 5     # cold frames: all misses
    cache.close()


# ---------------------------------------------------------------------------
# single-key read-through fast path
# ---------------------------------------------------------------------------

def test_kv_single_key_fast_path():
    def shout(frame):
        return frame.assign(out=np.asarray(
            [s + "!" for s in frame["text"].tolist()], dtype=object))

    t = GenericTransformer(shout, "shout", key_columns=("text",),
                           value_columns=("out",))
    cache = KeyValueCache(None, t, key="text", value="out")
    one = ColFrame({"text": ["hello"]})
    first = cache(one)
    assert first["out"].tolist() == ["hello!"]
    assert (cache.stats.hits, cache.stats.misses) == (0, 1)
    second = cache(one)                  # exercises _transform_single
    assert second["out"].tolist() == ["hello!"]
    assert (cache.stats.hits, cache.stats.misses) == (1, 1)
    # counts accumulate per thread until popped, then reset
    assert cache.pop_call_counts() == (1, 1)
    assert cache.pop_call_counts() == (0, 0)
    _, h, m = cache.call_with_counts(one)
    assert (h, m) == (1, 0)
    cache.close()


def test_retriever_single_key_fast_path():
    bm25 = INDEX.bm25(num_results=10)
    cache = RetrieverCache(None, bm25)
    one = ColFrame({"qid": ["q1"], "query": [TOPICS["query"][0]]})
    cold = cache(one)
    warm = cache(one)                    # exercises _transform_single
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    a = cold.sort_values(["docno"])
    b = warm.sort_values(["docno"])
    assert a["docno"].tolist() == b["docno"].tolist()
    assert np.array_equal(np.asarray(a["score"], dtype=np.float64),
                          np.asarray(b["score"], dtype=np.float64))
    cache.close()


# ---------------------------------------------------------------------------
# explain / registry / closed loop
# ---------------------------------------------------------------------------

def test_explain_carries_online_latency():
    svc = PipelineService(two_stage(), max_batch=4, max_wait_ms=5)
    for i in range(4):
        svc.submit(TOPICS["qid"][i], TOPICS["query"][i]).result(60)
    text = svc.explain()
    svc.close()
    assert "online[p50=" in text
    assert "online: requests=4" in text
    stats = svc.plan_stats()
    assert stats.online["requests"] == 4
    assert set(stats.node_exec_counts) == set(stats.online["nodes"])


def test_registry_and_closed_loop():
    scenario = build_scenario("bm25", scale=0.02, cutoff=5)
    svc = PipelineService(scenario.pipeline, cache_backend="memory",
                          max_batch=8, max_wait_ms=2)
    loop = run_closed_loop(svc, scenario, n_requests=40, n_clients=4)
    assert loop["requests"] == 40
    assert svc.stats.requests == 40
    svc.close()
    with pytest.raises(KeyError):
        build_scenario("no-such-pipeline")


def test_streaming_executor_propagates_errors():
    def boom(frame):
        raise RuntimeError("stage exploded")

    svc = PipelineService(GenericTransformer(boom, "boom"),
                          max_batch=2, max_wait_ms=5)
    fut = svc.submit("q1", "a query")
    with pytest.raises(RuntimeError, match="stage exploded"):
        fut.result(60)
    # the service survives a failed batch and serves the next request
    ok = GenericTransformer(lambda f: f, "id2")
    svc.close()
    svc2 = PipelineService(ok, max_batch=2, max_wait_ms=5)
    out = svc2.submit("q1", "a query").result(60)
    assert out["qid"].tolist() == ["q1"]
    svc2.close()
