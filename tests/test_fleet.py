"""FleetService (serve/fleet.py) + the unified ServeConfig surface:
multi-process serving over one cache directory — per-qid bit-identity
with the offline pipeline run, kill-a-worker robustness (no accepted
request lost), graceful drain with clean worker exits, and warm starts
with zero cold misses over a precomputed store."""
import glob
import os

import numpy as np
import pytest

from repro.serve import (FleetService, PipelineService, ServeConfig,
                         build_service, run_closed_loop)
from repro.caching import warm_scenario

pytestmark = pytest.mark.slow     # spawns worker processes

#: small, fast scenario shared by every fleet test
def _cfg(**kw):
    base = dict(pipeline="bm25", scale=0.02, cutoff=5, num_results=20,
                seed=0, max_batch=4, max_wait_ms=0.0, exec_workers=1,
                warm_start=False)
    base.update(kw)
    return ServeConfig(**base)


# -- ServeConfig surface ------------------------------------------------------

def test_serve_config_validates_eagerly():
    with pytest.raises(ValueError, match="workers"):
        ServeConfig(workers=0)
    with pytest.raises(ValueError, match="routing"):
        ServeConfig(routing="sticky")
    with pytest.raises(ValueError, match="selector"):
        ServeConfig(backend="bogus")
    # selectors are normalized at config time (what manifests record)
    assert ServeConfig(backend="mmap").backend == "mmap:sqlite"
    assert ServeConfig(backend=None).backend is None


def test_serve_config_coerce_and_single():
    cfg = ServeConfig.coerce({"pipeline": "bm25", "workers": 3})
    assert cfg.pipeline == "bm25" and cfg.workers == 3
    assert ServeConfig.coerce(cfg) is cfg
    assert ServeConfig.coerce(None) == ServeConfig()
    assert cfg.single().workers == 1
    assert cfg.single().pipeline == "bm25"
    with pytest.raises(TypeError, match="ServeConfig"):
        ServeConfig.coerce(42)


def test_build_service_dispatches_on_workers():
    svc = build_service(_cfg())
    try:
        assert isinstance(svc, PipelineService)
    finally:
        svc.close()
    with pytest.raises(ValueError, match="workers=1"):
        build_service(_cfg(workers=2), pipeline=object())


# -- fleet behaviour ----------------------------------------------------------

def test_fleet_bit_identity_and_clean_drain(tmp_path):
    """Every topic served through a 2-worker fleet equals the offline
    ``pipeline(topics)`` frame bit-for-bit; drain finishes in-flight
    work, refreshes the cache manifests and exits every worker 0."""
    cache_dir = str(tmp_path)
    cfg = _cfg(workers=2, cache_dir=cache_dir, warm_start=False)
    scenario = cfg.build_scenario()
    offline = scenario.pipeline(scenario.topics)
    with build_service(cfg) as svc:
        assert isinstance(svc, FleetService)
        assert sorted(svc.worker_ids) == [0, 1]
        futs = [(str(q), svc.submit(str(q), query))
                for q, query in zip(scenario.topics["qid"].tolist(),
                                    scenario.topics["query"].tolist())]
        for qid, fut in futs:
            served = fut.result(120)
            ref = offline.take(np.nonzero(offline["qid"] == qid)[0])
            assert served.equals(ref), f"fleet diverged from offline: {qid}"
        report = svc.drain()
        assert set(report["exit_codes"].values()) == {0}
        assert report["requeued"] == 0 and report["respawns"] == 0
        assert len(report["workers"]) == 2
        assert report["online"]["batches"] >= 1
        assert svc.drain() is report                     # idempotent
        with pytest.raises(RuntimeError):
            svc.submit("q1", "after drain")
    # worker close() wrote provenance manifests for the shared caches
    assert glob.glob(os.path.join(cache_dir, "**", "manifest.json"),
                     recursive=True)


def test_fleet_closed_loop_matches_single_process(tmp_path):
    """The demux resolves the same request stream a single process
    would: every request completes, none error."""
    cfg = _cfg(workers=2, cache_dir=str(tmp_path))
    with build_service(cfg) as svc:
        # run_closed_loop raises on any client error, so returning at
        # all means every request resolved
        loop = run_closed_loop(svc, cfg.build_scenario(),
                               n_requests=40, n_clients=4, seed=0)
        assert loop["requests"] == 40


def test_kill_worker_loses_no_accepted_request():
    """SIGKILL one worker with requests in flight: the demux requeues
    its accepted work to survivors and respawns the slot — every
    submitted future still resolves.  Uses the bm25-sim scenario so
    requests take long enough to be genuinely in flight."""
    cfg = _cfg(pipeline="bm25-sim", workers=3, max_batch=1)
    scenario = cfg.build_scenario()
    qids = [str(q) for q in scenario.topics["qid"].tolist()]
    queries = scenario.topics["query"].tolist()
    with FleetService(cfg) as svc:
        futs = []
        for i in range(60):                              # open loop
            j = i % len(qids)
            futs.append(svc.submit(qids[j], queries[j]))
        killed = svc.kill_worker()                       # chaos, mid-stream
        frames = [f.result(120) for f in futs]           # nothing lost
        assert len(frames) == 60
        assert all(frame is not None for frame in frames)
        assert svc.respawns >= 1
        report = svc.drain()
        # the killed worker's nonzero exit is recorded; survivors and
        # the respawned slot all drain cleanly
        live_codes = [c for wid, c in report["exit_codes"].items()
                      if wid != killed]
        assert live_codes and all(c == 0 for c in live_codes)


def test_fleet_warm_start_zero_misses(tmp_path):
    """Precompute the store offline, then serve with a fleet over the
    mmap read-mostly tier: every worker warms from the manifests on
    start and the serve epoch never misses."""
    cache_dir = str(tmp_path)
    cfg = _cfg(workers=2, cache_dir=cache_dir, backend="mmap:sqlite",
               warm_start=True)
    offline = warm_scenario(None, cache_dir, config=cfg)
    assert offline["queries_warmed"] > 0
    with FleetService(cfg) as svc:
        for wid, info in svc.warm_info.items():
            assert info["warm_misses"] == 0              # store was complete
            assert info["warm_hits"] > 0
        loop = run_closed_loop(svc, cfg.build_scenario(),
                               n_requests=40, n_clients=4, seed=0)
        assert loop["requests"] == 40
        report = svc.drain()
        assert report["online"]["cache_misses"] == 0     # no cold misses
        assert report["online"]["cache_hits"] > 0
        assert set(report["exit_codes"].values()) == {0}
