"""Provenance layer (caching/provenance.py): fingerprints, manifests,
stale-cache policies, and planner-level invalidation.

Acceptance coverage:

* mutating a cached transformer's config invalidates exactly that node
  (second run recomputes the mutated node + its downstream, still hits
  unaffected nodes);
* ``repro cache verify``-style manifest loading detects hand-corrupted
  manifests via the content checksum;
* fingerprinting is deterministic across processes (subprocess test);
* the kernel digest and its pure-Python fallback agree bit-for-bit.
"""
import os
import subprocess
import sys

import pytest

import repro.caching.provenance as prov
from repro.caching import (CacheManifest, KeyValueCache, ManifestError,
                           StaleCacheError, auto_cache)
from repro.caching.provenance import (canonical_bytes, combine_fingerprints,
                                      transformer_fingerprint)
from repro.core import (ColFrame, ExecutionPlan, GenericTransformer,
                        add_ranks)
from repro.ir import QueryExpander

QUERIES = ColFrame({"qid": ["q1", "q2", "q3"],
                    "query": ["alpha beta", "gamma delta", "epsilon zeta"]})


def make_retriever(name, n=4, base=10.0):
    def fn(inp):
        rows = [{"qid": q, "query": t, "docno": f"{name}_d{i}",
                 "score": base - i}
                for q, t in zip(inp["qid"].tolist(), inp["query"].tolist())
                for i in range(n)]
        return add_ranks(ColFrame.from_dicts(rows))
    return GenericTransformer(fn, name, one_to_many=True,
                              key_columns=("qid", "query"))


# -- fingerprints -------------------------------------------------------------

def test_fingerprint_stable_and_config_sensitive():
    assert QueryExpander(2).fingerprint() == QueryExpander(2).fingerprint()
    assert QueryExpander(2).fingerprint() != QueryExpander(3).fingerprint()
    # 16 lowercase hex chars (two FNV-1a lanes)
    fp = QueryExpander(2).fingerprint()
    assert len(fp) == 16 and int(fp, 16) >= 0


def test_fingerprint_extras_fold_in():
    class Versioned(QueryExpander):
        corpus_version = "v1"

        def fingerprint_extras(self):
            return (self.corpus_version,)

    a = Versioned(2)
    b = Versioned(2)
    b.corpus_version = "v2"
    assert a.fingerprint() != b.fingerprint()


def test_fingerprint_covers_composite_subtrees():
    qe = QueryExpander(2)
    r = make_retriever("A")
    assert (qe >> r).fingerprint() != (QueryExpander(3) >> r).fingerprint()
    assert (qe >> r).fingerprint() == \
        (QueryExpander(2) >> make_retriever("A")).fingerprint()


def test_combine_fingerprints_order_sensitive():
    assert combine_fingerprints("a", "b") != combine_fingerprints("b", "a")
    assert combine_fingerprints("a", "b") == combine_fingerprints("a", "b")


def test_canonical_bytes_distinguishes_types():
    # "1" vs 1 vs 1.0 vs True must not collide
    vals = ["1", 1, 1.0, True, (1,), b"1"]
    encs = [canonical_bytes(v) for v in vals]
    assert len(set(encs)) == len(vals)


def test_host_and_kernel_digests_agree():
    """The pure-Python fallback must be bit-identical to the
    cachekey_hash kernel digest."""
    data = canonical_bytes(("shared", 7, 2.5, ("nested", None)))
    saved = prov._DIGEST_IMPL
    try:
        prov._DIGEST_IMPL = prov._host_digest
        host = prov.digest_bytes(data)
        try:
            kernel = prov._kernel_digest_factory()
        except Exception:
            pytest.skip("cachekey_hash kernel unavailable")
        prov._DIGEST_IMPL = kernel
        assert prov.digest_bytes(data) == host
    finally:
        prov._DIGEST_IMPL = saved


@pytest.mark.slow
def test_fingerprint_deterministic_across_processes():
    script = ("from repro.ir import QueryExpander\n"
              "from repro.core import GenericTransformer\n"
              "print(QueryExpander(2).fingerprint())\n"
              "print(GenericTransformer(lambda x: x, 'named',"
              " params=(1, 2.5)).fingerprint())\n")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(root, "src"),
           "REPRO_PROVENANCE_HASH": "host"}   # skip jax startup in children
    outs = []
    for _ in range(2):
        p = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, env=env,
                           timeout=120)
        assert p.returncode == 0, p.stderr[-2000:]
        outs.append(p.stdout.split())
    assert outs[0] == outs[1]
    # ... and identical to this process's value (kernel or host path)
    assert outs[0][0] == QueryExpander(2).fingerprint()


# -- manifests ----------------------------------------------------------------

def test_manifest_roundtrip(tmp_path):
    m = CacheManifest.new(family="KeyValueCache", backend="sqlite",
                          fingerprint="aa" * 8, key_columns=["qid"],
                          value_columns=["query"])
    m.entry_count = 7
    m.save(str(tmp_path))
    loaded = CacheManifest.load(str(tmp_path))
    assert loaded == m


def test_manifest_checksum_detects_hand_edit(tmp_path):
    m = CacheManifest.new(family="KeyValueCache", backend="sqlite",
                          fingerprint="deadbeefdeadbeef")
    m.save(str(tmp_path))
    p = tmp_path / "manifest.json"
    p.write_text(p.read_text().replace("deadbeefdeadbeef",
                                       "deadbeefdeadbee0"))
    with pytest.raises(ManifestError, match="checksum"):
        CacheManifest.load(str(tmp_path))


def test_manifest_rejects_future_format_version(tmp_path):
    m = CacheManifest.new(family="X")
    m.format_version = prov.MANIFEST_VERSION + 1
    m.save(str(tmp_path))
    with pytest.raises(ManifestError, match="format_version"):
        CacheManifest.load(str(tmp_path))


def test_manifest_absent_returns_none(tmp_path):
    assert CacheManifest.load(str(tmp_path)) is None


# -- stale-cache policies -----------------------------------------------------

def _kv(path, t, **kw):
    return KeyValueCache(path, t, key=("qid", "query"), value=("query",),
                         **kw)


def test_stale_fingerprint_raises_by_default(tmp_path):
    t2, t3 = QueryExpander(2), QueryExpander(3)
    with _kv(str(tmp_path), t2, fingerprint=t2.fingerprint()) as kv:
        kv(QUERIES)
    with pytest.raises(StaleCacheError, match="fingerprint"):
        _kv(str(tmp_path), t3, fingerprint=t3.fingerprint())


def test_on_stale_recompute_discards_entries(tmp_path):
    t2, t3 = QueryExpander(2), QueryExpander(3)
    with _kv(str(tmp_path), t2, fingerprint=t2.fingerprint()) as kv:
        kv(QUERIES)
        assert len(kv) == len(QUERIES)
    with _kv(str(tmp_path), t3, fingerprint=t3.fingerprint(),
             on_stale="recompute") as kv:
        assert len(kv) == 0              # stale entries were wiped
        out = kv(QUERIES)
        assert kv.stats.misses == len(QUERIES)
        assert out["query"][0] == "alpha beta alpha alpha"   # repeat=3
    m = CacheManifest.load(str(tmp_path))
    assert m.fingerprint == t3.fingerprint()


def test_on_stale_readonly_serves_but_never_writes(tmp_path):
    t2, t3 = QueryExpander(2), QueryExpander(3)
    with _kv(str(tmp_path), t2, fingerprint=t2.fingerprint()) as kv:
        kv(QUERIES)
    extra = ColFrame({"qid": ["q9"], "query": ["eta theta"]})
    with _kv(str(tmp_path), t3, fingerprint=t3.fingerprint(),
             on_stale="readonly") as kv:
        assert kv.readonly
        kv(QUERIES)                      # stale hits, served as-is
        assert kv.stats.hits == len(QUERIES)
        kv(extra)                        # miss: computed, NOT inserted
        assert kv.stats.inserts == 0
        assert len(kv) == len(QUERIES)
    # the stale manifest was not overwritten either
    m = CacheManifest.load(str(tmp_path))
    assert m.fingerprint == t2.fingerprint()


def test_backend_mismatch_is_stale(tmp_path):
    t = QueryExpander(2)
    with _kv(str(tmp_path), t, backend="sqlite") as kv:
        kv(QUERIES)
    with pytest.raises(StaleCacheError, match="backend"):
        _kv(str(tmp_path), t, backend="dbm")


def test_invalid_on_stale_rejected(tmp_path):
    with pytest.raises(ValueError, match="on_stale"):
        _kv(str(tmp_path), QueryExpander(2), on_stale="panic")


def test_legacy_dir_without_manifest_is_adopted(tmp_path):
    """Directories written before the provenance layer (no manifest)
    stay warm: the first provenance-aware open adopts them and records
    the fingerprint."""
    t = QueryExpander(2)
    with _kv(str(tmp_path), t) as kv:    # no fingerprint recorded
        kv(QUERIES)
    os.remove(tmp_path / "manifest.json")        # simulate pre-PR3 dir
    fp = t.fingerprint()
    with _kv(str(tmp_path), t, fingerprint=fp) as kv:
        kv(QUERIES)
        assert kv.stats.hits == len(QUERIES)     # entries survived
    assert CacheManifest.load(str(tmp_path)).fingerprint == fp


def test_auto_cache_derives_fingerprint_and_detects_stale(tmp_path):
    c = auto_cache(QueryExpander(2), str(tmp_path))
    c(QUERIES)
    c.close()
    assert CacheManifest.load(str(tmp_path)).fingerprint == \
        QueryExpander(2).fingerprint()
    with pytest.raises(StaleCacheError):
        auto_cache(QueryExpander(3), str(tmp_path))
    c2 = auto_cache(QueryExpander(3), str(tmp_path), on_stale="recompute")
    assert len(c2) == 0
    c2.close()


# -- planner integration ------------------------------------------------------

def test_node_fingerprints_fold_upstream(tmp_path):
    a = make_retriever("A")
    plan2 = ExecutionPlan([QueryExpander(2) >> a])
    plan3 = ExecutionPlan([QueryExpander(3) >> a])
    fps2 = {n.label: plan2.node_fingerprints()[n.id]
            for n in plan2.nodes.values()}
    fps3 = {n.label: plan3.node_fingerprints()[n.id]
            for n in plan3.nodes.values()}
    assert fps2["<source>"] == fps3["<source>"]
    # the expander differs AND the downstream retriever node differs
    # (its provenance folds the upstream fingerprint in)
    assert fps2["QueryExpander(2,)"] != fps3["QueryExpander(3,)"]
    label_a = "GenericTransformer('A',)"
    assert fps2[label_a] != fps3[label_a]
    # replanning is deterministic
    replan = ExecutionPlan([QueryExpander(2) >> a])
    assert {n.label: replan.node_fingerprints()[n.id]
            for n in replan.nodes.values()} == fps2


def test_config_mutation_invalidates_exactly_that_node(tmp_path):
    """THE acceptance scenario: mutate one cached transformer's config;
    the second run recomputes the mutated node (and its downstream) but
    still hits every unaffected node."""
    def systems(repeat, a, b):
        return [QueryExpander(repeat) >> a, b]

    a, b = make_retriever("A"), make_retriever("B", base=8.0)
    with ExecutionPlan(systems(2, a, b), cache_dir=str(tmp_path)) as plan:
        plan.run(QUERIES)
    # same config, fresh plan: everything hits
    with ExecutionPlan(systems(2, a, b), cache_dir=str(tmp_path)) as plan:
        _, stats = plan.run(QUERIES)
        assert stats.cache_misses == 0 and stats.cache_hits > 0

    # mutate the expander's config (2 -> 3)
    with ExecutionPlan(systems(3, a, b), cache_dir=str(tmp_path)) as plan:
        node_cache = {n.stage: n.cache for n in plan.nodes.values()
                      if n.cache is not None}
        _, stats = plan.run(QUERIES)
    n = len(QUERIES)
    by_label = {type(s).__name__ if not hasattr(s, "name") else s.name: c
                for s, c in node_cache.items()}
    assert by_label["B"].stats.hits == n          # unaffected: pure hits
    assert by_label["B"].stats.misses == 0
    assert by_label["A"].stats.misses == n        # downstream of mutation
    expander = [c for s, c in node_cache.items()
                if isinstance(s, QueryExpander)][0]
    assert expander.stats.misses == n             # the mutated node
    assert stats.cache_hits == n                  # only B hit


def test_plan_manifest_written_and_updated(tmp_path):
    import json
    a, b = make_retriever("A"), make_retriever("B", base=8.0)
    with ExecutionPlan([a, b], cache_dir=str(tmp_path)) as plan:
        plan.run(QUERIES)
        path = plan._plan_manifest_path
    assert path is not None and os.path.exists(path)
    with open(path) as f:
        doc = json.load(f)
    assert doc["format_version"] == prov.PLAN_MANIFEST_VERSION
    assert len(doc["nodes"]) == 2
    assert all(nd["fingerprint"] for nd in doc["nodes"])
    assert len(doc["runs"]) == 1
    # a second plan over the same pipelines appends to the history
    with ExecutionPlan([a, b], cache_dir=str(tmp_path)) as plan:
        plan.run(QUERIES)
    with open(path) as f:
        assert len(json.load(f)["runs"]) == 2


def test_planner_on_stale_recompute_after_tamper(tmp_path):
    """Re-stamping a node dir with a foreign fingerprint trips the
    planner's default policy; on_stale='recompute' recovers."""
    a = make_retriever("A")
    with ExecutionPlan([a], cache_dir=str(tmp_path)) as plan:
        plan.run(QUERIES)
        node_dir = [n.cache.path for n in plan.nodes.values()
                    if n.cache is not None][0]
    m = CacheManifest.load(node_dir)
    m.fingerprint = "0" * 16                 # a different (valid) manifest
    m.save(node_dir)
    with pytest.raises(StaleCacheError):
        ExecutionPlan([a], cache_dir=str(tmp_path))
    with ExecutionPlan([a], cache_dir=str(tmp_path),
                       on_stale="recompute") as plan:
        _, stats = plan.run(QUERIES)
        assert stats.cache_misses == len(QUERIES)   # wiped, recomputed


def test_memo_factory_without_provenance_params_still_works(tmp_path):
    """Custom factories keep their minimal (stage, path) signature."""
    seen = []

    def factory(stage, path):
        seen.append((repr(stage), path))
        return None

    ExecutionPlan([make_retriever("A") % 3], cache_dir=str(tmp_path),
                  memo_factory=factory)
    assert len(seen) == 2 and all(p is not None for _, p in seen)


def test_experiment_forwards_on_stale(tmp_path):
    from repro.core import Experiment
    qrels = ColFrame({"qid": ["q1"], "docno": ["A_d0"], "label": [1]})
    a = make_retriever("A")
    systems = [a % 2, a % 3]
    Experiment(systems, QUERIES, qrels, ["nDCG@10"],
               precompute_prefix=True, precompute_mode="plan",
               cache_dir=str(tmp_path))
    node_dirs = [d for d in os.listdir(tmp_path) if d != "plans"]
    m = CacheManifest.load(os.path.join(str(tmp_path), node_dirs[0]))
    m.fingerprint = "1" * 16
    m.save(os.path.join(str(tmp_path), node_dirs[0]))
    with pytest.raises(StaleCacheError):
        Experiment(systems, QUERIES, qrels, ["nDCG@10"],
                   precompute_prefix=True, precompute_mode="plan",
                   cache_dir=str(tmp_path))
    Experiment(systems, QUERIES, qrels, ["nDCG@10"],
               precompute_prefix=True, precompute_mode="plan",
               cache_dir=str(tmp_path), on_stale="recompute")


def test_memo_factory_wrapper_without_path_attr(tmp_path):
    """A custom wrapper need not expose .path — the plan manifest
    records dir=None for it instead of crashing."""
    import json

    class BareMemo:
        def __init__(self, stage):
            self.stage = stage

        def __call__(self, inp):
            return self.stage(inp)

    plan = ExecutionPlan([make_retriever("A")],
                         cache_dir=str(tmp_path),
                         memo_factory=lambda stage, path: BareMemo(stage))
    outs, _ = plan.run(QUERIES)
    assert len(outs[0]) == len(QUERIES) * 4
    with open(plan._plan_manifest_path) as f:
        doc = json.load(f)
    assert doc["nodes"][0]["dir"] is None
    assert doc["nodes"][0]["family"] == "BareMemo"


def test_dense_cache_recompute_keeps_docno_enumeration(tmp_path):
    """on_stale='recompute' wipes the stale entries but must not strand
    the cache: the docno enumeration (key space) is re-used so the
    usual reopen-without-docnos path recomputes instead of raising."""
    from repro.caching import DenseScorerCache

    def scorer(shift):
        def fn(inp):
            return inp.assign(score=[float(len(d)) + shift
                                     for d in inp["docno"].tolist()])
        return GenericTransformer(fn, f"scorer{shift}",
                                  key_columns=("query", "docno"),
                                  value_columns=("score",))

    rows = ColFrame({"qid": ["q1", "q1"], "query": ["alpha", "alpha"],
                     "docno": ["d0", "d1"], "score": [0.0, 0.0]})
    s1, s2 = scorer(0.0), scorer(5.0)
    with DenseScorerCache(str(tmp_path), s1, docnos=["d0", "d1"],
                          fingerprint=s1.fingerprint()) as dc:
        dc(rows)
    with pytest.raises(StaleCacheError):
        DenseScorerCache(str(tmp_path), s2, fingerprint=s2.fingerprint())
    with DenseScorerCache(str(tmp_path), s2, fingerprint=s2.fingerprint(),
                          on_stale="recompute") as dc:
        out = dc(rows)
        assert dc.stats.misses == len(rows)       # wiped -> recomputed
        assert float(out["score"][0]) == 7.0      # len("d0") + 5.0
