"""Cost layer (core/cost.py) and the cost-aware optimizer passes.

Covers the :class:`CostModel` EWMA fold (recompute + cache-path
channels), manifest round-trips, the per-backend round-trip
microbenchmark, provenance-fingerprint stability under commutative
operand swaps, the ``cache-place`` skip/promote criteria, ``autotune``
evidence handling, explain()'s cost columns and the ``max_batch="auto"``
serving plumb-through — plus the hard invariant of the whole layer:
cost-aware plans (``optimize="all"``) are per-qid bit-identical to
cost-blind plans under the sequential scheduler, the sharded executor
and the streaming (serving) executor, property-tested over small
pipeline algebras with warm cost manifests.
"""
import tempfile

import pytest
from hypothesis import given, settings, strategies as st

from repro.caching.backends import measure_round_trip
from repro.core import ColFrame, ExecutionPlan
from repro.core.cost import (CostContext, CostModel, EWMA_ALPHA, fold_costs)
from repro.core.rewrite import run_pass
from repro.serve.service import PipelineService

from test_rewrite import (QUERIES, assert_bit_identical, boost,
                          docno_scorer, make_retriever)

#: the cost-blind reference pass list: every structural pass, none of
#: the cost-aware ones (operand-order / cache-place / autotune)
STATIC_PASSES = ["normalize", "cse", "pushdown", "cache-prune"]


# ---------------------------------------------------------------------------
# CostModel — EWMA folding + manifest round-trip
# ---------------------------------------------------------------------------

def test_observe_seeds_then_blends_ewma():
    m = CostModel()
    m.observe("fp", 1.0)
    assert m.measured_cost("fp") == 1.0
    m.observe("fp", 2.0)
    want = EWMA_ALPHA * 2.0 + (1.0 - EWMA_ALPHA) * 1.0
    assert m.measured_cost("fp") == pytest.approx(want)
    assert m.measured["fp"]["n"] == 2
    assert m.measured_cost(None) is None
    assert m.measured_cost("missing") is None


def test_observe_cache_keys_off_recompute_entry():
    m = CostModel()
    m.observe_cache("fp", 0.5)              # no recompute entry yet: no-op
    assert m.measured_cache_cost("fp") is None
    m.observe("fp", 1.0)
    m.observe_cache("fp", 0.5)              # seeds
    assert m.measured_cache_cost("fp") == 0.5
    m.observe_cache("fp", 1.5)              # blends
    want = EWMA_ALPHA * 1.5 + (1.0 - EWMA_ALPHA) * 0.5
    assert m.measured_cache_cost("fp") == pytest.approx(want)


def test_manifest_roundtrip_preserves_both_channels():
    m = CostModel()
    m.observe("fpA", 2e-3)
    m.observe_cache("fpA", 4e-4)
    m.observe("fpB", 1e-5)
    again = CostModel.from_manifest({"costs": m.to_manifest()})
    assert again.measured_cost("fpA") == pytest.approx(2e-3)
    assert again.measured_cache_cost("fpA") == pytest.approx(4e-4)
    assert again.measured_cost("fpB") == pytest.approx(1e-5)
    assert again.measured_cache_cost("fpB") is None


def test_from_manifest_tolerates_garbage():
    m = CostModel.from_manifest({"costs": {
        "ok": {"s_per_query": "0.25", "n": 3},
        "bad1": {"n": 1},                    # missing s_per_query
        "bad2": "not-a-dict",
        "bad3": {"s_per_query": "zebra"},
    }})
    assert m.measured_cost("ok") == 0.25
    assert m.measured_cost("bad1") is None
    assert m.measured_cost("bad2") is None
    assert m.measured_cost("bad3") is None
    assert CostModel.from_manifest(None).measured == {}
    assert CostModel.from_manifest({"costs": "garbled"}).measured == {}


def test_fold_costs_uses_compute_channel_for_cached_nodes():
    record = {"nodes": [{"label": "cached", "fingerprint": "fpC"},
                        {"label": "bare", "fingerprint": "fpB"}]}

    class Stats:
        n_queries = 10
        node_times_s = {"cached": 1.0, "bare": 0.5}
        node_compute_s = {"cached": 0.2}     # raw miss-path recompute
        node_compute_queries = {"cached": 4}

    fold_costs(record, Stats())
    costs = record["costs"]
    # cached node: recompute EWMA from the compute channel (0.2s / 4q),
    # NOT the store-dominated wrapper wall time; remainder is cache path
    assert costs["fpC"]["s_per_query"] == pytest.approx(0.05)
    assert costs["fpC"]["cache_s_per_query"] == pytest.approx(0.08)
    # uncached node: wall time over the run's query count
    assert costs["fpB"]["s_per_query"] == pytest.approx(0.05)

    class AllHits:
        n_queries = 10
        node_times_s = {"cached": 0.3}
        node_compute_s = {"cached": 0.0}
        node_compute_queries = {"cached": 0}  # recomputed nothing

    fold_costs(record, AllHits())
    costs = record["costs"]
    # an all-hit run contributes NO recompute observation (a near-zero
    # one would talk the planner into believing recompute is free)...
    assert costs["fpC"]["s_per_query"] == pytest.approx(0.05)
    assert costs["fpC"]["n"] == 1
    # ...but its wrapper time is a pure cache-path sample, EWMA-folded
    want = EWMA_ALPHA * 0.03 + (1.0 - EWMA_ALPHA) * 0.08
    assert costs["fpC"]["cache_s_per_query"] == pytest.approx(want)


# ---------------------------------------------------------------------------
# round-trip microbenchmark
# ---------------------------------------------------------------------------

def test_measure_round_trip_positive_and_memoized():
    v = measure_round_trip("sqlite")
    assert 0.0 < v < 1.0
    assert measure_round_trip("sqlite") == v   # per-process memo


# ---------------------------------------------------------------------------
# fingerprints — invariant under commutative operand order
# ---------------------------------------------------------------------------

def test_fingerprints_invariant_under_operand_swap():
    p1 = ExecutionPlan([make_retriever("A") + make_retriever("B", base=8.0)],
                       optimize="none")
    p2 = ExecutionPlan([make_retriever("B", base=8.0) + make_retriever("A")],
                       optimize="none")
    fp1 = p1.node_fingerprints()[p1.graph.terminals[0].id]
    fp2 = p2.node_fingerprints()[p2.graph.terminals[0].id]
    assert fp1 == fp2


# ---------------------------------------------------------------------------
# cache-place — skip/promote criteria
# ---------------------------------------------------------------------------

def _graph_with_ctx(model, round_trip_s, backend="sqlite"):
    plan = ExecutionPlan([make_retriever("A") >> docno_scorer("S")],
                         optimize=["normalize"])
    graph = plan.graph
    fps = plan.node_fingerprints()
    graph.cost = CostContext(model=model, fps=fps, backend=backend,
                             round_trip_s=round_trip_s)
    return graph, fps


def _stage_node(graph, name):
    return next(n for n in graph.nodes
                if n.kind == "stage" and name in (n.label or ""))


def test_cache_place_skips_measured_cheap_nodes():
    plan = ExecutionPlan([make_retriever("A") >> docno_scorer("S")],
                         optimize=["normalize"])
    graph, fps = plan.graph, plan.node_fingerprints()
    cheap = _stage_node(graph, "A")
    model = CostModel({fps[cheap.id]: {"s_per_query": 1e-7, "n": 3,
                                       "updated_at": 0.0}})
    graph.cost = CostContext(model=model, fps=fps, backend="sqlite",
                             round_trip_s=1e-5)
    stats = run_pass(graph, "cache-place")
    assert cheap.cache_skip is True
    assert cheap.cost_src == "measured"
    assert stats.caches_skipped == 1
    # the scorer had no measured entry: default evidence never loses a
    # cache, however cheap the prior says it is
    assert _stage_node(graph, "S").cache_skip is False


def test_cache_place_promotes_hot_expensive_nodes():
    plan = ExecutionPlan([make_retriever("A") >> docno_scorer("S")],
                         optimize=["normalize"])
    graph, fps = plan.graph, plan.node_fingerprints()
    hot = _stage_node(graph, "A")
    model = CostModel({fps[hot.id]: {"s_per_query": 1e-3, "n": 3,
                                     "updated_at": 0.0}})
    graph.cost = CostContext(model=model, fps=fps, backend="sqlite",
                             round_trip_s=1e-5)
    stats = run_pass(graph, "cache-place")
    assert hot.cache_skip is False
    assert hot.backend_override == "tiered:sqlite"
    assert stats.caches_promoted == 1


def test_cache_place_measured_cache_path_blocks_marginal_skips():
    plan = ExecutionPlan([make_retriever("A") >> docno_scorer("S")],
                         optimize=["normalize"])
    graph, fps = plan.graph, plan.node_fingerprints()
    node = _stage_node(graph, "A")
    # est*2 beats the per-entry round trip, but the node's MEASURED
    # cache path is cheaper still (e.g. a memory-fronted tier): the
    # skip must not fire — alt is min(round_trip, cache_path)
    model = CostModel({fps[node.id]: {"s_per_query": 1e-7, "n": 3,
                                      "updated_at": 0.0,
                                      "cache_s_per_query": 1e-8}})
    graph.cost = CostContext(model=model, fps=fps, backend="sqlite",
                             round_trip_s=1e-5)
    run_pass(graph, "cache-place")
    assert node.cache_skip is False


def test_cache_place_never_fires_on_cheap_round_trip():
    # round trip cheaper than recompute: skipping can only lose — the
    # est*2 < alt guard cannot fire when alt <= est
    plan = ExecutionPlan([make_retriever("A") >> docno_scorer("S")],
                         optimize=["normalize"])
    graph, fps = plan.graph, plan.node_fingerprints()
    node = _stage_node(graph, "A")
    model = CostModel({fps[node.id]: {"s_per_query": 1e-3, "n": 3,
                                      "updated_at": 0.0}})
    graph.cost = CostContext(model=model, fps=fps, backend="sqlite",
                             round_trip_s=1e-6)
    stats = run_pass(graph, "cache-place")
    assert node.cache_skip is False
    assert stats.caches_skipped == 0


def test_cache_place_noops_without_cost_context():
    plan = ExecutionPlan([make_retriever("A")], optimize=["normalize"])
    stats = run_pass(plan.graph, "cache-place")
    assert stats.caches_skipped == 0
    assert all(not n.cache_skip for n in plan.graph.nodes)


# ---------------------------------------------------------------------------
# autotune — knob selection from evidence
# ---------------------------------------------------------------------------

def test_autotune_prefers_measured_shard_history():
    plan = ExecutionPlan([make_retriever("A")], optimize=["normalize"])
    graph = plan.graph
    graph.cost = CostContext(history=[
        {"n_queries": 4, "wall_time_s": 1.0, "n_shards": 1},
        {"n_queries": 4, "wall_time_s": 0.2, "n_shards": 3},
    ])
    run_pass(graph, "autotune")
    assert graph.tuning["n_shards"] == {"value": 3,
                                        "source": "measured-history"}


def test_autotune_batch_knobs_from_online_stats():
    plan = ExecutionPlan([make_retriever("A")], optimize=["normalize"])
    graph = plan.graph
    graph.cost = CostContext(history=[
        {"n_queries": 8, "wall_time_s": 0.1, "n_shards": 1,
         "online": {"batch_occupancy": 0.95, "max_batch": 16,
                    "max_wait_ms": 2.0, "queue_depth_p99": 4.0}},
    ])
    run_pass(graph, "autotune")
    assert graph.tuning["max_batch"]["value"] == 32   # saturated: doubled
    assert graph.tuning["max_wait_ms"]["value"] == 2.0


def test_autotune_no_history_no_knobs():
    plan = ExecutionPlan([make_retriever("A")], optimize=["normalize"])
    plan.graph.cost = CostContext()
    run_pass(plan.graph, "autotune")
    assert plan.graph.tuning.get("max_batch") is None
    assert plan.tuning() == {k: v.get("value")
                             for k, v in plan.graph.tuning.items()}


# ---------------------------------------------------------------------------
# explain() — cost columns
# ---------------------------------------------------------------------------

def test_explain_renders_cost_columns(tmp_path):
    def build():
        return [make_retriever("A", 4) >> docno_scorer("S")]

    first = ExecutionPlan(build(), cache_dir=str(tmp_path),
                          cache_backend="sqlite", optimize="all")
    assert "cost[est=" in first.explain()     # estimates exist pre-run
    first.run(QUERIES)
    again = ExecutionPlan(build(), cache_dir=str(tmp_path),
                          cache_backend="sqlite", optimize="all")
    text = again.explain()
    assert "cost[est=" in text
    assert "act=" in text                     # actuals from the manifest
    assert "src=measured" in text


# ---------------------------------------------------------------------------
# serving — max_batch="auto" plumb-through
# ---------------------------------------------------------------------------

def test_max_batch_auto_resolves_without_evidence(tmp_path):
    svc = PipelineService(make_retriever("A", 4), cache_dir=str(tmp_path),
                          cache_backend="sqlite", max_batch="auto",
                          max_wait_ms="auto")
    try:
        assert svc.max_batch == 32            # fallback defaults
        assert svc.max_wait_ms == 2.0
        out = svc.search(QUERIES)
        assert len(out) > 0
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# the invariant: costs never change results
# ---------------------------------------------------------------------------

def _run_cost_vs_blind(build, run_kw=None):
    """Warm a cost manifest, then compare fresh cost-aware vs cost-blind
    compiles of the same pipelines over the same cache dir."""
    run_kw = run_kw or {}
    with tempfile.TemporaryDirectory() as td:
        warm = ExecutionPlan(build(), cache_dir=td, cache_backend="sqlite",
                             optimize="all")
        warm.run(QUERIES)
        warm.run(QUERIES)                     # fold measured costs + history
        outs_all, stats_all = ExecutionPlan(
            build(), cache_dir=td, cache_backend="sqlite",
            optimize="all").run(QUERIES, **run_kw)
        outs_blind, _ = ExecutionPlan(
            build(), cache_dir=td, cache_backend="sqlite",
            optimize=STATIC_PASSES).run(QUERIES, **run_kw)
        assert_bit_identical(outs_all, outs_blind)
        return stats_all


@settings(max_examples=6, deadline=None)
@given(shape=st.sampled_from(["sum", "weighted", "cse-twins", "chain"]),
       k=st.integers(min_value=2, max_value=5),
       w=st.sampled_from([0.5, 1.0, 2.0, 3.0]),
       n=st.integers(min_value=3, max_value=6))
def test_cost_aware_plans_bit_identical(shape, k, w, n):
    def build():
        a = make_retriever("A", n)
        b = make_retriever("B", n, base=8.0)
        if shape == "sum":
            return [(a + b) % k >> docno_scorer("S")]
        if shape == "weighted":
            return [(w * a + b) % k]
        if shape == "cse-twins":
            return [a + b, b + a]
        return [a >> boost("bst", factor=w) % k]

    _run_cost_vs_blind(build)                              # sequential
    _run_cost_vs_blind(build, {"n_shards": 2, "max_workers": 2})  # sharded


def test_cost_aware_streaming_bit_identical():
    def build():
        return (make_retriever("A", 5)
                + make_retriever("B", 5, base=8.0)) % 4

    with tempfile.TemporaryDirectory() as td:
        warm = ExecutionPlan([build()], cache_dir=td, cache_backend="sqlite",
                             optimize="all")
        warm.run(QUERIES)
        warm.run(QUERIES)
        outs = {}
        for key, opt in (("all", "all"), ("blind", STATIC_PASSES)):
            svc = PipelineService(build(), cache_dir=td,
                                  cache_backend="sqlite", optimize=opt,
                                  max_batch="auto", max_wait_ms=0.0)
            try:
                outs[key] = svc.search(QUERIES)
            finally:
                svc.close()
        assert_bit_identical([outs["all"]], [outs["blind"]])
