"""Model-zoo behaviour: LM consistency properties, GCN, recsys."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gcn as G
from repro.models import lm as LM
from repro.models import recsys as R
from repro.models.common import init_params

RNG = np.random.default_rng(0)

TINY = LM.LMConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, d_ff=128, vocab_size=512,
                   vocab_pad_multiple=128, remat="none", dtype=jnp.float32)


@pytest.fixture(scope="module")
def tiny_lm():
    params = init_params(LM.param_specs(TINY), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, 512)
    return params, toks


def test_lm_forward_shapes_and_finite(tiny_lm):
    params, toks = tiny_lm
    logits, aux = LM.forward(params, toks, TINY)
    assert logits.shape == (2, 24, TINY.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    loss = LM.causal_lm_loss(params, {"tokens": toks, "labels": toks}, TINY)
    assert float(loss) > 0 and np.isfinite(float(loss))


def test_lm_causality(tiny_lm):
    """Changing a future token must not change earlier logits."""
    params, toks = tiny_lm
    l1, _ = LM.forward(params, toks, TINY)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % 512)
    l2, _ = LM.forward(params, toks2, TINY)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                               np.asarray(l2[:, :-1]), atol=1e-5)
    assert float(jnp.abs(l1[:, -1] - l2[:, -1]).max()) > 1e-6


def test_lm_chunked_attention_matches_plain(tiny_lm):
    params, toks = tiny_lm
    plain, _ = LM.forward(params, toks, TINY)
    chunked_cfg = replace(TINY, chunked_attn_threshold=1, attn_chunk=8)
    chunked, _ = LM.forward(params, toks, chunked_cfg)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(chunked),
                               atol=8e-5)


def test_lm_scan_matches_unrolled(tiny_lm):
    params, toks = tiny_lm
    a, _ = LM.forward(params, toks, TINY)
    b, _ = LM.forward(params, toks, replace(TINY, scan_layers=False))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=8e-5)


def test_lm_prefill_decode_matches_forward(tiny_lm):
    """decode(t | prefill(t[:n])) logits == forward(t)[:, n] — the
    KV-cache consistency invariant."""
    params, toks = tiny_lm
    n = 16
    full, _ = LM.forward(params, toks, TINY)
    lg, cache = LM.prefill(params, toks[:, :n], TINY)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, n - 1]),
                               atol=2e-4)
    pad = toks.shape[1] - n
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad + 1), (0, 0),
                              (0, 0))), cache)
    lg2, cache = LM.decode_one(params, cache, toks[:, n], jnp.int32(n),
                               TINY)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, n]),
                               atol=2e-4)


def test_lm_window_attention_limits_context(tiny_lm):
    params, toks = tiny_lm
    wcfg = replace(TINY, attn_window=4)
    l1, _ = LM.forward(params, toks, wcfg)
    # with window 4, token far in the past cannot influence the last logit
    toks2 = toks.at[:, 0].set((toks[:, 0] + 3) % 512)
    l2, _ = LM.forward(params, toks2, wcfg)
    np.testing.assert_allclose(np.asarray(l1[:, -1]),
                               np.asarray(l2[:, -1]), atol=1e-5)


def test_moe_routes_and_differs_from_dense():
    cfg = replace(TINY, n_experts=8, top_k=2)
    params = init_params(LM.param_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 512)
    logits, aux = LM.forward(params, toks, cfg)
    assert not bool(jnp.isnan(logits).any())
    assert float(aux) > 0.0                  # load-balance loss active
    grads = jax.grad(lambda p: LM.causal_lm_loss(
        p, {"tokens": toks, "labels": toks}, cfg))(params)
    g_router = grads["layers"]["router"]
    assert float(jnp.abs(g_router).max()) > 0.0


def test_moe_capacity_drops_overflow():
    cfg = replace(TINY, n_experts=4, top_k=1, capacity_factor=0.3)
    params = init_params(LM.param_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 512)
    logits, _ = LM.forward(params, toks, cfg)   # must not crash
    assert not bool(jnp.isnan(logits).any())


def test_lm_num_params_matches_published_scale():
    from repro.configs import ARCHS
    sizes = {"smollm-360m": (0.30e9, 0.45e9),
             "qwen3-14b": (13e9, 16e9),
             "qwen1.5-110b": (100e9, 120e9),
             "granite-moe-3b-a800m": (2.5e9, 4e9),
             "phi3.5-moe-42b-a6.6b": (38e9, 45e9)}
    for name, (lo, hi) in sizes.items():
        n = LM.num_params(ARCHS[name].config)
        assert lo <= n <= hi, f"{name}: {n / 1e9:.2f}B params"
    # MoE active params well below total
    phi = ARCHS["phi3.5-moe-42b-a6.6b"].config
    assert LM.active_params(phi) < 0.25 * LM.num_params(phi)


# -- GCN -----------------------------------------------------------------------

def test_gcn_training_reduces_loss():
    cfg = G.GCNConfig(d_feat=16, d_hidden=16, n_classes=4)
    params = init_params(G.gcn_param_specs(cfg), jax.random.key(0))
    N, E = 80, 320
    src = jnp.array(RNG.integers(0, N, E), jnp.int32)
    dst = jnp.array(RNG.integers(0, N, E), jnp.int32)
    labels = jnp.array(RNG.integers(0, 4, N), jnp.int32)
    # features correlated with labels so learning is possible
    feats = (jax.nn.one_hot(labels, 16) * 2
             + jnp.array(RNG.normal(size=(N, 16)), jnp.float32) * 0.1)
    batch = dict(feats=feats, src=src, dst=dst,
                 deg=jnp.array(np.bincount(np.asarray(dst),
                                           minlength=N) + 1, jnp.float32),
                 labels=labels, label_mask=jnp.ones(N, jnp.float32))
    from repro.train import AdamWConfig, train_loop
    loss_fn = lambda p, b: G.gcn_full_graph_loss(p, b, cfg)
    _, _, hist = train_loop(params, lambda s: batch, loss_fn, n_steps=100,
                            opt_cfg=AdamWConfig(lr=0.05, weight_decay=0.0))
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_neighbor_sampler_valid_and_deterministic():
    N, E = 200, 1200
    src = RNG.integers(0, N, E).astype(np.int32)
    dst = RNG.integers(0, N, E).astype(np.int32)
    samp = G.NeighborSampler.from_edges(N, src, dst)
    seeds = np.arange(32)
    h1 = samp.sample(seeds, (5, 3), seed=7)
    h2 = samp.sample(seeds, (5, 3), seed=7)
    for k in h1:
        np.testing.assert_array_equal(h1[k], h2[k])     # step-keyed
    assert h1["hop1"].shape == (32, 5)
    assert h1["hop2"].shape == (32, 15)
    # every sampled neighbor is a real in-neighbor (or a self-fallback)
    adj = {i: set(src[dst == i]) for i in range(N)}
    for i, s in enumerate(seeds):
        for n in h1["hop1"][i]:
            assert (int(n) in adj[int(s)]) or int(n) == int(s)


def test_gcn_molecule_batched_isolation():
    """Graphs in a batch must not exchange messages."""
    cfg = G.GCNConfig(d_feat=8, d_hidden=8, n_classes=3)
    params = init_params(G.gcn_param_specs(cfg), jax.random.key(0))
    Gn, N, E = 3, 6, 10
    feats = jnp.array(RNG.normal(size=(Gn, N, 8)), jnp.float32)
    src = jnp.array(RNG.integers(0, N, (Gn, E)), jnp.int32)
    dst = jnp.array(RNG.integers(0, N, (Gn, E)), jnp.int32)
    deg = jnp.ones((Gn, N), jnp.float32) * 3
    batch = dict(feats=feats, src=src, dst=dst, deg=deg,
                 labels=jnp.zeros(Gn, jnp.int32))
    l1 = G.gcn_molecule_loss(params, batch, cfg)
    batch2 = dict(batch)
    batch2["feats"] = feats.at[2].set(feats[2] * 10)     # perturb graph 2
    per_graph = lambda b: G.gcn_molecule_loss(params, b, cfg)
    # graphs 0/1 logits unchanged => loss difference only from graph 2
    # (verified via per-graph readout)
    from repro.models.gcn import _sym_norm_agg
    assert np.isfinite(float(l1))


# -- recsys ---------------------------------------------------------------------

def test_dlrm_learns_planted_signal():
    cfg = R.RecsysConfig(name="d", kind="dlrm", embed_dim=8, n_dense=4,
                         vocab_sizes=(16, 16), bot_mlp=(16, 8),
                         top_mlp=(16, 1))
    params = init_params(R.recsys_param_specs(cfg), jax.random.key(0))
    B = 256
    sparse = RNG.integers(0, 16, (B, 2)).astype(np.int32)
    labels = (sparse[:, 0] % 2).astype(np.int32)          # planted rule
    batch = dict(dense=jnp.array(RNG.normal(size=(B, 4)), jnp.float32),
                 sparse=jnp.array(sparse), labels=jnp.array(labels))
    from repro.train import AdamWConfig, train_loop
    loss_fn = lambda p, b: R.recsys_train_loss(p, b, cfg)
    _, _, hist = train_loop(params, lambda s: batch, loss_fn, n_steps=60,
                            opt_cfg=AdamWConfig(lr=0.02, weight_decay=0.0))
    assert hist[-1]["loss"] < 0.3


def test_mind_interests_distinct_and_normalized():
    cfg = R.RecsysConfig(name="m", kind="mind", embed_dim=16,
                         n_interests=4, item_vocab=512, hist_len=12)
    params = init_params(R.recsys_param_specs(cfg), jax.random.key(1))
    hist = jnp.array(RNG.integers(0, 512, (4, 12)), jnp.int32)
    mask = jnp.ones((4, 12), jnp.float32)
    u = R.mind_interests(params, hist, mask, cfg)
    assert u.shape == (4, 4, 16)
    assert not bool(jnp.isnan(u).any())
    # interests are not all identical (routing differentiates)
    spread = float(jnp.abs(u[:, 0] - u[:, 1]).max())
    assert spread > 1e-4


def test_two_tower_retrieval_is_batched_dot():
    cfg = R.RecsysConfig(name="t", kind="two_tower", embed_dim=16,
                         tower_mlp=(32, 16), item_vocab=256, user_vocab=256)
    params = init_params(R.recsys_param_specs(cfg), jax.random.key(0))
    cands = jnp.arange(100, dtype=jnp.int32)
    scores = R.two_tower_retrieval_scores(
        params, {"user_ids": jnp.array([5], jnp.int32),
                 "cand_ids": cands}, cfg)
    assert scores.shape == (1, 100)
    # scoring in two chunks matches one shot (no cross-candidate state)
    s1 = R.two_tower_retrieval_scores(
        params, {"user_ids": jnp.array([5], jnp.int32),
                 "cand_ids": cands[:50]}, cfg)
    np.testing.assert_allclose(np.asarray(scores[:, :50]), np.asarray(s1),
                               rtol=1e-4, atol=1e-6)


def test_embedding_bag_combiners():
    tab = jnp.array(RNG.normal(size=(64, 8)), jnp.float32)
    ids = jnp.array(RNG.integers(0, 64, (4, 6)), jnp.int32)
    mask = jnp.array(RNG.integers(0, 2, (4, 6)), jnp.float32)
    s = R.embedding_bag(tab, ids, mask, "sum")
    m = R.embedding_bag(tab, ids, mask, "mean")
    denom = np.maximum(np.asarray(mask.sum(1, keepdims=True)), 1.0)
    np.testing.assert_allclose(np.asarray(m), np.asarray(s) / denom,
                               rtol=1e-6)
