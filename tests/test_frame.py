import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ColFrame, relation_of


def test_construction_and_basic_ops():
    f = ColFrame({"qid": ["q1", "q2", "q1"], "score": [3.0, 1.0, 2.0]})
    assert len(f) == 3
    assert set(f.columns) == {"qid", "score"}
    assert f["score"].dtype == np.float64
    head = f.head(2)
    assert len(head) == 2
    masked = f.mask(f["score"] > 1.5)
    assert len(masked) == 2


def test_from_dicts_roundtrip():
    rows = [{"a": 1, "b": "x"}, {"a": 2, "b": "y"}]
    f = ColFrame.from_dicts(rows)
    assert f.to_dicts() == rows


def test_relation_of():
    assert relation_of(ColFrame({"qid": ["1"], "query": ["a"]})) == "Q"
    assert relation_of(ColFrame({"docno": ["1"], "text": ["a"]})) == "D"
    assert relation_of(ColFrame({"qid": ["1"], "docno": ["d"],
                                 "score": [1.0], "rank": [0]})) == "R"
    assert relation_of(ColFrame({"qid": ["1"], "docno": ["d"],
                                 "label": [1]})) == "RA"


def test_sort_group_dedup():
    f = ColFrame({"qid": ["b", "a", "a"], "score": [1.0, 3.0, 2.0]})
    s = f.sort_values(["qid", "score"], ascending=[True, False])
    assert s["qid"].tolist() == ["a", "a", "b"]
    assert s["score"].tolist() == [3.0, 2.0, 1.0]
    groups = f.group_indices(["qid"])
    assert set(groups.keys()) == {("a",), ("b",)}
    assert len(groups[("a",)]) == 2
    d = f.dedup(["qid"])
    assert len(d) == 2


def test_merge_inner_and_left():
    a = ColFrame({"k": ["x", "y", "z"], "va": [1, 2, 3]})
    b = ColFrame({"k": ["y", "z"], "vb": [20, 30]})
    inner = a.merge(b, on=["k"])
    assert inner["k"].tolist() == ["y", "z"]
    assert inner["vb"].tolist() == [20, 30]
    left = a.merge(b, on=["k"], how="left")
    assert len(left) == 3
    assert left["vb"].tolist()[0] is None


def test_concat_preserves_common_columns():
    a = ColFrame({"x": [1], "y": ["p"]})
    b = ColFrame({"x": [2], "y": ["q"], "z": [9]})
    c = ColFrame.concat([a, b])
    assert set(c.columns) == {"x", "y"}
    assert c["x"].tolist() == [1, 2]


@given(st.lists(st.tuples(st.integers(0, 5), st.floats(-100, 100)),
                min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_property_sort_is_ordered(rows):
    f = ColFrame({"k": [r[0] for r in rows],
                  "v": [r[1] for r in rows]})
    s = f.sort_values(["v"])
    vals = s["v"].tolist()
    assert all(vals[i] <= vals[i + 1] for i in range(len(vals) - 1))


@given(st.lists(st.text(alphabet="abc", min_size=1, max_size=3),
                min_size=1, max_size=40))
@settings(max_examples=50, deadline=None)
def test_property_dedup_keeps_first_occurrence(keys):
    f = ColFrame({"k": keys, "i": list(range(len(keys)))})
    d = f.dedup(["k"])
    seen = {}
    for k, i in zip(keys, range(len(keys))):
        seen.setdefault(k, i)
    assert sorted(d["i"].tolist()) == sorted(seen.values())
