"""Shared pytest setup.

Tier-1 must collect on a bare interpreter: when the optional
``hypothesis`` dependency is missing, install the deterministic
fallback sampler from ``_hypothesis_fallback`` under the ``hypothesis``
module names *before* the test modules import it.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _mod = _hypothesis_fallback.make_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
