"""Documentation gauntlet (CI `docs` job).

Two checks over the markdown docs:

1. **Links and anchors** — every relative link in `docs/*.md` and
   `README.md` must resolve to a file in the repository, and every
   `#fragment` on a markdown target must match a heading in that file
   (GitHub anchor-style slugs). External (`http[s]://`) links are not
   fetched.
2. **Executable examples** — the fenced ```python blocks of the docs
   listed in ``EXECUTABLE_DOCS`` are concatenated top-to-bottom per
   file and executed; a doc whose examples don't run is treated as
   broken. Blocks fenced with any other info string (```text,
   ```console, ...) are prose.

Run from the repository root:

    PYTHONPATH=src python tools/docs_check.py
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: docs whose ```python blocks must execute (concatenated per file)
EXECUTABLE_DOCS = ("docs/architecture.md", "docs/caching.md")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^```(\S*)\s*$")
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")


def _strip_code(text: str) -> str:
    """Markdown with fenced code blocks blanked (links inside code are
    not links)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            out.append("")
            continue
        out.append("" if in_fence else line)
    return "\n".join(out)


def _anchor(heading: str) -> str:
    """GitHub-style slug: lowercase, drop punctuation, spaces → dashes."""
    slug = re.sub(r"[`*_]", "", heading.strip().lower())
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def anchors_of(path: str) -> set:
    with open(path, encoding="utf-8") as fh:
        text = _strip_code(fh.read())
    return {_anchor(m.group(2)) for m in map(_HEADING.match,
                                             text.splitlines()) if m}


def check_links(md_path: str) -> list:
    errors = []
    base = os.path.dirname(md_path)
    with open(md_path, encoding="utf-8") as fh:
        text = _strip_code(fh.read())
    rel = os.path.relpath(md_path, ROOT)
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        dest = md_path if not path_part else \
            os.path.normpath(os.path.join(base, path_part))
        if not os.path.exists(dest):
            errors.append(f"{rel}: broken link -> {target}")
            continue
        if frag and dest.endswith(".md"):
            if _anchor(frag) not in anchors_of(dest):
                errors.append(f"{rel}: missing anchor -> {target}")
    return errors


def python_blocks(md_path: str) -> list:
    blocks, cur = [], None
    with open(md_path, encoding="utf-8") as fh:
        for line in fh:
            m = _FENCE.match(line)
            if m:
                if cur is None and m.group(1) == "python":
                    cur = []
                elif cur is not None:
                    blocks.append("".join(cur))
                    cur = None
                continue
            if cur is not None:
                cur.append(line)
    return blocks


def run_examples(md_path: str) -> list:
    blocks = python_blocks(md_path)
    rel = os.path.relpath(md_path, ROOT)
    if not blocks:
        return [f"{rel}: no executable python blocks found"]
    src = "\n".join(blocks)
    print(f"  executing {len(blocks)} python block(s) from {rel}")
    try:
        exec(compile(src, rel, "exec"), {"__name__": f"docs:{rel}"})
    except Exception as exc:                       # noqa: BLE001
        import traceback
        traceback.print_exc()
        return [f"{rel}: examples failed: {type(exc).__name__}: {exc}"]
    return []


def main() -> int:
    docs = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    docs.append(os.path.join(ROOT, "README.md"))
    errors = []
    for path in docs:
        errors.extend(check_links(path))
    print(f"checked links in {len(docs)} file(s)")
    for rel in EXECUTABLE_DOCS:
        errors.extend(run_examples(os.path.join(ROOT, rel)))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print("docs check:", "FAIL" if errors else "ok")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
