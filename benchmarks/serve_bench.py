"""Online-serving benchmark: closed-loop request stream, cold vs warm.

Stands up a :class:`~repro.serve.PipelineService` over the two-stage
``bm25 % k >> text_loader >> mono_scorer`` pipeline and drives it with
N closed-loop client threads (each submits one query at a time and
waits — concurrency equals the client count, the service's
micro-batching does the coalescing).  Two epochs over one cache
directory:

* **cold** — a fresh cache directory: every request pays retrieval and
  the jitted reranker;
* **warm** — a *new service instance* over the same directory
  (provenance manifests re-validated once at its start).  Both epochs
  run in one process, so JAX's compile cache stays warm across them —
  the latency comparison shows the caching win on top of compilation;
  the *correctness* gate is the miss count: a warm epoch whose reads
  actually come from the store misses **zero** times (zipf traffic
  only repeats topic-pool queries the cold epoch already cached);
* **warmed** — speculative precomputation instead of organic traffic:
  ``repro.caching.warm_scenario`` precomputes a *fresh* directory
  offline over the scenario's expected traffic distribution, then a
  first-ever service runs over it.  Its very first epoch should look
  like steady state — the cold-start tail collapses without any prior
  serve epoch having touched the directory.

Reported per epoch: request p50/p99 latency, throughput, cache
hits/misses + hit rate, micro-batch occupancy and per-node online
latency — the request-level view of the paper's Table-2 mechanism.
The CI ``serve-smoke`` job asserts ``warm p50 < cold p50`` AND
``warm cache_misses == 0`` from the ``--json`` artifact (the second
catches a broken warm-restart path that latency alone cannot); the
``cache-lifecycle`` job additionally asserts the warmed-start epoch
misses zero times with first-epoch p50 within 1.3x of the organic
warm epoch's.

With ``--fleet`` two additional epochs measure the multi-process serve
fleet (``repro.serve.FleetService``) on the ``bm25-sim`` scenario —
bm25 served from a warmed shared cache (``mmap:sqlite`` read-mostly
tier) followed by an *uncacheable* simulated per-row device latency,
so throughput measures serving capacity rather than cache lookups:
one worker process vs ``--fleet-workers`` processes over the same
cache directory, same request stream.  The row set gains
``fleet_scaling`` (N-worker / 1-worker throughput; ≥3x on a warm
4-worker fleet since the simulated device waits overlap across
processes) and a per-qid ``bit_identical`` gate: every topic served
through the fleet must equal the offline ``pipeline(topics)`` frame
bit-for-bit.

``--quick`` shrinks the workload for CI; ``--json PATH`` writes
``{"rows": [...]}`` with one row per epoch.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional

import numpy as np

from repro.caching import warm_scenario
from repro.serve import (PipelineService, ServeConfig, build_scenario,
                         build_service, run_closed_loop)


def run_epoch(name: str, scenario, cache_dir: str, *, requests: int,
              clients: int, max_batch: int, max_wait_ms: float,
              workers: int, seed: int, prefetch: bool = True) -> Dict:
    svc = PipelineService(scenario.pipeline, cache_dir=cache_dir,
                          max_batch=max_batch, max_wait_ms=max_wait_ms,
                          max_workers=workers, prefetch=prefetch)
    try:
        loop = run_closed_loop(svc, scenario, n_requests=requests,
                               n_clients=clients, seed=seed)
        summary = svc.stats.summary()
        online = svc.online_stats.as_dict(svc.max_batch)
    finally:
        svc.close()
    row = {"name": name, "prefetch": prefetch, **loop,
           "p50_ms": round(summary["p50_ms"], 4),
           "p99_ms": round(summary["p99_ms"], 4),
           "hit_rate": round(summary["hit_rate"], 4),
           "cache_hits": online["cache_hits"],
           "cache_misses": online["cache_misses"],
           "batches": summary["batches"],
           "batch_occupancy": online["batch_occupancy"],
           "flush_size": online["flush_size"],
           "flush_timeout": online["flush_timeout"],
           "nodes": online["nodes"]}
    print(f"[{name}] p50={row['p50_ms']}ms p99={row['p99_ms']}ms "
          f"hit_rate={row['hit_rate']} "
          f"throughput={row['throughput_rps']} req/s "
          f"occupancy={row['batch_occupancy']}")
    return row


def _fleet_bit_identity(svc, scenario) -> bool:
    """Serve every topic through the fleet and compare per-qid frames
    against the offline pipeline run, bit for bit."""
    offline = scenario.pipeline(scenario.topics)
    qids = [str(q) for q in scenario.topics["qid"].tolist()]
    queries = scenario.topics["query"].tolist()
    futs = [(qid, svc.submit(qid, query, **scenario.request_extra.get(qid, {})))
            for qid, query in zip(qids, queries)]
    for qid, fut in futs:
        served = fut.result(120)
        ref = offline.take(np.nonzero(offline["qid"] == qid)[0])
        if not served.equals(ref):
            return False
    return True


def run_fleet_epoch(name: str, cfg: ServeConfig, *, requests: int,
                    clients: int, seed: int,
                    check_identity: bool = False) -> Dict:
    svc = build_service(cfg)
    try:
        scenario = cfg.build_scenario()
        loop = run_closed_loop(svc, scenario, n_requests=requests,
                               n_clients=clients, seed=seed)
        identical = (_fleet_bit_identity(svc, scenario)
                     if check_identity else None)
        if cfg.workers > 1:
            report = svc.drain()
            online = report["online"]
            exit_codes = report["exit_codes"]
        else:
            online = svc.online_stats.as_dict(svc.max_batch)
            exit_codes = None
        summary = svc.stats.summary()
    finally:
        svc.close()
    row = {"name": name, "workers": cfg.workers, **loop,
           "p50_ms": round(summary["p50_ms"], 4),
           "p99_ms": round(summary["p99_ms"], 4),
           "hit_rate": round(summary["hit_rate"], 4),
           "cache_hits": online["cache_hits"],
           "cache_misses": online["cache_misses"]}
    if identical is not None:
        row["bit_identical"] = identical
    if exit_codes is not None:
        row["exit_codes"] = {str(k): v for k, v in exit_codes.items()}
    print(f"[{name}] workers={cfg.workers} "
          f"throughput={row['throughput_rps']} req/s "
          f"p50={row['p50_ms']}ms misses={row['cache_misses']}"
          + (f" bit_identical={identical}" if identical is not None else ""))
    return row


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small workload for the CI smoke job")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write rows as a JSON artifact")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--cutoff", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--cache-dir", default=None,
                    help="cache root (default: a temp dir per run)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-prefetch", action="store_true",
                    help="ablation: serve every epoch with the async "
                         "data plane's query-keyed prefetch disabled "
                         "(PipelineService(prefetch=False)); without "
                         "this flag a serve_warm_noprefetch epoch is "
                         "added so the artifact carries the paired "
                         "comparison either way")
    ap.add_argument("--fleet", action="store_true",
                    help="add the multi-process fleet scaling epochs")
    ap.add_argument("--fleet-workers", type=int, default=4,
                    help="fleet size of the scaled epoch (vs 1 worker)")
    ap.add_argument("--fleet-clients", type=int, default=16,
                    help="closed-loop clients of the fleet epochs")
    args = ap.parse_args(argv)

    requests = args.requests or (120 if args.quick else 600)
    scale = args.scale or (0.02 if args.quick else 0.05)

    scenario = build_scenario("bm25-mono", scale=scale, cutoff=args.cutoff,
                              num_results=100, seed=args.seed)
    tmp = None
    cache_dir = args.cache_dir
    if cache_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="serve-bench-")
        cache_dir = tmp.name

    prefetch = not args.no_prefetch
    rows = []
    for epoch in ("serve_cold", "serve_warm"):
        rows.append(run_epoch(epoch, scenario, cache_dir,
                              requests=requests, clients=args.clients,
                              max_batch=args.max_batch,
                              max_wait_ms=args.max_wait_ms,
                              workers=args.workers, seed=args.seed,
                              prefetch=prefetch))
    cold, warm = rows
    print(f"warm/cold p50: {warm['p50_ms']}/{cold['p50_ms']}ms "
          f"({cold['p50_ms'] / max(warm['p50_ms'], 1e-9):.1f}x)")

    if prefetch:
        # ablation epoch: same warm directory, prefetch off — the JSON
        # artifact then carries the paired data-plane comparison
        noprefetch = run_epoch("serve_warm_noprefetch", scenario, cache_dir,
                               requests=requests, clients=args.clients,
                               max_batch=args.max_batch,
                               max_wait_ms=args.max_wait_ms,
                               workers=args.workers, seed=args.seed,
                               prefetch=False)
        rows.append(noprefetch)
        print(f"warm p50 prefetch on/off: {warm['p50_ms']}/"
              f"{noprefetch['p50_ms']}ms (misses="
              f"{noprefetch['cache_misses']})")

    # warmed-start epoch: precompute a FRESH directory offline, then
    # measure the first-ever service over it (same process, so the JIT
    # compile cache is equally warm — the comparison isolates the cache
    # effect from compilation)
    warmed_dir = os.path.join(cache_dir, "warmed-start")
    offline = warm_scenario(scenario, warmed_dir,
                            clients=args.clients, seed=args.seed)
    print(f"[warm_offline] precomputed {offline['queries_warmed']} "
          f"query(s), {offline['cache_misses']} entries computed, "
          f"{offline['wall_s']}s")
    warmed = run_epoch("serve_warmed", scenario, warmed_dir,
                       requests=requests, clients=args.clients,
                       max_batch=args.max_batch,
                       max_wait_ms=args.max_wait_ms,
                       workers=args.workers, seed=args.seed)
    rows.append(warmed)
    print(f"warmed/warm p50: {warmed['p50_ms']}/{warm['p50_ms']}ms "
          f"({warmed['p50_ms'] / max(warm['p50_ms'], 1e-9):.2f}x, "
          f"misses={warmed['cache_misses']})")

    fleet_scaling = None
    if args.fleet:
        # fleet epochs: warmed shared cache (mmap read-mostly tier) +
        # uncacheable simulated device latency; max_batch=1 /
        # exec_workers=1 model one synchronous replica per process, so
        # the only parallelism measured is the fleet's
        fleet_dir = os.path.join(cache_dir, "fleet")
        base = ServeConfig(pipeline="bm25-sim", scale=scale,
                           cutoff=args.cutoff, num_results=100,
                           seed=args.seed, cache_dir=fleet_dir,
                           backend="mmap:sqlite", max_batch=1,
                           max_wait_ms=0.0, exec_workers=1)
        fleet_offline = warm_scenario(None, fleet_dir, config=base)
        print(f"[fleet_offline] precomputed "
              f"{fleet_offline['queries_warmed']} query(s) into the "
              f"shared {base.backend} store")
        fleet_requests = args.requests or (160 if args.quick else 400)
        w1 = run_fleet_epoch("fleet_w1", base,
                             requests=fleet_requests,
                             clients=args.fleet_clients, seed=args.seed)
        wn = run_fleet_epoch(f"fleet_w{args.fleet_workers}",
                             dataclasses.replace(
                                 base, workers=args.fleet_workers),
                             requests=fleet_requests,
                             clients=args.fleet_clients, seed=args.seed,
                             check_identity=True)
        rows.extend([w1, wn])
        fleet_scaling = round(
            wn["throughput_rps"] / max(w1["throughput_rps"], 1e-9), 2)
        print(f"fleet scaling 1->{args.fleet_workers}: {fleet_scaling}x "
              f"(bit_identical={wn['bit_identical']})")

    if args.json:
        payload = {"rows": rows, "requests": requests, "scale": scale,
                   "clients": args.clients, "max_batch": args.max_batch,
                   "max_wait_ms": args.max_wait_ms,
                   "warm_offline": offline}
        if fleet_scaling is not None:
            payload["fleet_scaling"] = fleet_scaling
            payload["fleet_workers"] = args.fleet_workers
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[wrote {args.json}]")
    if tmp is not None:
        tmp.cleanup()
    return rows


if __name__ == "__main__":
    main()
