"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [table2|cache|precompute|kernels]

Emits CSV blocks per suite; table2_reproduction is the paper's §5
experiment (its assertions enforce the paper's qualitative claims).
Roofline terms for the dry-run grid are produced by
``repro.launch.dryrun`` (see EXPERIMENTS.md §Roofline), not here —
they need the 512-device placeholder env.
"""
from __future__ import annotations

import sys
import time

from . import cache_micro, kernels_bench, plan_bench, precompute_bench, \
    table2_reproduction

SUITES = {
    "table2": table2_reproduction.main,
    "cache": cache_micro.main,
    "precompute": precompute_bench.main,
    # plan_bench.main argparses its argv; the orchestrator passes none
    "plan": lambda: plan_bench.main([]),
    "kernels": kernels_bench.main,
}


def main(argv=None) -> None:
    args = argv if argv is not None else sys.argv[1:]
    names = args or list(SUITES)
    for name in names:
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        SUITES[name]()
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")


if __name__ == "__main__":
    main()
