"""Cache micro-benchmarks: per-row lookup/insert cost per backend.

One row per (cache family × operation); ``us_per_row`` is the paper-
relevant number (how much overhead a cache adds vs recomputation).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.caching import (DenseScorerCache, IndexerCache, KeyValueCache,
                           RetrieverCache, ScorerCache)
from repro.core import ColFrame, GenericTransformer, add_ranks
from repro.ir import InvertedIndex, msmarco_like


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    return out, time.perf_counter() - t0


def run(n_rows: int = 2000) -> List[Dict]:
    corpus = msmarco_like(1, scale=0.05)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    rows = []

    # a scorer frame with n_rows (query, docno) pairs
    docs = corpus.docs
    n = min(n_rows, len(docs))
    frame = ColFrame({
        "qid": [f"q{i % 50}" for i in range(n)],
        "query": [f"query text {i % 50}" for i in range(n)],
        "docno": [str(docs["docno"][i]) for i in range(n)],
        "score": np.zeros(n), "rank": np.zeros(n, dtype=np.int64)})

    scorer = GenericTransformer(
        lambda inp: inp.assign(score=np.arange(len(inp), dtype=np.float64)),
        "identity_scorer", key_columns=("query", "docno"),
        value_columns=("score",))

    with ScorerCache(None, scorer) as sc:
        _, t_cold = _timed(sc, frame)
        _, t_hot = _timed(sc, frame)
        rows.append({"name": "scorer_cache_insert",
                     "us_per_row": t_cold / n * 1e6})
        rows.append({"name": "scorer_cache_hit",
                     "us_per_row": t_hot / n * 1e6})

    with DenseScorerCache(None, scorer,
                          docnos=docs["docno"].tolist()) as dc:
        _, t_cold = _timed(dc, frame)
        _, t_hot = _timed(dc, frame)
        rows.append({"name": "dense_scorer_cache_insert",
                     "us_per_row": t_cold / n * 1e6})
        rows.append({"name": "dense_scorer_cache_hit",
                     "us_per_row": t_hot / n * 1e6})

    topics = corpus.get_topics()
    bm25 = index.bm25(num_results=100)
    with RetrieverCache(None, bm25) as rc:
        _, t_cold = _timed(rc, topics)
        out, t_hot = _timed(rc, topics)
        rows.append({"name": "retriever_cache_insert",
                     "us_per_row": t_cold / max(len(out), 1) * 1e6})
        rows.append({"name": "retriever_cache_hit",
                     "us_per_row": t_hot / max(len(out), 1) * 1e6})

    with IndexerCache(None) as ic:
        _, t_w = _timed(ic.index, corpus.get_corpus_iter())
        _, t_r = _timed(lambda: sum(1 for _ in ic))
        rows.append({"name": "indexer_cache_write",
                     "us_per_row": t_w / len(docs) * 1e6})
        rows.append({"name": "indexer_cache_replay",
                     "us_per_row": t_r / len(docs) * 1e6})

    return rows


def main():
    rows = run()
    print("name,us_per_row")
    for r in rows:
        print(f"{r['name']},{r['us_per_row']:.2f}")
    return rows


if __name__ == "__main__":
    main()
