"""Cache micro-benchmarks: per-row lookup/insert cost per backend.

One row per (cache family × operation); ``us_per_row`` is the paper-
relevant number (how much overhead a cache adds vs recomputation).

``backend_hit_*`` rows time the raw ``get_many`` hit path of the
storage backends themselves (min over repeats, batched lookups) — the
CI ``bench-smoke`` job asserts the tiered backend's hit path stays
within 1.5x of the bare memory LRU it fronts (``--json`` emits the
rows machine-readably, ``--quick`` shrinks the workload).
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from repro.caching import (DenseScorerCache, IndexerCache, KeyValueCache,
                           RetrieverCache, ScorerCache, open_backend)
from repro.core import ColFrame, GenericTransformer, add_ranks
from repro.ir import InvertedIndex, msmarco_like


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    return out, time.perf_counter() - t0


def backend_hit_rows(n_entries: int = 2000, repeats: int = 7) -> List[Dict]:
    """Raw backend ``get_many`` hit-path cost (all keys present)."""
    items = [(b"key-%d" % i, b"value-" + (b"x" * 64) + b"-%d" % i)
             for i in range(n_entries)]
    keys = [k for k, _ in items]
    rows = []
    with tempfile.TemporaryDirectory(prefix="cache-micro-") as tmp:
        for name in ("memory", "sqlite", "tiered:sqlite"):
            path = None if name == "memory" \
                else os.path.join(tmp, name.replace(":", "_"))
            be = open_backend(name, path)
            try:
                be.put_many(items)
                be.get_many(keys)          # tiered: promote into the front
                best = min(_timed(be.get_many, keys)[1]
                           for _ in range(repeats))
            finally:
                be.close()
            rows.append({"name": f"backend_hit_{name.replace(':', '_')}",
                         "us_per_row": best / n_entries * 1e6})
    return rows


def key_build_rows(n_rows: int = 2000, repeats: int = 7) -> List[Dict]:
    """Per-row key construction cost (``_keys_of`` hot path): the
    legacy per-row schemes — zip+pickle (KeyValueCache) and
    SHA256-of-pickle (RetrieverCache) — vs the vectorized four-lane
    FNV digest fresh directories negotiate (caching/codecs.py), on
    string keys (worst case for the digest: per-byte folds) and on
    numeric keys (where the byte matrix comes straight from the column
    buffers and the digest wins outright)."""
    import hashlib

    from repro.caching import vector_keys
    from repro.caching.base import pickle_key
    qids = np.empty(n_rows, dtype=object)
    qids[:] = [f"q{i}" for i in range(n_rows)]
    queries = np.empty(n_rows, dtype=object)
    queries[:] = [f"query text {i % 97}" for i in range(n_rows)]
    ids = np.arange(n_rows, dtype=np.int64)
    scores = np.linspace(0.0, 1.0, n_rows)

    def legacy_pickle():
        cols = [qids.tolist(), queries.tolist()]
        return [pickle_key(t) for t in zip(*cols)]

    def legacy_sha256():
        cols = [qids.tolist(), queries.tolist()]
        return [hashlib.sha256(pickle_key(t)).digest() for t in zip(*cols)]

    def legacy_pickle_num():
        cols = [ids.tolist(), scores.tolist()]
        return [pickle_key(t) for t in zip(*cols)]

    rows = []
    for name, fn in (
            ("key_build_str_pickle", legacy_pickle),
            ("key_build_str_sha256_pickle", legacy_sha256),
            ("key_build_str_vector", lambda: vector_keys([qids, queries])),
            ("key_build_num_pickle", legacy_pickle_num),
            ("key_build_num_vector", lambda: vector_keys([ids, scores]))):
        fn()                               # warm (allocator, caches)
        best = min(_timed(fn)[1] for _ in range(repeats))
        rows.append({"name": name, "us_per_row": best / n_rows * 1e6})
    return rows


def run(n_rows: int = 2000, scale: float = 0.05) -> List[Dict]:
    corpus = msmarco_like(1, scale=scale)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    rows = []

    # a scorer frame with n_rows (query, docno) pairs
    docs = corpus.docs
    n = min(n_rows, len(docs))
    frame = ColFrame({
        "qid": [f"q{i % 50}" for i in range(n)],
        "query": [f"query text {i % 50}" for i in range(n)],
        "docno": [str(docs["docno"][i]) for i in range(n)],
        "score": np.zeros(n), "rank": np.zeros(n, dtype=np.int64)})

    scorer = GenericTransformer(
        lambda inp: inp.assign(score=np.arange(len(inp), dtype=np.float64)),
        "identity_scorer", key_columns=("query", "docno"),
        value_columns=("score",))

    with ScorerCache(None, scorer) as sc:
        _, t_cold = _timed(sc, frame)
        _, t_hot = _timed(sc, frame)
        rows.append({"name": "scorer_cache_insert",
                     "us_per_row": t_cold / n * 1e6})
        rows.append({"name": "scorer_cache_hit",
                     "us_per_row": t_hot / n * 1e6})

    with DenseScorerCache(None, scorer,
                          docnos=docs["docno"].tolist()) as dc:
        _, t_cold = _timed(dc, frame)
        _, t_hot = _timed(dc, frame)
        rows.append({"name": "dense_scorer_cache_insert",
                     "us_per_row": t_cold / n * 1e6})
        rows.append({"name": "dense_scorer_cache_hit",
                     "us_per_row": t_hot / n * 1e6})

    topics = corpus.get_topics()
    bm25 = index.bm25(num_results=100)
    with RetrieverCache(None, bm25) as rc:
        _, t_cold = _timed(rc, topics)
        out, t_hot = _timed(rc, topics)
        rows.append({"name": "retriever_cache_insert",
                     "us_per_row": t_cold / max(len(out), 1) * 1e6})
        rows.append({"name": "retriever_cache_hit",
                     "us_per_row": t_hot / max(len(out), 1) * 1e6})

    with IndexerCache(None) as ic:
        _, t_w = _timed(ic.index, corpus.get_corpus_iter())
        _, t_r = _timed(lambda: sum(1 for _ in ic))
        rows.append({"name": "indexer_cache_write",
                     "us_per_row": t_w / len(docs) * 1e6})
        rows.append({"name": "indexer_cache_replay",
                     "us_per_row": t_r / len(docs) * 1e6})

    rows.extend(backend_hit_rows(n_entries=n_rows))
    rows.extend(key_build_rows(n_rows=n_rows))
    return rows


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small workload for the CI smoke job")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write rows as a JSON artifact")
    ap.add_argument("--rows", type=int, default=None)
    args = ap.parse_args(argv)
    n_rows = args.rows or (500 if args.quick else 2000)
    scale = 0.02 if args.quick else 0.05
    rows = run(n_rows=n_rows, scale=scale)
    print("name,us_per_row")
    for r in rows:
        print(f"{r['name']},{r['us_per_row']:.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "n_rows": n_rows, "scale": scale},
                      f, indent=2)
        print(f"[wrote {args.json}]")
    return rows


if __name__ == "__main__":
    main()
