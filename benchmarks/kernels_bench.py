"""Kernel benchmarks.

The container is CPU-only, so wall-clock here measures the XLA reference
path (the jnp oracle, jitted) — a correctness+throughput baseline.  The
Pallas kernels are verified (interpret mode) at the same shapes; their
TPU performance is projected from the roofline terms in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bm25_block import bm25_block_op, bm25_block_ref
from repro.kernels.cachekey_hash import cachekey_hash_op, cachekey_hash_ref
from repro.kernels.dense_topk import dense_topk_op, dense_topk_ref
from repro.kernels.embedding_bag import embedding_bag_op, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_op
from repro.launch.roofline import analyze_compiled


def _bench(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _dense_topk_rows(rng, quick: bool) -> List[Dict]:
    """Fused matmul+top-k vs the naive materialize-and-argsort baseline.

    The fused path (one contraction + ``lax.top_k`` — the exact math of
    ``kernels/dense_topk/ref.py``) is what ``ir/dense.py`` serves per
    corpus shard; naive is ``jnp.dot`` + a full ``argsort`` of the
    [Q, N] score matrix.  The Pallas kernel itself is parity-checked in
    interpret mode at a small shape, and the fused computation is
    roofline-analyzed (``launch/roofline.py``) for the TPU projection.
    """
    rows = []
    nq, d, k = 8, 64, 100
    sizes = [8192] if quick else [8192, 65536]
    for nd in sizes:
        q = jnp.array(rng.normal(size=(nq, d)), jnp.float32)
        c = jnp.array(rng.normal(size=(nd, d)), jnp.float32)
        fused = jax.jit(lambda q, c: dense_topk_ref(q, c, k=k))

        def naive_fn(q, c):
            s = jnp.dot(q, c.T)
            order = jnp.argsort(-s, axis=1)[:, :k]
            return jnp.take_along_axis(s, order, axis=1), order

        fused_t = _bench(fused, q, c)
        naive_t = _bench(jax.jit(naive_fn), q, c)
        # kernel parity (interpret mode) at a bounded shape
        pq, pc = q, c[:min(nd, 2048)]
        kv, ki = dense_topk_op(pq, pc, k=k)
        rv, ri = dense_topk_ref(pq, pc, k=min(k, pc.shape[0]))
        err = float(jnp.abs(kv - rv).max())
        idx_ok = bool((ki == ri).all())
        # roofline terms of the fused computation (TPU projection)
        rep = analyze_compiled(
            fused.lower(q, c).compile(), arch="dense_topk",
            shape=f"q{nq}n{nd}d{d}k{k}", mesh_name="1x1", n_devices=1,
            kind="retrieval", model_flops_global=2.0 * nq * nd * d)
        rows.append({
            "name": f"dense_topk_n{nd}",
            "us_per_call": fused_t * 1e6,
            "derived": f"naive_us={naive_t * 1e6:.1f};"
                       f"fused_speedup={naive_t / fused_t:.2f};"
                       f"kernel_max_err={err:.1e};"
                       f"kernel_idx_match={idx_ok};"
                       f"roofline_dom={rep.dominant};"
                       f"roofline_frac={rep.roofline_fraction:.3f}"})
    return rows


def run(quick: bool = False) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    rows += _dense_topk_rows(rng, quick)
    if quick:
        return rows

    # flash attention: oracle throughput + kernel equivalence
    for (B, H, K, S, hd) in [(1, 8, 2, 512, 64), (2, 8, 8, 1024, 64)]:
        q = jnp.array(rng.normal(size=(B, H, S, hd)), jnp.float32)
        k = jnp.array(rng.normal(size=(B, K, S, hd)), jnp.float32)
        v = jnp.array(rng.normal(size=(B, K, S, hd)), jnp.float32)
        ref_t = _bench(jax.jit(attention_ref), q, k, v)
        flops = 4.0 * B * H * S * S * hd
        out = flash_attention_op(q, k, v)
        err = float(jnp.abs(out - attention_ref(q, k, v)).max())
        rows.append({"name": f"flash_attn_B{B}H{H}S{S}",
                     "us_per_call": ref_t * 1e6,
                     "derived": f"xla_ref_gflops={flops / ref_t / 1e9:.1f};"
                                f"kernel_max_err={err:.1e}"})

    # embedding bag
    for (V, d, B, L) in [(100_000, 64, 4096, 10), (1_000_000, 64, 1024, 20)]:
        tab = jnp.array(rng.normal(size=(V, d)), jnp.float32)
        ids = jnp.array(rng.integers(0, V, (B, L)), jnp.int32)
        ref_t = _bench(jax.jit(embedding_bag_ref), tab, ids)
        small = (jnp.array(rng.normal(size=(1000, d)), jnp.float32),
                 jnp.array(rng.integers(0, 1000, (64, L)), jnp.int32))
        err = float(jnp.abs(embedding_bag_op(*small)
                            - embedding_bag_ref(*small)).max())
        gb = (B * L * d * 4) / 1e9
        rows.append({"name": f"embedding_bag_V{V}_B{B}",
                     "us_per_call": ref_t * 1e6,
                     "derived": f"xla_ref_gather_GBps={gb / ref_t:.1f};"
                                f"kernel_max_err={err:.1e}"})

    # cachekey hash vs host hashing (the cost the kernel eliminates)
    toks = jnp.array(rng.integers(0, 2 ** 31 - 1, (4096, 64)), jnp.int32)
    dev_t = _bench(jax.jit(cachekey_hash_ref), toks)
    import hashlib
    import pickle
    host_rows = np.asarray(toks)
    t0 = time.perf_counter()
    for i in range(512):
        hashlib.sha256(pickle.dumps(host_rows[i].tolist())).digest()
    host_t = (time.perf_counter() - t0) / 512 * 4096
    ok = bool((cachekey_hash_op(toks[:256]) ==
               cachekey_hash_ref(toks[:256])).all())
    rows.append({"name": "cachekey_hash_4096x64",
                 "us_per_call": dev_t * 1e6,
                 "derived": f"host_sha256pickle_us={host_t * 1e6:.0f};"
                            f"kernel_exact={ok}"})

    # bm25 block
    tf = jnp.array(rng.poisson(0.2, (64, 8192)), jnp.float32)
    idf = jnp.array(rng.random(64) * 5, jnp.float32)
    dl = jnp.array(rng.integers(20, 100, 8192), jnp.float32)
    ref_t = _bench(jax.jit(lambda *a: bm25_block_ref(*a, avg_dl=55.0)),
                   tf, idf, dl)
    err = float(jnp.abs(bm25_block_op(tf, idf, dl, avg_dl=55.0)
                        - bm25_block_ref(tf, idf, dl, avg_dl=55.0)).max())
    rows.append({"name": "bm25_block_64x8192",
                 "us_per_call": ref_t * 1e6,
                 "derived": f"docs_per_s={8192 / ref_t / 1e6:.2f}M;"
                            f"kernel_max_err={err:.1e}"})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit rows as a JSON array instead of CSV")
    ap.add_argument("--quick", action="store_true",
                    help="dense_topk rows only, smallest corpus size "
                         "(the CI bench-smoke floor)")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick)
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return rows


if __name__ == "__main__":
    main()
