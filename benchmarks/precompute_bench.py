"""Prefix-precomputation benchmark: naive vs LCP (§3) vs trie (beyond).

Sweeps the number of pipelines sharing a BM25 prefix; reports wall time
and stage invocations for each strategy, plus the §6 ablation pattern
(A; A»B; A»B»C) where the trie strictly dominates LCP.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (ColFrame, Experiment, GenericTransformer,
                        run_with_precompute, run_with_trie)
from repro.ir import InvertedIndex, TextLoader, msmarco_like


def run() -> List[Dict]:
    corpus = msmarco_like(1, scale=0.15)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    topics = corpus.get_topics()
    rows = []

    for n_pipes in (2, 4, 8):
        bm25 = index.bm25(num_results=200)
        calls = {"n": 0}
        orig = bm25.transform
        def counting(inp):
            calls["n"] += len(inp)
            return orig(inp)
        bm25.transform = counting
        systems = [bm25 % (10 * (i + 1)) for i in range(n_pipes)]

        calls["n"] = 0
        t0 = time.perf_counter()
        naive = [s(topics) for s in systems]
        t_naive = time.perf_counter() - t0
        calls_naive = calls["n"]

        calls["n"] = 0
        t0 = time.perf_counter()
        pre, _ = run_with_precompute(systems, topics)
        t_pre = time.perf_counter() - t0
        calls_pre = calls["n"]

        for got, want in zip(pre, naive):       # transparency invariant
            assert got.equals(want, cols=["qid", "docno", "score"])

        rows.append({"name": f"precompute_lcp_{n_pipes}pipes",
                     "t_naive_s": round(t_naive, 4),
                     "t_precompute_s": round(t_pre, 4),
                     "speedup": round(t_naive / max(t_pre, 1e-9), 2),
                     "bm25_calls_naive": calls_naive,
                     "bm25_calls_precompute": calls_pre})

    # §6 ablation: A; A>>B; A>>B>>C
    bm25 = index.bm25(num_results=100)
    rerank = GenericTransformer(
        lambda inp: inp.assign(score=inp["score"] * 1.1), "rerank1")
    rerank2 = GenericTransformer(
        lambda inp: inp.assign(score=inp["score"] + 1.0), "rerank2")
    pipes = [bm25, bm25 >> rerank, bm25 >> rerank >> rerank2]
    t0 = time.perf_counter()
    _, lcp_stats = run_with_precompute(pipes, topics)
    t_lcp = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, trie_stats = run_with_trie(pipes, topics)
    t_trie = time.perf_counter() - t0
    rows.append({"name": "ablation_lcp_vs_trie",
                 "t_naive_s": None, "t_precompute_s": round(t_trie, 4),
                 "speedup": round(t_lcp / max(t_trie, 1e-9), 2),
                 "bm25_calls_naive": lcp_stats.stage_invocations_saved,
                 "bm25_calls_precompute": trie_stats.stage_invocations_saved})
    return rows


def main():
    rows = run()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    main()
