"""Reproduction of the paper's §5 demonstration experiment (Table 2).

Pipelines: [bm25 % k >> Mono % 10 >> Duo for k in (20, 50, 100, 200)]
on MSMARCO-v1/v2-scaled synthetic corpora (43 / 53 queries, v2 ≈ 4.4×
v1 docs — the paper's ratios; absolute sizes reduced for CPU).

Settings (paper Table 2):
  (1) no caching            — BM25 executed once per pipeline (4×)
  (2) prefix precomputation — BM25 executed once (§3)
  (3) + cold ScorerCache    — Mono scored once per distinct (q,d) pair
  (4) + hot ScorerCache     — Mono fully cached from (3)

Reported: wall time + Δ% vs (1), BM25 invocations, Mono pair-scorings,
and the *result-equality* check (nDCG@10/MAP identical across settings —
the invariant that makes the caching sound).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.caching import ScorerCache
from repro.core import Experiment
from repro.ir import InvertedIndex, TextLoader, msmarco_like
from repro.models.cross_encoder import DuoScorer, EncoderConfig, MonoScorer

CUTS = (20, 50, 100, 200)
MEASURES = ["nDCG@10", "MAP"]
CE = EncoderConfig(n_layers=2, d_model=64, n_heads=4, d_ff=128,
                   vocab_size=8192, max_len=32)


class CountingBM25:
    def __init__(self, bm25):
        self.bm25 = bm25
        self.invocations = 0
        orig = bm25.transform
        def counting(inp):
            self.invocations += len(inp)
            return orig(inp)
        bm25.transform = counting


def run_version(version: int, scale: float) -> List[Dict]:
    corpus = msmarco_like(version, scale=scale)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    loader = TextLoader(corpus.text_map())
    topics, qrels = corpus.get_topics(), corpus.get_qrels()
    rows = []
    shared_cache_path = None
    baseline_means = None
    base_time = None

    for setting, (pre, cached) in enumerate(
            [(False, None), (True, None), (True, "cold"), (True, "hot")],
            start=1):
        bm25 = index.bm25(num_results=max(CUTS))
        counter = CountingBM25(bm25)
        mono = MonoScorer(CE)
        duo = DuoScorer(CE, max_docs=10)
        if cached is None:
            stage = mono
            cache = None
        else:
            if cached == "cold" or shared_cache_path is None:
                cache = ScorerCache(None, mono)
                cache._temporary = False
                shared_cache_path = cache.path
            else:
                cache = ScorerCache(shared_cache_path, mono)
            stage = cache
        systems = [bm25 % k >> loader >> stage % 10 >> duo for k in CUTS]

        t0 = time.perf_counter()
        res = Experiment(systems, topics, qrels, MEASURES,
                         precompute_prefix=pre,
                         names=[f"k={k}" for k in CUTS])
        dt = time.perf_counter() - t0
        if cache is not None:
            cache.close()

        if setting == 1:
            baseline_means = res.means
            base_time = dt
        else:   # result-equality invariant
            for n in res.names:
                for m in MEASURES:
                    assert abs(res.means[n][m]
                               - baseline_means[n][m]) < 1e-9, \
                        f"setting {setting} changed {n}/{m}!"

        rows.append({
            "corpus": f"msmarco-v{version}",
            "setting": setting,
            "precompute": pre,
            "mono_cache": cached or "none",
            "time_s": round(dt, 3),
            "delta_vs_1": round(dt / base_time, 3),
            "bm25_queries": counter.invocations,
            "mono_pairs_scored": mono.invocations,
            "nDCG@10(k=200)": round(res.means["k=200"]["nDCG@10"], 4),
        })
    import shutil
    if shared_cache_path:
        shutil.rmtree(shared_cache_path, ignore_errors=True)
    return rows


def run(scale: float = 0.08) -> List[Dict]:
    rows = []
    rows += run_version(1, scale)
    rows += run_version(2, scale)
    return rows


def main():
    rows = run()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    # the paper's qualitative claims, checked:
    for v in ("msmarco-v1", "msmarco-v2"):
        sub = [r for r in rows if r["corpus"] == v]
        assert sub[1]["bm25_queries"] < sub[0]["bm25_queries"], \
            "precompute must reduce BM25 work"
        assert sub[3]["mono_pairs_scored"] == 0, "hot cache must re-score 0"
        assert sub[2]["mono_pairs_scored"] <= sub[1]["mono_pairs_scored"]
    return rows


if __name__ == "__main__":
    main()
