"""Planner benchmarks: vectorized hot paths + plan-vs-naive sharing.

Two suites:

1. ``add_ranks``: the seed implementation looped over qid groups in
   Python; the vectorized version does one global lexsort.  Measured at
   10k queries × 100 docs (1M rows); the acceptance bar is ≥5×.
2. ExecutionPlan stage-invocation savings on the Table-2-style workload
   (``bm25 % k >> rerank`` over four cutoffs — §5's experiment shape)
   plus a binary-operator fusion workload the stage-list trie cannot
   share (``a + b``, ``a ** c``, ``a % k`` all reusing retriever ``a``).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import ColFrame, ExecutionPlan, GenericTransformer, add_ranks
from repro.ir import InvertedIndex, msmarco_like


# -- the seed per-qid loop, kept verbatim for comparison --------------------

def add_ranks_loop(res: ColFrame) -> ColFrame:
    if len(res) == 0:
        return res.assign(rank=np.empty(0, dtype=np.int64)) if "rank" not in res \
            else res
    ranks = np.zeros(len(res), dtype=np.int64)
    for _, idx in res.group_indices(["qid"]).items():
        scores = res["score"][idx].astype(np.float64)
        docnos = res["docno"][idx]
        order = np.lexsort((np.asarray(docnos, dtype=object).astype(str),
                            -scores))
        ranks[idx[order]] = np.arange(len(idx))
    return res.assign(rank=ranks)


def make_results(n_queries: int, n_docs: int, seed: int = 0) -> ColFrame:
    rng = np.random.default_rng(seed)
    qids = np.empty(n_queries * n_docs, dtype=object)
    docnos = np.empty(n_queries * n_docs, dtype=object)
    q_list = [f"q{i}" for i in range(n_queries)]
    d_list = [f"d{j}" for j in range(n_docs)]
    for i in range(n_queries):
        lo = i * n_docs
        qids[lo:lo + n_docs] = q_list[i]
        docnos[lo:lo + n_docs] = d_list
    scores = rng.normal(size=n_queries * n_docs)
    return ColFrame({"qid": qids, "docno": docnos, "score": scores})


def _best_of(fn, arg, repeats: int = 3):
    out, best = None, float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(arg)
        best = min(best, time.perf_counter() - t0)
    return out, best


def bench_add_ranks(n_queries: int = 10_000, n_docs: int = 100) -> Dict:
    res = make_results(n_queries, n_docs)
    loop_out, t_loop = _best_of(add_ranks_loop, res)
    vec_out, t_vec = _best_of(add_ranks, res)
    assert np.array_equal(loop_out["rank"], vec_out["rank"]), \
        "vectorized add_ranks disagrees with the seed loop"
    speedup = t_loop / max(t_vec, 1e-9)
    assert speedup >= 5.0, \
        f"expected >=5x speedup at {n_queries}x{n_docs}, got {speedup:.1f}x"
    return {"name": f"add_ranks_{n_queries}q_x_{n_docs}d",
            "t_loop_s": round(t_loop, 4), "t_vectorized_s": round(t_vec, 4),
            "speedup": round(speedup, 1)}


def bench_plan_sharing() -> List[Dict]:
    corpus = msmarco_like(1, scale=0.1)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    topics = corpus.get_topics()
    rows = []

    # Table-2 style: shared BM25 prefix over four cutoffs + a reranker
    bm25 = index.bm25(num_results=200)
    rerank = GenericTransformer(
        lambda inp: add_ranks(inp.assign(score=inp["score"] * 1.1)), "rerank")
    systems = [bm25 % k >> rerank for k in (20, 50, 100, 200)]
    t0 = time.perf_counter()
    naive = [s(topics) for s in systems]
    t_naive = time.perf_counter() - t0
    t0 = time.perf_counter()
    outs, stats = ExecutionPlan(systems).run(topics)
    t_plan = time.perf_counter() - t0
    for got, want in zip(outs, naive):        # transparency invariant
        assert got.equals(want, cols=["qid", "docno", "score"])
    rows.append({"name": "table2_style_4cutoffs",
                 "t_naive_s": round(t_naive, 4),
                 "t_plan_s": round(t_plan, 4),
                 "speedup": round(t_naive / max(t_plan, 1e-9), 2),
                 "invocations_naive": stats.nodes_total,
                 "invocations_plan": stats.nodes_executed,
                 "saved": stats.stage_invocations_saved})

    # binary-operator fusion: a shared under +, **, % — opaque to stages_of
    a = index.bm25(num_results=100)
    b = index.bm25(num_results=100, k1=2.0)
    systems = [a + b, a ** b, a % 10, a]
    t0 = time.perf_counter()
    naive = [s(topics) for s in systems]
    t_naive = time.perf_counter() - t0
    t0 = time.perf_counter()
    outs, stats = ExecutionPlan(systems).run(topics)
    t_plan = time.perf_counter() - t0
    for got, want in zip(outs, naive):
        cols = [c for c in ("qid", "docno", "score") if c in want.columns]
        assert got.sort_values(["qid", "docno"]).equals(
            want.sort_values(["qid", "docno"]), cols=cols)
    rows.append({"name": "binary_operator_fusion",
                 "t_naive_s": round(t_naive, 4),
                 "t_plan_s": round(t_plan, 4),
                 "speedup": round(t_naive / max(t_plan, 1e-9), 2),
                 "invocations_naive": stats.nodes_total,
                 "invocations_plan": stats.nodes_executed,
                 "saved": stats.stage_invocations_saved})
    return rows


def run() -> List[Dict]:
    rows = [bench_add_ranks()]
    rows.extend(bench_plan_sharing())
    return rows


def main():
    rows = run()
    for block in rows:
        cols = list(block.keys())
        print(",".join(cols))
        print(",".join(str(block[c]) for c in cols))
    return rows


if __name__ == "__main__":
    main()
