"""Planner benchmarks: vectorized hot paths, plan-vs-naive sharing,
the optimizer pass pipeline, the concurrent sharded executor, and the
cost-aware optimizer (``--suite bench_optimizer_cost``).

Five suites:

1. ``add_ranks``: the seed implementation looped over qid groups in
   Python; the vectorized version does one global lexsort.  Measured at
   10k queries × 100 docs (1M rows); the acceptance bar is ≥5×.
2. ExecutionPlan stage-invocation savings on the Table-2-style workload
   (``bm25 % k >> rerank`` over four cutoffs — §5's experiment shape)
   plus a binary-operator fusion workload the stage-list trie cannot
   share (``a + b``, ``a ** c``, ``a % k`` all reusing retriever ``a``).
3. Optimizer: rank-cutoff pushdown (``bm25 % k >> rerank`` fused into
   ``num_results=k``) and commutative CSE (``a + b`` shared with
   ``b + a``), each asserting bit-identical results vs. naive.
4. Concurrent vs. sequential plan execution on a 2-branch
   shared-retriever workload whose stages carry simulated per-query
   model latency (``time.sleep`` releases the GIL exactly like the
   I/O / BLAS / accelerator dispatch that dominates real pipelines).
   The acceptance bar is ≥1.5× with ≥4 workers (≥1.0× in ``--quick``
   CI smoke mode, where runner timing is noisy).
5. Cost-aware optimizer (``--suite bench_optimizer_cost``, needs
   ``--cache-dir``): a 3-pipeline hybrid workload compiled twice per
   invocation — a *static* leg (the cost-blind pass list, default
   knobs) and a *tuned* leg (``optimize="all"``, executor knobs from
   ``plan.tuning()``) — over two sub-directories of one cache dir.
   The first invocation runs on cold analytic/default priors; a second
   invocation over the same dir compiles against the measured costs
   the first folded into the plan manifests, and asserts the
   self-tuned leg beats the static leg on wall time, that cache-place
   dropped the memo of a provably cheap node (manifest ``dir: null``)
   while never touching the expensive ones, and that every leg and
   run produces the same ``result_checksum``.  Always writes
   ``BENCH_optimizer.json`` next to the CWD so the perf trajectory is
   tracked across PRs.

``--quick`` shrinks the workloads for the CI smoke job; ``--json PATH``
dumps every row plus the concurrent run's ``PlanStats`` and the
optimizer pass times as a build artifact.  ``--no-optimize`` plans with
``optimize="none"`` — each row records planned vs. executed node counts
and a deterministic result checksum, so the CI bench-smoke job can
assert optimized execution does no more work and changes no bits.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import ColFrame, ExecutionPlan, GenericTransformer, add_ranks
from repro.ir import InvertedIndex, msmarco_like


def frame_checksum(frames: List[ColFrame]) -> str:
    """Deterministic digest of result content under canonical row order
    (per-qid bit-identity: same (qid, docno, score, rank) values)."""
    h = hashlib.sha256()
    for f in frames:
        cols = [c for c in ("qid", "docno", "score", "rank")
                if c in f.columns]
        srt = f.sort_values([c for c in ("qid", "docno") if c in f.columns]) \
            if len(f) else f
        for c in cols:
            col = srt[c]
            if np.issubdtype(col.dtype, np.floating):
                h.update(b"|".join(float(v).hex().encode()
                                   for v in col.tolist()))
            else:
                h.update(repr(col.tolist()).encode())
    return h.hexdigest()[:16]


# -- the seed per-qid loop, kept verbatim for comparison --------------------

def add_ranks_loop(res: ColFrame) -> ColFrame:
    if len(res) == 0:
        return res.assign(rank=np.empty(0, dtype=np.int64)) if "rank" not in res \
            else res
    ranks = np.zeros(len(res), dtype=np.int64)
    for _, idx in res.group_indices(["qid"]).items():
        scores = res["score"][idx].astype(np.float64)
        docnos = res["docno"][idx]
        order = np.lexsort((np.asarray(docnos, dtype=object).astype(str),
                            -scores))
        ranks[idx[order]] = np.arange(len(idx))
    return res.assign(rank=ranks)


def make_results(n_queries: int, n_docs: int, seed: int = 0) -> ColFrame:
    rng = np.random.default_rng(seed)
    qids = np.empty(n_queries * n_docs, dtype=object)
    docnos = np.empty(n_queries * n_docs, dtype=object)
    q_list = [f"q{i}" for i in range(n_queries)]
    d_list = [f"d{j}" for j in range(n_docs)]
    for i in range(n_queries):
        lo = i * n_docs
        qids[lo:lo + n_docs] = q_list[i]
        docnos[lo:lo + n_docs] = d_list
    scores = rng.normal(size=n_queries * n_docs)
    return ColFrame({"qid": qids, "docno": docnos, "score": scores})


def _best_of(fn, arg, repeats: int = 3):
    out, best = None, float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(arg)
        best = min(best, time.perf_counter() - t0)
    return out, best


def bench_add_ranks(n_queries: int = 10_000, n_docs: int = 100,
                    min_speedup: float = 5.0) -> Dict:
    res = make_results(n_queries, n_docs)
    loop_out, t_loop = _best_of(add_ranks_loop, res)
    vec_out, t_vec = _best_of(add_ranks, res)
    assert np.array_equal(loop_out["rank"], vec_out["rank"]), \
        "vectorized add_ranks disagrees with the seed loop"
    speedup = t_loop / max(t_vec, 1e-9)
    assert speedup >= min_speedup, \
        f"expected >={min_speedup}x speedup at {n_queries}x{n_docs}, " \
        f"got {speedup:.1f}x"
    return {"name": f"add_ranks_{n_queries}q_x_{n_docs}d",
            "t_loop_s": round(t_loop, 4), "t_vectorized_s": round(t_vec, 4),
            "speedup": round(speedup, 1)}


def _plan_row(name: str, systems, topics, optimize: str = "all",
              sort_check: bool = True) -> Dict:
    """Run ``systems`` naively and through the planner; assert the
    transparency invariant; return a row with node counts, optimizer
    pass times and the canonical result checksum."""
    t0 = time.perf_counter()
    naive = [s(topics) for s in systems]
    t_naive = time.perf_counter() - t0
    t0 = time.perf_counter()
    plan = ExecutionPlan(systems, optimize=optimize)
    outs, stats = plan.run(topics)
    t_plan = time.perf_counter() - t0
    for got, want in zip(outs, naive):
        cols = [c for c in ("qid", "docno", "score") if c in want.columns]
        if sort_check:
            assert got.sort_values(["qid", "docno"]).equals(
                want.sort_values(["qid", "docno"]), cols=cols)
        else:
            assert got.equals(want, cols=cols)
    return {"name": name,
            "t_naive_s": round(t_naive, 4),
            "t_plan_s": round(t_plan, 4),
            "speedup": round(t_naive / max(t_plan, 1e-9), 2),
            "invocations_naive": stats.nodes_total,
            "nodes_planned": stats.nodes_planned,
            "invocations_plan": stats.nodes_executed,
            "saved": stats.stage_invocations_saved,
            "nodes_eliminated": stats.nodes_eliminated,
            "cutoffs_pushed": stats.cutoffs_pushed,
            "pass_times_s": stats.pass_times_s,
            "result_checksum": frame_checksum(outs)}


def bench_plan_sharing(optimize: str = "all") -> List[Dict]:
    corpus = msmarco_like(1, scale=0.1)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    topics = corpus.get_topics()
    rows = []

    # Table-2 style: shared BM25 prefix over four cutoffs + a reranker
    bm25 = index.bm25(num_results=200)
    rerank = GenericTransformer(
        lambda inp: add_ranks(inp.assign(score=inp["score"] * 1.1)), "rerank")
    systems = [bm25 % k >> rerank for k in (20, 50, 100, 200)]
    rows.append(_plan_row("table2_style_4cutoffs", systems, topics,
                          optimize, sort_check=False))

    # binary-operator fusion: a shared under +, **, % — opaque to stages_of
    a = index.bm25(num_results=100)
    b = index.bm25(num_results=100, k1=2.0)
    rows.append(_plan_row("binary_operator_fusion",
                          [a + b, a ** b, a % 10, a], topics, optimize))
    return rows


def bench_optimizer(optimize: str = "all") -> List[Dict]:
    """Optimizer-specific workloads: cutoff pushdown into retriever
    depth, and commutative normalization + CSE (``a + b`` vs ``b + a``)."""
    corpus = msmarco_like(1, scale=0.1)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    topics = corpus.get_topics()
    rows = []

    # pushdown: a deep retriever whose results are cut before reranking
    bm25 = index.bm25(num_results=500)
    rerank = GenericTransformer(
        lambda inp: add_ranks(inp.assign(score=inp["score"] * 1.1)),
        "rerank", rank_preserving=True)
    row = _plan_row("cutoff_pushdown", [bm25 % 10 >> rerank], topics,
                    optimize)
    rows.append(row)
    if optimize == "all":
        assert row["cutoffs_pushed"] == 1, \
            f"pushdown did not fire: {row}"

    # commutative sharing: the same reranker over a + b and b + a
    a = index.bm25(num_results=100)
    b = index.bm25(num_results=100, k1=2.0)
    row = _plan_row("commutative_cse",
                    [(a + b) >> rerank, (b + a) >> rerank], topics, optimize)
    rows.append(row)
    if optimize == "all":
        # a, b, one combine, one rerank — the commuted twin merged away
        assert row["nodes_planned"] == 4, f"commutative CSE missed: {row}"
    return rows


# -- concurrent sharded executor vs sequential ------------------------------

def _simulated_stage(name: str, per_row_s: float, shift: float,
                     n_docs: int = 0):
    """A pipeline stage with simulated per-row model latency.

    ``time.sleep`` releases the GIL like the I/O / BLAS / accelerator
    dispatch that dominates real retrieval stages, so the thread-pool
    executor can overlap it; the Python-side transform stays exact and
    deterministic so equality checks are bit-for-bit.
    """
    if n_docs:                           # retriever: one row → n_docs rows
        def fn(inp):
            time.sleep(per_row_s * len(inp))
            rows = [{"qid": q, "query": t, "docno": f"d{i}",
                     "score": shift - i}
                    for q, t in zip(inp["qid"].tolist(),
                                    inp["query"].tolist())
                    for i in range(n_docs)]
            return add_ranks(ColFrame.from_dicts(rows))
        return GenericTransformer(fn, name, one_to_many=True,
                                  key_columns=("qid", "query"))

    def fn(inp):
        time.sleep(per_row_s * len(set(inp["qid"].tolist())))
        return add_ranks(inp.assign(score=inp["score"] * 2.0 + shift))
    return GenericTransformer(fn, name)


def bench_concurrent_executor(quick: bool = False,
                              n_shards: int = 4,
                              max_workers: int = 4,
                              cache_dir: Optional[str] = None,
                              optimize: str = "all") -> Dict:
    """2-branch shared-retriever workload: ``retr >> rerankA`` and
    ``retr >> rerankB``.  Sequentially the three nodes serialize; the
    concurrent executor overlaps the two rerankers and all shards.

    With ``cache_dir`` the planner additionally auto-inserts a
    provenance-checked RetrieverCache around the retriever node (the
    CI cache-compat job runs this twice — cold then warm — against one
    directory and asserts a nonzero warm hit rate plus a clean
    ``repro cache verify``).  Caching changes the timed workload, so
    the speedup floor only applies to uncached runs; the equality
    checks (cache transparency) always apply.
    """
    n_queries = 24 if quick else 48
    per_row = 0.004 if quick else 0.006
    topics = ColFrame({"qid": [f"q{i}" for i in range(n_queries)],
                       "query": [f"terms {i}" for i in range(n_queries)]})
    retr = _simulated_stage("sim_retriever", per_row, 100.0, n_docs=10)
    rerank_a = _simulated_stage("sim_rerankA", per_row, 1.0)
    rerank_b = _simulated_stage("sim_rerankB", per_row, 2.0)
    systems = [retr >> rerank_a, retr >> rerank_b]

    with ExecutionPlan(systems, cache_dir=cache_dir,
                       optimize=optimize) as plan:
        seq_out, seq_stats = plan.run(topics)
    with ExecutionPlan(systems, cache_dir=cache_dir,
                       optimize=optimize) as plan:
        conc_out, conc_stats = plan.run(
            topics, n_shards=n_shards, max_workers=max_workers)
    for got, want in zip(conc_out, seq_out):
        assert got.sort_values(["qid", "docno"]).equals(
            want.sort_values(["qid", "docno"]),
            cols=["qid", "docno", "score", "rank"], rtol=0, atol=0), \
            "concurrent executor diverged from sequential"

    speedup = seq_stats.wall_time_s / max(conc_stats.wall_time_s, 1e-9)
    conc_stats.speedup_vs_sequential = round(speedup, 2)
    if cache_dir is None:
        floor = 1.0 if quick else 1.5
        assert speedup >= floor, \
            f"concurrent executor slower than expected: {speedup:.2f}x " \
            f"(floor {floor}x with {max_workers} workers)"
    else:
        # the sequential plan warmed (at least) the retriever cache, so
        # the concurrent pass must observe hits
        assert conc_stats.cache_hits > 0, \
            f"no cache hits against {cache_dir!r}"
    row = {"name": f"concurrent_2branch_{n_shards}shards_{max_workers}w",
           "t_sequential_s": round(seq_stats.wall_time_s, 4),
           "t_concurrent_s": round(conc_stats.wall_time_s, 4),
           "speedup": round(speedup, 2),
           "occupancy": round(conc_stats.occupancy, 3),
           # the *sequential* pass runs first, so on a warm cache dir its
           # hits prove cross-process reuse (the concurrent pass would hit
           # even against a broken dir — the sequential pass just warmed
           # this process); the CI cache-compat job asserts on these
           "seq_cache_hits": seq_stats.cache_hits,
           "seq_cache_misses": seq_stats.cache_misses,
           "cache_hits": conc_stats.cache_hits,
           "cache_misses": conc_stats.cache_misses,
           "shard_times_s": [round(t, 4) for t in conc_stats.shard_times_s]}
    row["_plan_stats"] = dataclasses.asdict(conc_stats)
    return row


# -- cost-aware optimizer: static pass list vs self-tuned -------------------

#: the cost-blind baseline the tuned leg is compared against — the full
#: structural pipeline minus the three cost-aware passes
STATIC_PASSES = ["normalize", "cse", "pushdown", "cache-prune"]


def _tag_stage():
    """A provably cheap cacheable stage: a pure vectorized column
    assignment with declared key/value columns, so the planner inserts
    a KeyValueCache around it — until measured history shows recompute
    is cheaper than the backend round trip and cache-place drops it."""
    def fn(inp):
        return inp.assign(tag=inp["docno"])  # pure column copy: ~1µs/query
    return GenericTransformer(fn, "tag_join", key_columns=("qid", "docno"),
                              value_columns=("tag",))


def _cost_workload(quick: bool):
    """Hybrid 2-pipeline workload mixing every cost regime: an
    expensive and a cheap retriever under a commutative combine
    (operand-order evidence), two uncached sleep-dominated rerankers
    (autotune's sharding evidence), and a trivially cheap cached tag
    stage (cache-place's skip evidence)."""
    n_queries = 24 if quick else 48
    per = 0.002 if quick else 0.004
    topics = ColFrame({"qid": [f"q{i}" for i in range(n_queries)],
                       "query": [f"terms {i}" for i in range(n_queries)]})
    heavy = _simulated_stage("sim_heavy_retr", 3 * per, 100.0, n_docs=8)
    light = _simulated_stage("sim_light_retr", per, 50.0, n_docs=5)
    rerank_a = _simulated_stage("sim_rerankA", per, 1.0)
    rerank_b = _simulated_stage("sim_rerankB", per, 2.0)
    systems = [(light + heavy) % 5 >> _tag_stage() >> rerank_a,
               heavy % 8 >> rerank_b]
    return topics, systems


#: explicit backend for the cost suite: pickle's per-entry round trip
#: (~10µs here) sits comfortably ABOVE the tag stage's measured
#: recompute (~2µs — skip window) and far BELOW the retrievers'
#: (milliseconds — no false skip, and 20×-round-trip promotion fires)
COST_SUITE_BACKEND = "pickle"


def _run_cost_leg(topics, systems, cache_dir: str, tuned: bool) -> Dict:
    """One compile+run over its own cache dir: the tuned leg plans with
    ``optimize="all"`` and forwards the autotuned ``n_shards`` to the
    executor; the static leg uses the cost-blind pass list and default
    (sequential) knobs."""
    optimize = "all" if tuned else STATIC_PASSES
    with ExecutionPlan(systems, cache_dir=cache_dir,
                       cache_backend=COST_SUITE_BACKEND,
                       optimize=optimize) as plan:
        shards = plan.tuning().get("n_shards") if tuned else None
        outs, stats = plan.run(
            topics,
            n_shards=int(shards) if shards else None,
            max_workers=int(shards) if shards else None)
        record = plan.to_record()
    return {"wall_s": stats.wall_time_s,
            "n_shards": stats.n_shards,
            "cache_hits": stats.cache_hits,
            "cache_misses": stats.cache_misses,
            "result_checksum": frame_checksum(outs),
            "nodes": record["nodes"],
            "optimizer": record["optimizer"],
            "tuning": {k: v.get("value")
                       for k, v in record.get("tuning", {}).items()}}


def bench_optimizer_cost(cache_dir: str, quick: bool = False) -> Dict:
    """Static vs self-tuned planning over one persistent cache dir.

    Run this suite TWICE against the same ``--cache-dir`` (the CI
    optimizer-smoke job does).  The first invocation compiles on cold
    analytic/default priors — the cost-aware passes refuse to act on
    weak evidence, so both legs run identically and the run's measured
    per-node costs are folded into each leg's plan manifest.  The
    second invocation compiles against that measured history and must
    show: the tuned leg beating the static leg on wall time (autotuned
    sharding overlaps the sleep-dominated rerankers), the cheap tag
    stage's planner cache provably dropped (``cache_skip`` with
    ``dir: null``) while the expensive retrievers — whose recompute
    dwarfs the backend round trip — stay cached, the commutative
    combine reordered expensive-subtree-first, and bit-identical
    result checksums across every leg and phase.
    """
    topics, systems = _cost_workload(quick)
    static = _run_cost_leg(topics, systems,
                           os.path.join(cache_dir, "static"), tuned=False)
    tuned = _run_cost_leg(topics, systems,
                          os.path.join(cache_dir, "tuned"), tuned=True)
    nodes = tuned["nodes"]
    measured = any(n.get("cost_src") == "measured" for n in nodes)

    assert tuned["result_checksum"] == static["result_checksum"], \
        "cost-aware planning changed result bits"
    tag_nodes = [n for n in nodes if "tag_join" in n["label"]
                 and n["kind"] == "stage"]
    retr_nodes = [n for n in nodes if n["kind"] == "stage"
                  and ("sim_heavy_retr" in n["label"]
                       or "sim_light_retr" in n["label"])]
    assert tag_nodes and retr_nodes, "workload shape changed"
    # expensive nodes must NEVER be skipped: their recompute cost dwarfs
    # the cache round trip, in either phase
    assert all(not n["cache_skip"] and n["dir"] is not None
               for n in retr_nodes), \
        f"cache-place dropped an expensive node's cache: {retr_nodes}"
    if measured:
        assert all(n["cache_skip"] and n["dir"] is None
                   for n in tag_nodes), \
            f"cache-place kept a cache cheaper to recompute: {tag_nodes}"
        assert tuned["optimizer"]["inputs_reordered"] >= 1, \
            "operand-order did not reorder the commutative combine"
        # the hot retrievers cost 20×+ the round trip: promoted to a
        # memory-tiered selector over the same store (tiered:pickle)
        assert tuned["optimizer"]["caches_promoted"] >= 1, \
            "cache-place promoted no hot node"
        assert int(tuned["tuning"].get("n_shards") or 0) >= 2, \
            f"autotune chose no sharding: {tuned['tuning']}"
        assert tuned["n_shards"] >= 2
        assert static["cache_hits"] > 0 and tuned["cache_hits"] > 0, \
            "second invocation did not start warm"
        assert tuned["wall_s"] < static["wall_s"], \
            f"self-tuned plan not faster: tuned {tuned['wall_s']:.4f}s " \
            f"vs static {static['wall_s']:.4f}s"
    else:
        # cold priors are weak evidence: no cache may be dropped on them
        assert not any(n["cache_skip"] for n in nodes), \
            f"cache-place skipped on cold priors: {nodes}"

    return {"name": "optimizer_cost_static_vs_tuned",
            "phase": "measured" if measured else "cold",
            "t_static_s": round(static["wall_s"], 4),
            "t_tuned_s": round(tuned["wall_s"], 4),
            "speedup": round(static["wall_s"] / max(tuned["wall_s"], 1e-9),
                             2),
            "n_shards_tuned": tuned["n_shards"],
            "tuning": tuned["tuning"],
            "caches_skipped": tuned["optimizer"]["caches_skipped"],
            "caches_promoted": tuned["optimizer"]["caches_promoted"],
            "inputs_reordered": tuned["optimizer"]["inputs_reordered"],
            "skipped_nodes": [n["label"] for n in nodes
                              if n.get("cache_skip")],
            "static_cache_hits": static["cache_hits"],
            "tuned_cache_hits": tuned["cache_hits"],
            "result_checksum": static["result_checksum"]}


# -- suite 6: asynchronous cache data plane (--suite dataplane) --------------

#: simulated remote-tier round trip per ``get_many`` call.  Local page-
#: cache reads finish in microseconds — prefetch has nothing to hide
#: there — so the suite models the regime the data plane exists for (a
#: shared store behind real storage/network latency) the same way the
#: concurrent suite models model latency: a GIL-releasing sleep.
REMOTE_SIM_RT_S = 0.010


def _register_remote_sim():
    """Register the ``remote-sim`` backend: a pickle store whose reads
    pay a fixed round trip.  Benchmark-only — registered here, never in
    ``repro.caching``."""
    from repro.caching.backends import BACKENDS, PickleDirBackend

    class RemoteSimBackend(PickleDirBackend):
        name = "remote-sim"

        def get_many(self, keys):
            time.sleep(REMOTE_SIM_RT_S)
            return super().get_many(keys)

    BACKENDS.setdefault("remote-sim", RemoteSimBackend)


def _dataplane_workload(quick: bool):
    """Four independent cached retrievers — four query-keyed prefetches
    the executor can issue concurrently at submit time."""
    n_queries = 24 if quick else 48
    topics = ColFrame({"qid": [f"q{i}" for i in range(n_queries)],
                       "query": [f"terms {i}" for i in range(n_queries)]})

    def make_retr(name, n_docs=12):
        def fn(inp):
            rows = []
            for qid, query in zip(inp["qid"].tolist(),
                                  inp["query"].tolist()):
                for i in range(n_docs):
                    rows.append({"qid": qid, "query": query,
                                 "docno": f"{name}_d{i}",
                                 "score": float(n_docs - i)})
            return add_ranks(ColFrame.from_dicts(rows))
        return GenericTransformer(fn, name, one_to_many=True,
                                  key_columns=("qid", "query"))

    systems = [make_retr(f"dp_retr{k}") % 8 for k in range(4)]
    return topics, systems


def _dataplane_leg(topics, systems, cache_dir: str, *, prefetch: bool,
                   repeats: int = 3) -> Dict:
    """Best-of-N warm run over an already-populated dir.  The static
    pass list keeps the cost-aware optimizer from re-planning the
    caches between legs (this suite measures the data plane, not
    cache placement)."""
    best, stats, outs = float("inf"), None, None
    for _ in range(repeats):
        with ExecutionPlan(systems, cache_dir=cache_dir,
                           cache_backend="remote-sim",
                           optimize=STATIC_PASSES,
                           prefetch=prefetch) as plan:
            t0 = time.perf_counter()
            outs, stats = plan.run(topics)
            best = min(best, time.perf_counter() - t0)
    return {"wall_s": best,
            "cache_hits": stats.cache_hits,
            "cache_misses": stats.cache_misses,
            "cache_prefetched": stats.cache_prefetched,
            "result_checksum": frame_checksum(outs)}


def bench_dataplane(cache_dir: str, quick: bool = False) -> Dict:
    """Query-keyed prefetch on vs off over one warm cache directory.

    One invocation: a cold run populates the store (write-behind on,
    the plan's default), then warm runs with prefetch off (synchronous
    inline ``get_many``) and on (submit-time fetches on the I/O pool)
    are timed best-of-3.  Asserts bit-identical result checksums across
    every leg, honest attribution (prefetched == 0 when off, > 0 when
    on, never exceeding hits), and the wall-clock floor the CI
    dataplane-smoke job gates on: with four caches behind a
    ~10 ms-round-trip store, the synchronous warm run pays the round
    trips serially while the prefetching run overlaps them, so ≥1.3×
    is a conservative bar (~2× expected)."""
    _register_remote_sim()
    topics, systems = _dataplane_workload(quick)
    n_q = len(topics)
    cold = _dataplane_leg(topics, systems, cache_dir,
                          prefetch=True, repeats=1)
    assert cold["cache_misses"] == n_q * len(systems), \
        f"cold leg expected all misses: {cold}"
    off = _dataplane_leg(topics, systems, cache_dir, prefetch=False)
    on = _dataplane_leg(topics, systems, cache_dir, prefetch=True)

    assert off["result_checksum"] == cold["result_checksum"], \
        "warm synchronous run changed result bits"
    assert on["result_checksum"] == cold["result_checksum"], \
        "prefetch changed result bits"
    assert off["cache_misses"] == 0 and on["cache_misses"] == 0, \
        "warm legs missed — write-behind flush lost entries"
    assert off["cache_prefetched"] == 0, \
        "prefetch-off leg reported prefetched hits"
    assert 0 < on["cache_prefetched"] <= on["cache_hits"], \
        f"dishonest prefetch attribution: {on}"
    speedup = off["wall_s"] / max(on["wall_s"], 1e-9)
    return {"name": "dataplane_prefetch_warm",
            "round_trip_s": REMOTE_SIM_RT_S,
            "n_queries": n_q,
            "n_caches": len(systems),
            "t_warm_sync_s": round(off["wall_s"], 4),
            "t_warm_prefetch_s": round(on["wall_s"], 4),
            "speedup": round(speedup, 2),
            "warm_hits": on["cache_hits"],
            "prefetched": on["cache_prefetched"],
            "result_checksum": on["result_checksum"]}


def run(quick: bool = False, cache_dir: Optional[str] = None,
        optimize: str = "all") -> List[Dict]:
    if quick:
        rows = [bench_add_ranks(2_000, 50, min_speedup=1.0)]
    else:
        rows = [bench_add_ranks()]
    rows.extend(bench_plan_sharing(optimize=optimize))
    rows.extend(bench_optimizer(optimize=optimize))
    rows.append(bench_concurrent_executor(quick=quick, cache_dir=cache_dir,
                                          optimize=optimize))
    return rows


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="shrunk workloads + relaxed floors (CI smoke)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write rows + concurrent PlanStats as JSON")
    ap.add_argument("--no-optimize", action="store_true",
                    help="plan with optimize='none' (naive forest) — the "
                         "CI bench-smoke job diffs node counts and result "
                         "checksums against the optimized run")
    ap.add_argument("--cache-dir", metavar="DIR", default=None,
                    help="run the concurrent suite against a persistent "
                         "planner cache dir (cold/warm cache-compat CI)")
    ap.add_argument("--suite",
                    choices=["all", "bench_optimizer_cost", "dataplane"],
                    default="all",
                    help="'bench_optimizer_cost' runs only the cost-aware "
                         "optimizer suite (requires --cache-dir; run it "
                         "twice over one dir: cold priors, then measured); "
                         "'dataplane' runs the async-data-plane suite "
                         "(prefetch on/off over one warm dir, requires "
                         "--cache-dir)")
    args = ap.parse_args(argv)
    optimize = "none" if args.no_optimize else "all"
    if args.suite == "bench_optimizer_cost":
        if args.cache_dir is None:
            ap.error("--suite bench_optimizer_cost requires --cache-dir")
        rows = [bench_optimizer_cost(args.cache_dir, quick=args.quick)]
        # the perf-trajectory artifact CI tracks across PRs
        with open("BENCH_optimizer.json", "w") as f:
            json.dump({"suite": "bench_optimizer_cost", "rows": rows},
                      f, indent=2)
        print("[wrote BENCH_optimizer.json]")
    elif args.suite == "dataplane":
        if args.cache_dir is None:
            ap.error("--suite dataplane requires --cache-dir")
        rows = [bench_dataplane(args.cache_dir, quick=args.quick)]
        with open("BENCH_dataplane.json", "w") as f:
            json.dump({"suite": "dataplane", "rows": rows}, f, indent=2)
        print("[wrote BENCH_dataplane.json]")
    else:
        rows = run(quick=args.quick, cache_dir=args.cache_dir,
                   optimize=optimize)
    plan_stats = None
    for block in rows:
        plan_stats = block.pop("_plan_stats", plan_stats)
        cols = list(block.keys())
        print(",".join(cols))
        print(",".join(str(block[c]) for c in cols))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "optimize": optimize,
                       "plan_stats": plan_stats}, f, indent=2)
        print(f"[wrote {args.json}]")
    return rows


if __name__ == "__main__":
    main()
