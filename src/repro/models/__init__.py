# Model zoo: LM family (dense+MoE), cross-encoders, GCN, recsys.
from . import common, lm, gcn, recsys, cross_encoder
from .common import (ParamSpec, init_params, abstract_params,
                     logical_axes_tree, count_params)

__all__ = ["common", "lm", "gcn", "recsys", "cross_encoder", "ParamSpec",
           "init_params", "abstract_params", "logical_axes_tree",
           "count_params"]
