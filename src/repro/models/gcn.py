"""GCN (Kipf & Welling, arXiv:1609.02907) with all four shape regimes.

Message passing is built on ``jax.ops.segment_sum`` over an edge-index →
node scatter (JAX has no CSR SpMM; this IS the system, per the
assignment note):

* ``full_graph``  — symmetric-normalized Ã·X·W over the whole graph
  (cora 2.7k nodes / ogbn-products 2.45M nodes);
* ``minibatch``   — GraphSAGE-style fixed-fanout neighbor sampling
  (a *real* numpy sampler over CSR) + per-hop dense gathers;
* ``molecule``    — batched small graphs, flattened with edge offsets.

Nodes/edges are padded to mesh-friendly multiples; padding rows carry
zero features and a degree of 1 so they are numerically inert.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ParamSpec

__all__ = ["GCNConfig", "gcn_param_specs", "gcn_full_graph_logits",
           "gcn_full_graph_loss", "gcn_sampled_loss", "gcn_molecule_loss",
           "NeighborSampler", "pad_graph"]


@dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_feat: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    aggregator: str = "mean"     # mean (sym-normalized)
    dtype: Any = jnp.float32
    # minibatch regime
    fanouts: Tuple[int, ...] = (15, 10)


def gcn_param_specs(cfg: GCNConfig) -> Dict:
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    layers = {}
    for i in range(cfg.n_layers):
        layers[f"w{i}"] = ParamSpec((dims[i], dims[i + 1]),
                                    ("gnn_in", "gnn_out"), cfg.dtype,
                                    init="he")
        layers[f"b{i}"] = ParamSpec((dims[i + 1],), ("gnn_out",), cfg.dtype,
                                    init="zeros")
    return layers


def pad_graph(n: int, multiple: int = 512) -> int:
    return ((n + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# full-graph regime
# ---------------------------------------------------------------------------

def _sym_norm_agg(x: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                  deg: jnp.ndarray) -> jnp.ndarray:
    """Ã X with Ã = D^-1/2 (A+I) D^-1/2; edges (src→dst) + self loops.

    x [N,F]; src/dst [E] int32; deg [N] (including self loop).
    """
    inv_sqrt = jax.lax.rsqrt(jnp.maximum(deg.astype(jnp.float32), 1.0))
    msg = x[src] * (inv_sqrt[src] * inv_sqrt[dst])[:, None].astype(x.dtype)
    agg = jax.ops.segment_sum(msg, dst, num_segments=x.shape[0])
    agg = agg + x * (inv_sqrt * inv_sqrt)[:, None].astype(x.dtype)  # self loop
    return agg


def gcn_full_graph_logits(params: Dict, feats: jnp.ndarray,
                          src: jnp.ndarray, dst: jnp.ndarray,
                          deg: jnp.ndarray, cfg: GCNConfig) -> jnp.ndarray:
    x = feats
    for i in range(cfg.n_layers):
        # aggregate-then-transform when fan-in > fan-out is cheaper the
        # other way round; GCN canonical order: X W then Ã (X W)
        x = jnp.einsum("nf,fo->no", x, params[f"w{i}"]) + params[f"b{i}"]
        x = _sym_norm_agg(x, src, dst, deg)
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x


def gcn_full_graph_loss(params: Dict, batch: Dict, cfg: GCNConfig):
    logits = gcn_full_graph_logits(params, batch["feats"], batch["src"],
                                   batch["dst"], batch["deg"], cfg)
    labels, mask = batch["labels"], batch["label_mask"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None],
                               axis=-1)[:, 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# sampled-minibatch regime (GraphSAGE-style fanout sampling)
# ---------------------------------------------------------------------------

class NeighborSampler:
    """Uniform fixed-fanout neighbor sampler over a CSR adjacency.

    Real sampling (numpy), deterministic given the step seed — the data
    pipeline contract required for fault-tolerant resume.
    """

    def __init__(self, indptr: np.ndarray, indices: np.ndarray):
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.indices = np.asarray(indices, dtype=np.int32)

    @classmethod
    def from_edges(cls, n_nodes: int, src: np.ndarray, dst: np.ndarray):
        order = np.argsort(dst, kind="stable")
        src_sorted = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return cls(indptr, src_sorted)

    def sample(self, seeds: np.ndarray, fanouts: Tuple[int, ...],
               seed: int) -> Dict[str, np.ndarray]:
        """Returns hop-wise neighbor id matrices.

        out["hop0"] = seeds [B]; out[f"hop{i+1}"] = [B, f1*…*fi] node ids
        (self-padded where degree < fanout).
        """
        rng = np.random.default_rng(seed)
        out = {"hop0": seeds.astype(np.int32)}
        frontier = seeds
        width = 1
        for h, f in enumerate(fanouts):
            lo = self.indptr[frontier]
            hi = self.indptr[frontier + 1]
            deg = (hi - lo)
            # uniform with replacement; degree-0 nodes self-loop
            r = rng.random((len(frontier), f))
            pick = lo[:, None] + np.floor(r * np.maximum(deg, 1)[:, None]
                                          ).astype(np.int64)
            neigh = self.indices[np.minimum(pick, len(self.indices) - 1)]
            neigh = np.where(deg[:, None] > 0, neigh,
                             frontier[:, None].astype(np.int32))
            width *= f
            out[f"hop{h + 1}"] = neigh.reshape(len(seeds), width) \
                if h else neigh
            frontier = neigh.reshape(-1)
        return out


def gcn_sampled_loss(params: Dict, batch: Dict, cfg: GCNConfig):
    """2-hop sampled GCN step (fanouts f1, f2).

    batch: feats_hop0 [B,F], feats_hop1 [B,f1,F], feats_hop2 [B,f1,f2,F],
    labels [B].  Mean aggregation per hop (sampled-GCN estimator).
    """
    f0, f1, f2 = batch["feats_hop0"], batch["feats_hop1"], batch["feats_hop2"]
    w0, b0 = params["w0"], params["b0"]
    w1, b1 = params["w1"], params["b1"]
    # layer 1 applied at hop-1 nodes: agg over their sampled neighbors
    h1_in = jnp.einsum("bkmf,fo->bkmo", f2, w0) + b0
    h1 = jax.nn.relu(jnp.einsum("bkf,fo->bko", f1, w0) + b0
                     + h1_in.mean(axis=2))
    # layer 2 at seeds: agg over hop-1
    h0_self = jnp.einsum("bf,fo->bo", f0, w0) + b0
    h0 = jax.nn.relu(h0_self + (jnp.einsum("bkf,fo->bko", f1, w0)
                                + b0).mean(axis=1))
    logits = (jnp.einsum("bo,oc->bc", h0, w1) + b1
              + jnp.einsum("bko,oc->bkc", h1, w1).mean(axis=1))
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


# ---------------------------------------------------------------------------
# batched-small-graphs regime (molecules)
# ---------------------------------------------------------------------------

def gcn_molecule_loss(params: Dict, batch: Dict, cfg: GCNConfig):
    """batch: feats [G,N,F], src/dst [G,E], deg [G,N], labels [G]."""
    G, N, F = batch["feats"].shape
    E = batch["src"].shape[1]
    # flatten graphs with node offsets so one segment_sum serves all
    offs = (jnp.arange(G) * N)[:, None]
    src = (batch["src"] + offs).reshape(-1)
    dst = (batch["dst"] + offs).reshape(-1)
    feats = batch["feats"].reshape(G * N, F)
    deg = batch["deg"].reshape(G * N)
    x = feats
    for i in range(cfg.n_layers):
        x = jnp.einsum("nf,fo->no", x, params[f"w{i}"]) + params[f"b{i}"]
        x = _sym_norm_agg(x, src, dst, deg)
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    pooled = x.reshape(G, N, -1).mean(axis=1)       # mean readout
    labels = batch["labels"]
    logz = jax.nn.logsumexp(pooled, axis=-1)
    gold = jnp.take_along_axis(pooled, labels[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()
