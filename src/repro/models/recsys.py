"""RecSys model family: DLRM, DCN-v2, MIND, two-tower retrieval.

The hot path is the sparse embedding lookup.  JAX has no native
EmbeddingBag or CSR sparse, so the lookup substrate here is built from
``jnp.take`` + ``jax.ops.segment_sum`` (one-hot fields) and masked
gather-sum (multi-hot bags) — with a Pallas TPU kernel
(``repro.kernels.embedding_bag``) as the accelerated path for bags.

Embedding tables are row-sharded over the combined (data, model) mesh
axes; per-field vocabularies are padded to a 512 multiple so every mesh
divides them (lookup ids never reach the padding rows).

Shapes follow the assignment: train_batch=65536, serve_p99=512,
serve_bulk=262144, retrieval_cand = 1 query × 1M candidates.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ParamSpec

__all__ = ["CRITEO_VOCABS", "RecsysConfig", "recsys_param_specs",
           "embedding_bag", "dlrm_forward", "dcn_forward", "mind_forward",
           "two_tower_embed", "recsys_train_loss", "recsys_serve",
           "two_tower_retrieval_scores"]

#: Criteo-Kaggle per-field categorical cardinalities (public DLRM config)
CRITEO_VOCABS: Tuple[int, ...] = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572)


def _pad512(v: int) -> int:
    return ((v + 511) // 512) * 512


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                      # dlrm | dcn | mind | two_tower
    embed_dim: int
    n_dense: int = 0
    vocab_sizes: Tuple[int, ...] = ()
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    n_cross_layers: int = 0
    deep_mlp: Tuple[int, ...] = ()
    tower_mlp: Tuple[int, ...] = ()
    n_interests: int = 0
    capsule_iters: int = 3
    hist_len: int = 50
    item_vocab: int = 1_000_000
    user_vocab: int = 2_000_000
    dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def _mlp_specs(dims: Sequence[int], prefix: str, dt) -> Dict[str, ParamSpec]:
    out = {}
    for i in range(len(dims) - 1):
        out[f"{prefix}_w{i}"] = ParamSpec(
            (dims[i], dims[i + 1]), ("mlp_in", "mlp_out"), dt, init="he")
        out[f"{prefix}_b{i}"] = ParamSpec(
            (dims[i + 1],), ("mlp_out",), dt, init="zeros")
    return out


def _mlp(x, params, prefix: str, n: int, final_act: bool = False):
    for i in range(n):
        x = jnp.einsum("...i,io->...o", x, params[f"{prefix}_w{i}"]) \
            + params[f"{prefix}_b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def recsys_param_specs(cfg: RecsysConfig) -> Dict:
    dt = cfg.dtype
    d = cfg.embed_dim
    specs: Dict[str, Any] = {}
    if cfg.kind in ("dlrm", "dcn"):
        specs["tables"] = {
            f"t{i}": ParamSpec((_pad512(v), d), ("table_rows", "table_dim"),
                               dt, init="embed", init_scale=1.0 / math.sqrt(d))
            for i, v in enumerate(cfg.vocab_sizes)}
    if cfg.kind == "dlrm":
        bot = (cfg.n_dense,) + cfg.bot_mlp
        n_int = cfg.n_sparse + 1
        d_inter = n_int * (n_int - 1) // 2 + cfg.bot_mlp[-1]
        top = (d_inter,) + cfg.top_mlp
        specs.update(_mlp_specs(bot, "bot", dt))
        specs.update(_mlp_specs(top, "top", dt))
    elif cfg.kind == "dcn":
        d0 = cfg.n_dense + cfg.n_sparse * d
        for i in range(cfg.n_cross_layers):
            specs[f"cross_w{i}"] = ParamSpec((d0, d0), ("mlp_in", "mlp_out"),
                                             dt, init="lecun")
            specs[f"cross_b{i}"] = ParamSpec((d0,), ("mlp_out",), dt,
                                             init="zeros")
        specs.update(_mlp_specs((d0,) + cfg.deep_mlp, "deep", dt))
        specs["logit_w"] = ParamSpec((d0 + cfg.deep_mlp[-1], 1),
                                     ("mlp_in", None), dt)
    elif cfg.kind == "mind":
        specs["item_embed"] = ParamSpec(
            (_pad512(cfg.item_vocab), d), ("table_rows", "table_dim"), dt,
            init="embed", init_scale=1.0 / math.sqrt(d))
        specs["S"] = ParamSpec((d, d), ("mlp_in", "mlp_out"), dt)
        specs.update(_mlp_specs((d, d * 2, d), "interest", dt))
    elif cfg.kind == "two_tower":
        specs["user_embed"] = ParamSpec(
            (_pad512(cfg.user_vocab), d), ("table_rows", "table_dim"), dt,
            init="embed", init_scale=1.0 / math.sqrt(d))
        specs["item_embed"] = ParamSpec(
            (_pad512(cfg.item_vocab), d), ("table_rows", "table_dim"), dt,
            init="embed", init_scale=1.0 / math.sqrt(d))
        specs.update(_mlp_specs((d,) + cfg.tower_mlp, "user_tower", dt))
        specs.update(_mlp_specs((d,) + cfg.tower_mlp, "item_tower", dt))
    else:
        raise ValueError(cfg.kind)
    return specs


# ---------------------------------------------------------------------------
# embedding substrate
# ---------------------------------------------------------------------------

def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None,
                  combiner: str = "sum") -> jnp.ndarray:
    """EmbeddingBag: ids [B, L] -> [B, dim] (sum/mean over the bag).

    The pure-JAX reference for the Pallas embedding_bag kernel.
    """
    emb = jnp.take(table, ids, axis=0, mode="clip")
    if mask is not None:
        emb = emb * mask[..., None].astype(emb.dtype)
    out = emb.sum(axis=1)
    if combiner == "mean":
        denom = (mask.sum(axis=1, keepdims=True) if mask is not None
                 else jnp.full((1, 1), ids.shape[1]))
        out = out / jnp.maximum(denom.astype(out.dtype), 1.0)
    return out


def _field_embeds(tables: Dict[str, jnp.ndarray],
                  sparse_ids: jnp.ndarray) -> jnp.ndarray:
    """sparse_ids [B, n_fields] (one id per field) -> [B, n_fields, d]."""
    cols = [jnp.take(tables[f"t{i}"], sparse_ids[:, i], axis=0,
                     mode="clip")
            for i in range(sparse_ids.shape[1])]
    return jnp.stack(cols, axis=1)


# ---------------------------------------------------------------------------
# DLRM (arXiv:1906.00091) — dot interaction
# ---------------------------------------------------------------------------

def dlrm_forward(params: Dict, batch: Dict, cfg: RecsysConfig) -> jnp.ndarray:
    dense, sparse = batch["dense"], batch["sparse"]     # [B,13], [B,26] int32
    bot = _mlp(dense, params, "bot", len(cfg.bot_mlp), final_act=True)
    emb = _field_embeds(params["tables"], sparse)       # [B, 26, d]
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)  # [B, 27, d]
    inter = jnp.einsum("bnd,bmd->bnm", z, z)
    n = z.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    flat = inter[:, iu, ju]                              # [B, n(n-1)/2]
    x = jnp.concatenate([bot, flat], axis=1)
    logit = _mlp(x, params, "top", len(cfg.top_mlp))
    return logit[:, 0]


# ---------------------------------------------------------------------------
# DCN-v2 (arXiv:2008.13535) — full-matrix cross layers ∥ deep MLP
# ---------------------------------------------------------------------------

def dcn_forward(params: Dict, batch: Dict, cfg: RecsysConfig) -> jnp.ndarray:
    dense, sparse = batch["dense"], batch["sparse"]
    emb = _field_embeds(params["tables"], sparse)       # [B, 26, d]
    x0 = jnp.concatenate([dense, emb.reshape(emb.shape[0], -1)], axis=1)
    x = x0
    for i in range(cfg.n_cross_layers):
        xw = jnp.einsum("bi,io->bo", x, params[f"cross_w{i}"]) \
            + params[f"cross_b{i}"]
        x = x0 * xw + x
    deep = _mlp(x0, params, "deep", len(cfg.deep_mlp), final_act=True)
    both = jnp.concatenate([x, deep], axis=1)
    return jnp.einsum("bi,io->bo", both, params["logit_w"])[:, 0]


# ---------------------------------------------------------------------------
# MIND (arXiv:1904.08030) — multi-interest capsule routing
# ---------------------------------------------------------------------------

def mind_interests(params: Dict, hist_ids: jnp.ndarray,
                   hist_mask: jnp.ndarray, cfg: RecsysConfig) -> jnp.ndarray:
    """B2I dynamic routing: history [B,L] -> K interest capsules [B,K,d]."""
    d, K = cfg.embed_dim, cfg.n_interests
    e = jnp.take(params["item_embed"], hist_ids, axis=0,
                 mode="clip")               # [B,L,d]
    e = e * hist_mask[..., None].astype(e.dtype)
    eS = jnp.einsum("bld,de->ble", e, params["S"])       # shared bilinear map
    B, L = hist_ids.shape
    # fixed random routing-logit init (paper §B2I): breaks the capsule
    # symmetry that all-zeros init would never escape
    b_init = jax.random.normal(jax.random.key(17), (1, K, L),
                               jnp.float32)
    b_logit = jnp.broadcast_to(b_init, (B, K, L))
    neg = jnp.where(hist_mask > 0, 0.0, -1e30)[:, None, :]
    u = jnp.zeros((B, K, d), e.dtype)
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b_logit + neg, axis=1)        # over capsules
        z = jnp.einsum("bkl,ble->bke", w.astype(eS.dtype), eS)
        sq = jnp.sum(jnp.square(z.astype(jnp.float32)), -1, keepdims=True)
        u = (z.astype(jnp.float32) * (sq / (1.0 + sq))
             * jax.lax.rsqrt(sq + 1e-9)).astype(e.dtype)  # squash
        b_logit = b_logit + jnp.einsum("bke,ble->bkl", u, eS
                                       ).astype(jnp.float32)
    # per-capsule MLP head (H-layer in the paper)
    return _mlp(u, params, "interest", 2)


def mind_forward(params: Dict, batch: Dict, cfg: RecsysConfig) -> jnp.ndarray:
    """In-batch sampled-softmax training logits [B, B]."""
    u = mind_interests(params, batch["hist_ids"], batch["hist_mask"], cfg)
    t = jnp.take(params["item_embed"], batch["target_ids"], axis=0,
                 mode="clip")               # [B,d]
    # label-aware attention ≈ max over interests (pow→∞ limit)
    scores = jnp.einsum("bkd,cd->bkc", u, t)             # [B,K,B]
    return scores.max(axis=1)                            # [B,B]


# ---------------------------------------------------------------------------
# Two-tower retrieval (YouTube RecSys'19) — in-batch sampled softmax
# ---------------------------------------------------------------------------

def two_tower_embed(params: Dict, ids: jnp.ndarray, tower: str,
                    cfg: RecsysConfig) -> jnp.ndarray:
    table = params["user_embed" if tower == "user" else "item_embed"]
    e = jnp.take(table, ids, axis=0, mode="clip")
    out = _mlp(e, params, f"{tower}_tower", len(cfg.tower_mlp))
    return out / jnp.maximum(
        jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)


def two_tower_retrieval_scores(params: Dict, batch: Dict,
                               cfg: RecsysConfig) -> jnp.ndarray:
    """1 query vs n_candidates: batched dot, not a loop."""
    u = two_tower_embed(params, batch["user_ids"], "user", cfg)     # [1,d']
    c = two_tower_embed(params, batch["cand_ids"], "item", cfg)     # [N,d']
    return jnp.einsum("qd,nd->qn", u, c)


# ---------------------------------------------------------------------------
# unified train/serve entry points
# ---------------------------------------------------------------------------

def recsys_train_loss(params: Dict, batch: Dict,
                      cfg: RecsysConfig) -> jnp.ndarray:
    if cfg.kind == "dlrm":
        logit = dlrm_forward(params, batch, cfg)
        y = batch["labels"].astype(jnp.float32)
        z = logit.astype(jnp.float32)
        return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
    if cfg.kind == "dcn":
        logit = dcn_forward(params, batch, cfg)
        y = batch["labels"].astype(jnp.float32)
        z = logit.astype(jnp.float32)
        return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
    if cfg.kind == "mind":
        logits = mind_forward(params, batch, cfg).astype(jnp.float32)
        labels = jnp.arange(logits.shape[0])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return (logz - gold).mean()
    if cfg.kind == "two_tower":
        u = two_tower_embed(params, batch["user_ids"], "user", cfg)
        i = two_tower_embed(params, batch["item_ids"], "item", cfg)
        logits = jnp.einsum("bd,cd->bc", u, i).astype(jnp.float32) * 10.0
        # logQ correction for in-batch sampling (uniform proposal)
        labels = jnp.arange(logits.shape[0])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return (logz - gold).mean()
    raise ValueError(cfg.kind)


def recsys_serve(params: Dict, batch: Dict, cfg: RecsysConfig) -> jnp.ndarray:
    if cfg.kind == "dlrm":
        return jax.nn.sigmoid(dlrm_forward(params, batch, cfg))
    if cfg.kind == "dcn":
        return jax.nn.sigmoid(dcn_forward(params, batch, cfg))
    if cfg.kind == "mind":
        u = mind_interests(params, batch["hist_ids"], batch["hist_mask"], cfg)
        t = jnp.take(params["item_embed"], batch["target_ids"], axis=0,
                     mode="clip")
        return jnp.einsum("bkd,bd->bk", u, t).max(axis=1)
    if cfg.kind == "two_tower":
        return two_tower_retrieval_scores(params, batch, cfg)
    raise ValueError(cfg.kind)
