"""Shared model machinery: parameter specs with logical sharding axes.

Every model defines ``param_specs(cfg) -> pytree[ParamSpec]``.  A spec
records shape, dtype, *logical axes* (mapped to mesh axes by
``repro.distrib.shardings``) and an initializer.  From specs we derive:

* ``init_params``      — materialized params (smoke tests / real training)
* ``abstract_params``  — ShapeDtypeStructs (dry-run lowering, no memory)
* sharding trees       — via the logical-axis rule engine

Pure JAX (no flax): params are nested dicts of arrays.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "init_params", "abstract_params", "logical_axes_tree",
           "rms_norm", "rope", "count_params", "he_init", "lecun_init",
           "embed_init", "zeros_init", "ones_init"]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "lecun"          # lecun | he | embed | zeros | ones | normal
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), \
            f"{self.shape} vs {self.logical_axes}"


def _initializer(spec: ParamSpec) -> Callable:
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    if spec.init == "zeros":
        return lambda k: jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return lambda k: jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = spec.init_scale
        return lambda k: (jax.random.normal(k, spec.shape, jnp.float32)
                          * std).astype(spec.dtype)
    if spec.init == "normal":
        return lambda k: (jax.random.normal(k, spec.shape, jnp.float32)
                          * spec.init_scale).astype(spec.dtype)
    if spec.init == "he":
        std = spec.init_scale * math.sqrt(2.0 / fan_in)
    else:  # lecun
        std = spec.init_scale * math.sqrt(1.0 / fan_in)
    return lambda k: (jax.random.normal(k, spec.shape, jnp.float32)
                      * std).astype(spec.dtype)


he_init = partial(ParamSpec, init="he")
lecun_init = partial(ParamSpec, init="lecun")
embed_init = partial(ParamSpec, init="embed")
zeros_init = partial(ParamSpec, init="zeros")
ones_init = partial(ParamSpec, init="ones")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key) -> Dict:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_initializer(s)(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs) -> Dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=_is_spec)


def logical_axes_tree(specs) -> Dict:
    return jax.tree.map(lambda s: s.logical_axes, specs, is_leaf=_is_spec)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))


# ---------------------------------------------------------------------------
# activation sharding constraints (GSPMD guidance, MaxText-style)
# ---------------------------------------------------------------------------
# Model code calls ``shard_act(x, ("batch", "seq", "d_ff"))`` at layer
# boundaries; outside a context this is the identity, inside
# ``activation_sharding(mesh, spec_fn)`` it pins the activation to the
# rule-resolved NamedSharding.  Without these constraints GSPMD's
# propagation can drop the batch sharding across chunked-attention
# backward passes (observed: per-device dots at global batch).

import contextlib
import threading

_ACT_CTX = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, spec_fn):
    """spec_fn(shape, logical_axes, mesh) -> PartitionSpec."""
    prev = getattr(_ACT_CTX, "value", None)
    _ACT_CTX.value = (mesh, spec_fn)
    try:
        yield
    finally:
        _ACT_CTX.value = prev


def shard_act(x, logical_axes: Sequence[Optional[str]]):
    ctx = getattr(_ACT_CTX, "value", None)
    if ctx is None:
        return x
    mesh, spec_fn = ctx
    from jax.sharding import NamedSharding
    spec = spec_fn(tuple(x.shape), tuple(logical_axes), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# numerics shared across models
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with fp32 *accumulation* but no materialized fp32 copy.

    ``jnp.mean(..., dtype=f32)`` reduces in fp32 while the [B,S,D]
    tensor itself stays bf16 — the earlier ``x.astype(f32)`` round-trip
    dominated the HLO byte traffic (measured in §Perf: 387 GB of
    ``convert`` results per qwen110 layer)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         base: float = 10000.0) -> jnp.ndarray:
    """Rotary position embedding. x: [..., seq, heads, d_head].

    cos/sin are computed in fp32 (tiny [S, d/2] tables) then applied in
    the activation dtype — no full-tensor fp32 intermediates."""
    d = x.shape[-1]
    half = d // 2
    freq = (1.0 / base) ** (jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freq  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)   # broadcast heads
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)
