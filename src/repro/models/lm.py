"""Decoder-only transformer LM family (dense + MoE), pure JAX.

Covers the five assigned LM architectures (granite-MoE, phi-3.5-MoE,
qwen3-14b, smollm-360m, qwen1.5-110b): GQA with optional qk-norm and
QKV bias, RoPE, SwiGLU FFN or top-k routed MoE, stacked-layer params
scanned with optional remat.

Three step functions (all pjit-compatible, global-shape semantics):

* ``train_step``    — causal-LM loss + AdamW update (via repro.train)
* ``prefill_step``  — forward-only; builds the KV cache; uses *chunked*
  (online-softmax) attention so 32k×32k score matrices are never
  materialized — the XLA formulation of the flash-attention schedule
  (the Pallas kernel in ``repro.kernels.flash_attention`` is the
  TPU-native version of the same algorithm);
* ``decode_step``   — one token per sequence against a sharded KV cache
  (cache sequence axis sharded over the model axis = split-K decode).

MoE uses sort-based capacity dispatch (GShard-style dropping,
MegaBlocks-style grouped-GEMM shape): tokens sort by expert, pack to
``[E, C, D]``, run batched einsums, and combine back.  Two dispatch
modes: the flat/global form (paper-faithful GShard baseline) and the
grouped **gather-only** form (``dispatch_groups>0``) where packing and
combining are pure gathers through the inverse sort permutation —
scatters replicate their operands under GSPMD (measured in
EXPERIMENTS.md §Perf: −90% collective bytes).  FLOPs scale with active
experts (×capacity factor), not total experts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ParamSpec, rms_norm, rope, shard_act

__all__ = ["LMConfig", "param_specs", "forward", "causal_lm_loss",
           "prefill", "decode_one", "init_cache_specs", "num_params"]


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    # MoE (0 experts = dense)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # arch flags
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_base: float = 10000.0
    tie_embeddings: bool = False
    # execution
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 256
    attn_window: Optional[int] = None        # sliding window (long-context)
    attn_chunk: int = 512                    # q-block for chunked attention
    chunked_attn_threshold: int = 2048       # use chunked attn when S >=
    remat: str = "full"                      # none | full | dots
    fuse_qkv: bool = False                   # fused [D, H+2K, hd] projection
    #: express GQA by materializing KV to all H heads. When H divides
    #: the model axis but K does not (qwen110: H=64, K=8 on 16-way TP),
    #: the (K,G)-factored attention einsums force GSPMD to replicate the
    #: fp32 score chain (the [8,8] reshape of a 16-way-sharded 64 is
    #: inexpressible); repeated-KV attention keeps every score tensor
    #: H-sharded. KV repeat itself is free: K<16 means KV was already
    #: replicated. Found in §Perf hillclimbing.
    gqa_repeat_kv: bool = False
    #: MoE dispatch groups (0 = flat/global GShard-style sort). With
    #: G == data-axis size, dispatch (sort, cumsum, scatter) is LOCAL to
    #: each data shard and only the packed [G,E,C,D] tensor crosses the
    #: mesh (all-to-all), not the raw token stream — the MoE collective
    #: schedule real deployments use. Found in §Perf hillclimbing.
    dispatch_groups: int = 0
    #: scan over layers (compact HLO, fast compiles) vs python-unrolled
    #: (×L HLO). The dry-run unrolls: XLA cost_analysis counts a while
    #: body ONCE regardless of trip count, so scanned-layer FLOPs/bytes
    #: would under-report by ×L in the roofline.
    scan_layers: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else \
            self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_specs(cfg: LMConfig) -> Dict:
    L, D, H, K = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd, F, Vp = cfg.head_dim, cfg.d_ff, cfg.padded_vocab
    dt = cfg.dtype
    lyr: Dict[str, ParamSpec] = {
        "ln1": ParamSpec((L, D), ("layers", "norm"), dt, init="ones"),
        "ln2": ParamSpec((L, D), ("layers", "norm"), dt, init="ones"),
    }
    if cfg.fuse_qkv:
        lyr["wqkv"] = ParamSpec((L, D, H + 2 * K, hd),
                                ("layers", "d_model", "heads", "head_dim"), dt)
    else:
        lyr["wq"] = ParamSpec((L, D, H, hd),
                              ("layers", "d_model", "heads", "head_dim"), dt)
        lyr["wk"] = ParamSpec((L, D, K, hd),
                              ("layers", "d_model", "kv_heads", "head_dim"), dt)
        lyr["wv"] = ParamSpec((L, D, K, hd),
                              ("layers", "d_model", "kv_heads", "head_dim"), dt)
    lyr["wo"] = ParamSpec((L, H, hd, D),
                          ("layers", "heads", "head_dim", "d_model_out"), dt)
    if cfg.qkv_bias:
        lyr["bq"] = ParamSpec((L, H, hd), ("layers", "heads", "head_dim"),
                              dt, init="zeros")
        lyr["bk"] = ParamSpec((L, K, hd), ("layers", "kv_heads", "head_dim"),
                              dt, init="zeros")
        lyr["bv"] = ParamSpec((L, K, hd), ("layers", "kv_heads", "head_dim"),
                              dt, init="zeros")
    if cfg.qk_norm:
        lyr["q_norm"] = ParamSpec((L, hd), ("layers", "norm"), dt, init="ones")
        lyr["k_norm"] = ParamSpec((L, hd), ("layers", "norm"), dt, init="ones")
    if cfg.is_moe:
        E = cfg.n_experts
        lyr["router"] = ParamSpec((L, D, E), ("layers", "d_model", "experts"),
                                  jnp.float32)
        lyr["w1"] = ParamSpec((L, E, D, F),
                              ("layers", "experts", "d_model", "d_ff"), dt)
        lyr["w3"] = ParamSpec((L, E, D, F),
                              ("layers", "experts", "d_model", "d_ff"), dt)
        lyr["w2"] = ParamSpec((L, E, F, D),
                              ("layers", "experts", "d_ff", "d_model_out"), dt)
    else:
        lyr["w1"] = ParamSpec((L, D, F), ("layers", "d_model", "d_ff"), dt)
        lyr["w3"] = ParamSpec((L, D, F), ("layers", "d_model", "d_ff"), dt)
        lyr["w2"] = ParamSpec((L, F, D), ("layers", "d_ff", "d_model_out"), dt)
    specs = {
        "embed": ParamSpec((Vp, D), ("vocab", "d_model"), dt, init="embed",
                           init_scale=0.02),
        "ln_f": ParamSpec((D,), ("norm",), dt, init="ones"),
        "layers": lyr,
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((D, Vp), ("d_model", "vocab"), dt)
    return specs


def num_params(cfg: LMConfig) -> int:
    from .common import count_params
    return count_params(param_specs(cfg))


def active_params(cfg: LMConfig) -> int:
    """Params touched per token (dense = all; MoE = top_k of E experts)."""
    total = num_params(cfg)
    if not cfg.is_moe:
        return total
    L, E, D, F = cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_ff
    expert_params = L * E * 3 * D * F
    active_expert = L * cfg.top_k * 3 * D * F
    return total - expert_params + active_expert


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _qkv(x, p, li, cfg: LMConfig):
    """x: [B,S,D] -> q [B,S,H,hd], k/v [B,S,K,hd] (rope NOT yet applied)."""
    if cfg.fuse_qkv:
        w = p["wqkv"][li]
        qkv = jnp.einsum("bsd,dnh->bsnh", x, w)
        q = qkv[..., :cfg.n_heads, :]
        k = qkv[..., cfg.n_heads:cfg.n_heads + cfg.n_kv_heads, :]
        v = qkv[..., cfg.n_heads + cfg.n_kv_heads:, :]
    else:
        q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"][li])
        k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"][li])
        v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"][li])
    if cfg.qkv_bias:
        q = q + p["bq"][li]
        k = k + p["bk"][li]
        v = v + p["bv"][li]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"][li])
        k = rms_norm(k, p["k_norm"][li])
    q = shard_act(q, ("batch", None, "heads", None))
    k = shard_act(k, ("batch", None, "kv_heads", None))
    v = shard_act(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _attn_scores_mask(S_q: int, S_k: int, q_offset,
                      window: Optional[int]) -> jnp.ndarray:
    """Causal (+ optional sliding window) mask [S_q, S_k]; True=keep."""
    qpos = jnp.arange(S_q) + q_offset
    kpos = jnp.arange(S_k)
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def _expand_kv(k, H):
    """[B,S,K,hd] -> [B,S,H,hd] (repeat each KV head H/K times) with an
    H-sharded constraint — see LMConfig.gqa_repeat_kv."""
    B, S, K, hd = k.shape
    G = H // K
    out = jnp.broadcast_to(k[:, :, :, None, :], (B, S, K, G, hd)) \
        .reshape(B, S, H, hd)
    return shard_act(out, ("batch", None, "heads", None))


def _plain_attention(q, k, v, cfg: LMConfig, q_offset=0):
    """q: [B,Sq,H,hd], k/v: [B,Sk,K,hd] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    mask = _attn_scores_mask(Sq, Sk, q_offset, cfg.attn_window)
    if cfg.gqa_repeat_kv:
        k, v = _expand_kv(k, H), _expand_kv(v, H)
        scores = jnp.einsum("bqnh,bsnh->bnqs", q,
                            k).astype(jnp.float32) * scale
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bnqs,bsnh->bqnh", probs, v)
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def _chunked_attention(q, k, v, cfg: LMConfig, q_offset=0):
    """Online-softmax attention scanning q-chunks (no [Sq,Sk] alloc).

    The XLA expression of the flash-attention schedule: for each query
    block, stream over keys in full, carrying (m, l, acc).  Forward-only
    use (prefill); memory per step is O(chunk × Sk / devices).
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    C = min(cfg.attn_chunk, Sq)
    n_chunks = (Sq + C - 1) // C
    pad = n_chunks * C - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(hd)
    kpos = jnp.arange(Sk)
    repeat_kv = cfg.gqa_repeat_kv
    if repeat_kv:
        k, v = _expand_kv(k, H), _expand_kv(v, H)
        qg = q.reshape(B, n_chunks, C, H, hd).transpose(1, 0, 2, 3, 4)
    else:
        qg = q.reshape(B, n_chunks, C, K, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def chunk_body(carry, inp):
        qc, ci = inp            # [B,C,H,hd] or [B,C,K,G,hd]
        if repeat_kv:
            scores = jnp.einsum("bqnh,bsnh->bnqs", qc,
                                k).astype(jnp.float32)
        else:
            scores = jnp.einsum("bqkgh,bskh->bkgqs", qc,
                                k).astype(jnp.float32)
        scores = scores * scale
        qpos = ci * C + jnp.arange(C) + q_offset
        mask = kpos[None, :] <= qpos[:, None]
        if cfg.attn_window is not None:
            mask &= kpos[None, :] > qpos[:, None] - cfg.attn_window
        nb = (None,) if repeat_kv else (None, None)
        scores = jnp.where(mask[(None,) + nb], scores, -1e30)
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        l = jnp.sum(p, axis=-1)
        if repeat_kv:
            o = jnp.einsum("bnqs,bsnh->bnqh", p.astype(qc.dtype), v)
            out = o / jnp.maximum(l, 1e-30)[..., None].astype(qc.dtype)
            return carry, out.transpose(0, 2, 1, 3)      # [B,C,H,hd]
        o = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(qc.dtype), v)
        out = o / jnp.maximum(l, 1e-30)[..., None].astype(qc.dtype)
        return carry, out.transpose(0, 3, 1, 2, 4)   # [B,C,K,G,hd]

    # remat the chunk body: the [C, Sk] score block is recomputed in the
    # backward pass instead of being saved per chunk (flash-attn schedule)
    chunk_body = jax.checkpoint(
        chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        _, outs = jax.lax.scan(chunk_body, None,
                               (qg, jnp.arange(n_chunks)))
    else:   # unrolled for honest while-free cost_analysis (see scan_layers)
        outs = jnp.stack([chunk_body(None, (qg[i], jnp.int32(i)))[1]
                          for i in range(n_chunks)])
    if repeat_kv:
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * C, H, hd)
    else:
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, n_chunks * C, H,
                                                       hd)
    return out[:, :Sq]


def _attention(q, k, v, cfg: LMConfig, q_offset=0):
    if q.shape[1] >= cfg.chunked_attn_threshold:
        return _chunked_attention(q, k, v, cfg, q_offset)
    return _plain_attention(q, k, v, cfg, q_offset)


# ---------------------------------------------------------------------------
# FFN (dense SwiGLU / MoE)
# ---------------------------------------------------------------------------

def _dense_ffn(x, p, li):
    h = jnp.einsum("bsd,df->bsf", x, p["w1"][li])
    g = jnp.einsum("bsd,df->bsf", x, p["w3"][li])
    a = shard_act(jax.nn.silu(h) * g, ("batch", None, "d_ff"))
    return jnp.einsum("bsf,fd->bsd", a, p["w2"][li])


def moe_capacity(cfg: LMConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.top_k * n_tokens * cfg.capacity_factor
                      / cfg.n_experts))
    return max(8, ((c + 7) // 8) * 8)   # pad to lane multiple


def _moe_ffn_grouped(x, p, li, cfg: LMConfig):
    """Hierarchical MoE dispatch: sort/pack per data-shard group.

    The flat dispatch sorts ALL T·k assignments globally — under GSPMD
    the sort, cumsum and scatter become cross-shard collectives over the
    full token stream.  Here tokens are split into G groups aligned with
    the data axis; each group sorts/packs only its own T/G tokens into
    [E, C/G, D] (all local), and only the packed expert tensor moves
    across the mesh for the expert-parallel einsum.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = cfg.dispatch_groups
    Tg = T // G
    Cg = moe_capacity(cfg, Tg)
    xt = x.reshape(G, Tg, D)
    xt = shard_act(xt, ("moe_groups", None, None))

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"][li])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)                # [G,Tg,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = experts.reshape(G, Tg * k)
    flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(Tg), k)[None],
                              (G, Tg * k))
    flat_g = gates.reshape(G, Tg * k)

    order = jnp.argsort(flat_e, axis=1)                     # per-group sort
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    # group-local start offsets per expert via searchsorted on sorted se
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)

    # GATHER-ONLY packing (no scatter!): expert slot j=(e,c) PULLS sorted
    # position starts[e]+c.  GSPMD partitions gathers along the output
    # dim, so the packed [G,E,Cg,D] stays (data, model/experts)-sharded;
    # a scatter here forces GSPMD to replicate the packed operand
    # (measured: 114.8s -> 64.4s memory term was still scatter-bound).
    j = jnp.arange(E * Cg)
    slot_e = j // Cg                                        # [E*Cg]
    slot_c = j % Cg
    src_pos = starts[:, slot_e] + slot_c[None, :]           # [G, E*Cg]
    ends = jnp.concatenate([starts[:, 1:],
                            jnp.full((G, 1), Tg * k)], axis=1)
    slot_valid = src_pos < ends[:, slot_e]
    src_pos = jnp.minimum(src_pos, Tg * k - 1)
    slot_token = jnp.take_along_axis(st, src_pos, axis=1)   # [G, E*Cg]
    xd = jnp.take_along_axis(xt, slot_token[..., None], axis=1) \
        * slot_valid[..., None].astype(xt.dtype)
    xd = xd.reshape(G, E, Cg, D)
    xd = shard_act(xd, ("moe_groups", "experts", "moe_capacity", None))

    h = jnp.einsum("gecd,edf->gecf", xd, p["w1"][li])
    g2 = jnp.einsum("gecd,edf->gecf", xd, p["w3"][li])
    a = jax.nn.silu(h) * g2
    a = shard_act(a, ("moe_groups", "experts", "moe_capacity", "d_ff"))
    ye = jnp.einsum("gecf,efd->gecd", a, p["w2"][li])
    ye = ye.reshape(G, E * Cg, D)

    # GATHER-ONLY combine: assignment i pulls its slot's output row.
    inv_order = jnp.argsort(order, axis=1)                  # flat -> sorted
    pos_in_e = inv_order - jnp.take_along_axis(starts, flat_e, axis=1)
    keep = pos_in_e < Cg
    slot_of = jnp.minimum(flat_e * Cg + pos_in_e, E * Cg - 1)
    pulled = jnp.take_along_axis(ye, slot_of[..., None], axis=1) \
        * (flat_g * keep).astype(ye.dtype)[..., None]
    y = pulled.reshape(G, Tg, k, D).sum(axis=2)
    y = shard_act(y, ("moe_groups", None, None))

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(experts[..., 0], E), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux


def _moe_ffn(x, p, li, cfg: LMConfig):
    """Sort-based capacity dispatch -> grouped einsum -> combine."""
    if cfg.dispatch_groups:
        return _moe_ffn_grouped(x, p, li, cfg)
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = moe_capacity(cfg, T)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"][li])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)                 # [T,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = experts.reshape(-1)                             # [T*k]
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)

    order = jnp.argsort(flat_e)                              # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[se]
    keep = pos < C
    dest = jnp.where(keep, se * C + pos, E * C)              # E*C = drop slot

    gathered = xt[st] * keep[:, None].astype(xt.dtype)
    xd = jnp.zeros((E * C + 1, D), xt.dtype).at[dest].set(gathered)
    xd = shard_act(xd[:E * C].reshape(E, C, D), ("experts", None, None))

    h = jnp.einsum("ecd,edf->ecf", xd, p["w1"][li])
    g = jnp.einsum("ecd,edf->ecf", xd, p["w3"][li])
    a = jax.nn.silu(h) * g
    a = shard_act(a, ("experts", None, "d_ff"))
    ye = jnp.einsum("ecf,efd->ecd", a, p["w2"][li]).reshape(E * C, D)

    safe_dest = jnp.minimum(dest, E * C - 1)
    contrib = ye[safe_dest] * (sg * keep).astype(ye.dtype)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[st].add(contrib)

    # router z-loss + load-balance aux (Switch) for training health
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(experts[:, 0], E), axis=0)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def layer_forward(x, layer, cfg: LMConfig, *, collect_kv: bool = False):
    """One transformer block on a per-layer param slice.

    x [B,S,D] -> (x', aux, (k, v) or None).  Used by forward / prefill
    scan bodies AND by the dry-run's single-layer probe (the probe
    corrects XLA's while-body-once cost accounting; see launch/dryrun).
    """
    S = x.shape[1]
    h = rms_norm(x, layer["ln1"])
    q, k, v = _qkv_sliced(h, layer, cfg)
    q = rope(q, jnp.arange(S)[None, :], cfg.rope_base)
    k = rope(k, jnp.arange(S)[None, :], cfg.rope_base)
    attn = shard_act(_attention(q, k, v, cfg),
                     ("batch", None, "heads", None))
    x = x + jnp.einsum("bqnh,nhd->bqd", attn, layer["wo"])
    x = shard_act(x, ("batch", "seq", None))
    h2 = rms_norm(x, layer["ln2"])
    if cfg.is_moe:
        ff, aux = _moe_ffn_sliced(h2, layer, cfg)
    else:
        ff = _dense_ffn_sliced(h2, layer)
        aux = jnp.zeros((), jnp.float32)
    out = shard_act(x + ff, ("batch", "seq", None))
    return out, aux, ((k, v) if collect_kv else None)


def layer_decode(x, layer, k_cache, v_cache, pos, cfg: LMConfig):
    """One decode step through one layer.

    x [B,D]; k_cache/v_cache [B,S,K,hd]; pos scalar.
    Returns (x', k_cache', v_cache').
    """
    B = x.shape[0]
    S = k_cache.shape[1]
    K, H = cfg.n_kv_heads, cfg.n_heads
    G = H // K
    scale = 1.0 / math.sqrt(cfg.head_dim)
    kpos = jnp.arange(S)
    h = rms_norm(x[:, None], layer["ln1"])
    q, k, v = _qkv_sliced(h, layer, cfg)            # q [B,1,H,hd]
    q = rope(q, pos[None, None], cfg.rope_base)
    k = rope(k, pos[None, None], cfg.rope_base)
    k_cache = shard_act(jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0)),
        ("batch", "kv_seq", "kv_heads", None))
    v_cache = shard_act(jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0)),
        ("batch", "kv_seq", "kv_heads", None))
    valid = kpos <= pos
    if cfg.attn_window is not None:
        valid &= kpos > pos - cfg.attn_window
    if cfg.gqa_repeat_kv:
        ke = _expand_kv(k_cache, H)
        ve = _expand_kv(v_cache, H)
        scores = shard_act(
            jnp.einsum("bnh,bsnh->bns", q[:, 0],
                       ke).astype(jnp.float32) * scale,
            ("batch", "heads", "kv_seq"))
        scores = jnp.where(valid[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        attn = jnp.einsum("bns,bsnh->bnh", probs, ve)[:, None]
    else:
        qg = q[:, 0].reshape(B, K, G, cfg.head_dim)
        scores = shard_act(
            jnp.einsum("bkgh,bskh->bkgs", qg,
                       k_cache).astype(jnp.float32) * scale,
            ("batch", "kv_heads", None, "kv_seq"))
        scores = jnp.where(valid[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        attn = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache)
        attn = attn.reshape(B, 1, H, cfg.head_dim)
    x = x + jnp.einsum("bqnh,nhd->bqd", attn, layer["wo"])[:, 0]
    h2 = rms_norm(x[:, None], layer["ln2"])
    if cfg.is_moe:
        ff, _ = _moe_ffn_sliced(h2, layer, cfg)
    else:
        ff = _dense_ffn_sliced(h2, layer)
    return x + ff[:, 0], k_cache, v_cache


def forward(params: Dict, tokens: jnp.ndarray, cfg: LMConfig,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B,S] -> (logits [B,S,V], aux_loss scalar)."""
    B, S = tokens.shape
    x = shard_act(jnp.take(params["embed"], tokens, axis=0, mode="clip"),
                  ("batch", "seq", None))
    lp = params["layers"]
    aux_total = jnp.zeros((), jnp.float32)

    def layer_body(carry, layer):
        x, aux = carry
        x, a, _ = layer_forward(x, layer, cfg)
        return (x, aux + a), None

    body = _apply_remat(layer_body, cfg)
    if cfg.scan_layers:
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), lp)
    else:
        carry = (x, aux_total)
        for li in range(cfg.n_layers):
            carry, _ = body(carry, jax.tree.map(lambda a: a[li], lp))
        x, aux_total = carry
    x = rms_norm(x, params["ln_f"])
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = shard_act(jnp.einsum("bsd,dv->bsv", x, unembed),
                       ("batch", None, "vocab"))
    return logits, aux_total / cfg.n_layers


def _apply_remat(body, cfg: LMConfig):
    if cfg.remat == "full":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return body


# per-layer-slice variants (layer dict has leading L removed by scan)
def _qkv_sliced(x, layer, cfg: LMConfig):
    p = {k2: v[None] for k2, v in layer.items()}   # reuse _qkv with li=0
    return _qkv(x, p, 0, cfg)


def _dense_ffn_sliced(x, layer):
    return _dense_ffn(x, {k: v[None] for k, v in layer.items()}, 0)


def _moe_ffn_sliced(x, layer, cfg: LMConfig):
    return _moe_ffn(x, {k: v[None] for k, v in layer.items()}, 0, cfg)


def causal_lm_loss(params: Dict, batch: Dict, cfg: LMConfig) -> jnp.ndarray:
    """Causal-LM cross entropy, written shard-friendly.

    The vocab axis of ``logits`` is model-sharded; a ``take_along_axis``
    (gather) on that axis would force GSPMD to replicate the full fp32
    [B,S,V] tensor on every device.  Instead both the padding mask and
    the gold-logit selection are *elementwise* in V followed by a
    reduction, which partitions cleanly (partial reduce + all-reduce).
    """
    tokens, labels = batch["tokens"], batch["labels"]
    logits, aux = forward(params, tokens, cfg)
    logits = logits.astype(jnp.float32)
    V = cfg.padded_vocab
    vocab_iota = jax.lax.iota(jnp.int32, V)
    if V != cfg.vocab_size:
        # mask padded vocab entries out of the softmax (elementwise)
        logits = logits + jnp.where(vocab_iota >= cfg.vocab_size,
                                    -1e30, 0.0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = vocab_iota[None, None, :] == labels[..., None]
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# inference: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_cache_specs(cfg: LMConfig, batch: int, max_len: int) -> Dict:
    """ShapeDtypeStruct/ParamSpec tree for the KV cache."""
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return {
        "k": ParamSpec((L, batch, max_len, K, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                       cfg.dtype, init="zeros"),
        "v": ParamSpec((L, batch, max_len, K, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                       cfg.dtype, init="zeros"),
    }


def prefill(params: Dict, tokens: jnp.ndarray, cfg: LMConfig,
            ) -> Tuple[jnp.ndarray, Dict]:
    """Forward-only pass building the KV cache.

    Returns (last-position logits [B,V], cache {k,v: [L,B,S,K,hd]}).
    """
    B, S = tokens.shape
    x = shard_act(jnp.take(params["embed"], tokens, axis=0, mode="clip"),
                  ("batch", "seq", None))
    lp = params["layers"]

    def layer_body(x, layer):
        x, _, kv = layer_forward(x, layer, cfg, collect_kv=True)
        return x, kv

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(layer_body, x, lp)
    else:
        ks_list, vs_list = [], []
        for li in range(cfg.n_layers):
            x, (k, v) = layer_body(x, jax.tree.map(lambda a: a[li], lp))
            ks_list.append(k)
            vs_list.append(v)
        ks, vs = jnp.stack(ks_list), jnp.stack(vs_list)
    x = rms_norm(x[:, -1], params["ln_f"])
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bd,dv->bv", x, unembed)
    return logits, {"k": ks, "v": vs}


def decode_one(params: Dict, cache: Dict, tokens: jnp.ndarray,
               pos: jnp.ndarray, cfg: LMConfig,
               ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.

    tokens [B] int32, pos scalar int32 (current length; same for all
    sequences — continuous batching padding is handled upstream).
    Returns (logits [B,V], updated cache).

    The cache sequence axis is sharded over the *model* mesh axis
    (split-K decode): scores and the softmax reduce across shards via
    GSPMD collectives — the TPU analogue of flash-decoding.
    """
    B = tokens.shape[0]
    S = cache["k"].shape[2]
    x = jnp.take(params["embed"], tokens, axis=0, mode="clip")   # [B,D]
    lp = params["layers"]
    kpos = jnp.arange(S)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    K, H = cfg.n_kv_heads, cfg.n_heads
    G = H // K

    def layer_body(carry, inp):
        x, = carry
        layer, k_cache, v_cache = inp
        x, k_cache, v_cache = layer_decode(x, layer, k_cache, v_cache,
                                           pos, cfg)
        return (x,), (k_cache, v_cache)

    if cfg.scan_layers:
        (x,), (ks, vs) = jax.lax.scan(layer_body, (x,),
                                      (lp, cache["k"], cache["v"]))
    else:
        ks_list, vs_list = [], []
        for li in range(cfg.n_layers):
            (x,), (k_c, v_c) = layer_body(
                (x,), (jax.tree.map(lambda a: a[li], lp),
                       cache["k"][li], cache["v"][li]))
            ks_list.append(k_c)
            vs_list.append(v_c)
        ks, vs = jnp.stack(ks_list), jnp.stack(vs_list)
    x = rms_norm(x, params["ln_f"])
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bd,dv->bv", x, unembed)
    return logits, {"k": ks, "v": vs}
