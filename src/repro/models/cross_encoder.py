"""Neural cross-encoder scorers as pipeline stages (MonoT5/DuoT5 roles).

``MonoScorer`` is a *pointwise* reranker: each (query, document) pair is
scored independently — the probability-ranking-principle pattern that
makes ScorerCache sound (paper §4.2).

``DuoScorer`` is a *pairwise* reranker: the score of a document depends
on the other retrieved documents for that query.  Exactly as the paper
notes for DuoT5 (§5), it is **not amenable to caching**; it declares
``cacheable=False`` and ``auto_cache`` refuses it.

Both wrap a small bidirectional JAX encoder over hash-tokenized text.
Execution details that matter on TPU/XLA:

* miss batches run through ``BucketedRunner`` so the jitted scorer sees
  O(log n) distinct shapes (see caching/bucketing.py);
* compiled executables are shared across pipeline stages via the
  process-wide ``CompileCache`` — two experiments instantiating the same
  scorer shape pay XLA compilation once.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..caching.bucketing import BucketedRunner
from ..caching.compile_cache import default_compile_cache
from ..core.frame import ColFrame
from ..core.pipeline import Transformer, add_ranks
from ..ir.tokenizer import HashTokenizer
from .common import ParamSpec, init_params, rms_norm

__all__ = ["EncoderConfig", "encoder_param_specs", "encoder_score",
           "MonoScorer", "DuoScorer"]


@dataclass(frozen=True)
class EncoderConfig:
    name: str = "mono-ce"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    vocab_size: int = 32768
    max_len: int = 64
    dtype: Any = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def encoder_param_specs(cfg: EncoderConfig) -> Dict:
    L, D, H, hd, F, V = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                         cfg.head_dim, cfg.d_ff, cfg.vocab_size)
    dt = cfg.dtype
    return {
        "embed": ParamSpec((V, D), ("vocab", "d_model"), dt, init="embed",
                           init_scale=0.02),
        "pos": ParamSpec((cfg.max_len, D), ("seq", "d_model"), dt,
                         init="embed", init_scale=0.02),
        "layers": {
            "ln1": ParamSpec((L, D), ("layers", "norm"), dt, init="ones"),
            "ln2": ParamSpec((L, D), ("layers", "norm"), dt, init="ones"),
            "wq": ParamSpec((L, D, H, hd),
                            ("layers", "d_model", "heads", "head_dim"), dt),
            "wk": ParamSpec((L, D, H, hd),
                            ("layers", "d_model", "heads", "head_dim"), dt),
            "wv": ParamSpec((L, D, H, hd),
                            ("layers", "d_model", "heads", "head_dim"), dt),
            "wo": ParamSpec((L, H, hd, D),
                            ("layers", "heads", "head_dim", "d_model_out"),
                            dt),
            "w1": ParamSpec((L, D, F), ("layers", "d_model", "d_ff"), dt),
            "w2": ParamSpec((L, F, D), ("layers", "d_ff", "d_model_out"), dt),
        },
        "ln_f": ParamSpec((D,), ("norm",), dt, init="ones"),
        "w_score": ParamSpec((D, 1), ("d_model", None), dt),
    }


def encoder_score(params: Dict, tokens: jnp.ndarray,
                  cfg: EncoderConfig) -> jnp.ndarray:
    """tokens [B, max_len] int32 -> scores [B] (bidirectional encoder)."""
    B, S = tokens.shape
    mask = (tokens != 0)
    x = jnp.take(params["embed"], tokens, axis=0, mode="clip")
    x = x + params["pos"][None, :S]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    bias = jnp.where(mask, 0.0, -1e30).astype(jnp.float32)[:, None, None, :]

    def layer_body(x, layer):
        h = rms_norm(x, layer["ln1"])
        q = jnp.einsum("bsd,dnh->bsnh", h, layer["wq"])
        k = jnp.einsum("bsd,dnh->bsnh", h, layer["wk"])
        v = jnp.einsum("bsd,dnh->bsnh", h, layer["wv"])
        scores = jnp.einsum("bqnh,bsnh->bnqs", q, k).astype(jnp.float32)
        probs = jax.nn.softmax(scores * scale + bias, axis=-1).astype(x.dtype)
        attn = jnp.einsum("bnqs,bsnh->bqnh", probs, v)
        x = x + jnp.einsum("bqnh,nhd->bqd", attn, layer["wo"])
        h2 = rms_norm(x, layer["ln2"])
        ff = jnp.einsum("bsf,fd->bsd",
                        jax.nn.gelu(jnp.einsum("bsd,df->bsf", h2,
                                               layer["w1"])),
                        layer["w2"])
        return x + ff, None

    x, _ = jax.lax.scan(layer_body, x, params["layers"])
    x = rms_norm(x, params["ln_f"])
    # masked mean pool -> linear score
    m = mask[..., None].astype(x.dtype)
    pooled = (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    return jnp.einsum("bd,do->bo", pooled, params["w_score"])[:, 0]


class _EncoderBase(Transformer):
    def __init__(self, cfg: EncoderConfig, seed: int = 0):
        self.cfg = cfg
        self.seed = seed
        self.params = init_params(encoder_param_specs(cfg),
                                  jax.random.key(seed))
        self.tokenizer = HashTokenizer(cfg.vocab_size)
        self.invocations = 0     # pairs actually scored (cache accounting)

        def _score(tokens):
            return default_compile_cache.call(
                f"{type(self).__name__}:{cfg.name}",
                lambda t: encoder_score(self.params, t, self.cfg), tokens)

        self._runner = BucketedRunner(_score, floor=8, max_bucket=1024)

    def _score_pairs(self, queries, texts) -> np.ndarray:
        toks = np.stack([
            self.tokenizer.encode_pair(q, t, self.cfg.max_len)
            for q, t in zip(queries, texts)])
        self.invocations += len(queries)
        return np.asarray(self._runner(toks), dtype=np.float64)


class MonoScorer(_EncoderBase):
    """Pointwise neural reranker (R→R).  Cache-safe (paper §4.2)."""

    input_columns = frozenset({"qid", "query", "docno", "text"})
    key_columns = ("query", "docno")
    value_columns = ("score",)
    cacheable = True

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        scores = self._score_pairs(inp["query"].tolist(),
                                   inp["text"].tolist())
        return add_ranks(inp.assign(score=scores))

    def signature(self):
        return ("MonoScorer", self.cfg.name, self.cfg.n_layers,
                self.cfg.d_model, self.seed)


class DuoScorer(_EncoderBase):
    """Pairwise reranker (R→R): score of d_i depends on the other
    candidates (sum over j of s(d_i ≻ d_j)).  NOT cacheable — §5."""

    input_columns = frozenset({"qid", "query", "docno", "text"})
    cacheable = False

    def __init__(self, cfg: EncoderConfig, seed: int = 1, max_docs: int = 10):
        super().__init__(cfg, seed)
        self.max_docs = int(max_docs)

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        out_parts = []
        for (qid,), idx in inp.group_indices(["qid"]).items():
            grp = inp.take(idx)
            if "rank" in grp:
                grp = grp.sort_values(["rank"])
            grp = grp.head(self.max_docs)
            n = len(grp)
            texts = grp["text"].tolist()
            query = grp["query"][0]
            if n <= 1:
                out_parts.append(grp.assign(
                    score=np.zeros(n, dtype=np.float64)))
                continue
            qs, ts = [], []
            pairs = [(i, j) for i in range(n) for j in range(n) if i != j]
            for i, j in pairs:
                qs.append(query)
                ts.append(texts[i] + " [VS] " + texts[j])
            s = self._score_pairs(qs, ts)
            agg = np.zeros(n, dtype=np.float64)
            for (i, j), v in zip(pairs, s):
                agg[i] += v          # wins of i over j
                agg[j] -= v
            out_parts.append(grp.assign(score=agg))
        return add_ranks(ColFrame.concat(out_parts))

    def signature(self):
        return ("DuoScorer", self.cfg.name, self.cfg.n_layers,
                self.cfg.d_model, self.seed, self.max_docs)
