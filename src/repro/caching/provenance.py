"""Provenance layer: fingerprints, cache manifests, staleness policies.

The source paper warns that cached result files are "brittle and can
cause a disconnect between the conceptual design of the pipeline and
its logical implementation" — a cache directory keyed only on *input
values* silently serves stale results after a transformer's parameters,
corpus or code change.  This module closes that gap:

* **Fingerprints** — every transformer has a stable provenance
  fingerprint: class identity (module + qualname + a hash of the class
  source when obtainable) plus its structural ``signature()`` plus any
  declared ``fingerprint_extras()`` (corpus versions, checkpoint ids),
  hashed with the dual-lane FNV-1a digest of the ``cachekey_hash``
  kernel (``kernels/cachekey_hash``) when JAX is importable, and with a
  bit-identical pure-Python implementation otherwise.  The execution
  planner extends this to *node* fingerprints by folding in the
  fingerprints of all upstream nodes, so invalidation propagates
  downstream exactly as results do.

* **Manifests** — every cache directory carries a versioned
  ``manifest.json`` recording the fingerprint, cache family, storage
  backend, schema (key/value columns), creation / last-use timestamps
  and entry counts, protected by a content checksum.  A cache dir is
  thereby self-describing: it can be listed, verified, garbage
  collected and shared (``repro cache`` CLI, ``cli/cache.py``).

* **Staleness policies** — opening a cache whose manifest disagrees
  with the caller's provenance raises :class:`StaleCacheError` by
  default; ``on_stale="recompute"`` discards the stale entries and
  recomputes, ``on_stale="readonly"`` serves the existing entries but
  refuses to write (useful when the mismatch is known-cosmetic).

This module deliberately imports nothing from ``repro.core`` (it works
on duck-typed transformers), so the CLI and the cache families can use
it without pulling in JAX.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import asdict, dataclass, field, fields
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .backends import atomic_write_bytes

__all__ = [
    "MANIFEST_NAME", "MANIFEST_VERSION", "PLAN_MANIFEST_VERSION",
    "PLANS_SUBDIR", "ProvenanceError", "ManifestError", "StaleCacheError",
    "canonical_bytes", "digest_bytes", "class_source_hash",
    "transformer_fingerprint", "combine_fingerprints", "CacheManifest",
    "manifest_path", "plan_manifest_dir", "save_plan_manifest",
    "iter_plan_manifests",
]

MANIFEST_NAME = "manifest.json"
#: v2 adds the optional cache-economics budget fields (max_entries /
#: max_bytes / ttl_seconds).  v1 manifests load unchanged — absent
#: fields keep their defaults — and are upgraded in place on the next
#: save (backward adoption; asserted in tests/test_economics.py).
MANIFEST_VERSION = 2
PLAN_MANIFEST_VERSION = 1
PLANS_SUBDIR = "plans"


class ProvenanceError(RuntimeError):
    """Base class for provenance failures."""


class ManifestError(ProvenanceError):
    """A cache manifest is unreadable, corrupted or from the future."""


class StaleCacheError(ProvenanceError):
    """A cache directory's recorded provenance does not match the
    pipeline being executed (see ``on_stale=`` for the policies)."""


# ---------------------------------------------------------------------------
# canonical encoding + digest
# ---------------------------------------------------------------------------
#
# Fingerprints must be identical across processes and machines, so the
# payload is serialized with an unambiguous, type-tagged encoding
# (Python's hash() is salted per process; pickle embeds memo indices).

def canonical_bytes(obj: Any) -> bytes:
    """Deterministic, type-tagged byte encoding of a nested value."""
    out = bytearray()
    _encode(obj, out)
    return bytes(out)


def _encode(o: Any, out: bytearray) -> None:
    if o is None:
        out += b"n;"
    elif o is True:
        out += b"T;"
    elif o is False:
        out += b"F;"
    elif isinstance(o, (int, np.integer)):
        out += b"i%d;" % int(o)
    elif isinstance(o, (float, np.floating)):
        out += b"f" + float(o).hex().encode("ascii") + b";"
    elif isinstance(o, str):
        b = o.encode("utf-8")
        out += b"s%d:" % len(b) + b + b";"
    elif isinstance(o, (bytes, bytearray)):
        out += b"b%d:" % len(o) + bytes(o) + b";"
    elif isinstance(o, (tuple, list)):
        out += b"("
        for e in o:
            _encode(e, out)
        out += b")"
    elif isinstance(o, (set, frozenset)):
        out += b"{"
        for e in sorted(o, key=repr):
            _encode(e, out)
        out += b"}"
    elif isinstance(o, dict):
        out += b"<"
        for k in sorted(o, key=repr):
            _encode(k, out)
            _encode(o[k], out)
        out += b">"
    else:
        r = repr(o).encode("utf-8")
        out += b"o%d:" % len(r) + r + b";"


# Constants mirror kernels/cachekey_hash/ref.py — the host digest below
# is the kernel's bit-identical reference ("shared cache entries").
_FNV_OFFSET = 0x811C9DC5
_FNV_PRIME = 0x01000193
_LANE2_OFFSET = 0x31415927

#: digests pad token buffers to multiples of this many uint32 words so
#: the jitted kernel compiles O(1) distinct shapes, not one per payload
_WORD_BUCKET = 64

_DIGEST_IMPL = None


def _host_digest(words: np.ndarray) -> bytes:
    """Pure-Python dual-lane FNV-1a over little-endian uint32 words
    (identical to ``kernels.cachekey_hash.ops.host_cachekey``)."""
    h0, h1 = _FNV_OFFSET, _LANE2_OFFSET
    for b in np.ascontiguousarray(words, dtype="<u4").tobytes():
        h0 = ((h0 ^ b) * _FNV_PRIME) & 0xFFFFFFFF
        h1 = ((h1 ^ b) * _FNV_PRIME) & 0xFFFFFFFF
    return h0.to_bytes(4, "little") + h1.to_bytes(4, "little")


def _kernel_digest_factory():
    from ..kernels.cachekey_hash.ops import cachekey_hash_op

    def impl(words: np.ndarray) -> bytes:
        tokens = np.ascontiguousarray(words, dtype=np.uint32) \
            .view(np.int32).reshape(1, -1)
        out = np.asarray(cachekey_hash_op(tokens))
        return (int(out[0, 0]) & 0xFFFFFFFF).to_bytes(4, "little") + \
               (int(out[0, 1]) & 0xFFFFFFFF).to_bytes(4, "little")
    return impl


def _digest_impl():
    """Resolve the digest implementation once per process.

    ``REPRO_PROVENANCE_HASH`` selects: ``auto`` (default — the
    ``cachekey_hash`` kernel when JAX imports, else the pure-Python
    fallback), ``kernel`` (require the kernel) or ``host`` (skip JAX
    entirely; useful for lightweight CLI invocations).  Both paths
    produce identical digests (asserted in tests/test_provenance.py).
    """
    global _DIGEST_IMPL
    if _DIGEST_IMPL is None:
        mode = os.environ.get("REPRO_PROVENANCE_HASH", "auto")
        if mode == "host":
            _DIGEST_IMPL = _host_digest
        else:
            try:
                impl = _kernel_digest_factory()
                impl(np.zeros(_WORD_BUCKET, dtype=np.uint32))  # smoke
                _DIGEST_IMPL = impl
            except Exception:
                if mode == "kernel":
                    raise
                _DIGEST_IMPL = _host_digest
    return _DIGEST_IMPL


def digest_bytes(data: bytes) -> str:
    """16-hex-char dual-lane FNV digest of ``data`` (length-prefixed,
    zero-padded to the kernel's word bucket)."""
    buf = len(data).to_bytes(8, "little") + data
    buf += b"\x00" * ((-len(buf)) % 4)
    words = np.frombuffer(buf, dtype="<u4")
    target = -(-len(words) // _WORD_BUCKET) * _WORD_BUCKET
    if target > len(words):
        words = np.concatenate(
            [words, np.zeros(target - len(words), dtype="<u4")])
    return _digest_impl()(words).hex()


# ---------------------------------------------------------------------------
# transformer / node fingerprints
# ---------------------------------------------------------------------------

_SOURCE_HASH_CACHE: Dict[type, str] = {}


def class_source_hash(cls: type) -> str:
    """Short hash of a class's source text ("" when unobtainable) —
    folds *code changes* into provenance, per the paper's warning."""
    h = _SOURCE_HASH_CACHE.get(cls)
    if h is None:
        try:
            import inspect
            h = hashlib.sha256(
                inspect.getsource(cls).encode("utf-8")).hexdigest()[:16]
        except Exception:
            h = ""
        _SOURCE_HASH_CACHE[cls] = h
    return h


def transformer_fingerprint(t: Any) -> str:
    """Stable provenance fingerprint of a transformer.

    Covers class identity (module + qualname + source hash), the
    structural ``signature()`` (configuration and, for composite
    transformers, the whole subtree), and ``fingerprint_extras()`` when
    the transformer defines it (declare corpus versions, checkpoint
    paths, anything behaviour-relevant that the signature misses).
    Only as stable as the signature: signatures embedding ``id()`` or
    default ``object.__repr__`` addresses yield per-process values.
    """
    cls = type(t)
    sig = t.signature() if hasattr(t, "signature") else repr(t)
    extras: Tuple = ()
    fe = getattr(t, "fingerprint_extras", None)
    if callable(fe):
        extras = tuple(fe())
    payload = ("transformer/v1", cls.__module__, cls.__qualname__,
               class_source_hash(cls), sig, extras)
    return digest_bytes(canonical_bytes(payload))


def combine_fingerprints(*parts: Any) -> str:
    """Fold fingerprints/tokens into one digest (plan-node provenance:
    a node's fingerprint folds its stage's over its inputs')."""
    return digest_bytes(canonical_bytes(("combine/v1",) + parts))


# ---------------------------------------------------------------------------
# cache-dir manifests
# ---------------------------------------------------------------------------

def manifest_path(dirpath: str) -> str:
    return os.path.join(dirpath, MANIFEST_NAME)


@dataclass
class CacheManifest:
    """The versioned ``manifest.json`` of one cache directory."""

    family: str = ""                       # cache class (KeyValueCache, ...)
    backend: Optional[str] = None          # storage backend registry name
    fingerprint: Optional[str] = None      # provenance fingerprint (or None)
    transformer: Optional[str] = None      # repr of the wrapped transformer
    key_columns: List[str] = field(default_factory=list)
    value_columns: List[str] = field(default_factory=list)
    created_at: float = 0.0
    last_used_at: float = 0.0
    entry_count: int = 0
    # -- cache-economics budgets (v2; all optional, None = unbounded) ------
    max_entries: Optional[int] = None      # entry-count budget
    max_bytes: Optional[int] = None        # store-size budget (bytes)
    ttl_seconds: Optional[float] = None    # entry time-to-live
    # -- serialization scheme (see caching/codecs.py) ----------------------
    #: recorded when a store is *created*; ``None`` (including every
    #: directory that predates the field) means the legacy pickled
    #: keys/values scheme, so pre-existing warm dirs stay warm.  An
    #: optional field rather than a version bump: older builds load a
    #: manifest that carries it (unknown fields are filtered out on
    #: load) and keep serving the directory with whatever scheme the
    #: family negotiates.
    codec: Optional[str] = None
    format_version: int = MANIFEST_VERSION

    @classmethod
    def new(cls, **kw) -> "CacheManifest":
        now = time.time()
        return cls(created_at=now, last_used_at=now, **kw)

    def has_budget(self) -> bool:
        return (self.max_entries is not None or self.max_bytes is not None
                or self.ttl_seconds is not None)

    # -- integrity ---------------------------------------------------------
    def body(self) -> Dict[str, Any]:
        return asdict(self)

    def checksum(self) -> str:
        return _body_checksum(self.body())

    # -- io ----------------------------------------------------------------
    def save(self, dirpath: str) -> str:
        # older schemas upgrade to the current one on write (v1 dirs
        # adopt v2 the first time a v2 build touches them); a *future*
        # version is left intact so load() still rejects it
        if self.format_version < MANIFEST_VERSION:
            self.format_version = MANIFEST_VERSION
        doc = self.body()
        doc["checksum"] = self.checksum()
        path = manifest_path(dirpath)
        atomic_write_bytes(
            path, json.dumps(doc, indent=2, sort_keys=True).encode("utf-8"))
        return path

    @classmethod
    def load(cls, dirpath: str) -> Optional["CacheManifest"]:
        """Load a directory's manifest; ``None`` when absent.

        Raises :class:`ManifestError` on unparseable JSON, a checksum
        mismatch (hand-edited / torn manifest) or a format version
        newer than this code understands.
        """
        path = manifest_path(dirpath)
        if not os.path.exists(path):
            return None
        try:
            with open(path, "r", encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            raise ManifestError(f"unreadable cache manifest {path!r}: {e}")
        if not isinstance(doc, dict):
            raise ManifestError(f"cache manifest {path!r} is not an object")
        recorded = doc.pop("checksum", None)
        if recorded != _body_checksum(doc):
            raise ManifestError(
                f"corrupted cache manifest {path!r}: checksum mismatch "
                f"(the file was edited by hand or torn mid-write)")
        ver = doc.get("format_version")
        if not isinstance(ver, int) or ver > MANIFEST_VERSION:
            raise ManifestError(
                f"cache manifest {path!r} has format_version {ver!r}; this "
                f"build understands <= {MANIFEST_VERSION}")
        known = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in doc.items() if k in known})


def _body_checksum(body: Dict[str, Any]) -> str:
    return hashlib.sha256(
        json.dumps(body, sort_keys=True).encode("utf-8")).hexdigest()


# ---------------------------------------------------------------------------
# plan manifests (a cache_dir is self-describing about the plans using it)
# ---------------------------------------------------------------------------

def plan_manifest_dir(cache_dir: str) -> str:
    return os.path.join(cache_dir, PLANS_SUBDIR)


def save_plan_manifest(cache_dir: str, record: Dict[str, Any]) -> str:
    """Write one plan's manifest under ``<cache_dir>/plans/<plan_id>.json``
    (atomic; re-planning the same pipeline set overwrites in place)."""
    d = plan_manifest_dir(cache_dir)
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{record['plan_id']}.json")
    atomic_write_bytes(
        path, json.dumps(record, indent=2, sort_keys=True).encode("utf-8"))
    return path


def iter_plan_manifests(cache_dir: str):
    """Yield ``(path, record_or_None, error_or_None)`` for every plan
    manifest under ``cache_dir`` (unparseable files yield an error)."""
    d = plan_manifest_dir(cache_dir)
    if not os.path.isdir(d):
        return
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(d, name)
        try:
            with open(path, "r", encoding="utf-8") as f:
                doc = json.load(f)
            if not isinstance(doc, dict):
                raise ValueError("not a JSON object")
        except (OSError, ValueError) as e:
            yield path, None, f"unreadable plan manifest: {e}"
            continue
        yield path, doc, None
