"""Speculative cache warming — precomputation as an operational tool.

The paper's central device is *precomputation*: express the pipeline
end-to-end, compute the expensive stages ahead of time, serve the rest
from caches.  ``warm_scenario`` packages that as an offline job
(`repro cache warm SCENARIO`): it builds the named serving scenario
(``serve/registry.py``), compiles its pipeline through the same plan
stack a :class:`~repro.serve.service.PipelineService` would — identical
expression, identical node fingerprints, identical cache directories —
and drives :meth:`~repro.core.plan.ExecutionPlan.warm` over the
scenario's expected traffic distribution (``warming_frame`` simulates
the closed-loop generator's zipf draws).  A service later opened over
the same ``cache_dir`` with matching scenario parameters starts warm:
its first requests are all cache hits, collapsing cold-start tail
latency (asserted by ``benchmarks/serve_bench.py``'s warmed-start epoch
and the cache-lifecycle CI job).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import numpy as np

__all__ = ["warm_scenario"]


def warm_scenario(scenario: Any, cache_dir: str, *,
                  config: Any = None,
                  queries: Any = None,
                  budget: Optional[int] = None,
                  backend: Optional[str] = None,
                  cache_budget: Any = None,
                  requests: int = 512, clients: int = 4,
                  scale: float = 0.05, cutoff: int = 10,
                  num_results: int = 100, seed: int = 0,
                  batch_size: Optional[int] = None,
                  chunk_rows: Optional[int] = None,
                  on_stale: str = "error") -> Dict[str, Any]:
    """Precompute a serving scenario's caches offline.

    Parameters
    ----------
    scenario:
        A scenario name (``"bm25"`` / ``"bm25-mono"`` / ``"mono"`` /
        ...) or an already-built
        :class:`~repro.serve.registry.ServeScenario`.
        Names are built with ``scale``/``cutoff``/``num_results``/
        ``seed`` — these MUST match the later serve invocation, or the
        node fingerprints (and hence cache directories) will differ.
    config:
        A :class:`~repro.serve.config.ServeConfig` (or kwargs dict)
        supplying the scenario identity and cache plumbing in one
        object — the same config a later ``build_service`` call (one
        process or a fleet) consumes, which removes the
        "parameters must match" failure mode by construction.  When
        given it overrides ``scale``/``cutoff``/``num_results``/
        ``seed``/``backend``/``on_stale`` (and ``scenario``, when that
        is ``None``).
    cache_dir / backend:
        Where the planner-inserted caches live and which store backs
        them — again forwarded exactly as ``repro serve`` would.
    queries:
        Optional explicit warming frame (anything
        ``ColFrame.coerce`` accepts, rows of qid/query[/extras]).
        Default: ``warming_frame(...)`` — the scenario's own expected
        traffic distribution, hottest queries first.
    budget:
        Warm only the ``budget`` most-expected queries (``None`` =
        the whole topic pool, guaranteeing a subsequent matching serve
        run has zero misses).
    cache_budget:
        Optional per-node size/TTL envelope recorded into the freshly
        warmed manifests (``economics.CacheBudget`` / dict / int).
    chunk_rows:
        Warm in qid-aligned chunks of at most this many rows
        (bounded-memory warming of large logs).

    Returns a report dict (queries warmed, per-run cache hit/miss
    counts, wall time) suitable for ``--json`` output.
    """
    # imports deferred: this module is reachable from `repro.caching`,
    # which core/plan itself imports — resolving the plan/serve stack
    # lazily keeps the package import-cycle free
    from ..core.frame import ColFrame
    from ..core.plan import ExecutionPlan
    from ..serve.config import ServeConfig
    from ..serve.registry import ServeScenario, warming_frame

    if config is not None:
        cfg = ServeConfig.coerce(config)
        backend = cfg.backend if backend is None else backend
        on_stale = cfg.on_stale
        seed = cfg.seed
    else:
        cfg = ServeConfig(
            pipeline=scenario if isinstance(scenario, str) else "bm25-mono",
            scale=scale, cutoff=cutoff, num_results=num_results,
            seed=seed, cache_dir=cache_dir, backend=backend,
            on_stale=on_stale)
    if not isinstance(scenario, ServeScenario):
        if scenario is not None and str(scenario) != cfg.pipeline:
            cfg = dataclasses.replace(cfg, pipeline=str(scenario))
        scenario = cfg.build_scenario()
    if queries is None:
        frame = warming_frame(scenario, budget=budget,
                              n_requests=requests, n_clients=clients,
                              seed=seed)
    else:
        frame = ColFrame.coerce(queries)
        if budget is not None:
            frame = frame.take(np.arange(min(int(budget), len(frame))))

    t0 = time.perf_counter()
    plan = ExecutionPlan([scenario.pipeline], cache_dir=cache_dir,
                         cache_backend=backend, on_stale=on_stale,
                         cache_budget=cache_budget)
    try:
        stats = plan.warm(frame, batch_size=batch_size,
                          chunk_rows=chunk_rows)
    finally:
        plan.close()
    wall = time.perf_counter() - t0
    return {
        "scenario": scenario.name,
        "cache_dir": cache_dir,
        "backend": backend,
        "queries_warmed": int(len(frame)),
        "cache_hits": int(stats.cache_hits),
        "cache_misses": int(stats.cache_misses),
        "nodes_executed": int(stats.nodes_executed),
        "wall_s": round(wall, 4),
    }
