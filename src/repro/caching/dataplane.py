"""Asynchronous cache data plane: the I/O pool, staging maps and
write-behind stores that take cache round trips off the executor's
critical path.

The paper's premise is that caching must never change *what* a pipeline
computes — only when the bytes move.  Everything here preserves that
contract by construction:

* **I/O pool** — one small, per-process thread pool shared by every
  cache family.  Prefetches and write-behind flushes run here; the
  pool never executes transformer code, so compute stays on the
  executor's own threads and a pool stall can only delay I/O, never
  results.

* **``StagingMap``** — a per-cache overlay where prefetched
  ``get_many`` results land before the owning node consumes them.
  The contract: entries are *only* deposited by prefetch tasks, are
  popped (consumed at most once) by the first ``transform`` /
  ``serve_from_store`` that asks for the key, and anything left over
  is discarded when the run ends.  Because deposits come straight from
  the backend and backend entries are immutable (deterministic
  transformers never rewrite a key with a different value), serving
  from the staging map is observationally identical to reading the
  backend — hit/miss accounting happens at the consuming node, never
  at the pool.

* **``WriteBehindWriter``** — a bounded background writer per cache
  store.  Miss-path puts land in an in-memory pending overlay that
  every read consults, and a pool task drains the overlay to the
  backend in batches; ``flush()`` drains synchronously and is called
  from ``close()``/``drain()``/manifest refresh/store enumeration, so
  every durable observation of the store sees the writes.  A crash
  before flush loses only pending entries — the store itself is never
  half-written (each backend's ``put_many`` is atomic at entry
  granularity) — so recovery is recompute, never corruption.

Compute-once note: within a process the locked recheck consults the
overlay, and *across* processes the families call :meth:`barrier`
before releasing the backend's cross-process lock — the overlay is
invisible to other processes, so the barrier is what keeps the
exactly-once guarantee intact under write-behind.  Bare cache families
still leave write-behind off by default; the plan compiler (whose
executors own the run lifecycle and drain on close) switches it on for
planner-inserted caches.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "io_pool", "prefetch_default", "write_behind_default",
    "StagingMap", "WriteBehindWriter",
]

# -- the shared per-process I/O pool -----------------------------------------

_POOL: Optional[ThreadPoolExecutor] = None
_POOL_PID: Optional[int] = None
_POOL_LOCK = threading.Lock()

#: default I/O pool width; cache round trips are I/O bound (file reads,
#: sqlite calls, zlib — all release the GIL) so a handful of threads
#: covers many concurrent branch prefetches
DEFAULT_IO_THREADS = 4


def io_pool() -> ThreadPoolExecutor:
    """The process-wide cache I/O pool, created lazily and re-created
    after a ``fork`` (a forked child must not share the parent's worker
    threads — they do not survive the fork)."""
    global _POOL, _POOL_PID
    pid = os.getpid()
    if _POOL is None or _POOL_PID != pid:
        with _POOL_LOCK:
            if _POOL is None or _POOL_PID != pid:
                width = int(os.environ.get(
                    "REPRO_IO_THREADS", DEFAULT_IO_THREADS))
                _POOL = ThreadPoolExecutor(
                    max_workers=max(1, width),
                    thread_name_prefix="repro-cache-io")
                _POOL_PID = pid
    return _POOL


def prefetch_default() -> bool:
    """Process-wide prefetch kill switch (``REPRO_PREFETCH=0``)."""
    return os.environ.get("REPRO_PREFETCH", "1") != "0"


def write_behind_default() -> bool:
    """Process-wide write-behind kill switch (``REPRO_WRITE_BEHIND=0``)."""
    return os.environ.get("REPRO_WRITE_BEHIND", "1") != "0"


# -- staging map -------------------------------------------------------------

class StagingMap:
    """Overlay where prefetched backend reads land until consumed.

    Thread-safe; shared by every concurrent batch flowing through one
    cache instance (the streaming executor interleaves batches), which
    is safe precisely because deposits are immutable backend blobs —
    two batches racing on one qid pop the same bytes either would have
    read inline.

    ``pop`` semantics: a consumer takes staged entries out of the map
    (they are owned by exactly one lookup), and ``pop_many`` first
    waits for any in-flight prefetch whose key set intersects the
    request — the consumer would otherwise race past a fetch that is
    about to land and read the backend twice for nothing.
    """

    #: safety valve — beyond this many staged blobs new deposits are
    #: dropped (the consumer falls through to the backend, correctness
    #: unaffected); generous enough that only a runaway prefetcher hits it
    MAX_STAGED = 262_144

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._staged: Dict[bytes, Optional[bytes]] = {}
        #: in-flight prefetch futures and the key set each will deposit
        self._inflight: Dict[Future, frozenset] = {}

    # -- producer side (I/O pool) -------------------------------------------
    def covered(self, keys: Sequence[bytes]) -> List[bytes]:
        """The subset of ``keys`` neither staged nor in flight — what a
        new prefetch should actually fetch (dedup against ourselves)."""
        with self._lock:
            inflight = set()
            for ks in self._inflight.values():
                inflight |= ks
            return [k for k in keys
                    if k not in self._staged and k not in inflight]

    def track(self, fut: Future, keys: Sequence[bytes]) -> None:
        """Register an in-flight fetch; the future must eventually call
        :meth:`deposit` (or fail) for these keys."""
        with self._lock:
            self._inflight[fut] = frozenset(keys)
        fut.add_done_callback(self._untrack)

    def _untrack(self, fut: Future) -> None:
        with self._lock:
            self._inflight.pop(fut, None)

    def deposit(self, pairs: Iterable[Tuple[bytes, Optional[bytes]]]) -> None:
        """Stage fetched blobs.  ``None`` results (backend misses) are
        staged too — they tell the consumer "the backend was asked and
        had nothing", saving the inline re-read on the miss path."""
        with self._lock:
            for k, v in pairs:
                if len(self._staged) >= self.MAX_STAGED:
                    break
                self._staged.setdefault(k, v)

    # -- consumer side (executor threads) -----------------------------------
    def pop_many(self, keys: Sequence[bytes]
                 ) -> Dict[bytes, Optional[bytes]]:
        """Blobs staged for ``keys``, removed from the map.  Waits for
        intersecting in-flight fetches first.  Keys absent from the
        result were never prefetched — read them from the backend."""
        with self._lock:
            waits = [f for f, ks in self._inflight.items()
                     if not ks.isdisjoint(keys)]
        for f in waits:
            try:
                f.result()
            except Exception:       # a failed prefetch is just a non-fetch
                pass
        out: Dict[bytes, Optional[bytes]] = {}
        with self._lock:
            for k in keys:
                if k in self._staged:
                    out[k] = self._staged.pop(k)
        return out

    def discard(self) -> None:
        """Drop everything staged (run teardown — leftovers are entries
        the run prefetched but never consumed)."""
        with self._lock:
            self._staged.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._staged)


# -- write-behind ------------------------------------------------------------

class WriteBehindWriter:
    """Bounded background writer over one backend's ``put_many``.

    Pending entries stay readable through :meth:`overlay_many` until a
    drain has made them durable — the overlay entry is removed only
    *after* ``put_many`` returns, so a read can never observe a window
    where an enqueued entry is neither in the overlay nor on disk.
    """

    #: entries per backend ``put_many`` batch while draining
    DRAIN_BATCH = 1024
    #: pending entries beyond which ``put`` applies backpressure by
    #: draining synchronously on the calling thread
    MAX_PENDING = 8192

    def __init__(self, put_many: Callable[[List[Tuple[bytes, bytes]]], None],
                 *, lock: Optional[Callable[[], object]] = None,
                 max_pending: int = MAX_PENDING) -> None:
        self._put_many = put_many
        #: the backend's re-entrant compute-once lock (a zero-arg
        #: context-manager factory).  Drains take it BEFORE
        #: ``_flush_lock`` — the same order as the miss path (which
        #: holds it when it enqueues and when ``barrier()`` drains) —
        #: so a background drain and a lock-holding barrier can never
        #: deadlock on the pair
        self._backend_lock = lock
        self._max_pending = max_pending
        self._lock = threading.Lock()          # overlay + queue state
        self._flush_lock = threading.Lock()    # serializes drains
        self._overlay: Dict[bytes, bytes] = {}
        self._order: List[bytes] = []
        self._task_live = False
        self._closed = False
        #: test hook — ``REPRO_WRITE_BEHIND_HOLD=1`` disables the
        #: background drain so pending state is deterministic (the
        #: crash-consistency test kills a process in exactly this window)
        self._hold = os.environ.get("REPRO_WRITE_BEHIND_HOLD") == "1"

    # -- producer (miss path, under the compute-once lock) -------------------
    def put(self, items: Sequence[Tuple[bytes, bytes]]) -> None:
        if not items:
            return
        with self._lock:
            if self._closed:
                raise RuntimeError("write-behind writer is closed")
            for k, v in items:
                if k not in self._overlay:
                    self._order.append(k)
                self._overlay[k] = v
            backlog = len(self._order)
        if self._hold:
            return
        if backlog > self._max_pending:
            self.flush()                       # backpressure: drain inline
        else:
            self._schedule()

    def _schedule(self) -> None:
        with self._lock:
            if self._task_live or not self._order:
                return
            self._task_live = True
        io_pool().submit(self._background_drain)

    def _background_drain(self) -> None:
        try:
            self._drain()
        finally:
            with self._lock:
                self._task_live = False
                rearm = bool(self._order) and not self._closed
            if rearm:                          # a put raced the drain
                self._schedule()

    def _drain(self) -> None:
        if self._backend_lock is not None:
            with self._backend_lock():
                self._drain_ordered()
        else:
            self._drain_ordered()

    def _drain_ordered(self) -> None:
        with self._flush_lock:
            while True:
                with self._lock:
                    batch_keys = self._order[:self.DRAIN_BATCH]
                    del self._order[:len(batch_keys)]
                    batch = [(k, self._overlay[k]) for k in batch_keys]
                if not batch:
                    return
                try:
                    self._put_many(batch)
                except Exception:
                    # keep the entries readable (and re-flushable): put
                    # them back at the front and surface on next flush
                    with self._lock:
                        self._order[:0] = batch_keys
                    raise
                with self._lock:
                    for k in batch_keys:
                        self._overlay.pop(k, None)

    # -- consumer (read paths) ----------------------------------------------
    def overlay_many(self, keys: Sequence[bytes]) -> Dict[bytes, bytes]:
        """Pending (not yet durable) entries among ``keys``."""
        with self._lock:
            if not self._overlay:
                return {}
            return {k: self._overlay[k] for k in keys if k in self._overlay}

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._order)

    # -- flush points --------------------------------------------------------
    def barrier(self) -> None:
        """Durability barrier for the compute-once protocol: families
        call this *before releasing the backend's cross-process lock*,
        so a racing process's locked recheck observes every put of this
        miss batch and the exactly-once guarantee survives write-behind
        (the in-memory overlay is invisible across processes).  Honors
        the HOLD test hook — which is exactly a simulated crash inside
        the pre-flush window."""
        if self._hold:
            return
        self._drain()

    def flush(self) -> None:
        """Drain synchronously; on return every accepted put is durable
        (modulo a concurrent ``put`` racing in after the drain)."""
        self._drain()

    def close(self) -> None:
        """Final flush, then reject further puts."""
        with self._lock:
            self._closed = True
        self._drain()
