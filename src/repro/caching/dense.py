"""DenseScorerCache — dense-array scorer cache (paper §4.2 impl. detail).

When a large proportion of a corpus is scored (e.g. exhaustive
cross-encoder studies), SQLite pays high per-row overheads re-storing
document identifiers.  The paper's alternative backend uses HDF5 plus an
``npids`` docno⇄index sidecar.  HDF5 is unavailable offline, so we use a
functionally identical layout:

* ``scores.npy`` — a memory-mapped float32 matrix ``[n_query_rows, n_docs]``
  with NaN = "not cached";
* ``npids.json`` — the docno enumeration (docno → column index);
* ``queries.json`` — query string → row index (grown on demand).

Like every cache family here, the directory is provenance-managed: a
checksummed ``manifest.json`` records the wrapped transformer's
fingerprint (``on_stale`` = ``error``/``recompute``/``readonly``
applies as usual), budgets from ``caching/economics.py`` are enforced
row-granularly by :meth:`DenseScorerCache.evict`, and an
``access.json`` sidecar feeds TTL-then-LRU victim selection.  The
plan compiler does *not* select this family automatically:
``auto_cache`` routes one-to-many retriever nodes — including the
kernel-backed ``ir/dense.py`` ``DenseRetriever`` — to
``RetrieverCache`` (whole rankings, any registry backend) and
pointwise scorers to ``ScorerCache``; ``DenseScorerCache`` is the
hand-placed alternative for exhaustive (query × docno) scoring
studies where per-row backend overheads dominate.

The sidecar JSON files are written with the shared atomic-rename
primitive and row allocation / matrix growth happen under the shared
``FileLock`` (``backends.py``), so concurrent shards/threads *of one
process* never observe a torn sidecar or clobber each other's row
assignments.  Concurrent **writer processes** remain unsupported for
this family specifically: each process holds its own in-memory row
map and memmap handle, which the lock cannot reconcile (readers of a
warm cache are fine).  For a directory shared by concurrent writers
use ``ScorerCache`` with any registry backend that does cross-process
locking (``"dbm"``, ``"sqlite"``, or ``"tiered:<disk>"`` —
``caching/backends.py``, ``caching/tiered.py``).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..core.frame import ColFrame
from ..core.pipeline import add_ranks
from .backends import FileLock, atomic_write_bytes
from .base import CacheTransformer
from .economics import AccessStats, CacheBudget

__all__ = ["DenseScorerCache"]


class DenseScorerCache(CacheTransformer):
    """(query row, docno column) → float32 score, dense storage."""

    GROW = 64  # row-capacity growth quantum

    def __init__(self, path: Optional[str] = None, transformer: Any = None,
                 *, docnos: Optional[Sequence[str]] = None,
                 verify_fraction: float = 0.0,
                 fingerprint: Optional[str] = None,
                 on_stale: str = "error",
                 budget: Any = None):
        super().__init__(path, transformer, verify_fraction=verify_fraction,
                         fingerprint=fingerprint, on_stale=on_stale,
                         budget=budget)
        self._npids_path = os.path.join(self.path, "npids.json")
        # the docno enumeration is the cache's key space, not a cached
        # value: keep it across an on_stale="recompute" wipe so the
        # normal reopen-without-docnos path can still rebuild (pass
        # ``docnos`` explicitly when the corpus itself changed)
        if docnos is None and os.path.exists(self._npids_path):
            try:
                with open(self._npids_path) as f:
                    docnos = json.load(f)
            except (OSError, ValueError):
                pass
        self._open_manifest(backend="dense",
                            key_columns=("query", "docno"),
                            value_columns=("score",))
        self._queries_path = os.path.join(self.path, "queries.json")
        self._scores_path = os.path.join(self.path, "scores.npy")
        self._write_lock = FileLock(os.path.join(self.path, ".lock"))
        if os.path.exists(self._npids_path):
            with open(self._npids_path) as f:
                self.docnos: List[str] = json.load(f)
        else:
            if docnos is None:
                raise ValueError("DenseScorerCache needs `docnos` on first "
                                 "creation (the npids enumeration)")
            self.docnos = [str(d) for d in docnos]
            atomic_write_bytes(self._npids_path,
                               json.dumps(self.docnos).encode())
        self._doc_idx: Dict[str, int] = {d: i for i, d in
                                         enumerate(self.docnos)}
        if os.path.exists(self._queries_path):
            with open(self._queries_path) as f:
                self._query_rows: Dict[str, int] = json.load(f)
        else:
            self._query_rows = {}
        self._mat = self._open_matrix()

    # -- storage --------------------------------------------------------------
    def _open_matrix(self) -> np.memmap:
        n_docs = len(self.docnos)
        if not os.path.exists(self._scores_path):
            cap = max(self.GROW, len(self._query_rows))
            mat = np.lib.format.open_memmap(
                self._scores_path, mode="w+", dtype=np.float32,
                shape=(cap, n_docs))
            mat[:] = np.nan
            mat.flush()
            return mat
        return np.lib.format.open_memmap(self._scores_path, mode="r+")

    def _row_for(self, query: str, create: bool) -> Optional[int]:
        row = self._query_rows.get(query)
        if row is None and create:
            # first *free* row index, not len(): eviction leaves gaps in
            # the occupied-row set, and reusing len() would collide with
            # a still-occupied row
            used = set(self._query_rows.values())
            row = next(i for i in range(len(used) + 1) if i not in used)
            if row >= self._mat.shape[0]:
                self._grow(row + 1)
            self._query_rows[query] = row
            atomic_write_bytes(self._queries_path,
                               json.dumps(self._query_rows).encode())
        return row

    def _grow(self, need: int):
        old = self._mat
        cap = max(need, old.shape[0] * 2, self.GROW)
        tmp = self._scores_path + ".tmp"
        new = np.lib.format.open_memmap(tmp, mode="w+", dtype=np.float32,
                                        shape=(cap, old.shape[1]))
        new[:old.shape[0]] = old[:]
        new[old.shape[0]:] = np.nan
        new.flush()
        del old
        os.replace(tmp, self._scores_path)
        self._mat = np.lib.format.open_memmap(self._scores_path, mode="r+")

    def _close_backend(self):
        try:
            self._mat.flush()
            del self._mat
        except Exception:
            pass

    def __len__(self) -> int:
        if not self._query_rows:
            return 0
        rows = sorted(self._query_rows.values())
        return int(np.sum(~np.isnan(self._mat[rows])))

    # -- transform --------------------------------------------------------------
    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        queries = [str(q) for q in inp["query"].tolist()]
        docnos = [str(d) for d in inp["docno"].tolist()]
        scores = np.full(len(inp), np.nan, dtype=np.float64)
        miss_idx: List[int] = []
        for i, (q, d) in enumerate(zip(queries, docnos)):
            row = self._query_rows.get(q)
            col = self._doc_idx.get(d)
            if col is None:
                raise KeyError(f"docno {d!r} not in npids enumeration")
            if row is not None:
                v = float(self._mat[row, col])
                if not np.isnan(v):
                    scores[i] = v
                    continue
            miss_idx.append(i)
        self.stats.add(hits=len(inp) - len(miss_idx),
                       misses=len(miss_idx))
        self._note_call(len(inp) - len(miss_idx), len(miss_idx))
        self._note_access(sorted({q.encode("utf-8") for q in queries}))

        if miss_idx:
            t = self._require_transformer(len(miss_idx))
            sub = inp.take(np.asarray(miss_idx, dtype=np.int64))
            out = t(sub)
            if len(out) != len(miss_idx):
                raise ValueError("DenseScorerCache requires a pointwise "
                                 "(1:1) scorer")
            fresh = np.asarray(out["score"], dtype=np.float64)
            if self.readonly:            # stale-readonly: never insert
                for j, i in enumerate(miss_idx):
                    scores[i] = fresh[j]
            else:
                with self._write_lock:   # row alloc + growth are exclusive
                    for j, i in enumerate(miss_idx):
                        row = self._row_for(queries[i], create=True)
                        col = self._doc_idx[docnos[i]]
                        self._mat[row, col] = np.float32(fresh[j])
                        scores[i] = fresh[j]
                    self._mat.flush()
                self.stats.add(inserts=len(miss_idx))

        return add_ranks(inp.assign(score=scores))

    # -- cache economics: row-granular eviction ------------------------------
    def evict(self, budget: Any = None, *,
              now: Optional[float] = None) -> Dict[str, Any]:
        """Row-level eviction: the unit of storage is a query row, so
        TTL/LRU victims are whole rows (NaN-ed out and their row index
        returned to the free pool).  ``max_entries``/``max_bytes``
        budget the non-NaN *cells* (matching ``__len__``) at 4 bytes
        per stored score."""
        eff = CacheBudget.coerce(budget)
        if eff.empty():
            eff = self.budget
        if eff.empty():
            return {"skipped": "no budget (none passed, none recorded)"}
        if self.readonly:
            return {"skipped": "readonly cache (stale-readonly policy)"}
        now = time.time() if now is None else float(now)
        self._flush_access()
        access = AccessStats.load(self.path)
        created = self._manifest.created_at \
            if self._manifest is not None else 0.0
        rows = []                        # (last_used, key, query, row, cells)
        for q, r in self._query_rows.items():
            key = q.encode("utf-8")
            cells = int(np.sum(~np.isnan(self._mat[r])))
            rows.append((access.last_used(key, created), key, q, r, cells))
        rows.sort(key=lambda t: (t[0], t[1]))
        n_cells = sum(t[4] for t in rows)

        victims = []
        survivors = rows
        if eff.ttl_seconds is not None:
            cutoff = now - float(eff.ttl_seconds)
            expired = [t for t in rows if t[0] <= cutoff]
            survivors = rows[len(expired):]
            victims.extend(expired)
        n_expired = len(victims)
        left = n_cells - sum(t[4] for t in victims)
        i = 0
        while i < len(survivors) and (
                (eff.max_entries is not None and left > eff.max_entries)
                or (eff.max_bytes is not None and left * 4 > eff.max_bytes)):
            victims.append(survivors[i])
            left -= survivors[i][4]
            i += 1

        evicted_cells = n_cells - left
        if victims:
            with self._write_lock:
                for _, _, q, r, _ in victims:
                    self._mat[r] = np.nan
                    self._query_rows.pop(q, None)
                self._mat.flush()
                atomic_write_bytes(self._queries_path,
                                   json.dumps(self._query_rows).encode())
            access.forget([t[1] for t in victims])
            access.save(self.path)
        # refresh counts immediately (not only on close) so a verify
        # against the still-open cache sees the post-eviction truth
        self._update_manifest()
        return {"examined": len(rows), "expired": n_expired,
                "evicted": len(victims),
                "evicted_bytes": int(evicted_cells * 4),
                "entries_before": int(n_cells),
                "entries_after": int(left),
                "bytes_after": int(left * 4),
                "bytes_approximate": True,
                "unevictable": 0}
