"""Cache economics: budgets, per-entry access stats, LRU/TTL eviction.

PRs 2–5 made caches correct, shareable and self-describing; this module
makes them *bounded*.  Three pieces:

* :class:`CacheBudget` — a per-family resource envelope (entry count,
  store bytes, entry TTL).  Budgets are recorded in the directory's v2
  ``manifest.json`` (``caching/provenance.py``), so every tool that can
  see the directory knows its limits — enforcement does not depend on
  the process that configured the budget still being around.

* :class:`AccessStats` — a per-directory ``access.json`` sidecar
  mapping entry keys to ``[last_used_ts, hit_count]``.  Cache families
  note accesses in memory (``CacheTransformer._note_access``) and merge
  them into the sidecar on close / eviction; the eviction pass ranks
  entries least-recently-used first from it.  The sidecar is advisory:
  entries it does not know about are assumed as old as the directory.

* :func:`evict_entries` / :func:`enforce_dir` — the eviction pass:
  TTL-expired entries go first, then LRU entries until the store is
  within its entry/byte budget, deleted through the backend's
  ``delete_many``.  Crucially the manifest's ``entry_count`` is
  refreshed *immediately* after any destructive operation (not only on
  ``close()``), so ``repro cache verify`` stays truthful against a
  still-open backend — the PR-6 bugfix, regression-tested in
  ``tests/test_economics.py``.

``enforce_dir`` is the offline entry point (`repro cache evict`): it
re-opens the directory's family from its manifest alone (no transformer
needed — eviction never computes) and runs the family's ``evict()``.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .backends import CacheBackend, atomic_write_bytes
from .provenance import CacheManifest, ManifestError

__all__ = ["CacheBudget", "AccessStats", "ACCESS_STATS_NAME",
           "evict_entries", "enforce_dir", "open_family_for_dir"]

ACCESS_STATS_NAME = "access.json"

BudgetLike = Union["CacheBudget", Dict[str, Any], int, None]


# ---------------------------------------------------------------------------
# budgets
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheBudget:
    """A cache family's resource envelope; ``None`` fields are
    unbounded.  An all-``None`` budget is "no budget" (``empty()``)."""

    max_entries: Optional[int] = None
    max_bytes: Optional[int] = None
    ttl_seconds: Optional[float] = None

    def empty(self) -> bool:
        return (self.max_entries is None and self.max_bytes is None
                and self.ttl_seconds is None)

    @classmethod
    def coerce(cls, value: BudgetLike) -> "CacheBudget":
        """Accept a ``CacheBudget``, a ``{"max_entries": ...}`` dict, a
        bare int (entry budget — the common CLI shorthand) or ``None``
        (empty budget)."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, bool):
            raise TypeError(f"cache budget cannot be a bool: {value!r}")
        if isinstance(value, int):
            return cls(max_entries=value)
        if isinstance(value, dict):
            unknown = set(value) - {"max_entries", "max_bytes",
                                    "ttl_seconds"}
            if unknown:
                raise ValueError(
                    f"unknown cache budget field(s) {sorted(unknown)}; "
                    f"valid: max_entries, max_bytes, ttl_seconds")
            return cls(**value)
        raise TypeError(
            f"cache budget must be a CacheBudget, dict, int or None — "
            f"got {type(value).__name__}: {value!r}")

    @classmethod
    def from_manifest(cls, m: Optional[CacheManifest]) -> "CacheBudget":
        if m is None:
            return cls()
        return cls(max_entries=m.max_entries, max_bytes=m.max_bytes,
                   ttl_seconds=m.ttl_seconds)

    def record_in(self, m: CacheManifest) -> bool:
        """Write this budget into a manifest; True when it changed."""
        changed = (m.max_entries, m.max_bytes, m.ttl_seconds) != \
            (self.max_entries, self.max_bytes, self.ttl_seconds)
        m.max_entries = self.max_entries
        m.max_bytes = self.max_bytes
        m.ttl_seconds = self.ttl_seconds
        return changed


# ---------------------------------------------------------------------------
# per-entry access stats (the eviction pass's recency signal)
# ---------------------------------------------------------------------------

class AccessStats:
    """``access.json``: hex-encoded entry key → [last_used_ts, hits].

    Keys are the *backend-level* keys (pickled tuples for KeyValueCache,
    sha256 digests for RetrieverCache, utf-8 query strings for
    DenseScorerCache) so the eviction pass can hand them straight to
    ``delete_many``.  Writes are atomic and merge-on-save, so two
    closers of one directory lose at most recency precision, never the
    file."""

    def __init__(self, data: Optional[Dict[str, List[float]]] = None):
        self._data: Dict[str, List[float]] = dict(data or {})

    # -- io ------------------------------------------------------------------
    @staticmethod
    def path_of(dirpath: str) -> str:
        return os.path.join(dirpath, ACCESS_STATS_NAME)

    @classmethod
    def load(cls, dirpath: str) -> "AccessStats":
        path = cls.path_of(dirpath)
        try:
            with open(path, "r", encoding="utf-8") as f:
                doc = json.load(f)
            if not isinstance(doc, dict):
                raise ValueError("not an object")
            data = {str(k): [float(v[0]), int(v[1])]
                    for k, v in doc.items()}
        except (OSError, ValueError, TypeError, IndexError):
            data = {}
        return cls(data)

    def save(self, dirpath: str) -> None:
        atomic_write_bytes(
            self.path_of(dirpath),
            json.dumps(self._data, sort_keys=True).encode("utf-8"))

    # -- updates -------------------------------------------------------------
    def merge_pending(self, pending: Dict[bytes, List[float]]) -> None:
        """Fold a family's in-memory ``{key: [last_ts, hits]}`` deltas
        in (later timestamps win; hit counts add)."""
        for k, (ts, hits) in pending.items():
            hk = k.hex()
            cur = self._data.get(hk)
            if cur is None:
                self._data[hk] = [float(ts), int(hits)]
            else:
                cur[0] = max(cur[0], float(ts))
                cur[1] += int(hits)

    def forget(self, keys: Sequence[bytes]) -> None:
        for k in keys:
            self._data.pop(k.hex(), None)

    # -- views ---------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._data)

    def keys_bytes(self) -> List[bytes]:
        return [bytes.fromhex(k) for k in self._data]

    def last_used(self, key: bytes, default: float = 0.0) -> float:
        e = self._data.get(key.hex())
        return e[0] if e is not None else default

    def hits(self, key: bytes) -> int:
        e = self._data.get(key.hex())
        return int(e[1]) if e is not None else 0

    def total_hits(self) -> int:
        return int(sum(e[1] for e in self._data.values()))


# ---------------------------------------------------------------------------
# the eviction pass
# ---------------------------------------------------------------------------

def evict_entries(backend: CacheBackend, dirpath: str,
                  budget: CacheBudget, *,
                  access: Optional[AccessStats] = None,
                  created_at: float = 0.0,
                  now: Optional[float] = None) -> Dict[str, Any]:
    """Bring ``backend`` within ``budget``: TTL-expired entries first,
    then least-recently-used until both the entry and byte budgets
    hold.  Returns an accounting report; the caller refreshes the
    manifest (families do this via ``CacheTransformer.evict``).

    Entries the access sidecar has never seen are treated as old as the
    directory (``created_at``), so pre-economics stores evict oldest-
    unknown first rather than surviving TTLs forever.  Backends that
    cannot enumerate entries (``pickle``) fall back to the sidecar's
    key set as the candidate pool — entries written before access
    tracking are then unevictable and reported as such.
    """
    now = time.time() if now is None else float(now)
    access = access if access is not None else AccessStats.load(dirpath)
    approx_bytes = False
    try:
        stats = backend.entry_stats()
        total_bytes = sum(s for _, s in stats)
    except NotImplementedError:
        keys = access.keys_bytes()
        sizes = backend.stat_entries(keys)
        stats = [(k, s) for k, s in zip(keys, sizes) if s is not None]
        total_bytes = sum(s for _, s in stats)
        approx_bytes = True
    n_total = len(backend)

    entries = sorted(
        ((access.last_used(k, created_at), k, s) for k, s in stats),
        key=lambda t: (t[0], t[1]))

    evict: List[Tuple[float, bytes, int]] = []
    survivors = entries
    if budget.ttl_seconds is not None:
        cutoff = now - float(budget.ttl_seconds)
        expired = [e for e in entries if e[0] <= cutoff]
        survivors = entries[len(expired):]
        evict.extend(expired)
    n_expired = len(evict)

    n_left = n_total - len(evict)
    bytes_left = total_bytes - sum(s for _, _, s in evict)
    i = 0
    while i < len(survivors) and (
            (budget.max_entries is not None
             and n_left > budget.max_entries)
            or (budget.max_bytes is not None
                and bytes_left > budget.max_bytes)):
        e = survivors[i]
        evict.append(e)
        n_left -= 1
        bytes_left -= e[2]
        i += 1

    deleted = 0
    if evict:
        victim_keys = [k for _, k, _ in evict]
        deleted = backend.delete_many(victim_keys)
        access.forget(victim_keys)
        access.save(dirpath)

    entries_after = len(backend)
    unevictable = 0
    if budget.max_entries is not None \
            and entries_after > budget.max_entries:
        unevictable = entries_after - budget.max_entries
    return {"examined": len(stats), "expired": n_expired,
            "evicted": deleted,
            "evicted_bytes": int(sum(s for _, _, s in evict)),
            "entries_before": int(n_total),
            "entries_after": int(entries_after),
            "bytes_after": int(bytes_left),
            "bytes_approximate": approx_bytes,
            "unevictable": int(unevictable)}


# ---------------------------------------------------------------------------
# offline enforcement (the `repro cache evict` path)
# ---------------------------------------------------------------------------

def open_family_for_dir(dirpath: str, manifest: CacheManifest):
    """Re-open a cache directory's family from its manifest alone (no
    transformer — eviction never computes).  ``None`` for families that
    do not support budget enforcement (IndexerCache's append-only log)
    or stores with nothing on disk (``memory``)."""
    backend = manifest.backend
    if backend is None or backend == "memory" or backend == "log":
        return None
    family = manifest.family
    common = dict(fingerprint=None, on_stale="error")
    if family in ("KeyValueCache", "ScorerCache"):
        from .kv import KeyValueCache
        return KeyValueCache(
            dirpath, None, key=tuple(manifest.key_columns) or "text",
            value=tuple(manifest.value_columns) or "text",
            backend=backend, **common)
    if family == "RetrieverCache":
        from .retriever import RetrieverCache
        return RetrieverCache(
            dirpath, None,
            key=tuple(manifest.key_columns) or ("qid", "query"),
            backend=backend, **common)
    if family == "DenseScorerCache" or backend == "dense":
        from .dense import DenseScorerCache
        return DenseScorerCache(dirpath, None, **common)
    return None


def enforce_dir(dirpath: str, budget: BudgetLike = None, *,
                now: Optional[float] = None) -> Dict[str, Any]:
    """Enforce a budget on one cache directory, offline.

    ``budget=None`` uses the budget recorded in the directory's
    manifest.  Returns the eviction report, or ``{"skipped": reason}``
    when there is nothing to do / the family cannot be enforced."""
    try:
        manifest = CacheManifest.load(dirpath)
    except ManifestError as e:
        return {"skipped": f"unreadable manifest: {e}"}
    if manifest is None:
        return {"skipped": "no manifest"}
    eff = CacheBudget.coerce(budget)
    if eff.empty():
        eff = CacheBudget.from_manifest(manifest)
    if eff.empty():
        return {"skipped": "no budget (none passed, none recorded)"}
    family = open_family_for_dir(dirpath, manifest)
    if family is None:
        return {"skipped": f"family {manifest.family!r} (backend "
                           f"{manifest.backend!r}) does not support "
                           f"eviction"}
    try:
        return family.evict(eff, now=now)
    finally:
        family.close()
