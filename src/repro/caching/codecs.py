"""Vectorized cache serialization: batch key digests and columnar
value codecs, negotiated per directory through the manifest.

Why a second key scheme exists at all: the original ``_keys_of`` walks
every row through ``zip(*cols)`` + ``pickle.dumps`` — a Python-level
loop that shows up at the top of warm-path profiles once the store
round trip itself is prefetched off the critical path.  The scheme
here builds all keys for a frame with a handful of numpy passes:

* **``fnv128`` keys** — per key column, a four-lane FNV-1a digest
  (the same per-byte fold as the ``cachekey_hash`` kernel and
  ``provenance._host_digest``, widened from two lanes to four so a
  column contributes 128 bits) folded *vectorized across rows*: the
  column is laid out as an ``(N, W)`` byte matrix and the fold runs
  once per byte *position*, masked by per-row lengths — so a row's
  digest depends only on its own bytes, never on what else shares the
  batch.  A key is the concatenation of its columns' 16-byte digests.

* **tagged KV values** — an all-``float`` value tuple packs as a raw
  little-endian float64 vector behind a one-byte tag; anything else
  keeps the pickle representation behind a different tag.  A warm
  batch whose blobs are all packed decodes into value *columns* with
  one ``frombuffer``/``reshape`` instead of N ``pickle.loads``.

* **columnar retriever entries** — a cached result frame is stored as
  named column arrays (raw numeric bytes, length-prefixed UTF-8 for
  strings, pickle only for exotic dtypes), zlib-1 compressed, and
  decodes straight into ``ColFrame`` columns — no per-row dict round
  trip.  Scores keep their stored dtype (float64 end to end), so a
  decoded frame is bit-identical to the frame that was encoded.

Negotiation: the directory's manifest records ``codec`` when a store
is *created*; directories that predate the field (or were written by
older builds) have none and are served with the legacy pickle scheme
forever — an existing warm dir stays warm, byte for byte.

Determinism caveat (documented contract): ``fnv128`` encodes numeric
key columns from their array bytes, so a logical value that arrives as
``int64`` in one frame and as a Python object in another digests
differently — a spurious *miss* (recompute, identical result), never a
false hit.  ``ColFrame`` column construction is deterministic per
source type, so in practice a family sees one layout for its lifetime.
"""
from __future__ import annotations

import pickle
import struct
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .provenance import _FNV_OFFSET, _FNV_PRIME, _LANE2_OFFSET, \
    canonical_bytes

__all__ = [
    "KV_CODEC", "RETRIEVER_CODEC", "KNOWN_CODECS",
    "vector_keys", "scalar_key",
    "encode_kv_value", "decode_kv_value", "decode_kv_batch",
    "encode_columnar_frame", "decode_columnar_frame",
]

#: manifest ``codec`` values understood by this build
KV_CODEC = "kv-fnv128-pack1"
RETRIEVER_CODEC = "ret-fnv128-col1"
KNOWN_CODECS = frozenset({KV_CODEC, RETRIEVER_CODEC})

# four FNV-1a lanes: the provenance/kernel pair plus two more offsets
# (golden-ratio and murmur3 constants) so one column yields 128 bits
_LANES = np.array([_FNV_OFFSET, _LANE2_OFFSET, 0x9E3779B9, 0x85EBCA6B],
                  dtype=np.uint64)
_PRIME = np.uint64(_FNV_PRIME)
_MASK32 = np.uint64(0xFFFFFFFF)

#: per-column byte-matrix width beyond which the vector fold would cost
#: more than it saves — such columns fall back to the scalar fold
_MAX_VECTOR_WIDTH = 4096


# -- the fold ----------------------------------------------------------------

def _fold_const(lanes: np.ndarray, byte: int) -> np.ndarray:
    """Fold one constant byte into every row's lanes."""
    return ((lanes ^ np.uint64(byte)) * _PRIME) & _MASK32


def _fold_matrix(lanes: np.ndarray, mat: np.ndarray,
                 lens: np.ndarray) -> np.ndarray:
    """Fold an ``(N, W)`` byte matrix into ``(N, 4)`` lanes, row ``i``
    consuming only its first ``lens[i]`` bytes — each row's digest
    depends only on its own bytes, so results are independent of batch
    composition."""
    width = mat.shape[1]
    if width == 0:
        return lanes
    if bool((lens == width).all()):
        m64 = mat.astype(np.uint64)
        out = np.array(lanes, dtype=np.uint64)
        for j in range(width):
            # in-place fold: no temporaries on the hot path
            np.bitwise_xor(out, m64[:, j:j + 1], out=out)
            np.multiply(out, _PRIME, out=out)
            np.bitwise_and(out, _MASK32, out=out)
        return out
    # ragged rows: sort by length descending so the rows still active
    # at byte position j are a contiguous prefix — folds run on views,
    # no per-position mask
    order = np.argsort(-lens, kind="stable")
    m64 = mat[order].astype(np.uint64)
    sorted_lens = lens[order]
    # counts[j] = rows with length > j (prefix size at position j)
    counts = len(lens) - np.searchsorted(sorted_lens[::-1],
                                         np.arange(width), side="right")
    out = np.array(lanes[order], dtype=np.uint64)
    for j in range(width):
        k = int(counts[j])
        if k == 0:
            break
        seg = out[:k]
        np.bitwise_xor(seg, m64[:k, j:j + 1], out=seg)
        np.multiply(seg, _PRIME, out=seg)
        np.bitwise_and(seg, _MASK32, out=seg)
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    return out[inv]


def _scalar_fold(lanes: List[int], data: bytes) -> List[int]:
    out = list(lanes)
    for b in data:
        out = [((h ^ b) * _FNV_PRIME) & 0xFFFFFFFF for h in out]
    return out


# -- per-column byte layout ---------------------------------------------------

def _object_payloads(col: Sequence[Any]) -> List[bytes]:
    """Type-marked bytes for each value of an object column: strings
    take the fast UTF-8 path, everything else the canonical encoding."""
    out: List[bytes] = []
    for v in col:
        if isinstance(v, str):
            out.append(b"s" + v.encode("utf-8"))
        else:
            out.append(b"c" + canonical_bytes(v))
    return out


def _string_matrix(col: List[Any]
                   ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Padded payload matrix for an all-``str`` column via numpy's
    fixed-width encode — no per-row Python encode loop.  ``None`` when
    the column is mixed-type or a string contains NUL (fixed-width
    ``S`` storage strips trailing NULs, which would change the digest
    vs :func:`scalar_key` — such columns take the general path)."""
    if not all(type(v) is str for v in col):
        return None
    ucol = np.asarray(col, dtype="U")
    if ucol.size and int(np.char.find(ucol, "\x00").max()) >= 0:
        return None
    enc = np.char.encode(ucol, "utf-8")
    n, width = len(col), enc.dtype.itemsize
    raw = np.frombuffer(enc.tobytes(), dtype=np.uint8).reshape(n, width) \
        if width else np.zeros((n, 0), dtype=np.uint8)
    # payload = b"s" + utf8 bytes: prepend the tag column
    mat = np.empty((n, width + 1), dtype=np.uint8)
    mat[:, 0] = ord("s")
    mat[:, 1:] = raw
    lens = np.char.str_len(enc).astype(np.int64) + 1
    return mat, lens


def _payload_matrix(payloads: List[bytes]
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Pack variable-length payloads into an ``(N, W)`` uint8 matrix
    plus a length vector, via one join + one fancy-index gather."""
    n = len(payloads)
    lens = np.fromiter((len(p) for p in payloads), dtype=np.int64, count=n)
    width = int(lens.max()) if n else 0
    if width == 0:
        return np.zeros((n, 0), dtype=np.uint8), lens
    arr = np.frombuffer(b"".join(payloads), dtype=np.uint8)
    offs = np.zeros(n, dtype=np.int64)
    np.cumsum(lens[:-1], out=offs[1:])
    idx = offs[:, None] + np.arange(width, dtype=np.int64)[None, :]
    np.minimum(idx, max(len(arr) - 1, 0), out=idx)
    return arr[idx], lens


def _column_lanes(col: np.ndarray) -> np.ndarray:
    """``(N, 4)`` uint64 lanes digesting one key column."""
    n = len(col)
    lanes = np.broadcast_to(_LANES, (n, 4)).astype(np.uint64)
    kind = col.dtype.kind
    if kind in "iu":
        mat = np.ascontiguousarray(
            col.astype("<i8")).view(np.uint8).reshape(n, 8)
        lens = np.full(n, 8, dtype=np.int64)
        lanes = _fold_const(lanes, ord("q"))
    elif kind == "f":
        mat = np.ascontiguousarray(
            col.astype("<f8")).view(np.uint8).reshape(n, 8)
        lens = np.full(n, 8, dtype=np.int64)
        lanes = _fold_const(lanes, ord("d"))
    else:
        lanes = _fold_const(lanes, ord("o"))
        col_list = col.tolist()
        packed = _string_matrix(col_list)
        if packed is not None:
            mat, lens = packed
        else:
            payloads = _object_payloads(col_list)
            mat, lens = _payload_matrix(payloads)
            if mat.shape[1] > _MAX_VECTOR_WIDTH:
                return np.array(
                    [_scalar_column_lanes_obj(p) for p in payloads],
                    dtype=np.uint64)
        if mat.shape[1] > _MAX_VECTOR_WIDTH:
            return np.array(
                [_scalar_column_lanes_obj(b"s" + v.encode("utf-8"))
                 for v in col_list], dtype=np.uint64)
    # 4-byte little-endian length prefix, then the payload bytes
    len_bytes = np.ascontiguousarray(
        lens.astype("<u4")).view(np.uint8).reshape(n, 4)
    lanes = _fold_matrix(lanes, len_bytes, np.full(n, 4, dtype=np.int64))
    return _fold_matrix(lanes, mat, lens)


def _scalar_column_lanes_obj(payload: bytes) -> List[int]:
    lanes = _scalar_fold([int(x) for x in _LANES], b"o")
    lanes = _scalar_fold(lanes, struct.pack("<I", len(payload)))
    return _scalar_fold(lanes, payload)


def vector_keys(cols: Sequence[np.ndarray]) -> List[bytes]:
    """One 16·ncols-byte key per row, built with numpy passes over the
    key columns.  Bit-compatible with :func:`scalar_key`."""
    if not cols or len(cols[0]) == 0:
        return []
    n = len(cols[0])
    lanes = np.concatenate([_column_lanes(np.asarray(c)) for c in cols],
                           axis=1)                       # (N, 4·C)
    packed = np.ascontiguousarray(lanes.astype("<u4")) \
        .view(np.uint8).reshape(n, -1)                   # (N, 16·C)
    return [row.tobytes() for row in packed]


def scalar_key(values: Sequence[Any], kinds: Sequence[str]) -> bytes:
    """Single-row reference implementation of :func:`vector_keys` —
    property-tested to match it bit for bit.  ``kinds`` are the key
    columns' dtype kinds (``col.dtype.kind``)."""
    out = bytearray()
    for v, kind in zip(values, kinds):
        if kind in "iu":
            tag, payload = ord("q"), struct.pack("<q", int(v))
        elif kind == "f":
            tag, payload = ord("d"), struct.pack("<d", float(v))
        elif isinstance(v, str):
            tag, payload = ord("o"), b"s" + v.encode("utf-8")
        else:
            tag, payload = ord("o"), b"c" + canonical_bytes(v)
        lanes = _scalar_fold([int(x) for x in _LANES], bytes([tag]))
        lanes = _scalar_fold(lanes, struct.pack("<I", len(payload)))
        lanes = _scalar_fold(lanes, payload)
        out += b"".join(struct.pack("<I", h) for h in lanes)
    return bytes(out)


# -- tagged KV value codec ----------------------------------------------------

_TAG_PICKLE = 0x01
_TAG_F64 = 0x02


def encode_kv_value(vals: Tuple) -> bytes:
    """Pack an all-float value tuple raw; keep pickle for the rest."""
    if vals and all(isinstance(v, (float, np.floating)) for v in vals):
        return bytes([_TAG_F64]) + \
            np.asarray(vals, dtype="<f8").tobytes()
    return bytes([_TAG_PICKLE]) + \
        pickle.dumps(vals, protocol=pickle.HIGHEST_PROTOCOL)


def decode_kv_value(blob: bytes) -> Tuple:
    tag = blob[0]
    if tag == _TAG_F64:
        return tuple(np.frombuffer(blob, dtype="<f8", offset=1).tolist())
    if tag == _TAG_PICKLE:
        return pickle.loads(blob[1:])
    raise ValueError(f"unknown KV value tag {tag:#x}")


def decode_kv_batch(blobs: Sequence[bytes],
                    n_cols: int) -> Optional[np.ndarray]:
    """Vectorized decode of a warm batch: if *every* blob is a packed
    float vector of ``n_cols`` values, return an ``(N, n_cols)``
    float64 array in one pass; otherwise ``None`` (decode row-wise)."""
    want = 1 + 8 * n_cols
    if not blobs or any(
            b is None or b[0] != _TAG_F64 or len(b) != want for b in blobs):
        return None
    joined = b"".join(bytes(memoryview(b)[1:]) for b in blobs)
    return np.frombuffer(joined, dtype="<f8").reshape(len(blobs), n_cols)


# -- columnar retriever entry codec ------------------------------------------

_COL_MAGIC = b"RCOL1"
_KIND_F64 = ord("f")
_KIND_I64 = ord("i")
_KIND_STR = ord("s")
_KIND_PKL = ord("p")


def encode_columnar_frame(cols: Sequence[Tuple[str, np.ndarray]],
                          n_rows: int) -> bytes:
    """Encode named columns as raw arrays (zlib-1 over the whole blob).
    Numeric dtypes keep their width — a float64 score round-trips bit
    identical; strings store a length array plus joined UTF-8."""
    parts: List[bytes] = [
        _COL_MAGIC, struct.pack("<IH", n_rows, len(cols))]
    for name, arr in cols:
        nb = name.encode("utf-8")
        kind = arr.dtype.kind
        if kind == "f":
            tag, payload = _KIND_F64, \
                np.ascontiguousarray(arr.astype("<f8")).tobytes()
        elif kind in "iu":
            tag, payload = _KIND_I64, \
                np.ascontiguousarray(arr.astype("<i8")).tobytes()
        else:
            vals = arr.tolist()
            if all(isinstance(v, str) for v in vals):
                encoded = [v.encode("utf-8") for v in vals]
                lens = np.fromiter((len(e) for e in encoded),
                                   dtype="<u4", count=len(encoded))
                tag, payload = _KIND_STR, lens.tobytes() + b"".join(encoded)
            else:
                tag, payload = _KIND_PKL, pickle.dumps(
                    vals, protocol=pickle.HIGHEST_PROTOCOL)
        parts.append(struct.pack("<HBI", len(nb), tag, len(payload)))
        parts.append(nb)
        parts.append(payload)
    return zlib.compress(b"".join(parts), 1)


def decode_columnar_frame(blob: bytes) -> Dict[str, np.ndarray]:
    """Decode straight to column arrays (strings as object dtype, the
    layout ``ColFrame`` itself uses) — no per-row dict materialization."""
    raw = zlib.decompress(blob)
    if raw[:len(_COL_MAGIC)] != _COL_MAGIC:
        raise ValueError("bad columnar frame magic")
    off = len(_COL_MAGIC)
    n_rows, n_cols = struct.unpack_from("<IH", raw, off)
    off += struct.calcsize("<IH")
    out: Dict[str, np.ndarray] = {}
    for _ in range(n_cols):
        nlen, tag, plen = struct.unpack_from("<HBI", raw, off)
        off += struct.calcsize("<HBI")
        name = raw[off:off + nlen].decode("utf-8")
        off += nlen
        payload = raw[off:off + plen]
        off += plen
        if tag == _KIND_F64:
            out[name] = np.frombuffer(payload, dtype="<f8").astype(np.float64)
        elif tag == _KIND_I64:
            out[name] = np.frombuffer(payload, dtype="<i8").astype(np.int64)
        elif tag == _KIND_STR:
            lens = np.frombuffer(payload, dtype="<u4", count=n_rows)
            col = np.empty(n_rows, dtype=object)
            p = 4 * n_rows
            for i, ln in enumerate(lens.tolist()):
                col[i] = payload[p:p + ln].decode("utf-8")
                p += ln
            out[name] = col
        elif tag == _KIND_PKL:
            col = np.empty(n_rows, dtype=object)
            vals = pickle.loads(payload)
            for i, v in enumerate(vals):
                col[i] = v
            out[name] = col
        else:
            raise ValueError(f"unknown column tag {tag:#x}")
    return out
