"""CompileCache — "precomputation of compilation" (beyond-paper family).

On TPU the first invocation of a pipeline component is dominated not by
model compute but by XLA *compilation* (minutes for large models).  Two
experiment pipelines sharing the same scorer at the same shapes should
pay that cost once — the exact analogue, one level down, of the paper's
prefix precomputation.  ``CompileCache`` memoizes lowered+compiled
executables keyed by (function identity, abstract input signature, mesh
fingerprint).

An optional on-disk layer persists serialized executables across
processes via ``jax.experimental.serialize_executable`` where the
backend supports it (best-effort: deserialization failures fall back to
recompilation — correctness never depends on the disk layer).
"""
from __future__ import annotations

import hashlib
import os
import pickle
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["CompileCache", "signature_of_args"]


def _abstractify(x: Any):
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return ("arr", tuple(x.shape), str(x.dtype))
    return ("lit", repr(x))


def signature_of_args(args, kwargs) -> Tuple:
    leaves, treedef = jax.tree.flatten((args, kwargs))
    return (tuple(_abstractify(l) for l in leaves), str(treedef))


@dataclass
class CompileCacheStats:
    compile_hits: int = 0
    compile_misses: int = 0
    disk_hits: int = 0
    compile_time_s: float = 0.0

    def __str__(self):
        return (f"compiles={self.compile_misses} reuses={self.compile_hits} "
                f"disk_hits={self.disk_hits} "
                f"compile_time={self.compile_time_s:.2f}s")


class CompileCache:
    """Process-wide executable cache with optional disk persistence."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        if path:
            os.makedirs(path, exist_ok=True)
        self._mem: Dict[Tuple, Any] = {}
        self.stats = CompileCacheStats()

    def _mesh_fingerprint(self) -> str:
        # Capture the ambient mesh if any (set via `with mesh:`).
        try:
            from jax.interpreters import pxla
            env = pxla.thread_resources.env
            m = env.physical_mesh
            if m.empty:
                return "nomesh"
            return f"{tuple(m.shape.items())}"
        except Exception:
            return "nomesh"

    def _disk_key(self, key: Tuple) -> str:
        return hashlib.sha256(repr(key).encode()).hexdigest()[:24]

    def get_compiled(self, name: str, fn: Callable, *args,
                     jit_kwargs: Optional[dict] = None, **kwargs):
        """Return a compiled executable for fn at these (abstract) args."""
        jit_kwargs = jit_kwargs or {}
        key = (name, signature_of_args(args, kwargs),
               self._mesh_fingerprint(),
               tuple(sorted((k, repr(v)) for k, v in jit_kwargs.items())))
        hit = self._mem.get(key)
        if hit is not None:
            self.stats.compile_hits += 1
            return hit
        jitted = jax.jit(fn, **jit_kwargs)
        t0 = time.perf_counter()
        compiled = None
        if self.path:
            compiled = self._try_load_disk(key, jitted, args, kwargs)
            if compiled is not None:
                self.stats.disk_hits += 1
        if compiled is None:
            lowered = jitted.lower(*args, **kwargs)
            compiled = lowered.compile()
            self.stats.compile_misses += 1
            if self.path:
                self._try_save_disk(key, compiled)
        self.stats.compile_time_s += time.perf_counter() - t0
        self._mem[key] = compiled
        return compiled

    def call(self, name: str, fn: Callable, *args,
             jit_kwargs: Optional[dict] = None, **kwargs):
        compiled = self.get_compiled(name, fn, *args,
                                     jit_kwargs=jit_kwargs, **kwargs)
        return compiled(*args, **kwargs)

    # -- disk layer (best-effort) ---------------------------------------------
    def _try_save_disk(self, key: Tuple, compiled) -> None:
        try:
            from jax.experimental import serialize_executable as se
            payload = se.serialize(compiled)
            with open(os.path.join(self.path, self._disk_key(key)), "wb") as f:
                pickle.dump(payload, f)
        except Exception:
            pass

    def _try_load_disk(self, key: Tuple, jitted, args, kwargs):
        try:
            from jax.experimental import serialize_executable as se
            p = os.path.join(self.path, self._disk_key(key))
            if not os.path.exists(p):
                return None
            with open(p, "rb") as f:
                payload = pickle.load(f)
            return se.deserialize_and_load(payload[0], payload[1], payload[2]) \
                if isinstance(payload, tuple) and len(payload) == 3 \
                else se.deserialize_and_load(*payload)
        except Exception:
            return None


#: module-level default instance (shared across pipeline stages)
default_compile_cache = CompileCache()
