"""RetrieverCache — one input row → many output rows (paper §4.3).

Caches whole per-query result frames.  Storage is delegated to a
pluggable ``CacheBackend`` (``backends.py``); the default ``"dbm"``
matches the paper: a ``dbm`` database whose keys are SHA256 hashes of
the pickled key tuple and whose values are compressed pickles of the
value frame.  (The paper compresses with LZ4; LZ4 is unavailable
offline so we use zlib level 1 — same interface, same asymptotics;
noted in DESIGN.md.)

Misses are re-checked and computed inside the backend's exclusive lock,
so concurrent shards/processes sharing one cache directory retrieve
each query exactly once.
"""
from __future__ import annotations

import hashlib
import pickle
import time
import zlib
from typing import Any, List, Optional, Tuple

import numpy as np

from ..core.frame import ColFrame
from .backends import CacheBackend, open_backend, resolve_backend_name
from .base import CacheTransformer, n_frame_queries, pickle_key

__all__ = ["RetrieverCache"]


class RetrieverCache(CacheTransformer):
    """Caches the full result frame per input row (keyed ⟨qid,query⟩)."""

    default_backend = "dbm"

    def __init__(self, path: Optional[str] = None, retriever: Any = None,
                 *, key: Any = ("qid", "query"),
                 verify_fraction: float = 0.0,
                 backend: Any = None,
                 fingerprint: Optional[str] = None,
                 on_stale: str = "error",
                 budget: Any = None):
        super().__init__(path, retriever, verify_fraction=verify_fraction,
                         fingerprint=fingerprint, on_stale=on_stale,
                         budget=budget)
        self.key_cols: Tuple[str, ...] = \
            (key,) if isinstance(key, str) else tuple(key)
        self._open_manifest(
            backend=resolve_backend_name(backend, self.default_backend),
            key_columns=self.key_cols)
        self._backend: CacheBackend = open_backend(
            backend, self.path, default=self.default_backend)

    @property
    def backend(self) -> CacheBackend:
        return self._backend

    def _close_backend(self):
        self._backend.close()

    # -- encoding ----------------------------------------------------------
    @staticmethod
    def _hash_key(key_tuple: Tuple) -> bytes:
        return hashlib.sha256(pickle_key(key_tuple)).digest()

    @staticmethod
    def _encode_frame(rows: List[dict]) -> bytes:
        return zlib.compress(
            pickle.dumps(rows, protocol=pickle.HIGHEST_PROTOCOL), 1)

    @staticmethod
    def _decode_frame(blob: bytes) -> List[dict]:
        return pickle.loads(zlib.decompress(blob))

    def __len__(self) -> int:
        return len(self._backend)

    # -- store-only probe (cache-aware pruning, core/rewrite.py) -----------
    def serve_from_store(self, inp: ColFrame) -> Optional[ColFrame]:
        """Serve the full result from cached entries alone, or ``None``
        when any key misses — never computes.

        Sound as a stand-in for ``transform`` on *any* frame carrying
        the same key-column values, because the output is assembled
        purely from stored rows (input columns never leak into it):
        the planner probes with the input of a deferred augment-only
        chain and only executes the chain when this returns ``None``.
        Counts hits only on success (a failed probe is retried by the
        normal miss path, which does its own accounting).
        """
        if len(inp) == 0:
            return inp
        if any(c not in inp for c in self.key_cols):
            return None                  # probe frame lacks key columns
        key_tuples = inp.key_tuples(list(self.key_cols))
        hashes = [self._hash_key(k) for k in key_tuples]
        blobs = self._backend.get_many(hashes)
        if any(b is None for b in blobs):
            return None
        self.stats.add(hits=len(hashes))
        self._note_call(len(hashes), 0)
        self._note_access(hashes)
        all_rows: List[dict] = []
        for b in blobs:
            all_rows.extend(self._decode_frame(b))
        return ColFrame.from_dicts(all_rows)

    # -- transform ----------------------------------------------------------
    def _transform_single(self, hashed: bytes) -> Optional[ColFrame]:
        """Single-key read-through fast path (online serving): one
        ``backend.get`` and one frame decode — no batched lookup lists,
        no per-entry result bookkeeping.  ``None`` on a miss."""
        blob = self._backend.get(hashed)
        if blob is None:
            return None
        self.stats.add(hits=1)
        self._note_call(1, 0)
        self._note_access([hashed])
        return ColFrame.from_dicts(self._decode_frame(blob))

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        key_tuples = inp.key_tuples(list(self.key_cols))
        hashes = [self._hash_key(k) for k in key_tuples]
        if len(inp) == 1:
            hit = self._transform_single(hashes[0])
            if hit is not None:
                return hit
            blobs: List[Optional[bytes]] = [None]   # already probed —
            # the compute-once recheck under the lock re-queries anyway
        else:
            blobs = self._backend.get_many(hashes)
        results: List[Optional[List[dict]]] = \
            [self._decode_frame(b) if b is not None else None for b in blobs]
        miss_idx = [i for i, b in enumerate(blobs) if b is None]

        if miss_idx:
            miss_idx = self._fill_misses(inp, key_tuples, hashes, results,
                                         miss_idx)
        self.stats.add(hits=len(hashes) - len(miss_idx),
                       misses=len(miss_idx))
        self._note_call(len(hashes) - len(miss_idx), len(miss_idx))
        self._note_access(hashes)        # hits + fresh inserts alike

        all_rows: List[dict] = []
        for rows in results:
            all_rows.extend(rows or [])
        return ColFrame.from_dicts(all_rows)

    def _fill_misses(self, inp: ColFrame, key_tuples: List[Tuple],
                     hashes: List[bytes],
                     results: List[Optional[List[dict]]],
                     miss_idx: List[int]) -> List[int]:
        """Compute-once miss handling under the backend lock (see
        ``KeyValueCache._fill_misses``)."""
        with self._backend.lock():
            recheck = self._backend.get_many([hashes[i] for i in miss_idx])
            still = []
            for i, blob in zip(miss_idx, recheck):
                if blob is None:
                    still.append(i)
                else:
                    results[i] = self._decode_frame(blob)
            if not still:
                return []
            t = self._require_transformer(len(still))
            sub = inp.take(np.asarray(still, dtype=np.int64))
            t0 = time.perf_counter()
            out = t(sub)
            self.stats.add(compute_s=time.perf_counter() - t0,
                           compute_queries=n_frame_queries(sub))
            groups = out.group_indices(list(self.key_cols)) if len(out) else {}
            items = []
            for i in still:
                k = key_tuples[i]
                idxs = groups.get(k)
                rows = out.take(idxs).to_dicts() if idxs is not None else []
                items.append((hashes[i], self._encode_frame(rows)))
                results[i] = rows
            if not self.readonly:        # stale-readonly: never insert
                self._backend.put_many(items)
                self.stats.add(inserts=len(still))
            return still
