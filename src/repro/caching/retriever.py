"""RetrieverCache — one input row → many output rows (paper §4.3).

Caches whole per-query result frames.  Implementation matches the
paper: a ``dbm`` database whose keys are SHA256 hashes of the pickled
key tuple and whose values are compressed pickles of the value frame.
(The paper compresses with LZ4; LZ4 is unavailable offline so we use
zlib level 1 — same interface, same asymptotics; noted in DESIGN.md.)
"""
from __future__ import annotations

import dbm
import hashlib
import os
import pickle
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.frame import ColFrame
from .base import CacheMissError, CacheTransformer, pickle_key

__all__ = ["RetrieverCache"]


class RetrieverCache(CacheTransformer):
    """Caches the full result frame per input row (keyed ⟨qid,query⟩)."""

    def __init__(self, path: Optional[str] = None, retriever: Any = None,
                 *, key: Any = ("qid", "query"),
                 verify_fraction: float = 0.0):
        super().__init__(path, retriever, verify_fraction=verify_fraction)
        self.key_cols: Tuple[str, ...] = \
            (key,) if isinstance(key, str) else tuple(key)
        self._db = dbm.open(os.path.join(self.path, "retriever.db"), "c")

    def _close_backend(self):
        try:
            self._db.close()
        except Exception:
            pass

    # -- encoding ----------------------------------------------------------
    @staticmethod
    def _hash_key(key_tuple: Tuple) -> bytes:
        return hashlib.sha256(pickle_key(key_tuple)).digest()

    @staticmethod
    def _encode_frame(rows: List[dict]) -> bytes:
        return zlib.compress(
            pickle.dumps(rows, protocol=pickle.HIGHEST_PROTOCOL), 1)

    @staticmethod
    def _decode_frame(blob: bytes) -> List[dict]:
        return pickle.loads(zlib.decompress(blob))

    def __len__(self) -> int:
        return len(self._db.keys())

    # -- transform ----------------------------------------------------------
    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        key_tuples = inp.key_tuples(list(self.key_cols))
        hashes = [self._hash_key(k) for k in key_tuples]
        results: List[Optional[List[dict]]] = []
        miss_idx: List[int] = []
        for i, h in enumerate(hashes):
            blob = self._db.get(h)
            if blob is None:
                results.append(None)
                miss_idx.append(i)
            else:
                results.append(self._decode_frame(blob))
        self.stats.hits += len(hashes) - len(miss_idx)
        self.stats.misses += len(miss_idx)

        if miss_idx:
            t = self._require_transformer(len(miss_idx))
            sub = inp.take(np.asarray(miss_idx, dtype=np.int64))
            out = t(sub)
            groups = out.group_indices(list(self.key_cols)) if len(out) else {}
            for i in miss_idx:
                k = key_tuples[i]
                idxs = groups.get(k)
                rows = out.take(idxs).to_dicts() if idxs is not None else []
                self._db[hashes[i]] = self._encode_frame(rows)
                results[i] = rows
            self.stats.inserts += len(miss_idx)

        all_rows: List[dict] = []
        for rows in results:
            all_rows.extend(rows or [])
        return ColFrame.from_dicts(all_rows)
