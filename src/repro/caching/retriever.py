"""RetrieverCache — one input row → many output rows (paper §4.3).

Caches whole per-query result frames.  Storage is delegated to a
pluggable ``CacheBackend`` (``backends.py``); the default ``"dbm"``
matches the paper: a ``dbm`` database keyed per query whose values are
compressed encodings of the value frame.  (The paper compresses with
LZ4; LZ4 is unavailable offline so we use zlib level 1 — same
interface, same asymptotics; noted in DESIGN.md.)

Serialization is negotiated per directory through the manifest's
``codec`` field (``caching/codecs.py``): a fresh directory keys
entries with the vectorized four-lane FNV digest and stores result
frames *columnar* (raw score/docno arrays — decode goes straight to
``ColFrame`` columns, no per-row dict round trip), while a directory
that predates the field keeps its original SHA256-of-pickle keys and
pickled row dicts, so existing warm dirs stay warm byte for byte.

Misses are re-checked and computed inside the backend's exclusive lock,
so concurrent shards/processes sharing one cache directory retrieve
each query exactly once.
"""
from __future__ import annotations

import hashlib
import pickle
import time
import zlib
from typing import Any, List, Optional, Tuple

import numpy as np

from ..core.frame import ColFrame
from .backends import CacheBackend, open_backend, resolve_backend_name
from .base import CacheTransformer, n_frame_queries, pickle_key
from .codecs import (RETRIEVER_CODEC, decode_columnar_frame,
                     encode_columnar_frame, vector_keys)

__all__ = ["RetrieverCache"]


class RetrieverCache(CacheTransformer):
    """Caches the full result frame per input row (keyed ⟨qid,query⟩)."""

    default_backend = "dbm"

    def __init__(self, path: Optional[str] = None, retriever: Any = None,
                 *, key: Any = ("qid", "query"),
                 verify_fraction: float = 0.0,
                 backend: Any = None,
                 fingerprint: Optional[str] = None,
                 on_stale: str = "error",
                 budget: Any = None,
                 async_writes: Optional[bool] = None):
        super().__init__(path, retriever, verify_fraction=verify_fraction,
                         fingerprint=fingerprint, on_stale=on_stale,
                         budget=budget, async_writes=async_writes)
        self.key_cols: Tuple[str, ...] = \
            (key,) if isinstance(key, str) else tuple(key)
        self._open_manifest(
            backend=resolve_backend_name(backend, self.default_backend),
            key_columns=self.key_cols, codec=RETRIEVER_CODEC)
        self._backend: CacheBackend = open_backend(
            backend, self.path, default=self.default_backend)
        self._init_dataplane()

    @property
    def backend(self) -> CacheBackend:
        return self._backend

    def _close_backend(self):
        self._backend.close()

    # -- encoding ----------------------------------------------------------
    @staticmethod
    def _hash_key(key_tuple: Tuple) -> bytes:
        return hashlib.sha256(pickle_key(key_tuple)).digest()

    def _keys_of(self, frame: ColFrame) -> List[bytes]:
        """Backend keys for every row — the vectorized digest under the
        modern codec, SHA256-of-pickle for legacy directories."""
        if len(frame) == 0:
            return []
        if self.codec == RETRIEVER_CODEC:
            return vector_keys([frame[c] for c in self.key_cols])
        return [self._hash_key(k)
                for k in frame.key_tuples(list(self.key_cols))]

    def _encode_entry(self, sub: ColFrame) -> bytes:
        if self.codec == RETRIEVER_CODEC:
            return encode_columnar_frame(
                [(c, sub[c]) for c in sub.columns], len(sub))
        return zlib.compress(
            pickle.dumps(sub.to_dicts(), protocol=pickle.HIGHEST_PROTOCOL), 1)

    def _decode_entry(self, blob: bytes) -> ColFrame:
        if self.codec == RETRIEVER_CODEC:
            return ColFrame(_unsafe=decode_columnar_frame(blob))
        return ColFrame.from_dicts(pickle.loads(zlib.decompress(blob)))

    def __len__(self) -> int:
        self._drain_writes()             # enumeration is a flush point
        return len(self._backend)

    # -- prefetch (keys derive from the input frame alone) -------------------
    def prefetch_columns(self) -> Optional[Tuple[str, ...]]:
        return self.key_cols

    def prefetch_keys(self, frame: ColFrame) -> List[bytes]:
        return self._keys_of(frame)

    # -- store-only probe (cache-aware pruning, core/rewrite.py) -----------
    def serve_from_store(self, inp: ColFrame) -> Optional[ColFrame]:
        """Serve the full result from cached entries alone, or ``None``
        when any key misses — never computes.

        Sound as a stand-in for ``transform`` on *any* frame carrying
        the same key-column values, because the output is assembled
        purely from stored rows (input columns never leak into it):
        the planner probes with the input of a deferred augment-only
        chain and only executes the chain when this returns ``None``.
        Counts hits only on success (a failed probe is retried by the
        normal miss path, which does its own accounting).
        """
        if len(inp) == 0:
            return inp
        if any(c not in inp for c in self.key_cols):
            return None                  # probe frame lacks key columns
        hashes = self._keys_of(inp)
        blobs, prefetched = self._lookup_many(hashes)
        if any(b is None for b in blobs):
            return None
        self.stats.add(hits=len(hashes), prefetched=prefetched)
        self._note_call(len(hashes), 0)
        self._note_access(hashes)
        return ColFrame.concat([self._decode_entry(b) for b in blobs])

    # -- transform ----------------------------------------------------------
    def _transform_single(self, hashed: bytes) -> Optional[ColFrame]:
        """Single-key read-through fast path (online serving): one
        lookup and one frame decode — no batched lookup lists, no
        per-entry result bookkeeping.  ``None`` on a miss."""
        blobs, prefetched = self._lookup_many([hashed])
        blob = blobs[0]
        if blob is None:
            return None
        self.stats.add(hits=1, prefetched=prefetched)
        self._note_call(1, 0)
        self._note_access([hashed])
        return self._decode_entry(blob)

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        hashes = self._keys_of(inp)
        if len(inp) == 1:
            hit = self._transform_single(hashes[0])
            if hit is not None:
                return hit
            blobs: List[Optional[bytes]] = [None]   # already probed —
            # the compute-once recheck under the lock re-queries anyway
            prefetched = 0
        else:
            blobs, prefetched = self._lookup_many(hashes)
        results: List[Optional[ColFrame]] = \
            [self._decode_entry(b) if b is not None else None for b in blobs]
        miss_idx = [i for i, b in enumerate(blobs) if b is None]

        if miss_idx:
            miss_idx = self._fill_misses(inp, hashes, results, miss_idx)
        self.stats.add(hits=len(hashes) - len(miss_idx),
                       misses=len(miss_idx), prefetched=prefetched)
        self._note_call(len(hashes) - len(miss_idx), len(miss_idx))
        self._note_access(hashes)        # hits + fresh inserts alike

        return ColFrame.concat([r for r in results if r is not None])

    def _fill_misses(self, inp: ColFrame, hashes: List[bytes],
                     results: List[Optional[ColFrame]],
                     miss_idx: List[int]) -> List[int]:
        """Compute-once miss handling under the backend lock (see
        ``KeyValueCache._fill_misses``)."""
        key_tuples = inp.key_tuples(list(self.key_cols))
        with self._backend.lock():
            recheck = self._recheck_many([hashes[i] for i in miss_idx])
            still = []
            for i, blob in zip(miss_idx, recheck):
                if blob is None:
                    still.append(i)
                else:
                    results[i] = self._decode_entry(blob)
            if not still:
                return []
            t = self._require_transformer(len(still))
            sub = inp.take(np.asarray(still, dtype=np.int64))
            t0 = time.perf_counter()
            out = t(sub)
            self.stats.add(compute_s=time.perf_counter() - t0,
                           compute_queries=n_frame_queries(sub))
            groups = out.group_indices(list(self.key_cols)) if len(out) else {}
            empty = out.take(np.asarray([], dtype=np.int64))
            items = []
            for i in still:
                idxs = groups.get(key_tuples[i])
                entry = out.take(idxs) if idxs is not None else empty
                items.append((hashes[i], self._encode_entry(entry)))
                results[i] = entry
            if not self.readonly:        # stale-readonly: never insert
                # write-behind: an enqueue under the lock (the racing
                # recheck sees the overlay); the barrier makes it
                # durable before the lock releases so other processes'
                # rechecks see it too
                self._store_many(items)
                self.stats.add(inserts=len(still))
            self._write_barrier()
            return still
