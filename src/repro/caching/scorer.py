"""ScorerCache — caching pointwise scorer/reranker results (paper §4.2).

Pointwise scorers assign each document a new score independently (the
probability ranking principle), so ``(query, docno) → score`` caching is
sound.  After merging cached + fresh scores the rank column is
re-assigned.  The key/value columns can be overridden (e.g.
``("qid","docno","query","text")`` to be robust to query/text rewriting,
exactly as §2.1 discusses).

Not applicable to pairwise/listwise scorers (DuoT5) or adaptive
rerankers — their scores depend on the candidate pool; such transformers
carry ``cacheable=False`` and ``auto_cache`` refuses to wrap them.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from ..core.frame import ColFrame
from ..core.pipeline import add_ranks
from .kv import KeyValueCache

__all__ = ["ScorerCache"]


class ScorerCache(KeyValueCache):
    """(query, docno) → score cache with rank re-assignment."""

    def __init__(self, path: Optional[str] = None, transformer: Any = None,
                 *, key: Any = ("query", "docno"), value: Any = ("score",),
                 verify_fraction: float = 0.0, backend: Any = None,
                 fingerprint: Optional[str] = None, on_stale: str = "error",
                 budget: Any = None,
                 async_writes: Optional[bool] = None):
        super().__init__(path, transformer, key=key, value=value,
                         verify_fraction=verify_fraction, backend=backend,
                         fingerprint=fingerprint, on_stale=on_stale,
                         budget=budget, async_writes=async_writes)

    # Doc-keyed: ``docno`` only exists once the upstream retriever has
    # produced its candidates, so the executors prefetch this cache the
    # moment that node completes (overlapping sibling-branch work)
    # rather than at submit time — ``prefetch_columns`` says so by
    # naming columns the source frame does not carry.  The inherited
    # all-float fast path decodes a warm score batch with one
    # ``frombuffer`` (the packed ``kv-fnv128-pack1`` value codec).

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        out = super().transform(inp)
        score = np.asarray(out["score"], dtype=np.float64)
        out = out.assign(score=score)
        return add_ranks(out)
