"""TieredBackend — a memory-LRU front tier over any disk backend.

The cache-economics layer of the paper's precomputation story: disk
stores (`sqlite`/`dbm`/`pickle`, ``backends.py``) make entries durable
and shareable, but every hit still pays a syscall or an SQL round-trip.
``TieredBackend`` composes a bounded in-process
:class:`~repro.caching.backends.MemoryLRUBackend` *in front of* a disk
backend so repeat lookups inside one process are dictionary reads while
the disk tier remains the durable source of truth:

* **write-through puts** — every insert lands in both tiers, so the
  front never holds an entry the disk tier lacks;
* **promote-on-hit** — disk-tier hits are copied into the front, so a
  key's second lookup is served from memory;
* **observational parity** — ``get``/``get_many``/``items()``/
  ``__len__``/``delete_many`` are bit-identical to the bare disk
  backend (property-tested in ``tests/test_tiered.py``, including
  across close/reopen cycles): the front is a pure accelerator, never
  an independent store.

Selected through the normal registry plumbing as ``"tiered"`` (sqlite
disk tier) or ``"tiered:<disk>"``, so ``ExecutionPlan`` /
``PipelineService`` / ``auto_cache`` pick it up via their existing
``cache_backend=``/``backend=`` parameters with no API change.

Scope: the front tier is per-process and is *not* invalidated by other
processes writing the shared disk store.  That is safe for the cache
families' append-only usage (entries are only ever inserted or evicted,
never rewritten with different values — deterministic transformers), and
``lock()``/``delete_many`` go through the disk tier so compute-once and
eviction stay correct across processes; but a foreign process's
evictions are not seen by this process's front until it re-opens.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Iterable, List, Optional, Sequence, Tuple

from .backends import (BACKENDS, CacheBackend, MemoryLRUBackend,
                       split_tiered)

__all__ = ["TieredBackend", "DEFAULT_FRONT_CAPACITY"]

#: default bound of the memory front tier (entries, not bytes)
DEFAULT_FRONT_CAPACITY = 65536


class TieredBackend(CacheBackend):
    """Memory-LRU front over a persistent disk backend (write-through,
    promote-on-hit)."""

    persistent = True
    #: still worth prefetching: the front only absorbs *repeat* reads,
    #: so a run's first pass over a warm store pays the disk tier's
    #: round trip — exactly the read the I/O pool can overlap (and the
    #: promote-on-hit then happens on the pool thread for free)
    prefetchable = True

    def __init__(self, path: Optional[str], *,
                 disk: str = "sqlite",
                 front_capacity: int = DEFAULT_FRONT_CAPACITY):
        if isinstance(disk, CacheBackend):
            self.disk: CacheBackend = disk
        else:
            resolved = split_tiered(f"tiered:{disk}")
            self.disk = BACKENDS[resolved](path)
        # no super().__init__: the disk tier already owns the directory
        # and its FileLock — a second FileLock on the same sidecar file
        # would deadlock the nested lock()->put_many path (flock is
        # per-open-file-description, not re-entrant across fds)
        self.path = self.disk.path
        self.name = f"tiered:{self.disk.name}"
        self.front = MemoryLRUBackend(capacity=front_capacity)
        self._closed = False

    # -- reads (probe front, fall through, promote) -------------------------
    def get(self, key: bytes) -> Optional[bytes]:
        v = self.front.get(key)
        if v is not None:
            return v
        v = self.disk.get(key)
        if v is not None:
            self.front.put(key, v)
        return v

    def get_many(self, keys: Sequence[bytes]) -> List[Optional[bytes]]:
        out = self.front.get_many(keys)
        miss = [i for i, v in enumerate(out) if v is None]
        if not miss:
            return out
        fetched = self.disk.get_many([keys[i] for i in miss])
        promote = []
        for i, v in zip(miss, fetched):
            if v is not None:
                out[i] = v
                promote.append((keys[i], v))
        if promote:
            self.front.put_many(promote)
        return out

    # -- writes (write-through) ---------------------------------------------
    def put_many(self, items: Iterable[Tuple[bytes, bytes]]) -> None:
        items = list(items)
        self.disk.put_many(items)
        self.front.put_many(items)

    def delete_many(self, keys: Sequence[bytes]) -> int:
        self.front.delete_many(keys)
        return self.disk.delete_many(keys)

    # -- parity views: the disk tier is the source of truth -----------------
    def __len__(self) -> int:
        return len(self.disk)

    def items(self) -> List[Tuple[bytes, bytes]]:
        return self.disk.items()

    def entry_stats(self) -> List[Tuple[bytes, int]]:
        return self.disk.entry_stats()

    def stat_entries(self, keys: Sequence[bytes]) -> List[Optional[int]]:
        return self.disk.stat_entries(keys)

    # -- compute-once: delegate the cross-process exclusive section ---------
    @contextmanager
    def lock(self):
        with self.disk.lock():
            yield self

    @classmethod
    def store_exists(cls, path: str) -> bool:   # pragma: no cover - the
        # CLI resolves tiered selectors through backend_store_exists,
        # which dispatches on the *disk* tier's class
        return False

    def close(self) -> None:
        if self._closed:
            return
        self.disk.close()
        self.front.close()
        self._closed = True
