"""IndexerCache — caching an ordered indexing stream (paper §4.4).

Stores an entire sequence of input rows (order matters — e.g. recursive
graph bisection reorderings).  Unlike the other caches it *is* an
indexer: it is placed after the expensive encoder
(``splade >> IndexerCache(path)``) rather than wrapping it.  Iterating
over the cache replays the stream row by row; if a ``docno`` column is
present an npids sidecar provides forward-index lookups.

Storage: one append-only log of zlib-compressed pickled rows + an
offsets array, plus ``npids.json`` for docno → ordinal lookup.
"""
from __future__ import annotations

import json
import os
import pickle
import zlib
from typing import Any, Dict, Iterable, Iterator, List, Optional

import numpy as np

from ..core.frame import ColFrame
from ..core.pipeline import Indexer
from .base import CacheTransformer

__all__ = ["IndexerCache"]


class IndexerCache(CacheTransformer, Indexer):
    """Sequence cache: write once via .index(), replay via iteration."""

    def __init__(self, path: Optional[str] = None, *,
                 fingerprint: Optional[str] = None,
                 on_stale: str = "error"):
        CacheTransformer.__init__(self, path, None, fingerprint=fingerprint,
                                  on_stale=on_stale)
        self._open_manifest(backend="log", key_columns=("docno",))
        self._log_path = os.path.join(self.path, "rows.log")
        self._off_path = os.path.join(self.path, "offsets.npy")
        self._npids_path = os.path.join(self.path, "npids.json")

    # -- writing ---------------------------------------------------------------
    def index(self, corpus_iter: Iterable[dict]) -> "IndexerCache":
        if self.readonly:
            raise RuntimeError(
                f"IndexerCache at {self.path!r} opened read-only "
                f"(stale provenance); refusing to overwrite the stream")
        offsets: List[int] = []
        docnos: List[str] = []
        with open(self._log_path, "wb") as log:
            pos = 0
            for row in corpus_iter:
                if not isinstance(row, dict):
                    row = dict(row)
                blob = zlib.compress(
                    pickle.dumps(row, protocol=pickle.HIGHEST_PROTOCOL), 1)
                log.write(len(blob).to_bytes(8, "little"))
                log.write(blob)
                offsets.append(pos)
                pos += 8 + len(blob)
                if "docno" in row:
                    docnos.append(str(row["docno"]))
                self.stats.add(inserts=1)
        np.save(self._off_path, np.asarray(offsets, dtype=np.int64))
        if docnos:
            with open(self._npids_path, "w") as f:
                json.dump(docnos, f)
        return self

    @property
    def built(self) -> bool:
        return os.path.exists(self._off_path)

    def __len__(self) -> int:
        if not self.built:
            return 0
        return int(np.load(self._off_path).shape[0])

    # -- replay ------------------------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        if not self.built:
            return
        with open(self._log_path, "rb") as log:
            while True:
                head = log.read(8)
                if len(head) < 8:
                    return
                n = int.from_bytes(head, "little")
                yield pickle.loads(zlib.decompress(log.read(n)))

    def get_corpus_iter(self) -> Iterator[dict]:
        return iter(self)

    # -- forward-index lookups (docno → row) --------------------------------------
    def _docno_ordinals(self) -> Dict[str, int]:
        if not os.path.exists(self._npids_path):
            raise KeyError("IndexerCache has no docno column — forward "
                           "index unavailable")
        with open(self._npids_path) as f:
            return {d: i for i, d in enumerate(json.load(f))}

    def get(self, docno: str) -> dict:
        ords = self._docno_ordinals()
        i = ords[str(docno)]
        offsets = np.load(self._off_path)
        with open(self._log_path, "rb") as log:
            log.seek(int(offsets[i]))
            n = int.from_bytes(log.read(8), "little")
            row = pickle.loads(zlib.decompress(log.read(n)))
            self.stats.add(hits=1)
            return row

    # -- as a transformer: forward-index text lookup (D-side join) ----------------
    def transform(self, inp: ColFrame) -> ColFrame:
        rows = [self.get(d) for d in inp["docno"].tolist()]
        out = inp
        if rows:
            extra_cols = set().union(*[set(r) for r in rows]) - {"docno"}
            for c in sorted(extra_cols):
                col = np.empty(len(inp), dtype=object)
                col[:] = [r.get(c) for r in rows]
                out = out.assign(**{c: col})
        return out

    def signature(self):
        return ("IndexerCache", os.path.abspath(self.path), len(self))
