"""Pluggable storage backends for the explicit cache families (§4).

Before this module each cache family rolled its own persistence —
``KeyValueCache`` embedded SQLite, ``RetrieverCache`` embedded ``dbm``,
``DenseScorerCache`` hand-managed memmaps.  All of them reduce to the
same contract: an (optionally persistent) ``bytes → bytes`` map with
batched lookup/insert.  ``CacheBackend`` names that contract once and
the families select an implementation via a ``backend=`` parameter
(also plumbed through ``auto_cache`` and the execution planner).

Implementations:

* ``"memory"`` — a bounded in-process LRU (no persistence; ideal for
  planner-inserted memos inside a single run);
* ``"pickle"`` — one file per entry under the cache directory, written
  with atomic renames (content-addressed like a git object store);
* ``"dbm"``    — a single ``dbm`` database, every open/read/write under
  an inter-process file lock (gdbm handles cannot be shared);
* ``"sqlite"`` — the paper's §4.1 choice, kept as the
  ``KeyValueCache`` default.

Concurrency contract (the executor in ``core/plan.py`` relies on it):

* every method is safe to call from multiple threads of one process;
* on-disk backends are safe against concurrent *processes* sharing one
  cache directory: writes happen under an ``fcntl`` file lock and/or an
  atomic ``os.replace``, so readers never observe torn entries;
* ``lock()`` exposes the same exclusive lock to callers, letting the
  cache families implement *compute-once* misses: take the lock,
  re-check, compute only what is still absent, insert, release.  Two
  shards (or two CI jobs) racing on the same key therefore compute it
  exactly once — the stress tests in ``tests/test_backends.py`` assert
  this for every backend.  The exactly-once guarantee deliberately
  serializes *miss computation* across workers sharing one store; pure
  hits stay concurrent (lock-free pickle reads, shared-flock dbm
  reads, WAL sqlite reads).
"""
from __future__ import annotations

import hashlib
import os
import sqlite3
import tempfile
import threading
from collections import OrderedDict
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type, Union

try:                                     # POSIX; on other platforms the
    import fcntl                         # thread lock still serializes
except ImportError:                      # pragma: no cover - linux CI
    fcntl = None

__all__ = ["CacheBackend", "MemoryLRUBackend", "PickleDirBackend",
           "DbmBackend", "SQLiteBackend", "FileLock", "atomic_write_bytes",
           "open_backend", "resolve_backend_name", "select_backend",
           "BACKENDS", "split_tiered", "split_mmap", "split_combinator",
           "registered_selectors", "storage_identity",
           "backend_store_exists", "measure_round_trip"]


# ---------------------------------------------------------------------------
# shared primitives
# ---------------------------------------------------------------------------

def atomic_write_bytes(path: str, data: bytes) -> None:
    """Write ``data`` to ``path`` via a same-directory temp file and an
    atomic ``os.replace`` — concurrent readers see the old blob or the
    new blob, never a torn one."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(prefix=".tmp-", dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class FileLock:
    """Re-entrant exclusive lock spanning threads *and* processes.

    A ``threading.RLock`` serializes threads of this process; an
    ``fcntl.flock`` on a sidecar file serializes against other
    processes.  Usable as a context manager.
    """

    def __init__(self, path: str):
        self.path = path
        self._tlock = threading.RLock()
        self._depth = 0
        self._fd: Optional[int] = None
        self._owner: Optional[int] = None

    def held(self) -> bool:
        """True when the *calling thread* holds this lock (lets read
        paths inside a compute-once critical section skip re-locking)."""
        return self._owner == threading.get_ident()

    def acquire(self) -> None:
        self._tlock.acquire()
        try:
            if self._depth == 0 and fcntl is not None:
                fd = os.open(self.path, os.O_RDWR | os.O_CREAT, 0o644)
                try:
                    fcntl.flock(fd, fcntl.LOCK_EX)
                except BaseException:
                    os.close(fd)
                    raise
                self._fd = fd
            self._depth += 1
            self._owner = threading.get_ident()
        except BaseException:
            # roll back the thread lock so a failed acquire (unwritable
            # lock file, interrupt) surfaces instead of deadlocking
            # every other thread touching this cache
            self._tlock.release()
            raise

    def release(self) -> None:
        try:
            if self._depth == 1:
                self._owner = None
                if self._fd is not None:
                    fcntl.flock(self._fd, fcntl.LOCK_UN)
                    os.close(self._fd)
                    self._fd = None
        finally:
            self._depth -= 1
            self._tlock.release()

    def __enter__(self) -> "FileLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


@contextmanager
def _shared_flock(path: str):
    """A short-lived *shared* flock for read paths: concurrent readers
    proceed together, while a writer holding the exclusive ``FileLock``
    on the same file excludes them."""
    if fcntl is None:                    # pragma: no cover - linux CI
        yield
        return
    fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_SH)
        yield
    finally:
        try:
            fcntl.flock(fd, fcntl.LOCK_UN)
        finally:
            os.close(fd)


def _store_file(path: str, preferred: str, legacy: str) -> str:
    """Resolve a backend's store file, honouring directories written by
    the pre-backend cache families (kv.sqlite3 / retriever.db) so warm
    caches stay warm across the refactor."""
    new = os.path.join(path, preferred)
    old = os.path.join(path, legacy)
    if not os.path.exists(new) and _legacy_store_exists(old):
        return old
    return new


def _legacy_store_exists(base: str) -> bool:
    # dbm flavours append suffixes (gdbm: none; ndbm: .db; dumb: .dat)
    if os.path.exists(base):
        return True
    return any(os.path.exists(base + suf) for suf in (".db", ".dat", ".dir"))


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------

class CacheBackend:
    """``bytes → bytes`` store with batched access and an exclusive lock.

    Subclasses implement ``get_many`` / ``put_many`` / ``__len__`` /
    ``_close``; everything else is shared.  ``close()`` is idempotent.
    """

    #: registry name, set on concrete classes
    name: str = ""
    #: whether entries survive the process (drives test parametrization)
    persistent: bool = True
    #: whether ``items()``/``entry_stats()`` can enumerate the store
    #: (``mmap:<disk>`` snapshots require it; pickle stores hashed keys
    #: only and opts out)
    enumerable: bool = True
    #: whether moving this backend's reads onto the I/O pool can pay
    #: (see ``caching/dataplane.py``): disk stores say yes, while a
    #: memory-speed read path (the in-process LRU, the mmap snapshot
    #: tier) opts out — staging a dict lookup only adds bookkeeping
    prefetchable: bool = True

    def __init__(self, path: Optional[str]):
        self.path = path
        self._closed = False
        if path is not None:
            os.makedirs(path, exist_ok=True)
            self._lock = FileLock(os.path.join(path, ".lock"))
        else:
            self._lock = threading.RLock()   # memory backend: threads only

    # -- required ----------------------------------------------------------
    def get_many(self, keys: Sequence[bytes]) -> List[Optional[bytes]]:
        raise NotImplementedError

    def put_many(self, items: Iterable[Tuple[bytes, bytes]]) -> None:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def items(self) -> List[Tuple[bytes, bytes]]:
        """All ``(key, value)`` entries (drives ``repro cache export``).

        Optional: backends that cannot recover keys from their store
        raise ``NotImplementedError`` and are exported as raw files.
        """
        raise NotImplementedError(
            f"{type(self).__name__} cannot enumerate entries")

    def delete_many(self, keys: Sequence[bytes]) -> int:
        """Remove entries (eviction / budget enforcement); returns the
        number actually deleted.  Absent keys are ignored."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support entry deletion")

    def entry_stats(self) -> List[Tuple[bytes, int]]:
        """``(key, value_size_bytes)`` for every entry — the eviction
        pass ranks these by recency.  Backends that cannot enumerate
        keys raise ``NotImplementedError`` (same contract as
        ``items()``); the default derives sizes from ``items()``."""
        return [(k, len(v)) for k, v in self.items()]

    def stat_entries(self, keys: Sequence[bytes]
                     ) -> List[Optional[int]]:
        """Value sizes for the given keys (``None`` = absent).  Works on
        every backend — including ones whose stores cannot enumerate —
        at the cost of reading the values."""
        return [len(v) if v is not None else None
                for v in self.get_many(keys)]

    @classmethod
    def store_exists(cls, path: str) -> bool:
        """Whether ``path`` already holds this backend's store files —
        answered *without* opening (and thereby creating) a store, for
        offline inspection (``repro cache verify`` / ``export``)."""
        return False

    def _close(self) -> None:
        pass

    # -- shared ------------------------------------------------------------
    def get(self, key: bytes) -> Optional[bytes]:
        """Single-key lookup.  The default delegates to ``get_many``;
        backends override it with a leaner path (one SELECT, one file
        read) — the read-through fast path the serving layer leans on
        for per-request lookups."""
        return self.get_many([key])[0]

    def put(self, key: bytes, value: bytes) -> None:
        self.put_many([(key, value)])

    @contextmanager
    def lock(self):
        """Exclusive section across threads and (for disk backends)
        processes — the compute-once critical section."""
        with self._lock:
            yield self

    def close(self) -> None:
        if self._closed:
            return
        self._close()
        self._closed = True


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------

class MemoryLRUBackend(CacheBackend):
    """Bounded in-process LRU; ``path`` is ignored (no persistence)."""

    name = "memory"
    persistent = False
    prefetchable = False                 # reads are already a dict lookup

    def __init__(self, path: Optional[str] = None, *,
                 capacity: int = 1_000_000):
        super().__init__(None)
        self.capacity = int(capacity)
        self._data: "OrderedDict[bytes, bytes]" = OrderedDict()

    def get_many(self, keys: Sequence[bytes]) -> List[Optional[bytes]]:
        with self._lock:
            out: List[Optional[bytes]] = []
            for k in keys:
                v = self._data.get(k)
                if v is not None:
                    self._data.move_to_end(k)
                out.append(v)
            return out

    def get(self, key: bytes) -> Optional[bytes]:
        with self._lock:
            v = self._data.get(key)
            if v is not None:
                self._data.move_to_end(key)
            return v

    def put_many(self, items: Iterable[Tuple[bytes, bytes]]) -> None:
        with self._lock:
            for k, v in items:
                self._data[k] = v
                self._data.move_to_end(k)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def items(self) -> List[Tuple[bytes, bytes]]:
        with self._lock:
            return list(self._data.items())

    def delete_many(self, keys: Sequence[bytes]) -> int:
        with self._lock:
            return sum(self._data.pop(k, None) is not None for k in keys)


class PickleDirBackend(CacheBackend):
    """One file per entry, named by the SHA-256 of the key, written with
    atomic renames.  Lock-free reads; concurrent writers of the same key
    are idempotent (deterministic transformers ⇒ identical blobs), so a
    lost race costs a rewrite, never a torn entry."""

    name = "pickle"
    enumerable = False

    def __init__(self, path: str):
        if path is None:
            raise ValueError("PickleDirBackend requires a directory")
        super().__init__(path)
        self._objdir = os.path.join(path, "objects")
        os.makedirs(self._objdir, exist_ok=True)

    def _file_of(self, key: bytes) -> str:
        h = hashlib.sha256(key).hexdigest()
        return os.path.join(self._objdir, h[:2], h[2:] + ".bin")

    def get(self, key: bytes) -> Optional[bytes]:
        try:
            with open(self._file_of(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def get_many(self, keys: Sequence[bytes]) -> List[Optional[bytes]]:
        out: List[Optional[bytes]] = []
        for k in keys:
            try:
                with open(self._file_of(k), "rb") as f:
                    out.append(f.read())
            except FileNotFoundError:
                out.append(None)
        return out

    def put_many(self, items: Iterable[Tuple[bytes, bytes]]) -> None:
        for k, v in items:
            fp = self._file_of(k)
            os.makedirs(os.path.dirname(fp), exist_ok=True)
            atomic_write_bytes(fp, v)

    def __len__(self) -> int:
        n = 0
        for _, _, files in os.walk(self._objdir):
            n += sum(f.endswith(".bin") for f in files)
        return n

    def items(self) -> List[Tuple[bytes, bytes]]:
        # entry files are named by the *hash* of the key; the key itself
        # is unrecoverable, so this store exports as raw files instead
        raise NotImplementedError(
            "PickleDirBackend stores hashed keys only; export the cache "
            "directory as raw files")

    def delete_many(self, keys: Sequence[bytes]) -> int:
        n = 0
        for k in keys:
            try:
                os.unlink(self._file_of(k))
                n += 1
            except FileNotFoundError:
                pass
        return n

    def stat_entries(self, keys: Sequence[bytes]) -> List[Optional[int]]:
        out: List[Optional[int]] = []
        for k in keys:
            try:
                out.append(os.path.getsize(self._file_of(k)))
            except OSError:
                out.append(None)
        return out

    @classmethod
    def store_exists(cls, path: str) -> bool:
        return os.path.isdir(os.path.join(path, "objects"))


class DbmBackend(CacheBackend):
    """A single ``dbm`` database (the paper's §4.3 retriever store).

    gdbm handles are single-writer and do not observe other writers, so
    the database is opened per operation: writes under the exclusive
    inter-process file lock, reads under a *shared* flock (concurrent
    readers proceed together; a writer excludes them) — so concurrent
    shards, threads and CI jobs sharing one cache directory never
    corrupt the store, and pure cache hits do not serialize.
    """

    name = "dbm"

    def __init__(self, path: str):
        if path is None:
            raise ValueError("DbmBackend requires a directory")
        super().__init__(path)
        self._file = _store_file(path, "cache.dbm", "retriever.db")
        import dbm
        self._dbm = dbm
        with self._lock:                     # create eagerly for readers
            db = dbm.open(self._file, "c")
            db.close()

    @contextmanager
    def _read_locked(self):
        # inside our own exclusive section (compute-once recheck), a
        # shared flock on the same file would deadlock — skip it
        if self._lock.held():
            yield
        else:
            with _shared_flock(self._lock.path):
                yield

    def get_many(self, keys: Sequence[bytes]) -> List[Optional[bytes]]:
        with self._read_locked():
            db = self._dbm.open(self._file, "r")
            try:
                return [db[k] if k in db else None for k in keys]
            finally:
                db.close()

    def put_many(self, items: Iterable[Tuple[bytes, bytes]]) -> None:
        with self._lock:
            db = self._dbm.open(self._file, "c")
            try:
                for k, v in items:
                    db[k] = v
            finally:
                db.close()

    def __len__(self) -> int:
        with self._read_locked():
            db = self._dbm.open(self._file, "r")
            try:
                return len(db)
            finally:
                db.close()

    def items(self) -> List[Tuple[bytes, bytes]]:
        with self._read_locked():
            db = self._dbm.open(self._file, "r")
            try:
                return [(bytes(k), bytes(db[k])) for k in db.keys()]
            finally:
                db.close()

    def delete_many(self, keys: Sequence[bytes]) -> int:
        n = 0
        with self._lock:
            db = self._dbm.open(self._file, "w")
            try:
                for k in keys:
                    if k in db:
                        del db[k]
                        n += 1
            finally:
                db.close()
        return n

    @classmethod
    def store_exists(cls, path: str) -> bool:
        return _legacy_store_exists(os.path.join(path, "cache.dbm")) or \
            _legacy_store_exists(os.path.join(path, "retriever.db"))


_SQLITE_SCHEMA = """
CREATE TABLE IF NOT EXISTS kv (
  key   BLOB PRIMARY KEY,
  value BLOB NOT NULL
) WITHOUT ROWID;
"""


class SQLiteBackend(CacheBackend):
    """SQLite store (the paper's §4.1 KeyValueCache implementation).

    One connection shared across threads (``check_same_thread=False``)
    behind an in-process lock; SQLite's WAL journal already lets
    concurrent *processes* read alongside a writer, so reads and writes
    deliberately avoid the inter-process ``FileLock`` — it is reserved
    for ``lock()`` (the compute-once critical section).
    """

    name = "sqlite"

    def __init__(self, path: str):
        if path is None:
            raise ValueError("SQLiteBackend requires a directory")
        super().__init__(path)
        self._conn_lock = threading.Lock()
        self._db = sqlite3.connect(
            _store_file(path, "cache.sqlite3", "kv.sqlite3"),
            check_same_thread=False)
        self._db.executescript(_SQLITE_SCHEMA)
        # bulk lookups are much faster with a page cache
        self._db.execute("PRAGMA cache_size = -65536")
        self._db.execute("PRAGMA journal_mode = WAL")
        self._db.execute("PRAGMA synchronous = NORMAL")

    def get_many(self, keys: Sequence[bytes]) -> List[Optional[bytes]]:
        out: List[Optional[bytes]] = [None] * len(keys)
        CHUNK = 900                          # sqlite var limit is 999
        # a key may occur several times in one lookup batch (e.g. a
        # micro-batch coalescing concurrent requests for the same hot
        # query) — every occurrence must resolve, not just the last
        pos: Dict[bytes, List[int]] = {}
        for i, k in enumerate(keys):
            pos.setdefault(k, []).append(i)
        uniq = list(pos)
        with self._conn_lock:
            for lo in range(0, len(uniq), CHUNK):
                chunk = uniq[lo:lo + CHUNK]
                q = ("SELECT key, value FROM kv WHERE key IN (%s)"
                     % ",".join("?" * len(chunk)))
                for k, v in self._db.execute(q, chunk):
                    blob = bytes(v)
                    for i in pos[bytes(k)]:
                        out[i] = blob
        return out

    def get(self, key: bytes) -> Optional[bytes]:
        with self._conn_lock:
            row = self._db.execute(
                "SELECT value FROM kv WHERE key = ?", (key,)).fetchone()
        return bytes(row[0]) if row is not None else None

    def put_many(self, items: Iterable[Tuple[bytes, bytes]]) -> None:
        with self._conn_lock:
            with self._db:
                self._db.executemany(
                    "INSERT OR REPLACE INTO kv (key, value) VALUES (?, ?)",
                    items)

    def __len__(self) -> int:
        with self._conn_lock:
            (n,) = self._db.execute("SELECT COUNT(*) FROM kv").fetchone()
        return int(n)

    def items(self) -> List[Tuple[bytes, bytes]]:
        with self._conn_lock:
            return [(bytes(k), bytes(v)) for k, v in
                    self._db.execute("SELECT key, value FROM kv")]

    def delete_many(self, keys: Sequence[bytes]) -> int:
        CHUNK = 900
        n = 0
        with self._conn_lock:
            with self._db:
                for lo in range(0, len(keys), CHUNK):
                    chunk = list(keys[lo:lo + CHUNK])
                    cur = self._db.execute(
                        "DELETE FROM kv WHERE key IN (%s)"
                        % ",".join("?" * len(chunk)), chunk)
                    n += cur.rowcount
        return n

    def entry_stats(self) -> List[Tuple[bytes, int]]:
        with self._conn_lock:
            return [(bytes(k), int(n)) for k, n in self._db.execute(
                "SELECT key, length(value) FROM kv")]

    @classmethod
    def store_exists(cls, path: str) -> bool:
        return os.path.exists(os.path.join(path, "cache.sqlite3")) or \
            os.path.exists(os.path.join(path, "kv.sqlite3"))

    def _close(self) -> None:
        try:
            self._db.close()
        except Exception:
            pass


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

BACKENDS: Dict[str, Type[CacheBackend]] = {
    "memory": MemoryLRUBackend,
    "pickle": PickleDirBackend,
    "dbm": DbmBackend,
    "sqlite": SQLiteBackend,
}

#: default disk tier of the bare ``"tiered"`` / ``"mmap"`` selectors
TIERED_DEFAULT_DISK = "sqlite"

#: combinator selectors: accelerator tiers composed *over* a persistent
#: registry backend (``"<combinator>[:<disk>]"``).  ``requires_enumerable``
#: marks combinators that must enumerate the disk store (the mmap tier
#: packs a snapshot of every entry, so it cannot sit over ``pickle``).
_COMBINATORS: Dict[str, Dict[str, bool]] = {
    "tiered": {"requires_enumerable": False},
    "mmap": {"requires_enumerable": True},
}


def _combinator_disks(combinator: str) -> List[str]:
    """Registry disk names a combinator may compose over."""
    req = _COMBINATORS[combinator]["requires_enumerable"]
    return sorted(n for n, cls in BACKENDS.items()
                  if cls.persistent and (cls.enumerable or not req))


def _split_combinator_as(combinator: str, name: str) -> Optional[str]:
    """The validated disk-tier name of a ``"<combinator>[:<disk>]"``
    selector; ``None`` when ``name`` is not that combinator at all."""
    if not isinstance(name, str) or \
            not (name == combinator or name.startswith(combinator + ":")):
        return None
    disk = name.partition(":")[2] or TIERED_DEFAULT_DISK
    if disk not in _combinator_disks(combinator):
        known = ", ".join(f"'{combinator}:{n}'"
                          for n in _combinator_disks(combinator))
        extra = (" that can enumerate its entries"
                 if _COMBINATORS[combinator]["requires_enumerable"] else "")
        raise ValueError(
            f"unknown {combinator} cache selector {name!r}; the disk tier "
            f"must be a persistent registry backend{extra} — valid "
            f"selectors are {known} (bare '{combinator}' means "
            f"'{combinator}:{TIERED_DEFAULT_DISK}')")
    return disk


def split_tiered(name: str) -> Optional[str]:
    """The disk-tier registry name of a ``"tiered"`` /
    ``"tiered:<disk>"`` selector, validated; ``None`` when ``name`` is
    not a tiered selector at all.  Raises ``ValueError`` for a tiered
    selector over an unknown or non-persistent disk tier."""
    return _split_combinator_as("tiered", name)


def split_mmap(name: str) -> Optional[str]:
    """The disk-tier registry name of an ``"mmap"`` / ``"mmap:<disk>"``
    selector, validated; ``None`` when ``name`` is not an mmap selector.
    Raises ``ValueError`` over a disk tier that is unknown,
    non-persistent, or cannot enumerate its entries (``pickle``)."""
    return _split_combinator_as("mmap", name)


def split_combinator(name: str) -> Optional[Tuple[str, str]]:
    """``(combinator, disk)`` for a combinator selector, validated;
    ``None`` for plain registry names (and non-strings)."""
    for combinator in _COMBINATORS:
        disk = _split_combinator_as(combinator, name)
        if disk is not None:
            return combinator, disk
    return None


def registered_selectors() -> List[str]:
    """Every valid ``backend=`` selector string: the registry names
    plus each combinator over each admissible disk tier.  This is the
    list unknown-selector errors print and the CLI help references."""
    out = sorted(BACKENDS)
    for combinator in sorted(_COMBINATORS):
        out.extend(f"{combinator}:{n}" for n in _combinator_disks(combinator))
    return out


def storage_identity(name) -> Optional[str]:
    """The disk store a selector ultimately persists into — combinator
    prefixes stripped (``"tiered:sqlite"`` / ``"mmap:sqlite"`` →
    ``"sqlite"``).  Combinators are pure accelerators over the same
    store files, so two selectors with equal storage identity can open
    the same warm cache directory interchangeably (this is what the
    manifest staleness check compares).  Unknown/invalid selectors pass
    through unchanged — the caller's name validation reports them."""
    if not isinstance(name, str):
        return name
    try:
        combo = split_combinator(name)
    except ValueError:
        return name
    return combo[1] if combo is not None else name


def resolve_backend_name(spec: Union[str, CacheBackend, None],
                         default: str = "sqlite") -> str:
    """The registry name a ``backend=`` selector resolves to, validated
    *without* opening a store (so callers can check manifests first).

    Besides the registry names, the combinator selectors compose an
    accelerator tier over a named disk backend — ``"tiered[:<disk>]"``
    (:class:`~repro.caching.tiered.TieredBackend`, a memory-LRU front)
    and ``"mmap[:<disk>]"``
    (:class:`~repro.caching.mmap_tier.MmapTier`, a packed read-only
    snapshot shared across processes) — and normalize to the explicit
    ``"<combinator>:<disk>"`` form (what manifests record).

    Raises ``TypeError`` for selectors that are neither a name, an
    instance nor ``None``, and ``ValueError`` (listing every registered
    selector) for unknown names.
    """
    if isinstance(spec, CacheBackend):
        return spec.name or type(spec).__name__
    if spec is None:
        spec = default
    if not isinstance(spec, str):
        raise TypeError(
            f"cache backend selector must be a registry name "
            f"({', '.join(repr(n) for n in sorted(BACKENDS))}), a "
            f"CacheBackend instance, or None — got "
            f"{type(spec).__name__}: {spec!r}")
    combo = split_combinator(spec)
    if combo is not None:
        return f"{combo[0]}:{combo[1]}"
    if spec not in BACKENDS:
        known = ", ".join(repr(n) for n in registered_selectors())
        raise ValueError(
            f"unknown cache backend {spec!r}; registered selectors are "
            f"{known} — 'tiered:<disk>' is a memory-LRU front over a disk "
            f"backend, 'mmap:<disk>' a packed read-only snapshot whose "
            f"hits skip the inter-process lock (pass a CacheBackend "
            f"instance for a custom store)")
    return spec


def select_backend(selector: Union[str, CacheBackend, None],
                   default: str = "sqlite") -> str:
    """Public backend-selection API: validate a ``backend=`` selector
    and return the normalized registry name it resolves to, without
    opening (or creating) any store.

    Accepts plain registry names (``"memory"`` / ``"pickle"`` /
    ``"dbm"`` / ``"sqlite"``), the combinator forms ``"tiered[:<disk>]"``
    and ``"mmap[:<disk>]"``, a :class:`CacheBackend` instance (resolves
    to its ``name``), or ``None`` (resolves to ``default``).  Unknown
    selectors raise ``ValueError`` listing every registered selector
    (see :func:`registered_selectors`).  This is the single entry point
    the CLI, :class:`~repro.serve.config.ServeConfig` and the serve
    fleet route through.
    """
    return resolve_backend_name(selector, default)


def open_backend(spec: Union[str, CacheBackend, None], path: Optional[str],
                 default: str = "sqlite") -> CacheBackend:
    """Resolve a ``backend=`` argument: an instance passes through, a
    name is looked up in ``BACKENDS``, ``None`` means ``default``,
    ``"tiered[:<disk>]"`` builds a ``TieredBackend`` and
    ``"mmap[:<disk>]"`` an ``MmapTier`` over the named disk backend.
    Unknown selectors raise with the registered selectors spelled
    out."""
    if isinstance(spec, CacheBackend):
        return spec
    name = resolve_backend_name(spec, default)
    combo = split_combinator(name)
    if combo is not None:
        combinator, disk = combo
        if combinator == "tiered":
            from .tiered import TieredBackend   # deferred: imports us
            return TieredBackend(path, disk=disk)
        from .mmap_tier import MmapTier         # deferred: imports us
        return MmapTier(path, disk=disk)
    return BACKENDS[name](path)


# one measurement per resolved selector per process — the figure feeds
# cost *estimates*, so amortizing it is more valuable than freshness
_ROUND_TRIP_CACHE: Dict[str, float] = {}
_ROUND_TRIP_LOCK = threading.Lock()


def measure_round_trip(spec: Union[str, CacheBackend, None], *,
                       default: str = "sqlite", payload_bytes: int = 2048,
                       n_entries: int = 32, n_rounds: int = 3) -> float:
    """Measured warm per-entry round-trip cost of a backend selector
    (seconds): the amortized cost of one entry in a batched
    ``get_many`` over a freshly-written throwaway store.

    This is the figure the plan compiler's ``cache-place`` pass weighs
    against a node's estimated recompute cost — caching a stage whose
    recompute is cheaper than this round trip only *adds* latency (and
    disk), so the planner skips it.  Microbenchmarked once per resolved
    selector per process (cached); combinator selectors
    (``tiered:<disk>`` / ``mmap:<disk>``) measure the combinator's own
    warm-hit path, which is the one serving traffic sees.
    """
    name = resolve_backend_name(spec, default)
    with _ROUND_TRIP_LOCK:
        hit = _ROUND_TRIP_CACHE.get(name)
    if hit is not None:
        return hit
    import shutil
    import time
    tmp = tempfile.mkdtemp(prefix="repro-rt-")
    try:
        backend = open_backend(name, tmp)
        try:
            payload = b"\x5a" * max(1, int(payload_bytes))
            keys = [b"rt-%06d" % i for i in range(max(1, int(n_entries)))]
            backend.put_many((k, payload) for k in keys)
            backend.get_many(keys)       # warm any front tier / page cache
            best = float("inf")
            for _ in range(max(1, int(n_rounds))):
                t0 = time.perf_counter()
                backend.get_many(keys)
                best = min(best, time.perf_counter() - t0)
            per_entry = best / len(keys)
        finally:
            backend.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    with _ROUND_TRIP_LOCK:
        _ROUND_TRIP_CACHE[name] = per_entry
    return per_entry


def backend_store_exists(name: Optional[str], path: str) -> bool:
    """``store_exists`` by resolved backend *name*, understanding the
    ``tiered:<disk>`` / ``mmap:<disk>`` combinators (whose on-disk
    footprint is their disk tier's) — for offline inspection without
    opening a store."""
    try:
        combo = split_combinator(name) if isinstance(name, str) else None
    except ValueError:
        return False
    if combo is not None:
        return BACKENDS[combo[1]].store_exists(path)
    if name in BACKENDS:
        return BACKENDS[name].store_exists(path)
    return False
