"""Artifact API — sharing caches across research groups (paper §4.5).

Cache objects serialize to a directory; the Artifact layer packages that
directory with a metadata record and pushes/pulls it to a *hub*.  The
paper uses HuggingFace / Zenodo; offline we implement the same API over
a local hub directory (``$REPRO_HUB`` or ``~/.repro_hub``) — the
network transport is the only thing stubbed, the packaging/metadata/
resolution logic is real.
"""
from __future__ import annotations

import json
import os
import shutil
import tarfile
import tempfile
import time
from typing import Any, Dict, Optional, Type

__all__ = ["Artifact", "hub_dir", "to_hub", "from_hub"]


def hub_dir() -> str:
    d = os.environ.get("REPRO_HUB", os.path.expanduser("~/.repro_hub"))
    os.makedirs(d, exist_ok=True)
    return d


def _meta_of(obj: Any) -> Dict[str, Any]:
    return {
        "artifact_type": type(obj).__name__,
        "module": type(obj).__module__,
        "created": time.time(),
        "format_version": 1,
    }


def to_hub(obj: Any, repo_id: str) -> str:
    """Package ``obj.path`` (a cache directory) into the hub as a tarball."""
    path = getattr(obj, "path", None)
    if path is None or not os.path.isdir(path):
        raise ValueError(f"{obj!r} has no directory to share")
    if hasattr(obj, "_close_backend"):
        obj._close_backend()  # flush
    dest = os.path.join(hub_dir(), repo_id.replace("/", "__"))
    os.makedirs(dest, exist_ok=True)
    tar_path = os.path.join(dest, "artifact.tar")
    with tarfile.open(tar_path, "w") as tar:
        tar.add(path, arcname="artifact")
    with open(os.path.join(dest, "metadata.json"), "w") as f:
        json.dump(_meta_of(obj), f, indent=2)
    return dest


def from_hub(repo_id: str, dest_path: Optional[str] = None) -> str:
    """Fetch an artifact directory from the hub; returns the local path."""
    src = os.path.join(hub_dir(), repo_id.replace("/", "__"))
    tar_path = os.path.join(src, "artifact.tar")
    if not os.path.exists(tar_path):
        raise FileNotFoundError(f"artifact {repo_id!r} not found in hub "
                                f"{hub_dir()!r}")
    if dest_path is None:
        dest_path = tempfile.mkdtemp(prefix="repro-artifact-")
    with tarfile.open(tar_path) as tar:
        if hasattr(tarfile, "data_filter"):
            tar.extractall(dest_path, filter="data")
        else:                            # pragma: no cover - old stdlib
            tar.extractall(dest_path)
    return os.path.join(dest_path, "artifact")


class Artifact:
    """Mixin/namespace mirroring the paper's ``pt.Artifact`` calls."""

    @staticmethod
    def from_hf(repo_id: str, cls: Optional[Type] = None, **kwargs):
        path = from_hub(repo_id)
        return cls(path, **kwargs) if cls is not None else path

    @staticmethod
    def from_zenodo(record_id: str, cls: Optional[Type] = None, **kwargs):
        path = from_hub(f"zenodo/{record_id}")
        return cls(path, **kwargs) if cls is not None else path


def _to_hf(self, repo_id: str) -> str:
    return to_hub(self, repo_id)


def _to_zenodo(self, record_id: str = "0") -> str:
    return to_hub(self, f"zenodo/{record_id}")


def install_artifact_methods(cls: Type) -> Type:
    """Grafts to_hf/to_zenodo onto a cache class (Artifact conformance)."""
    cls.to_hf = _to_hf
    cls.to_zenodo = _to_zenodo
    return cls
