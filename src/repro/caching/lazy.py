"""Lazy transformer construction (paper §4.5).

``Lazy(lambda: ExpensiveScorer())`` defers constructing a transformer
(e.g. loading a model onto an accelerator) until it is actually invoked
— useful when a hot cache means it may never be needed.
"""
from __future__ import annotations

from typing import Callable, Optional

from ..core.frame import ColFrame
from ..core.pipeline import Transformer

__all__ = ["Lazy"]


class Lazy(Transformer):
    """Constructs the wrapped transformer at most once, on first use."""

    def __init__(self, factory: Callable[[], Transformer],
                 name: str = "lazy"):
        self.factory = factory
        self.name = name
        self._instance: Optional[Transformer] = None
        self.construction_count = 0

    def _resolve_lazy(self) -> Transformer:
        if self._instance is None:
            self._instance = self.factory()
            self.construction_count += 1
        return self._instance

    @property
    def constructed(self) -> bool:
        return self._instance is not None

    def transform(self, inp: ColFrame) -> ColFrame:
        return self._resolve_lazy()(inp)

    def signature(self):
        if self._instance is not None:
            return self._instance.signature()
        return ("Lazy", self.name)
