"""KeyValueCache — row-wise key→value memoization (paper §4.1).

Maps one or more *key* columns to one or more *value* columns under the
assumption that rows are independent and values depend only on keys.
Suitable for Q→Q / D→D stages (query/document rewriters, Doc2Query).

Implementation matches the paper: a SQLite database whose keys and
values are pickled blobs.  Rows that miss are batched through the
wrapped transformer, inserted, and merged back in position.
"""
from __future__ import annotations

import sqlite3
import os
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.frame import ColFrame
from .base import (CacheMissError, CacheTransformer, pickle_key,
                   pickle_value, unpickle_value)

__all__ = ["KeyValueCache"]

_SCHEMA = """
CREATE TABLE IF NOT EXISTS kv (
  key   BLOB PRIMARY KEY,
  value BLOB NOT NULL
) WITHOUT ROWID;
"""


class KeyValueCache(CacheTransformer):
    """Row-by-row key→value cache backed by SQLite."""

    def __init__(self, path: Optional[str] = None, transformer: Any = None,
                 *, key: Any = "text", value: Any = "text",
                 verify_fraction: float = 0.0):
        super().__init__(path, transformer, verify_fraction=verify_fraction)
        self.key_cols: Tuple[str, ...] = \
            (key,) if isinstance(key, str) else tuple(key)
        self.value_cols: Tuple[str, ...] = \
            (value,) if isinstance(value, str) else tuple(value)
        self._db = sqlite3.connect(os.path.join(self.path, "kv.sqlite3"))
        self._db.executescript(_SCHEMA)
        # bulk lookups are much faster with a page cache
        self._db.execute("PRAGMA cache_size = -65536")
        self._db.execute("PRAGMA journal_mode = WAL")
        self._db.execute("PRAGMA synchronous = NORMAL")

    # -- backend -------------------------------------------------------------
    def _close_backend(self):
        try:
            self._db.close()
        except Exception:
            pass

    def _get_many(self, keys: List[bytes]) -> List[Optional[bytes]]:
        out: List[Optional[bytes]] = [None] * len(keys)
        CHUNK = 900  # sqlite var limit is 999
        pos = {k: i for i, k in enumerate(keys)}
        for lo in range(0, len(keys), CHUNK):
            chunk = keys[lo:lo + CHUNK]
            q = ("SELECT key, value FROM kv WHERE key IN (%s)"
                 % ",".join("?" * len(chunk)))
            for k, v in self._db.execute(q, chunk):
                out[pos[bytes(k)]] = bytes(v)
        return out

    def _put_many(self, items: Iterable[Tuple[bytes, bytes]]):
        with self._db:
            self._db.executemany(
                "INSERT OR REPLACE INTO kv (key, value) VALUES (?, ?)", items)

    def __len__(self) -> int:
        (n,) = self._db.execute("SELECT COUNT(*) FROM kv").fetchone()
        return int(n)

    # -- transform -----------------------------------------------------------
    def _keys_of(self, frame: ColFrame) -> List[bytes]:
        cols = [frame[c].tolist() for c in self.key_cols]
        return [pickle_key(t) for t in zip(*cols)] if len(frame) else []

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        keys = self._keys_of(inp)
        found = self._get_many(keys)
        miss_idx = [i for i, v in enumerate(found) if v is None]
        self.stats.hits += len(keys) - len(miss_idx)
        self.stats.misses += len(miss_idx)

        values: List[Optional[Tuple]] = \
            [unpickle_value(v) if v is not None else None for v in found]

        if miss_idx:
            t = self._require_transformer(len(miss_idx))
            # dedup identical keys within the miss batch
            uniq: dict = {}
            for i in miss_idx:
                uniq.setdefault(keys[i], []).append(i)
            rep_rows = [idxs[0] for idxs in uniq.values()]
            miss_frame = inp.take(np.asarray(rep_rows, dtype=np.int64))
            out = t(miss_frame)
            if len(out) != len(rep_rows):
                raise ValueError(
                    f"KeyValueCache: wrapped transformer returned {len(out)} "
                    f"rows for {len(rep_rows)} inputs — KeyValueCache "
                    f"requires a row-wise (1:1) transformer")
            new_items = []
            for j, (k, idxs) in enumerate(uniq.items()):
                val = tuple(out[c][j] for c in self.value_cols)
                new_items.append((k, pickle_value(val)))
                for i in idxs:
                    values[i] = val
            self._put_many(new_items)
            self.stats.inserts += len(new_items)

        if self.verify_fraction > 0 and len(keys) > len(miss_idx):
            self._verify(inp, keys, values, miss_idx)

        out_frame = inp
        for ci, c in enumerate(self.value_cols):
            col = np.empty(len(inp), dtype=object)
            col[:] = [v[ci] for v in values]
            # preserve numeric dtype when possible
            try:
                col = col.astype(np.float64) if all(
                    isinstance(x, (int, float, np.floating, np.integer))
                    for x in col.tolist()) else col
            except Exception:
                pass
            out_frame = out_frame.assign(**{c: col})
        return out_frame

    # -- determinism verification (beyond paper §6) ---------------------------
    def _verify(self, inp: ColFrame, keys: List[bytes],
                values: List[Optional[Tuple]], miss_idx: List[int]):
        t = self.transformer
        if t is None:
            return
        hit_idx = [i for i in range(len(keys)) if i not in set(miss_idx)]
        rng = np.random.default_rng(0)
        n = max(1, int(len(hit_idx) * self.verify_fraction))
        sample = rng.choice(hit_idx, size=min(n, len(hit_idx)), replace=False)
        frame = inp.take(np.asarray(sample, dtype=np.int64))
        fresh = t(frame)
        for j, i in enumerate(sample):
            got = tuple(fresh[c][j] for c in self.value_cols)
            exp = values[i]
            ok = all(_val_eq(g, e) for g, e in zip(got, exp))
            if not ok:
                raise AssertionError(
                    f"KeyValueCache determinism violation at key index {i}: "
                    f"cached {exp!r} vs fresh {got!r}")
        self.stats.verified += len(sample)


def _val_eq(a, b) -> bool:
    if isinstance(a, (float, np.floating)) and isinstance(b, (float, np.floating)):
        return bool(np.isclose(a, b, rtol=1e-5, atol=1e-6))
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return bool(np.allclose(np.asarray(a), np.asarray(b),
                                rtol=1e-5, atol=1e-6))
    return a == b
