"""KeyValueCache — row-wise key→value memoization (paper §4.1).

Maps one or more *key* columns to one or more *value* columns under the
assumption that rows are independent and values depend only on keys.
Suitable for Q→Q / D→D stages (query/document rewriters, Doc2Query).

Storage is delegated to a pluggable ``CacheBackend`` (``backends.py``);
the default ``"sqlite"`` matches the paper's implementation (a SQLite
database of pickled blobs).  Rows that miss are re-checked and batched
through the wrapped transformer *inside the backend's exclusive lock*,
so concurrent shards/processes sharing one cache directory compute each
entry exactly once.
"""
from __future__ import annotations

import time
from typing import Any, List, Optional, Tuple

import numpy as np

from ..core.frame import ColFrame
from .backends import CacheBackend, open_backend, resolve_backend_name
from .base import (CacheTransformer, n_frame_queries, pickle_key,
                   pickle_value, unpickle_value)
from .codecs import (KV_CODEC, decode_kv_batch, decode_kv_value,
                     encode_kv_value, vector_keys)

__all__ = ["KeyValueCache"]


class KeyValueCache(CacheTransformer):
    """Row-by-row key→value cache over a pluggable backend."""

    #: registry name passed to ``open_backend`` when ``backend=None``
    default_backend = "sqlite"

    def __init__(self, path: Optional[str] = None, transformer: Any = None,
                 *, key: Any = "text", value: Any = "text",
                 verify_fraction: float = 0.0,
                 backend: Any = None,
                 fingerprint: Optional[str] = None,
                 on_stale: str = "error",
                 budget: Any = None,
                 async_writes: Optional[bool] = None):
        super().__init__(path, transformer, verify_fraction=verify_fraction,
                         fingerprint=fingerprint, on_stale=on_stale,
                         budget=budget, async_writes=async_writes)
        self.key_cols: Tuple[str, ...] = \
            (key,) if isinstance(key, str) else tuple(key)
        self.value_cols: Tuple[str, ...] = \
            (value,) if isinstance(value, str) else tuple(value)
        # manifest check precedes the store open so a stale directory
        # can be wiped under on_stale="recompute"; fresh dirs negotiate
        # the vectorized codec, pre-codec dirs stay on pickled keys
        self._open_manifest(
            backend=resolve_backend_name(backend, self.default_backend),
            key_columns=self.key_cols, value_columns=self.value_cols,
            codec=KV_CODEC)
        self._backend: CacheBackend = open_backend(
            backend, self.path, default=self.default_backend)
        self._init_dataplane()

    # -- backend -------------------------------------------------------------
    @property
    def backend(self) -> CacheBackend:
        return self._backend

    def _close_backend(self):
        self._backend.close()

    def __len__(self) -> int:
        self._drain_writes()             # enumeration is a flush point
        return len(self._backend)

    # -- transform -----------------------------------------------------------
    def _keys_of(self, frame: ColFrame) -> List[bytes]:
        if len(frame) == 0:
            return []
        if self.codec == KV_CODEC:
            return vector_keys([frame[c] for c in self.key_cols])
        cols = [frame[c].tolist() for c in self.key_cols]
        return [pickle_key(t) for t in zip(*cols)]

    # -- codec dispatch (negotiated per directory, see _open_manifest) -------
    def _encode_value(self, vals: Tuple) -> bytes:
        return encode_kv_value(vals) if self.codec == KV_CODEC \
            else pickle_value(vals)

    def _decode_value(self, blob: bytes) -> Tuple:
        return decode_kv_value(blob) if self.codec == KV_CODEC \
            else unpickle_value(blob)

    # -- prefetch (keys derive from the input frame alone) -------------------
    def prefetch_columns(self) -> Optional[Tuple[str, ...]]:
        return self.key_cols

    def prefetch_keys(self, frame: ColFrame) -> List[bytes]:
        return self._keys_of(frame)

    def _transform_single(self, inp: ColFrame,
                          key: bytes) -> Optional[ColFrame]:
        """Single-key read-through fast path (online serving): one
        ``backend.get``, scalar column assignment — skips the batched
        lookup plumbing and full-frame value assembly on a hit.
        Returns ``None`` on a miss (the generic path then handles the
        compute-once protocol)."""
        blobs, prefetched = self._lookup_many([key])
        blob = blobs[0]
        if blob is None:
            return None
        vals = self._decode_value(blob)
        self.stats.add(hits=1, prefetched=prefetched)
        self._note_call(1, 0)
        self._note_access([key])
        out = inp
        for ci, c in enumerate(self.value_cols):
            v = vals[ci]
            if isinstance(v, (int, float, np.floating, np.integer)):
                col = np.asarray([v], dtype=np.float64)
            else:
                col = np.empty(1, dtype=object)
                col[0] = v
            out = out.assign(**{c: col})
        return out

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        keys = self._keys_of(inp)
        if len(inp) == 1 and self.verify_fraction == 0:
            hit = self._transform_single(inp, keys[0])
            if hit is not None:
                return hit
            found: List[Optional[bytes]] = [None]   # already probed —
            # the compute-once recheck under the lock re-queries anyway
            prefetched = 0
        else:
            found, prefetched = self._lookup_many(keys)
        miss_idx = [i for i, v in enumerate(found) if v is None]

        if not miss_idx and self.codec == KV_CODEC \
                and self.verify_fraction == 0:
            cols = decode_kv_batch(found, len(self.value_cols))
            if cols is not None:
                # warm all-float batch: one frombuffer/reshape instead
                # of N pickle.loads + per-row column assembly
                self.stats.add(hits=len(keys), prefetched=prefetched)
                self._note_call(len(keys), 0)
                self._note_access(keys)
                out_frame = inp
                for ci, c in enumerate(self.value_cols):
                    out_frame = out_frame.assign(
                        **{c: np.ascontiguousarray(cols[:, ci])})
                return out_frame

        values: List[Optional[Tuple]] = \
            [self._decode_value(v) if v is not None else None for v in found]

        if miss_idx:
            miss_idx = self._fill_misses(inp, keys, values, miss_idx)
        self.stats.add(hits=len(keys) - len(miss_idx), misses=len(miss_idx),
                       prefetched=prefetched)
        self._note_call(len(keys) - len(miss_idx), len(miss_idx))
        self._note_access(keys)          # hits + fresh inserts alike

        if self.verify_fraction > 0 and len(keys) > len(miss_idx):
            self._verify(inp, keys, values, miss_idx)

        out_frame = inp
        for ci, c in enumerate(self.value_cols):
            col = np.empty(len(inp), dtype=object)
            col[:] = [v[ci] for v in values]
            # preserve numeric dtype when possible
            try:
                col = col.astype(np.float64) if all(
                    isinstance(x, (int, float, np.floating, np.integer))
                    for x in col.tolist()) else col
            except Exception:
                pass
            out_frame = out_frame.assign(**{c: col})
        return out_frame

    def _fill_misses(self, inp: ColFrame, keys: List[bytes],
                     values: List[Optional[Tuple]],
                     miss_idx: List[int]) -> List[int]:
        """Compute-once miss handling: under the backend's exclusive
        lock, re-check the missing keys (another thread/process may have
        inserted them since the optimistic lookup), run the wrapped
        transformer only on what is still absent, and insert.  Returns
        the indices this call actually computed.

        Holding the lock across the compute is what makes the
        exactly-once guarantee hold; the price is that cold-cache
        misses serialize across workers sharing one store (hits stay
        concurrent).  Run cold warm-ups uncached, or accept first-run
        serialization for never-recompute semantics."""
        with self._backend.lock():
            recheck = self._recheck_many([keys[i] for i in miss_idx])
            still = []
            for i, blob in zip(miss_idx, recheck):
                if blob is None:
                    still.append(i)
                else:
                    values[i] = self._decode_value(blob)
            if not still:
                return []
            t = self._require_transformer(len(still))
            # dedup identical keys within the miss batch
            uniq: dict = {}
            for i in still:
                uniq.setdefault(keys[i], []).append(i)
            rep_rows = [idxs[0] for idxs in uniq.values()]
            miss_frame = inp.take(np.asarray(rep_rows, dtype=np.int64))
            t0 = time.perf_counter()
            out = t(miss_frame)
            self.stats.add(compute_s=time.perf_counter() - t0,
                           compute_queries=n_frame_queries(miss_frame))
            if len(out) != len(rep_rows):
                raise ValueError(
                    f"{type(self).__name__}: wrapped transformer returned "
                    f"{len(out)} rows for {len(rep_rows)} inputs — "
                    f"{type(self).__name__} requires a row-wise (1:1) "
                    f"transformer")
            new_items = []
            for j, (k, idxs) in enumerate(uniq.items()):
                val = tuple(out[c][j] for c in self.value_cols)
                new_items.append((k, self._encode_value(val)))
                for i in idxs:
                    values[i] = val
            if not self.readonly:        # stale-readonly: never insert
                # under write-behind this *enqueues* inside the locked
                # section (the racing recheck sees the overlay); the
                # barrier makes it durable before the lock releases so
                # other processes' rechecks see it too
                self._store_many(new_items)
                self.stats.add(inserts=len(new_items))
            self._write_barrier()
            return still

    # -- determinism verification (beyond paper §6) ---------------------------
    def _verify(self, inp: ColFrame, keys: List[bytes],
                values: List[Optional[Tuple]], miss_idx: List[int]):
        t = self.transformer
        if t is None:
            return
        hit_idx = [i for i in range(len(keys)) if i not in set(miss_idx)]
        rng = np.random.default_rng(0)
        n = max(1, int(len(hit_idx) * self.verify_fraction))
        sample = rng.choice(hit_idx, size=min(n, len(hit_idx)), replace=False)
        frame = inp.take(np.asarray(sample, dtype=np.int64))
        fresh = t(frame)
        for j, i in enumerate(sample):
            got = tuple(fresh[c][j] for c in self.value_cols)
            exp = values[i]
            ok = all(_val_eq(g, e) for g, e in zip(got, exp))
            if not ok:
                raise AssertionError(
                    f"KeyValueCache determinism violation at key index {i}: "
                    f"cached {exp!r} vs fresh {got!r}")
        self.stats.add(verified=len(sample))


def _val_eq(a, b) -> bool:
    if isinstance(a, (float, np.floating)) and isinstance(b, (float, np.floating)):
        return bool(np.isclose(a, b, rtol=1e-5, atol=1e-6))
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return bool(np.allclose(np.asarray(a), np.asarray(b),
                                rtol=1e-5, atol=1e-6))
    return a == b
