"""auto_cache — inferring the caching strategy (paper §6 future work).

The paper notes explicit caches "rely on direct application by the
researcher ... since current transformer implementations do not provide
sufficient information to automatically infer the correct caching
strategy.  In the future, we may enhance the Transformer API to include
this kind of information, e.g. the input and output columns."

Our Transformer base class carries exactly that metadata
(``key_columns`` / ``value_columns`` / ``one_to_many`` / ``cacheable`` /
``deterministic``), so the inference is implementable:

* ``cacheable=False``  → refuse (pairwise/listwise scorers, adaptive
  rerankers — §5's DuoT5 caveat);
* ``one_to_many=True`` → RetrieverCache keyed by ``key_columns``;
* ``score`` among the value columns → ScorerCache (re-ranks after merge);
* otherwise            → KeyValueCache on (key_columns → value_columns).

The same metadata powers ``typecheck_pipeline`` — the "added benefit"
footnote 13 anticipates (automatic type-checking of pipelines).
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

from ..core.frame import ColFrame
from ..core.pipeline import Compose, Transformer, stages_of
from .kv import KeyValueCache
from .retriever import RetrieverCache
from .scorer import ScorerCache

__all__ = ["auto_cache", "auto_cache_or_none", "derive_fingerprint",
           "typecheck_pipeline", "UncacheableError"]


class UncacheableError(TypeError):
    pass


def derive_fingerprint(transformer: Any) -> Optional[str]:
    """``transformer.fingerprint()`` when safely derivable, else None
    (no transformer, unconstructed ``Lazy`` — whose placeholder
    signature would change once constructed — or a failing hook)."""
    if transformer is None:
        return None
    if hasattr(transformer, "_resolve_lazy"):
        if not getattr(transformer, "constructed", True):
            return None
        transformer = transformer._resolve_lazy()    # already built: free
    try:
        return transformer.fingerprint()
    except Exception:
        return None


def auto_cache(transformer: Transformer, path: Optional[str] = None,
               *, backend: Optional[str] = None,
               fingerprint: Optional[str] = None,
               on_stale: Optional[str] = None,
               budget: Any = None, **kwargs):
    """Pick and construct the right cache family from metadata.

    ``backend`` selects the storage implementation by registry name
    (``"memory"`` / ``"pickle"`` / ``"dbm"`` / ``"sqlite"``, plus the
    ``"tiered[:<disk>]"`` combinator — see ``backends.py``); ``None``
    keeps each family's default (SQLite for key-value/scorer caches,
    dbm for retriever caches, both per §4).  ``budget`` bounds the
    store (``economics.CacheBudget`` / dict / int max-entries) —
    recorded in the manifest and enforced on ``close()`` or via
    ``repro cache evict``.

    Provenance (``caching/provenance.py``): ``fingerprint`` defaults to
    ``transformer.fingerprint()`` (skipped for unconstructed ``Lazy``
    wrappers — deriving it would force construction), so reopening a
    cache directory after the transformer's config or code changed
    trips the ``on_stale`` policy (``"error"`` | ``"recompute"`` |
    ``"readonly"``) instead of silently serving stale results.
    """
    if backend is not None:
        kwargs["backend"] = backend
    if on_stale is not None:
        kwargs["on_stale"] = on_stale
    if budget is not None:
        kwargs["budget"] = budget        # size/TTL envelope (economics.py)
    if fingerprint is None:
        fingerprint = derive_fingerprint(transformer)
    if fingerprint is not None:
        kwargs["fingerprint"] = fingerprint
    if isinstance(transformer, Compose):
        raise UncacheableError(
            "auto_cache wraps a single stage; wrap stages individually or "
            "rely on prefix precomputation for whole-pipeline sharing")
    if not getattr(transformer, "cacheable", True):
        raise UncacheableError(
            f"{transformer!r} declares cacheable=False (its outputs depend "
            f"on the candidate pool, like DuoT5 — see paper §5)")
    if not getattr(transformer, "deterministic", True):
        raise UncacheableError(
            f"{transformer!r} declares deterministic=False; caching would "
            f"freeze one sample of a stochastic process")
    keys = tuple(getattr(transformer, "key_columns", ()) or ())
    vals = tuple(getattr(transformer, "value_columns", ()) or ())
    if getattr(transformer, "one_to_many", False):
        return RetrieverCache(path, transformer,
                              key=keys or ("qid", "query"), **kwargs)
    if "score" in vals or (not vals and "docno" in keys):
        # only stages that *produce* a score are scorers — a docno-keyed
        # augmenter (TextLoader: docno → text) must not be re-ranked, and
        # after SetUnion its input has no score column to fall back on
        return ScorerCache(path, transformer,
                           key=keys or ("query", "docno"),
                           value=vals or ("score",), **kwargs)
    if not keys or not vals:
        raise UncacheableError(
            f"{transformer!r} does not declare key/value columns; cannot "
            f"infer a caching strategy (the paper-§6 situation)")
    return KeyValueCache(path, transformer, key=keys, value=vals, **kwargs)


def auto_cache_or_none(transformer: Transformer, path: Optional[str] = None,
                       **kwargs):
    """``auto_cache`` as a *policy*: ``None`` instead of an exception.

    This is the default ``memo_factory`` of ``core.plan.ExecutionPlan``
    — nodes whose metadata admits a caching strategy get one inserted by
    the planner; everything else (uncacheable, nondeterministic,
    already-cached, undeclared) runs bare.  Accepts the same
    ``backend=`` selector as ``auto_cache`` (the planner forwards its
    ``cache_backend`` argument here).
    """
    from .base import CacheTransformer
    if isinstance(transformer, (Compose, CacheTransformer)):
        return None
    try:
        return auto_cache(transformer, path, **kwargs)
    except UncacheableError:
        return None


def typecheck_pipeline(pipeline: Transformer) -> List[Tuple[str, str]]:
    """Static column-flow check along a Compose chain.

    Returns a list of (stage repr, error) — empty when well-typed.
    Uses the declared input/output column sets; stages without
    declarations pass through unchanged columns conservatively.
    """
    errors: List[Tuple[str, str]] = []
    available: Optional[set] = None  # None = unknown/any
    for stage in stages_of(pipeline):
        need = getattr(stage, "input_columns", None)
        if need is not None and available is not None:
            missing = set(need) - available
            if missing:
                errors.append((repr(stage),
                               f"missing input columns {sorted(missing)} "
                               f"(have {sorted(available)})"))
        out_cols = getattr(stage, "output_columns", None)
        if out_cols is not None:
            available = set(out_cols)
        else:
            produced = set(getattr(stage, "value_columns", ()) or ())
            if available is not None:
                available = available | produced
            if need is not None and available is None:
                available = set(need) | produced
    return errors
