"""Bucketed miss execution — TPU/XLA adaptation of cache-miss batches.

The paper's caches run cache-miss rows through the wrapped component as
an arbitrary-size residual batch.  Under XLA every new batch size is a
fresh compilation; an experiment whose hit pattern produces 37-, then
61-, then 14-row miss batches would thrash the compile cache.  We pad
miss batches up to power-of-two buckets (with a floor), so the number of
distinct compiled shapes is O(log max_batch) — the standard serving
trick (cf. bucketed batching in fairseq/T5), applied here to *cache-miss
re-execution*, which is new relative to the paper.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Sequence, Tuple

import numpy as np

__all__ = ["bucket_size", "pad_batch", "BucketedRunner"]


def bucket_size(n: int, *, floor: int = 8, ceiling: int = 1 << 20) -> int:
    """Smallest power-of-two ≥ n (≥ floor)."""
    if n <= 0:
        return floor
    return min(max(floor, 1 << (int(n - 1).bit_length())), ceiling)


def pad_batch(arr: np.ndarray, target: int) -> np.ndarray:
    """Pad the leading dim of `arr` to `target` rows (repeat row 0 so
    padded rows stay in-distribution and produce finite scores)."""
    n = arr.shape[0]
    if n == target:
        return arr
    if n == 0:
        raise ValueError("cannot pad an empty batch")
    pad = np.broadcast_to(arr[:1], (target - n,) + arr.shape[1:])
    return np.concatenate([arr, pad], axis=0)


class BucketedRunner:
    """Runs ``fn(batch_arrays) -> scores`` over padded buckets.

    ``fn`` sees only O(log n) distinct leading dimensions, so a jitted
    scorer compiles a handful of times per experiment instead of once
    per miss batch.  Tracks the shapes issued for test assertions.
    """

    def __init__(self, fn: Callable[..., np.ndarray], *, floor: int = 8,
                 max_bucket: int = 4096):
        self.fn = fn
        self.floor = int(floor)
        self.max_bucket = int(max_bucket)
        self.shapes_issued: Dict[int, int] = {}

    def __call__(self, *arrays: np.ndarray) -> np.ndarray:
        n = arrays[0].shape[0]
        if n == 0:
            return np.zeros((0,), dtype=np.float32)
        outs = []
        for lo in range(0, n, self.max_bucket):
            chunk = [a[lo:lo + self.max_bucket] for a in arrays]
            m = chunk[0].shape[0]
            b = bucket_size(m, floor=self.floor, ceiling=self.max_bucket)
            padded = [pad_batch(a, b) for a in chunk]
            self.shapes_issued[b] = self.shapes_issued.get(b, 0) + 1
            out = np.asarray(self.fn(*padded))
            outs.append(out[:m])
        return np.concatenate(outs, axis=0)
