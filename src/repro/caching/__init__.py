# Explicit caching strategies (paper §4) + TPU adaptations.
from .backends import (BACKENDS, CacheBackend, DbmBackend, FileLock,
                       MemoryLRUBackend, PickleDirBackend, SQLiteBackend,
                       atomic_write_bytes, backend_store_exists,
                       open_backend, registered_selectors,
                       resolve_backend_name, select_backend, split_mmap,
                       split_tiered, storage_identity)
from .tiered import TieredBackend
from .mmap_tier import MmapTier
from .provenance import (CacheManifest, ManifestError, ProvenanceError,
                         StaleCacheError, combine_fingerprints,
                         transformer_fingerprint)
from .economics import (AccessStats, CacheBudget, enforce_dir,
                        evict_entries)
from .base import CacheMissError, CacheStats, CacheTransformer
from .codecs import (KV_CODEC, RETRIEVER_CODEC, KNOWN_CODECS, scalar_key,
                     vector_keys)
from .dataplane import (StagingMap, WriteBehindWriter, io_pool,
                        prefetch_default, write_behind_default)
from .warming import warm_scenario
from .kv import KeyValueCache
from .scorer import ScorerCache
from .dense import DenseScorerCache
from .retriever import RetrieverCache
from .indexer import IndexerCache
from .lazy import Lazy
from .artifact import Artifact, to_hub, from_hub, hub_dir, \
    install_artifact_methods
from .bucketing import BucketedRunner, bucket_size, pad_batch
from .compile_cache import CompileCache, default_compile_cache
from .auto import (auto_cache, auto_cache_or_none, derive_fingerprint,
                   typecheck_pipeline, UncacheableError)

# Artifact API conformance for every cache family (paper §4.5)
for _cls in (KeyValueCache, ScorerCache, DenseScorerCache, RetrieverCache,
             IndexerCache):
    install_artifact_methods(_cls)

__all__ = [
    "BACKENDS", "CacheBackend", "MemoryLRUBackend", "PickleDirBackend",
    "DbmBackend", "SQLiteBackend", "TieredBackend", "MmapTier", "FileLock",
    "atomic_write_bytes", "backend_store_exists",
    "open_backend", "registered_selectors", "resolve_backend_name",
    "select_backend", "split_mmap", "split_tiered", "storage_identity",
    "CacheManifest", "ManifestError", "ProvenanceError", "StaleCacheError",
    "combine_fingerprints", "transformer_fingerprint",
    "AccessStats", "CacheBudget", "enforce_dir", "evict_entries",
    "warm_scenario",
    "CacheMissError", "CacheStats", "CacheTransformer",
    "KV_CODEC", "RETRIEVER_CODEC", "KNOWN_CODECS", "scalar_key",
    "vector_keys",
    "StagingMap", "WriteBehindWriter", "io_pool", "prefetch_default",
    "write_behind_default",
    "KeyValueCache", "ScorerCache", "DenseScorerCache", "RetrieverCache",
    "IndexerCache", "Lazy", "Artifact", "to_hub", "from_hub", "hub_dir",
    "BucketedRunner", "bucket_size", "pad_batch",
    "CompileCache", "default_compile_cache",
    "auto_cache", "auto_cache_or_none", "derive_fingerprint",
    "typecheck_pipeline", "UncacheableError",
]
