"""Shared machinery for explicit caches (paper §4).

Common behaviours across all cache families:

* **temporary mode** — omit the path and a temp directory is created and
  deleted when the cache is closed / used as a context manager (§4.5);
* **no-transformer mode** — a cache constructed without a wrapped
  transformer raises ``CacheMissError`` on miss (§4.5);
* **Lazy transformers** — resolved only when first needed (§4.5);
* **determinism verification** — beyond-paper: ``verify_fraction>0``
  re-executes a sample of *hit* rows through the wrapped transformer and
  asserts the cached values match (the paper §6 notes determinism is
  assumed; on TPU/XLA SPMD it is checkable, so we check);
* **hit/miss accounting** — exposed as ``stats``.
"""
from __future__ import annotations

import os
import pickle
import shutil
import sys
import tempfile
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.pipeline import Transformer
from .economics import AccessStats, CacheBudget, evict_entries
from .provenance import CacheManifest, ManifestError, StaleCacheError

__all__ = ["CacheMissError", "CacheStats", "CacheTransformer",
           "n_frame_queries", "resolve_transformer", "pickle_key",
           "pickle_value", "unpickle_value"]

#: valid ``on_stale=`` policies (see CacheTransformer)
ON_STALE_POLICIES = ("error", "recompute", "readonly")


class CacheMissError(KeyError):
    """Raised on a miss when no wrapped transformer was provided."""


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    verified: int = 0
    #: how many of ``hits`` were served from the prefetch staging map
    #: rather than an inline backend read.  Counted *here*, by the node
    #: that consumed the entry — the I/O pool never touches stats — so
    #: hit rates stay honest under overlap: ``hits``/``misses`` are
    #: identical with prefetch on or off, and ``prefetched`` only says
    #: how many round trips left the critical path.
    prefetched: int = 0
    #: wall seconds spent inside the *wrapped transformer* on the miss
    #: path, and the input queries those computes covered.  This is the
    #: raw recompute cost — cache lookups/inserts excluded — which is
    #: what the planner's cost model (core/cost.py) needs: the wrapper
    #: call time a run records for a cached node is dominated by store
    #: round trips, so folding it would make every cached node look
    #: exactly as expensive as its cache and the cache-place pass could
    #: never learn that recompute is cheaper.
    compute_s: float = 0.0
    compute_queries: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def add(self, *, hits: int = 0, misses: int = 0, inserts: int = 0,
            verified: int = 0, prefetched: int = 0, compute_s: float = 0.0,
            compute_queries: int = 0) -> None:
        """Atomic increment — cache families are shared by the
        concurrent plan executor, so counter updates must not race."""
        with self._lock:
            self.hits += hits
            self.misses += misses
            self.inserts += inserts
            self.verified += verified
            self.prefetched += prefetched
            self.compute_s += compute_s
            self.compute_queries += compute_queries

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def __str__(self):
        return (f"hits={self.hits} misses={self.misses} "
                f"hit_rate={self.hit_rate:.3f}")


def n_frame_queries(frame: Any) -> int:
    """How many input *queries* a frame covers: unique qids when the
    column exists, else rows.  Per-query is the planner cost model's
    unit, so the families normalize ``CacheStats.compute_s`` by this."""
    try:
        if "qid" in frame:
            return len(set(frame["qid"].tolist()))
    except Exception:
        pass
    return len(frame)


def resolve_transformer(t: Any) -> Optional[Transformer]:
    """Resolve Lazy wrappers (see lazy.py) to a concrete transformer."""
    if t is None:
        return None
    if hasattr(t, "_resolve_lazy"):
        return t._resolve_lazy()
    return t


def pickle_key(vals: Tuple) -> bytes:
    return pickle.dumps(vals, protocol=pickle.HIGHEST_PROTOCOL)


def pickle_value(vals: Tuple) -> bytes:
    return pickle.dumps(vals, protocol=pickle.HIGHEST_PROTOCOL)


def unpickle_value(b: bytes) -> Tuple:
    return pickle.loads(b)


class CacheTransformer(Transformer):
    """Base for cache components that wrap a transformer.

    Provenance (beyond-paper; see ``caching/provenance.py``): pass
    ``fingerprint=`` (usually ``transformer.fingerprint()`` or a
    planner node fingerprint) and the cache checks it against the
    directory's ``manifest.json`` on open.  On mismatch the
    ``on_stale`` policy applies:

    * ``"error"`` (default) — raise :class:`StaleCacheError`;
    * ``"recompute"`` — discard the stale entries (the directory is
      wiped) and recompute from the wrapped transformer;
    * ``"readonly"`` — serve the existing entries as-is but never
      write (misses are computed yet not inserted).

    Without a ``fingerprint`` the manifest is still written/maintained
    (family, backend, schema, timestamps, entry counts) so the
    directory stays inspectable by the ``repro cache`` CLI.
    """

    def __init__(self, path: Optional[str], transformer: Any = None,
                 *, verify_fraction: float = 0.0,
                 fingerprint: Optional[str] = None,
                 on_stale: str = "error",
                 budget: Any = None,
                 async_writes: Optional[bool] = None):
        if on_stale not in ON_STALE_POLICIES:
            raise ValueError(f"on_stale must be one of {ON_STALE_POLICIES}, "
                             f"got {on_stale!r}")
        self._transformer_raw = transformer
        # write-behind is *opt-in* (the plan compiler passes True for
        # planner-inserted caches): deferring puts keeps compute-once
        # exact within a process but relaxes it across processes
        # sharing a directory, and a bare family must preserve the
        # strict cross-process contract its docstring promises
        self._async_writes = bool(async_writes) if async_writes is not None \
            else False
        self._staging = None                  # StagingMap, see dataplane.py
        self._writer = None                   # WriteBehindWriter or None
        self.codec: Optional[str] = None      # negotiated via the manifest
        self._budget = CacheBudget.coerce(budget)
        #: in-memory {backend key: [last_used_ts, hits]} deltas, merged
        #: into the directory's access.json sidecar by _flush_access
        self._access_pending: Dict[bytes, List[float]] = {}
        self._access_lock = threading.Lock()
        self._temporary = path is None
        if path is None:
            path = tempfile.mkdtemp(prefix="repro-cache-")
        self.path = path
        os.makedirs(self.path, exist_ok=True)
        self.stats = CacheStats()
        #: per-call hit/miss counts, thread-local (see call_with_counts)
        self._call_tls = threading.local()
        self.verify_fraction = float(verify_fraction)
        self.provenance_fingerprint = fingerprint
        self.on_stale = on_stale
        #: set by ``_open_manifest`` under the "readonly" stale policy
        self.readonly = False
        self._manifest: Optional[CacheManifest] = None
        self._closed = False

    # -- provenance ----------------------------------------------------------
    @property
    def manifest(self) -> Optional[CacheManifest]:
        return self._manifest

    def _open_manifest(self, *, backend: Optional[str],
                       key_columns: Sequence[str] = (),
                       value_columns: Sequence[str] = (),
                       codec: Optional[str] = None) -> None:
        """Validate (or create) this directory's manifest.

        Families call this *before* opening their store, so that the
        ``recompute`` policy can wipe a stale directory first.

        ``codec`` is the serialization scheme this family would use for
        a *fresh* directory (see ``caching/codecs.py``); an existing
        directory keeps whatever its manifest records — ``None`` means
        the legacy pickle scheme, so pre-codec dirs stay warm — and a
        manifest naming a codec this build does not know trips the
        normal staleness machinery (the entries are unreadable to us).
        """
        try:
            existing = CacheManifest.load(self.path)
        except ManifestError:
            if self.on_stale != "recompute":
                raise
            self._wipe_dir()
            existing = None
        if existing is not None:
            reasons = self._stale_reasons(existing, backend,
                                          key_columns, value_columns,
                                          codec)
            if reasons:
                if self.on_stale == "error":
                    raise StaleCacheError(
                        f"{type(self).__name__} at {self.path!r} is stale: "
                        f"{'; '.join(reasons)}.  Pass on_stale='recompute' "
                        f"to discard the cached entries, or "
                        f"on_stale='readonly' to use them anyway without "
                        f"writing")
                if self.on_stale == "recompute":
                    self._wipe_dir()
                    existing = None
                else:                              # readonly
                    self.readonly = True
        if existing is None:
            self._manifest = CacheManifest.new(
                family=type(self).__name__, backend=backend,
                fingerprint=self.provenance_fingerprint,
                transformer=self._transformer_label(),
                key_columns=list(key_columns),
                value_columns=list(value_columns),
                codec=codec)
            self._manifest.save(self.path)
        else:
            # adopt (incl. pre-provenance dirs); record our fingerprint
            # the first time one is known for this directory
            if existing.fingerprint is None \
                    and self.provenance_fingerprint is not None \
                    and not self.readonly:
                existing.fingerprint = self.provenance_fingerprint
                existing.save(self.path)
            self._manifest = existing
        # record a constructor-passed budget so offline enforcement
        # (`repro cache evict`, close()) sees it without this process
        if not self._budget.empty() and not self.readonly:
            if self._budget.record_in(self._manifest) \
                    and not self._temporary:
                self._manifest.save(self.path)
        #: the scheme every subsequent read/write of this store uses
        self.codec = getattr(self._manifest, "codec", None)

    def _stale_reasons(self, m: CacheManifest, backend: Optional[str],
                       key_columns: Sequence[str],
                       value_columns: Sequence[str],
                       codec: Optional[str] = None) -> list:
        reasons = []
        ours = self.provenance_fingerprint
        if ours is not None and m.fingerprint is not None \
                and m.fingerprint != ours:
            reasons.append(f"recorded fingerprint {m.fingerprint} != "
                           f"expected {ours}")
        # combinator selectors (tiered:/mmap:) are pure accelerators
        # over the same store files, so compatibility is decided by the
        # *storage identity* — a dir warmed with "sqlite" opens warm
        # under "mmap:sqlite" (the fleet's read-mostly tier), while
        # "dbm" vs "sqlite" still trips staleness
        from .backends import storage_identity
        if backend is not None and m.backend is not None \
                and storage_identity(m.backend) != storage_identity(backend):
            reasons.append(f"recorded backend {m.backend!r} != "
                           f"requested {backend!r}")
        if key_columns and m.key_columns \
                and list(key_columns) != list(m.key_columns):
            reasons.append(f"recorded key columns {m.key_columns} != "
                           f"requested {list(key_columns)}")
        if value_columns and m.value_columns \
                and list(value_columns) != list(m.value_columns):
            reasons.append(f"recorded value columns {m.value_columns} != "
                           f"requested {list(value_columns)}")
        # a recorded codec we don't implement means the stored bytes are
        # unreadable to this build; a recorded codec of None is always
        # fine (the legacy pickle scheme every build speaks)
        recorded_codec = getattr(m, "codec", None)
        if recorded_codec is not None and recorded_codec != codec:
            reasons.append(f"recorded codec {recorded_codec!r} is not "
                           f"supported here (this build speaks "
                           f"{codec!r} and the legacy pickle scheme)")
        return reasons

    def _transformer_label(self) -> Optional[str]:
        t = self._transformer_raw
        if t is None:
            return None
        try:
            return repr(t)
        except Exception:
            return type(t).__name__

    def _wipe_dir(self) -> None:
        """Discard every entry (and the manifest) under ``self.path``."""
        for name in os.listdir(self.path):
            p = os.path.join(self.path, name)
            if os.path.isdir(p) and not os.path.islink(p):
                shutil.rmtree(p, ignore_errors=True)
            else:
                try:
                    os.unlink(p)
                except OSError:
                    pass

    def _update_manifest(self) -> None:
        """Refresh last-use timestamp and entry count on disk.  A
        manifest refresh is a write-behind flush point: the recorded
        entry count must describe the *durable* store."""
        if self._manifest is None or self.readonly or self._temporary:
            return
        self._drain_writes()
        try:
            n = len(self)                    # families define __len__
        except Exception:
            n = self._manifest.entry_count
        self._manifest.entry_count = int(n)
        self._manifest.last_used_at = time.time()
        self._manifest.save(self.path)

    # -- cache economics: budgets, access stats, eviction --------------------
    @property
    def budget(self) -> CacheBudget:
        """Effective budget: the constructor's, else the manifest's."""
        if not self._budget.empty():
            return self._budget
        return CacheBudget.from_manifest(self._manifest)

    def _note_access(self, keys: Sequence[bytes]) -> None:
        """Record that ``keys`` were read/written now — feeds the LRU
        eviction pass via the access.json sidecar (flushed on close /
        evict, not per call)."""
        if self._temporary or not keys:
            return
        now = time.time()
        with self._access_lock:
            pend = self._access_pending
            for k in keys:
                cur = pend.get(k)
                if cur is None:
                    pend[k] = [now, 1]
                else:
                    cur[0] = now
                    cur[1] += 1

    def _flush_access(self) -> None:
        with self._access_lock:
            pending, self._access_pending = self._access_pending, {}
        if not pending or self._temporary or self.readonly:
            return
        stats = AccessStats.load(self.path)
        stats.merge_pending(pending)
        stats.save(self.path)

    def evict(self, budget: Any = None, *,
              now: Optional[float] = None) -> Dict[str, Any]:
        """Bring the store within ``budget`` (default: the recorded /
        constructor budget): TTL-expired entries first, then LRU.
        Returns the eviction report (see ``economics.evict_entries``).

        The manifest's entry count is refreshed *immediately* — not
        only on ``close()`` — so ``repro cache verify`` stays truthful
        against a still-open backend."""
        eff = CacheBudget.coerce(budget)
        if eff.empty():
            eff = self.budget
        if eff.empty():
            return {"skipped": "no budget (none passed, none recorded)"}
        if self.readonly:
            return {"skipped": "readonly cache (stale-readonly policy)"}
        backend = getattr(self, "_backend", None)
        if backend is None:
            raise NotImplementedError(
                f"{type(self).__name__} does not support budget eviction")
        self._drain_writes()                 # evict over the durable store
        self._flush_access()
        created = self._manifest.created_at \
            if self._manifest is not None else 0.0
        report = evict_entries(backend, self.path, eff,
                               created_at=created, now=now)
        self._update_manifest()
        return report

    # -- per-call accounting -------------------------------------------------
    # ``stats`` is cumulative and shared: when several threads, shards
    # or services use one cache, deriving a caller's hits/misses from
    # counter *deltas* misattributes concurrent calls.  Families instead
    # note each call's own counts into thread-local storage; callers
    # that need per-call numbers (the serving layer, the streaming
    # executor) read them back with ``pop_call_counts`` /
    # ``call_with_counts`` — race-free because a transform call runs
    # wholly on the calling thread.

    def _note_call(self, hits: int, misses: int) -> None:
        prev = getattr(self._call_tls, "counts", (0, 0))
        self._call_tls.counts = (prev[0] + int(hits), prev[1] + int(misses))

    def pop_call_counts(self) -> Tuple[int, int]:
        """(hits, misses) accumulated by this thread's calls since the
        last pop; resets to (0, 0)."""
        counts = getattr(self._call_tls, "counts", (0, 0))
        self._call_tls.counts = (0, 0)
        return counts

    def call_with_counts(self, inp: Any) -> Tuple[Any, int, int]:
        """Run the cache and return ``(output, hits, misses)`` for THIS
        call only, regardless of concurrent users of the same cache."""
        self.pop_call_counts()
        out = self(inp)
        hits, misses = self.pop_call_counts()
        return out, hits, misses

    # -- asynchronous data plane (see caching/dataplane.py) ------------------
    # Families that own a backend call ``_init_dataplane()`` after
    # opening it; everything here degrades to the synchronous path when
    # they don't (``_staging``/``_writer`` stay None).

    def _init_dataplane(self) -> None:
        from .dataplane import StagingMap, WriteBehindWriter, \
            write_behind_default
        backend = getattr(self, "_backend", None)
        if backend is None:                   # pragma: no cover - guard
            return
        self._staging = StagingMap()
        if self._async_writes and write_behind_default() \
                and not self.readonly:
            # the writer drains under the backend's re-entrant lock
            # (taken before its own flush lock) so background drains,
            # lock-holding barriers and flush points order consistently
            self._writer = WriteBehindWriter(backend.put_many,
                                             lock=backend.lock)

    @property
    def prefetchable(self) -> bool:
        """Whether prefetching this cache's backend can pay: the
        backend must exist and not already be a memory-speed read path
        (backends declare via ``prefetchable``; the in-memory LRU and
        the mmap snapshot tier opt out — staging a dict/page-cache read
        only adds bookkeeping)."""
        backend = getattr(self, "_backend", None)
        return backend is not None and self._staging is not None \
            and bool(getattr(backend, "prefetchable", True))

    def prefetch_columns(self) -> Optional[Tuple[str, ...]]:
        """The input columns that fully determine this cache's keys, or
        ``None`` when the family does not support key prefetch.
        Executors use this to decide *when* a node's keys are known:
        at submit time if the source frame carries the columns, else
        the moment the upstream node completes."""
        return None

    def prefetch_keys(self, frame: Any) -> List[bytes]:
        """Backend keys for ``frame`` — overridden by families that
        support prefetch."""
        raise NotImplementedError

    def prefetch_async(self, frame: Any):
        """Issue ``get_many`` for ``frame``'s keys on the I/O pool;
        results land in the staging map for the next ``transform`` /
        ``serve_from_store`` over the same keys.  Returns the pool
        future (``None`` when there is nothing to fetch).  No stats,
        no access notes — accounting happens at consumption.
        """
        if not self.prefetchable or self._closed:
            return None
        try:
            keys = self.prefetch_keys(frame)
        except (NotImplementedError, KeyError):
            return None
        todo = self._staging.covered(keys)
        if not todo:
            return None
        backend = self._backend
        staging = self._staging
        writer = self._writer

        def fetch():
            want = todo
            if writer is not None:
                pending = writer.overlay_many(want)
                if pending:
                    staging.deposit(pending.items())
                    want = [k for k in want if k not in pending]
                    if not want:
                        return
            staging.deposit(zip(want, backend.get_many(want)))

        from .dataplane import io_pool
        fut = io_pool().submit(fetch)
        self._staging.track(fut, todo)
        return fut

    def discard_staging(self) -> None:
        """Drop unconsumed staged entries (run teardown)."""
        if self._staging is not None:
            self._staging.discard()

    def _lookup_many(self, keys: Sequence[bytes]
                     ) -> Tuple[List[Optional[bytes]], int]:
        """Read ``keys`` through the data plane: the write-behind
        overlay first (pending entries must be visible), then the
        staging map, then the backend for whatever remains.  Returns
        ``(blobs, n_prefetched)`` — the second number is how many
        non-None blobs came out of the staging map, for
        ``CacheStats.prefetched`` attribution by the caller."""
        n = len(keys)
        out: List[Optional[bytes]] = [None] * n
        remaining = list(range(n))
        if self._writer is not None:
            pending = self._writer.overlay_many(keys)
            if pending:
                remaining = []
                for i, k in enumerate(keys):
                    v = pending.get(k)
                    if v is not None:
                        out[i] = v
                    else:
                        remaining.append(i)
        prefetched = 0
        if remaining and self._staging is not None:
            # pop_many waits on any in-flight prefetch covering these
            # keys before looking — the consumer must not race past a
            # fetch that is about to land and hit the backend twice
            staged = self._staging.pop_many([keys[i] for i in remaining])
            if staged:
                left = []
                for i in remaining:
                    k = keys[i]
                    if k in staged:
                        out[i] = staged[k]   # may be a staged miss (None)
                        if staged[k] is not None:
                            prefetched += 1
                    else:
                        left.append(i)
                remaining = left
        if remaining:
            fetched = self._backend.get_many([keys[i] for i in remaining])
            for i, v in zip(remaining, fetched):
                out[i] = v
        return out, prefetched

    def _recheck_many(self, keys: Sequence[bytes]
                      ) -> List[Optional[bytes]]:
        """The locked miss-path recheck: the write-behind overlay (a
        racing thread's compute may still be pending) then the backend.
        The staging map is deliberately *not* consulted — its deposits
        predate the lock and were already offered to ``_lookup_many``."""
        if self._writer is None:
            return self._backend.get_many(keys)
        pending = self._writer.overlay_many(keys)
        out: List[Optional[bytes]] = [pending.get(k) for k in keys]
        remaining = [i for i, v in enumerate(out) if v is None]
        if remaining:
            fetched = self._backend.get_many([keys[i] for i in remaining])
            for i, v in zip(remaining, fetched):
                out[i] = v
        return out

    def _store_many(self, items: Sequence[Tuple[bytes, bytes]]) -> None:
        """Miss-path put: enqueue on the write-behind writer when one
        is live, else write through synchronously.  Called inside the
        compute-once critical section either way — the *enqueue* under
        the lock is the sentinel that keeps in-process compute-once
        exact (the recheck sees the overlay), while durability is
        deferred to :meth:`_write_barrier` / the flush points."""
        if self._writer is not None:
            self._writer.put(list(items))
        else:
            self._backend.put_many(items)

    def _write_barrier(self) -> None:
        """Durability barrier before the backend's cross-process lock is
        released (see ``WriteBehindWriter.barrier``): other processes'
        locked rechecks cannot see the in-memory overlay, so the puts
        must be on disk by the time they can acquire the lock — this is
        what keeps compute-exactly-once exact across processes under
        write-behind."""
        if self._writer is not None:
            self._writer.barrier()

    def _drain_writes(self) -> None:
        """Synchronously flush pending write-behind state (flush points:
        ``close()``, ``drain()``, manifest refresh, eviction, store
        enumeration)."""
        if self._writer is not None:
            self._writer.flush()

    def drain(self) -> None:
        """Make every accepted write durable and the sidecars current —
        the executor/service quiescence hook (graceful fleet drain)."""
        self._drain_writes()
        self._flush_access()

    # -- wrapped transformer -------------------------------------------------
    @property
    def transformer(self) -> Optional[Transformer]:
        t = resolve_transformer(self._transformer_raw)
        return t

    def _require_transformer(self, n_misses: int) -> Transformer:
        t = self.transformer
        if t is None:
            raise CacheMissError(
                f"{type(self).__name__} at {self.path!r}: {n_misses} cache "
                f"misses but no transformer was provided")
        return t

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        if self._writer is not None:
            try:
                self._writer.close()     # final write-behind flush
            except Exception:
                pass                     # entries recompute; never corrupt
        if not self.budget.empty() and not self.readonly:
            try:
                self.evict()             # automatic budget enforcement
            except Exception:
                pass
        try:
            self._flush_access()
            self._update_manifest()
        except Exception:
            pass                         # manifest refresh is best-effort
        self.discard_staging()
        self._close_backend()
        if self._temporary:
            shutil.rmtree(self.path, ignore_errors=True)
        self._closed = True

    def _close_backend(self) -> None:  # pragma: no cover - overridden
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def __del__(self):
        # Best-effort temp cleanup.  During interpreter shutdown module
        # globals (os/shutil/tempfile) may already be torn down, in which
        # case close() can raise things `except Exception` does not stop
        # (the attribute machinery itself may be gone) — so bail out
        # early when finalizing, and never propagate from a finalizer.
        try:
            if getattr(self, "_closed", True):
                return
            if sys is None or sys.is_finalizing() or shutil is None:
                return
            self.close()
        except BaseException:
            pass

    # -- transparency: caches delegate the wrapped transformer's
    #    scheduling metadata — a hand-wrapped cache must not launder a
    #    shardable=False declaration into the class default.
    @property
    def shardable(self) -> bool:
        t = self._transformer_raw
        if t is not None and hasattr(t, "_resolve_lazy") \
                and not getattr(t, "constructed", True):
            # don't force a Lazy to construct just to read metadata;
            # an unconstructed Lazy reports its own declaration
            return bool(getattr(t, "shardable", True))
        return bool(getattr(self.transformer, "shardable", True))

    # -- equality: caches are transparent, so they inherit the wrapped
    #    transformer's signature for LCP purposes *plus* a cache marker.
    def signature(self):
        inner = self.transformer
        return (type(self).__name__,
                inner.signature() if inner is not None else None,
                os.path.abspath(self.path))
