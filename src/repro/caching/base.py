"""Shared machinery for explicit caches (paper §4).

Common behaviours across all cache families:

* **temporary mode** — omit the path and a temp directory is created and
  deleted when the cache is closed / used as a context manager (§4.5);
* **no-transformer mode** — a cache constructed without a wrapped
  transformer raises ``CacheMissError`` on miss (§4.5);
* **Lazy transformers** — resolved only when first needed (§4.5);
* **determinism verification** — beyond-paper: ``verify_fraction>0``
  re-executes a sample of *hit* rows through the wrapped transformer and
  asserts the cached values match (the paper §6 notes determinism is
  assumed; on TPU/XLA SPMD it is checkable, so we check);
* **hit/miss accounting** — exposed as ``stats``.
"""
from __future__ import annotations

import os
import pickle
import shutil
import sys
import tempfile
import threading
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

from ..core.pipeline import Transformer

__all__ = ["CacheMissError", "CacheStats", "CacheTransformer",
           "resolve_transformer", "pickle_key", "pickle_value",
           "unpickle_value"]


class CacheMissError(KeyError):
    """Raised on a miss when no wrapped transformer was provided."""


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    verified: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def add(self, *, hits: int = 0, misses: int = 0, inserts: int = 0,
            verified: int = 0) -> None:
        """Atomic increment — cache families are shared by the
        concurrent plan executor, so counter updates must not race."""
        with self._lock:
            self.hits += hits
            self.misses += misses
            self.inserts += inserts
            self.verified += verified

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def __str__(self):
        return (f"hits={self.hits} misses={self.misses} "
                f"hit_rate={self.hit_rate:.3f}")


def resolve_transformer(t: Any) -> Optional[Transformer]:
    """Resolve Lazy wrappers (see lazy.py) to a concrete transformer."""
    if t is None:
        return None
    if hasattr(t, "_resolve_lazy"):
        return t._resolve_lazy()
    return t


def pickle_key(vals: Tuple) -> bytes:
    return pickle.dumps(vals, protocol=pickle.HIGHEST_PROTOCOL)


def pickle_value(vals: Tuple) -> bytes:
    return pickle.dumps(vals, protocol=pickle.HIGHEST_PROTOCOL)


def unpickle_value(b: bytes) -> Tuple:
    return pickle.loads(b)


class CacheTransformer(Transformer):
    """Base for cache components that wrap a transformer."""

    def __init__(self, path: Optional[str], transformer: Any = None,
                 *, verify_fraction: float = 0.0):
        self._transformer_raw = transformer
        self._temporary = path is None
        if path is None:
            path = tempfile.mkdtemp(prefix="repro-cache-")
        self.path = path
        os.makedirs(self.path, exist_ok=True)
        self.stats = CacheStats()
        self.verify_fraction = float(verify_fraction)
        self._closed = False

    # -- wrapped transformer -------------------------------------------------
    @property
    def transformer(self) -> Optional[Transformer]:
        t = resolve_transformer(self._transformer_raw)
        return t

    def _require_transformer(self, n_misses: int) -> Transformer:
        t = self.transformer
        if t is None:
            raise CacheMissError(
                f"{type(self).__name__} at {self.path!r}: {n_misses} cache "
                f"misses but no transformer was provided")
        return t

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._close_backend()
        if self._temporary:
            shutil.rmtree(self.path, ignore_errors=True)
        self._closed = True

    def _close_backend(self) -> None:  # pragma: no cover - overridden
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def __del__(self):
        # Best-effort temp cleanup.  During interpreter shutdown module
        # globals (os/shutil/tempfile) may already be torn down, in which
        # case close() can raise things `except Exception` does not stop
        # (the attribute machinery itself may be gone) — so bail out
        # early when finalizing, and never propagate from a finalizer.
        try:
            if getattr(self, "_closed", True):
                return
            if sys is None or sys.is_finalizing() or shutil is None:
                return
            self.close()
        except BaseException:
            pass

    # -- transparency: caches delegate the wrapped transformer's
    #    scheduling metadata — a hand-wrapped cache must not launder a
    #    shardable=False declaration into the class default.
    @property
    def shardable(self) -> bool:
        t = self._transformer_raw
        if t is not None and hasattr(t, "_resolve_lazy") \
                and not getattr(t, "constructed", True):
            # don't force a Lazy to construct just to read metadata;
            # an unconstructed Lazy reports its own declaration
            return bool(getattr(t, "shardable", True))
        return bool(getattr(self.transformer, "shardable", True))

    # -- equality: caches are transparent, so they inherit the wrapped
    #    transformer's signature for LCP purposes *plus* a cache marker.
    def signature(self):
        inner = self.transformer
        return (type(self).__name__,
                inner.signature() if inner is not None else None,
                os.path.abspath(self.path))
