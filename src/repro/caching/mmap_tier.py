"""MmapTier — a packed, read-only, lock-free snapshot over a disk store.

The fleet-scaling piece of the paper's precomputation story: with N
worker processes serving the *same* warm cache directory
(``serve/fleet.py``), every hit on the ``dbm`` backend takes a shared
``flock`` and re-opens the database, and ``sqlite`` hits pay an SQL
round-trip under a connection lock.  For read-mostly traffic — which is
exactly what a warmed cache serves — none of that coordination buys
anything: the entries are immutable (deterministic transformers) and
already on disk.

``MmapTier`` therefore snapshots the disk store into a packed
append-only file (``mmap-snapshot.pack``, written with an atomic
rename) and ``mmap``s it read-only.  Hits resolve against the mapping
with **no file lock, no db open, no syscall beyond the page fault** —
the OS page cache is shared across every worker process mapping the
same file, so N workers serve hits from one copy of the data:

* **reads** probe the snapshot first and fall through to the disk
  backend on a snapshot miss, so the tier is observationally identical
  to the bare disk store (property-tested next to ``TieredBackend``);
* **writes still go through the locked compute-once path** — ``put``
  lands in the disk backend only, and ``lock()`` delegates to the disk
  tier's inter-process ``FileLock``, so concurrent misses across the
  fleet compute exactly once, same as every other backend;
* **refresh on a miss-rate trigger** — keys written after the snapshot
  was taken are tracked (and served from disk); once ``refresh_after``
  fall-throughs have *found* entries the snapshot lacks, the tier
  repacks, so a worker that keeps missing into a growing store
  converges back to lock-free hits.

Consistency contract: the snapshot may lag the disk store, never
contradict it.  A key written or deleted *through this tier* is
shadowed (always resolved against disk) until the next refresh; a key
written by a *foreign process* is found via the disk fall-through (a
snapshot miss), counted toward the refresh trigger.  Since cache
entries are append-only — deterministic transformers never rewrite a
key with a different value — a stale snapshot can only be missing
entries, not wrong about them.

Selected as ``"mmap"`` (sqlite disk tier) or ``"mmap:<disk>"`` through
the normal registry plumbing (``caching.select_backend``); the disk
tier must be able to enumerate its entries, so ``mmap:pickle`` is
rejected at selector-validation time.
"""
from __future__ import annotations

import mmap
import os
import struct
import threading
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .backends import (BACKENDS, CacheBackend, atomic_write_bytes,
                       split_mmap)

__all__ = ["MmapTier", "DEFAULT_REFRESH_AFTER", "PACK_FILE"]

#: snapshot fall-throughs that *found* a disk entry before a repack
DEFAULT_REFRESH_AFTER = 64

#: the packed snapshot's file name inside the cache directory
PACK_FILE = "mmap-snapshot.pack"

_MAGIC = b"RMMPACK1"
_HEADER = struct.Struct("<8sQ")          # magic, entry count
_ENTRY = struct.Struct("<II")            # key length, value length


def _pack_entries(entries: Iterable[Tuple[bytes, bytes]], path: str) -> int:
    """Write a packed snapshot atomically; returns the entry count."""
    chunks: List[bytes] = []
    n = 0
    for k, v in entries:
        chunks.append(_ENTRY.pack(len(k), len(v)))
        chunks.append(bytes(k))
        chunks.append(bytes(v))
        n += 1
    atomic_write_bytes(path, _HEADER.pack(_MAGIC, n) + b"".join(chunks))
    return n


class _Snapshot:
    """One immutable mapped view of a pack file plus its key index.

    Never mutated after construction; the tier swaps whole snapshots
    atomically, and readers keep a local reference — so a concurrent
    refresh can never invalidate a lookup in flight.  The mapping is
    closed by GC once the last reader drops its reference.
    """

    __slots__ = ("_mm", "_index", "path")

    def __init__(self, path: str):
        self.path = path
        self._index: Dict[bytes, Tuple[int, int]] = {}
        with open(path, "rb") as f:
            size = os.fstat(f.fileno()).st_size
            if size < _HEADER.size:
                raise ValueError(f"truncated snapshot pack {path!r}")
            self._mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
        magic, count = _HEADER.unpack_from(self._mm, 0)
        if magic != _MAGIC:
            raise ValueError(f"bad snapshot magic in {path!r}")
        off = _HEADER.size
        for _ in range(count):
            klen, vlen = _ENTRY.unpack_from(self._mm, off)
            off += _ENTRY.size
            key = bytes(self._mm[off:off + klen])
            off += klen
            self._index[key] = (off, vlen)
            off += vlen

    def get(self, key: bytes) -> Optional[bytes]:
        e = self._index.get(key)
        if e is None:
            return None
        off, vlen = e
        return bytes(self._mm[off:off + vlen])

    def __len__(self) -> int:
        return len(self._index)


class MmapTier(CacheBackend):
    """Read-mostly accelerator: lock-free mmap'd snapshot reads over a
    persistent disk backend; writes and compute-once locking delegate
    to the disk tier."""

    persistent = True
    #: snapshot hits are lock-free page-cache reads — prefetching them
    #: onto the I/O pool would only copy memory-speed lookups into a
    #: staging map, so the data plane skips this tier entirely
    prefetchable = False

    def __init__(self, path: Optional[str], *,
                 disk: str = "sqlite",
                 refresh_after: int = DEFAULT_REFRESH_AFTER):
        if isinstance(disk, CacheBackend):
            self.disk: CacheBackend = disk
        else:
            resolved = split_mmap(f"mmap:{disk}")
            if path is None:
                raise ValueError(
                    "MmapTier requires a cache directory (its snapshot "
                    "pack lives next to the disk store)")
            self.disk = BACKENDS[resolved](path)
        # no super().__init__: the disk tier already owns the directory
        # and its FileLock (same reasoning as TieredBackend — a second
        # FileLock on the sidecar would deadlock the nested
        # lock()->put_many path)
        self.path = self.disk.path
        self.name = f"mmap:{self.disk.name}"
        self.refresh_after = max(1, int(refresh_after))
        self.refreshes = 0
        self._pack_path = os.path.join(self.path, PACK_FILE)
        self._mutate_lock = threading.Lock()
        #: keys written/deleted through this tier since the snapshot —
        #: always resolved against disk until the next refresh
        self._shadow: Set[bytes] = set()
        self._found_on_disk = 0
        self._snap: Optional[_Snapshot] = None
        self._closed = False
        self.refresh()

    # -- snapshot lifecycle --------------------------------------------------
    def refresh(self) -> int:
        """Repack the snapshot from the disk store and swap it in;
        returns the new snapshot's entry count.  Enumeration happens
        through the disk backend's own read path (shared flock / WAL
        read), so a concurrent writer is excluded exactly as it would
        be for any bulk read."""
        with self._mutate_lock:
            _pack_entries(self.disk.items(), self._pack_path)
            snap = _Snapshot(self._pack_path)
            # single reference swap: in-flight readers keep the old
            # snapshot alive via their local reference
            self._snap = snap
            self._shadow = set()
            self._found_on_disk = 0
            self.refreshes += 1
            return len(snap)

    def _note_found_on_disk(self) -> None:
        self._found_on_disk += 1
        if self._found_on_disk >= self.refresh_after:
            self.refresh()

    # -- reads (snapshot first, disk fall-through) ---------------------------
    def get(self, key: bytes) -> Optional[bytes]:
        snap, shadow = self._snap, self._shadow
        if key not in shadow:
            v = snap.get(key)
            if v is not None:
                return v
        v = self.disk.get(key)
        if v is not None and key not in shadow:
            # the snapshot lacks an entry the store has: count toward
            # the refresh trigger
            self._note_found_on_disk()
        return v

    def get_many(self, keys: Sequence[bytes]) -> List[Optional[bytes]]:
        snap, shadow = self._snap, self._shadow
        out: List[Optional[bytes]] = [None] * len(keys)
        miss: List[int] = []
        for i, k in enumerate(keys):
            v = snap.get(k) if k not in shadow else None
            if v is None:
                miss.append(i)
            else:
                out[i] = v
        if miss:
            fetched = self.disk.get_many([keys[i] for i in miss])
            stale = 0
            for i, v in zip(miss, fetched):
                out[i] = v
                if v is not None and keys[i] not in shadow:
                    stale += 1
            for _ in range(stale):
                self._note_found_on_disk()
        return out

    # -- writes (disk only: the locked compute-once path) --------------------
    def put_many(self, items: Iterable[Tuple[bytes, bytes]]) -> None:
        items = list(items)
        self.disk.put_many(items)
        with self._mutate_lock:
            self._shadow.update(k for k, _ in items)

    def delete_many(self, keys: Sequence[bytes]) -> int:
        n = self.disk.delete_many(keys)
        with self._mutate_lock:
            self._shadow.update(keys)
        return n

    # -- parity views: the disk tier is the source of truth -----------------
    def __len__(self) -> int:
        return len(self.disk)

    def items(self) -> List[Tuple[bytes, bytes]]:
        return self.disk.items()

    def entry_stats(self) -> List[Tuple[bytes, int]]:
        return self.disk.entry_stats()

    def stat_entries(self, keys: Sequence[bytes]) -> List[Optional[int]]:
        return self.disk.stat_entries(keys)

    # -- compute-once: delegate the cross-process exclusive section ---------
    @contextmanager
    def lock(self):
        with self.disk.lock():
            yield self

    @classmethod
    def store_exists(cls, path: str) -> bool:   # pragma: no cover - the
        # CLI resolves mmap selectors through backend_store_exists,
        # which dispatches on the *disk* tier's class
        return False

    def close(self) -> None:
        if self._closed:
            return
        self.disk.close()
        self._snap = None                # GC unmaps once readers drop it
        self._closed = True
