from .service import ScoringService, ServiceStats

__all__ = ["ScoringService", "ServiceStats"]
