# Online serving: one config (ServeConfig), one factory (build_service),
# one process (PipelineService) or many (FleetService) — see
# docs/serving.md.  ScoringService still imports for one more release
# but is deprecated and intentionally absent from __all__.
from .config import ServeConfig, build_service, drive_closed_loop
from .fleet import FleetService
from .registry import (SERVE_PIPELINES, ServeScenario, build_scenario,
                       run_closed_loop, warming_frame)
from .service import PipelineService, ServiceStats
from .service import ScoringService  # noqa: F401 - deprecated compat import

__all__ = ["ServeConfig", "build_service", "drive_closed_loop",
           "PipelineService", "FleetService", "ServiceStats",
           "ServeScenario", "SERVE_PIPELINES", "build_scenario",
           "run_closed_loop", "warming_frame"]
