from .registry import (SERVE_PIPELINES, ServeScenario, build_scenario,
                       run_closed_loop)
from .service import PipelineService, ScoringService, ServiceStats

__all__ = ["PipelineService", "ScoringService", "ServiceStats",
           "ServeScenario", "SERVE_PIPELINES", "build_scenario",
           "run_closed_loop"]
