"""Batched scoring service with cache integration.

The serving-side composition the paper's §4.2 example builds
(``index.bm25() >> cached_scorer``), packaged as a long-lived service:

* requests (query, docno, text) accumulate into batches;
* the ScorerCache is consulted first — only misses reach the model;
* misses run through the BucketedRunner (bounded compile shapes) on the
  jitted/pjit scorer;
* per-request latency statistics expose the cache's effect (the Table-2
  mechanism, measured at the request level).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..caching.scorer import ScorerCache
from ..core.frame import ColFrame
from ..core.pipeline import Transformer

__all__ = ["ScoringService", "ServiceStats"]


@dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    latencies_ms: List[float] = field(default_factory=list)

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies_ms, p)) \
            if self.latencies_ms else 0.0

    def summary(self) -> Dict[str, float]:
        return {"requests": self.requests, "batches": self.batches,
                "hit_rate": self.cache_hits / max(1, self.cache_hits
                                                  + self.cache_misses),
                "p50_ms": self.percentile(50), "p99_ms": self.percentile(99)}


class ScoringService:
    """Synchronous micro-batching scorer front-end."""

    def __init__(self, scorer: Transformer,
                 cache_path: Optional[str] = None,
                 max_batch: int = 256, use_cache: bool = True):
        self.scorer = scorer
        self.cache = ScorerCache(cache_path, scorer) if use_cache else None
        self.max_batch = max_batch
        self.stats = ServiceStats()
        self._queue: List[Dict] = []

    def submit(self, qid: str, query: str, docno: str, text: str) -> None:
        self._queue.append({"qid": qid, "query": query, "docno": docno,
                            "text": text, "score": 0.0, "rank": 0})

    def flush(self) -> ColFrame:
        """Score everything queued; returns the scored frame."""
        if not self._queue:
            return ColFrame()
        outs = []
        while self._queue:
            chunk, self._queue = (self._queue[:self.max_batch],
                                  self._queue[self.max_batch:])
            frame = ColFrame.from_dicts(chunk)
            t0 = time.perf_counter()
            if self.cache is not None:
                before = (self.cache.stats.hits, self.cache.stats.misses)
                out = self.cache(frame)
                self.stats.cache_hits += self.cache.stats.hits - before[0]
                self.stats.cache_misses += \
                    self.cache.stats.misses - before[1]
            else:
                out = self.scorer(frame)
            dt_ms = (time.perf_counter() - t0) * 1000.0
            self.stats.batches += 1
            self.stats.requests += len(chunk)
            self.stats.latencies_ms.extend([dt_ms / len(chunk)] * len(chunk))
            outs.append(out)
        return ColFrame.concat(outs)

    def close(self):
        if self.cache is not None:
            self.cache.close()
