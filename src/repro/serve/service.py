"""Online serving on top of the plan compiler.

The paper's thesis is that pipelines should be *expressed* end-to-end
while caching and precomputation remove the redundant work.  This
module brings that to the online path: :class:`PipelineService` accepts
an **arbitrary** pipeline expression (``bm25 % 100 >> loader >> mono``),
compiles it ONCE through the full compiler stack — lowering
(``core/ir.py``), optimizer passes incl. top-k pushdown and cache-prune
against warm stores (``core/rewrite.py``) — and serves requests through
the incremental scheduler (``core.executor.StreamingExecutor``):

* concurrent client submissions coalesce into micro-batches (bounded
  queue; flush on ``max_batch`` or ``max_wait_ms``) that flow through
  DAG wavefronts, so N in-flight requests sharing a query hit the
  retriever once and the reranker in one jitted batch;
* planner-inserted caches (``cache_dir`` / ``cache_backend``) make
  repeat traffic cheap per-request — the paper's Table-2 mechanism,
  measured at the request level;
* provenance manifests (``caching/provenance.py``) are validated once,
  at service start (plan construction opens every cache and checks its
  manifest) — never per request;
* ``stats`` keeps per-request latency in a bounded reservoir (a
  long-lived service does not grow memory per request) and derives its
  hit/miss totals from *per-call* cache counts, not shared-counter
  deltas.

:class:`ScoringService` — the pre-compiler, single-scorer-stage service
— survives as a thin compatibility front-end over ``PipelineService``.
"""
from __future__ import annotations

import threading
import warnings
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Sequence, Union

from ..core.executor import Reservoir, StreamingExecutor
from ..core.frame import ColFrame
from ..core.pipeline import Transformer
from ..core.plan import ExecutionPlan, PlanStats

# ScoringService is deprecated and deliberately absent: it still
# imports (one more release) but warns on construction
__all__ = ["PipelineService", "ServiceStats"]


class ServiceStats:
    """Thread-safe request-level statistics.

    Latencies live in a bounded :class:`~repro.core.executor.Reservoir`
    (capacity ``reservoir_capacity``), so a long-lived service holds a
    constant amount of memory while p50/p99 stay stable estimates of
    the whole request stream.  Hit/miss totals are accumulated from
    per-call cache counts (``CacheTransformer.pop_call_counts``), which
    stay correct when several threads or services share one cache.
    """

    def __init__(self, reservoir_capacity: int = 4096):
        self._lock = threading.Lock()
        self.requests = 0
        self.batches = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.latencies = Reservoir(reservoir_capacity)

    # -- updates -------------------------------------------------------------
    def record_batch(self, *, n_requests: int,
                     latencies_ms: Sequence[float] = ()) -> None:
        with self._lock:
            self.requests += int(n_requests)
            self.batches += 1
        self.latencies.extend(latencies_ms)

    def add_cache_counts(self, hits: int, misses: int) -> None:
        with self._lock:
            self.cache_hits += int(hits)
            self.cache_misses += int(misses)

    # -- views ---------------------------------------------------------------
    @property
    def latencies_ms(self) -> List[float]:
        """Snapshot of the latency reservoir (compatibility view of the
        old unbounded list)."""
        return self.latencies.snapshot()

    @property
    def hit_rate(self) -> float:
        lookups = self.cache_hits + self.cache_misses
        return self.cache_hits / lookups if lookups else 0.0

    def percentile(self, p: float) -> float:
        return self.latencies.percentile(p)

    def summary(self) -> Dict[str, float]:
        return {"requests": self.requests, "batches": self.batches,
                "hit_rate": self.hit_rate,
                "p50_ms": self.percentile(50), "p99_ms": self.percentile(99)}


class PipelineService:
    """Serve an arbitrary pipeline expression, compiled once.

    Parameters
    ----------
    pipeline:
        Any operator-algebra expression (``core/pipeline.py``).
    cache_dir / cache_backend / on_stale / optimize:
        Forwarded to :class:`~repro.core.plan.ExecutionPlan` — the
        service compiles ``[pipeline]`` through the full stack at
        construction time.  Provenance manifests are therefore checked
        exactly once, at service start.  ``cache_backend="memory"``
        alone enables in-process memoization; a ``cache_dir`` persists
        caches across service restarts (warm starts).
    max_batch / max_wait_ms:
        Micro-batching knobs: a batch dispatches when ``max_batch``
        requests are pending or ``max_wait_ms`` after its first
        request, whichever first.  ``max_wait_ms=0`` disables the
        batching delay (each dispatch takes whatever is queued).
        Either knob accepts ``"auto"``: the value the compiled plan's
        ``autotune`` pass derived from the manifest's measured
        batch-occupancy / queue-depth history (falling back to the
        defaults when there is no evidence yet).  Each service run
        records its online stats back into the plan manifest on
        ``close()``, so an ``"auto"`` service self-tunes across
        restarts.
    max_workers:
        Thread-pool size of the streaming executor (DAG branches and
        in-flight micro-batches run concurrently on it).
    queue_capacity:
        Bound of the submission queue; ``submit`` blocks when full
        (backpressure instead of unbounded buffering).
    """

    def __init__(self, pipeline: Transformer, *,
                 cache_dir: Optional[str] = None,
                 cache_backend: Optional[str] = None,
                 on_stale: str = "error",
                 optimize: Union[str, Sequence[str], None] = "all",
                 max_batch: Union[int, str] = 32,
                 max_wait_ms: Union[float, str] = 2.0,
                 max_workers: int = 4, queue_capacity: int = 1024,
                 batch_size: Optional[int] = None,
                 reservoir_capacity: int = 4096,
                 prefetch: bool = True):
        self.pipeline = pipeline
        self.plan = ExecutionPlan([pipeline], cache_dir=cache_dir,
                                  cache_backend=cache_backend,
                                  on_stale=on_stale, optimize=optimize,
                                  prefetch=prefetch)
        tuned = self.plan.tuning()
        if max_batch == "auto":
            max_batch = int(tuned.get("max_batch", 32))
        if max_wait_ms == "auto":
            max_wait_ms = float(tuned.get("max_wait_ms", 2.0))
        self.stats = ServiceStats(reservoir_capacity)
        self._exec = StreamingExecutor(
            self.plan.graph, batch_size=batch_size, max_batch=max_batch,
            max_wait_ms=max_wait_ms, max_workers=max_workers,
            queue_capacity=queue_capacity, on_batch=self._on_batch)
        self.max_batch = self._exec.max_batch
        self.max_wait_ms = float(max_wait_ms)
        self._compute_base = self.plan._compute_counters()
        self._cache_base = self.plan._cache_counters()
        self._closed = False

    # -- request path --------------------------------------------------------
    def submit(self, qid: Any, query: str, **extra: Any) -> Future:
        """Asynchronously serve one query; resolves to the pipeline's
        result frame for this qid.  Concurrent submissions coalesce
        into micro-batches (identical (qid, query) submissions share
        one execution)."""
        row = {"qid": str(qid), "query": query, **extra}
        return self._exec.submit([row])

    def search(self, queries: Any, timeout: Optional[float] = None
               ) -> ColFrame:
        """Synchronously serve a query frame (one request, possibly
        many qids); dispatches immediately."""
        frame = ColFrame.coerce(queries)
        fut = self._exec.submit(frame.to_dicts())
        self._exec.flush()
        return fut.result(timeout)

    def flush(self) -> None:
        """Dispatch pending submissions without waiting for the batch
        window."""
        self._exec.flush()

    def drain(self) -> None:
        """Make the service's caches durable without stopping it: flush
        each planner-inserted cache's write-behind queue and access log
        (``caching/dataplane.py``).  Long-lived services call this at
        quiet points; ``close()`` always drains."""
        self.plan.drain()

    # -- stats / introspection -----------------------------------------------
    def _on_batch(self, *, n_requests: int, latencies_ms: List[float],
                  cause: str, cache_hits: int = 0,
                  cache_misses: int = 0) -> None:
        self.stats.record_batch(n_requests=n_requests,
                                latencies_ms=latencies_ms)
        self.stats.add_cache_counts(cache_hits, cache_misses)

    @property
    def online_stats(self):
        """The streaming executor's :class:`StreamStats` (flush
        triggers, queue depth, batch occupancy, per-node latency)."""
        return self._exec.stats

    def plan_stats(self) -> PlanStats:
        """Optimizer accounting plus ONLINE execution statistics: how
        often each plan node ran, its p50/p99 latency, queue depth and
        micro-batch occupancy — the serving analogue of the stats an
        offline ``plan.run`` returns."""
        stats = self.plan._new_stats()
        s = self._exec.stats
        per_node = s.node_dicts()
        stats.node_exec_counts = {label: int(d["executions"])
                                  for label, d in per_node.items()}
        # approximate total per-node seconds from the online latency
        # reservoirs (executions × p50) — what _record_run folds into
        # the manifest's measured cost table, so a served plan's costs
        # inform the next compile exactly like an offline run's
        stats.node_times_s = {
            label: int(d["executions"]) * float(d["p50_ms"]) / 1e3
            for label, d in per_node.items() if d["executions"]}
        stats.n_queries = int(s.requests)
        stats.nodes_executed = len(per_node)
        # cached nodes fold their raw miss-path compute time instead of
        # the store-dominated wrapper latency (see cost.fold_costs)
        self.plan._fill_compute_stats(stats, self._compute_base)
        stats.cache_hits = s.cache_hits
        stats.cache_misses = s.cache_misses
        # staged-served subset of the hits (dataplane prefetch) — read
        # from the family counters, which attribute a prefetched hit to
        # the *consuming* node at consumption time, so it is always a
        # subset of the hits counted above (never an extra lookup)
        stats.cache_prefetched = \
            self.plan._cache_counters()[2] - self._cache_base[2]
        stats.online = s.as_dict(self.max_batch)
        stats.online.setdefault("max_batch", self.max_batch)
        stats.online.setdefault("max_wait_ms", self.max_wait_ms)
        return stats

    def explain(self) -> str:
        """The compiled plan's ``explain()`` tree, annotated per node
        with online latency (``online[p50=.. p99=.. n=..]``), plus a
        service summary line."""
        import copy

        from ..core.ir import render_explain
        record = copy.deepcopy(self.plan.to_record())
        per_node = self._exec.stats.node_dicts()
        for n in record["nodes"]:
            onl = per_node.get(n["label"])
            if onl:
                n["online"] = onl
        s = self._exec.stats
        tail = (f"online: requests={s.requests} batches={s.batches} "
                f"occupancy={s.occupancy(self.max_batch):.2f} "
                f"queue_p99={s.queue_depth.percentile(99):.1f} "
                f"flush[size={s.flush_size} timeout={s.flush_timeout} "
                f"forced={s.flush_forced}] "
                f"hits={s.cache_hits} misses={s.cache_misses}")
        return render_explain(record) + "\n" + tail

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._exec.close()
        if self.plan._plan_manifest_path is not None \
                and self._exec.stats.requests:
            try:
                # persist this service run (incl. online batch stats) to
                # the plan manifest: the next compile's autotune pass
                # reads it back — this is what makes "auto" self-tuning
                self.plan._record_run(self.plan_stats())
            except Exception:
                pass
        self.plan.close()

    def __enter__(self) -> "PipelineService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class ScoringService:
    """DEPRECATED compatibility front-end: the paper's §4.2
    single-scorer service (``index.bm25() >> cached_scorer`` packaged
    as a long-lived service), now a thin wrapper over
    :class:`PipelineService`.

    Construction emits a :class:`DeprecationWarning`; the import
    survives one more release, but the class is no longer part of
    ``serve.__all__``.  Use ``PipelineService`` (optionally wrapping
    the scorer in a ``ScorerCache``) — it serves whole pipelines,
    micro-batches concurrent clients and scales to a process fleet via
    ``serve.build_service(..., workers=N)``.
    """

    def __init__(self, scorer: Transformer,
                 cache_path: Optional[str] = None,
                 max_batch: int = 256, use_cache: bool = True):
        warnings.warn(
            "ScoringService is deprecated and will be removed in the next "
            "release; wrap the scorer in a ScorerCache and serve it with "
            "PipelineService (or serve.build_service)",
            DeprecationWarning, stacklevel=2)
        from ..caching.scorer import ScorerCache
        self.scorer = scorer
        self.cache = ScorerCache(cache_path, scorer) if use_cache else None
        stage = self.cache if self.cache is not None else scorer
        self.max_batch = int(max_batch)
        self._svc = PipelineService(stage, max_batch=self.max_batch,
                                    max_wait_ms=0.0, max_workers=1)
        self._queue: List[Dict] = []

    @property
    def stats(self) -> ServiceStats:
        return self._svc.stats

    def submit(self, qid: str, query: str, docno: str, text: str) -> None:
        self._queue.append({"qid": qid, "query": query, "docno": docno,
                            "text": text, "score": 0.0, "rank": 0})

    def flush(self) -> ColFrame:
        """Score everything queued; returns the scored frame."""
        if not self._queue:
            return ColFrame()
        outs = []
        while self._queue:
            chunk, self._queue = (self._queue[:self.max_batch],
                                  self._queue[self.max_batch:])
            outs.append(self._svc.search(chunk))
        return ColFrame.concat(outs)

    def close(self):
        self._svc.close()
        if self.cache is not None:
            self.cache.close()
