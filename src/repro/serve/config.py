"""ServeConfig — one declarative surface for every serving entry point.

Before this module the repo had three ways to stand a service up, each
with its own parameter plumbing: ``repro serve`` (``cli/serve.py``),
the legacy launch driver (``launch/serve.py``) and the cache-warming
job (``caching/warming.py``).  They all describe the same thing — a
registry scenario, a cache location, micro-batching knobs — so
:class:`ServeConfig` names that description once and
:func:`build_service` turns it into a running service:

* ``workers=1`` (default) → an in-process
  :class:`~repro.serve.service.PipelineService`;
* ``workers=N`` → a :class:`~repro.serve.fleet.FleetService` of N
  worker processes over the same cache directory, behind the demux.

One-process and N-process serving therefore differ only by
``workers=``; fleet worker processes consume the *same* config (with
``workers`` forced to 1) to build their local service, which is what
guarantees per-qid bit-identity between a fleet and a single process —
identical scenario construction, identical compiled plan, identical
caches.  The config is a plain picklable dataclass so it crosses the
``multiprocessing`` spawn boundary unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Union

__all__ = ["ServeConfig", "build_service", "drive_closed_loop"]


@dataclass
class ServeConfig:
    """Everything needed to stand up (and warm) a serving scenario.

    Scenario identity — ``pipeline``/``scale``/``cutoff``/
    ``num_results``/``seed`` — must match between warming and serving
    (and does by construction when both read one config): node
    fingerprints, and hence cache directories, derive from it.
    """

    # -- scenario identity ---------------------------------------------------
    pipeline: str = "bm25-mono"
    scale: float = 0.05
    cutoff: int = 10
    num_results: int = 100
    seed: int = 0

    # -- cache plumbing ------------------------------------------------------
    cache_dir: Optional[str] = None
    #: a ``caching.select_backend`` selector (``"sqlite"``,
    #: ``"tiered:dbm"``, ``"mmap:sqlite"``, …); ``None`` keeps each
    #: cache family's default
    backend: Optional[str] = None
    on_stale: str = "error"
    optimize: Union[str, Sequence[str], None] = "all"
    #: asynchronous cache data plane (``caching/dataplane.py``): issue
    #: warm-path store reads on a background I/O pool as soon as a
    #: batch's frame exists and buffer miss-path writes behind.  Results
    #: are per-qid bit-identical either way — ``False`` is the ablation
    #: knob (``serve_bench --no-prefetch``)
    prefetch: bool = True

    # -- micro-batching / executor knobs ------------------------------------
    #: positive int, or ``"auto"`` to take the compiled plan's autotuned
    #: value (derived from the manifest's measured occupancy history)
    max_batch: Union[int, str] = 16
    #: milliseconds, or ``"auto"`` (see ``max_batch``)
    max_wait_ms: Union[float, str] = 2.0
    #: thread-pool size of each service's streaming executor
    exec_workers: int = 4
    queue_capacity: int = 1024

    # -- fleet topology ------------------------------------------------------
    #: worker *processes*; 1 = in-process service, N>1 = FleetService
    workers: int = 1
    #: demux routing policy: ``"rr"`` round-robins requests over live
    #: workers (load-balanced — a zipf-hot qid does not bottleneck one
    #: worker); ``"qid"`` hashes the qid so repeat traffic for a query
    #: always lands on the same worker's micro-batcher (maximizes
    #: dedup of concurrent identical requests).  Results are
    #: reassembled per qid either way, and deterministic pipelines
    #: make the answers routing-independent.
    routing: str = "rr"
    #: fleet workers replay the scenario's expected traffic through
    #: their plan on start (all hits over a warmed dir), so a respawned
    #: worker rejoins warm; ignored without a ``cache_dir``
    warm_start: bool = True
    #: cap the warm replay to the N most-expected queries
    warm_budget: Optional[int] = None

    extra: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        from ..caching import select_backend
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.routing not in ("rr", "qid"):
            raise ValueError(f"routing must be 'rr' or 'qid', "
                             f"got {self.routing!r}")
        for knob in ("max_batch", "max_wait_ms"):
            v = getattr(self, knob)
            if isinstance(v, str) and v != "auto":
                raise ValueError(f"{knob} must be a number or 'auto', "
                                 f"got {v!r}")
        if not isinstance(self.max_batch, str) and int(self.max_batch) < 1:
            raise ValueError(f"max_batch must be >= 1, "
                             f"got {self.max_batch}")
        if self.backend is not None:
            # validate eagerly (and keep the normalized form) so a bad
            # selector fails at config time, not inside a worker process
            self.backend = select_backend(self.backend)

    # -- derived -------------------------------------------------------------
    def build_scenario(self):
        """The registry scenario this config names — deterministic, so
        every fleet worker reconstructs the identical pipeline."""
        from .registry import build_scenario
        return build_scenario(self.pipeline, scale=self.scale,
                              cutoff=self.cutoff,
                              num_results=self.num_results, seed=self.seed)

    def service_kwargs(self) -> Dict[str, Any]:
        """Constructor kwargs for a single
        :class:`~repro.serve.service.PipelineService`."""
        return dict(cache_dir=self.cache_dir, cache_backend=self.backend,
                    on_stale=self.on_stale, optimize=self.optimize,
                    max_batch=self.max_batch, max_wait_ms=self.max_wait_ms,
                    max_workers=self.exec_workers,
                    queue_capacity=self.queue_capacity,
                    prefetch=self.prefetch)

    def single(self) -> "ServeConfig":
        """This config as one worker process sees it (``workers=1``)."""
        return dataclasses.replace(self, workers=1)

    @classmethod
    def coerce(cls, obj: Any) -> "ServeConfig":
        """Accept a ``ServeConfig``, a kwargs dict, or ``None``."""
        if obj is None:
            return cls()
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls(**obj)
        raise TypeError(f"cannot build a ServeConfig from "
                        f"{type(obj).__name__}: {obj!r}")


def build_service(config: Any = None, *, scenario: Any = None,
                  pipeline: Any = None, **overrides: Any):
    """The one serving factory: a running service from a config.

    ``config`` is anything :meth:`ServeConfig.coerce` accepts;
    ``overrides`` are applied on top (``build_service(workers=4)``).
    With ``workers == 1`` returns an in-process
    :class:`~repro.serve.service.PipelineService`; with ``workers > 1``
    a :class:`~repro.serve.fleet.FleetService` over worker processes.

    ``pipeline`` (a transformer expression) or ``scenario`` (a built
    :class:`~repro.serve.registry.ServeScenario`) short-circuit the
    registry lookup for the in-process case; the fleet always rebuilds
    the scenario from the config's name inside each worker — custom
    unpicklable pipeline objects cannot cross the process boundary.
    """
    cfg = ServeConfig.coerce(config)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if cfg.workers > 1:
        if pipeline is not None or scenario is not None:
            raise ValueError(
                "a fleet rebuilds its scenario from the config's registry "
                "name inside each worker process; pass pipeline=/scenario= "
                "only with workers=1")
        from .fleet import FleetService
        return FleetService(cfg)
    from .service import PipelineService
    if pipeline is None:
        if scenario is None:
            scenario = cfg.build_scenario()
        pipeline = scenario.pipeline
    return PipelineService(pipeline, **cfg.service_kwargs())


def drive_closed_loop(config: Any = None, *, requests: int = 200,
                      clients: int = 4, explain: bool = False,
                      drain: bool = False,
                      **overrides: Any) -> Dict[str, Any]:
    """Stand the configured service up, run the closed-loop generator,
    tear down, return a JSON-able stats record — the shared engine of
    ``repro serve`` and the legacy launch driver, for one process or a
    whole fleet."""
    cfg = ServeConfig.coerce(config)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    from .registry import run_closed_loop
    scenario = cfg.build_scenario()
    svc = build_service(cfg, scenario=scenario if cfg.workers == 1 else None)
    explained = None
    fleet_report = None
    try:
        loop = run_closed_loop(svc, scenario, n_requests=requests,
                               n_clients=clients, seed=cfg.seed)
        if cfg.workers > 1:
            # graceful drain folds the workers' cache totals into
            # svc.stats before the summary is taken
            fleet_report = svc.drain()
            online = fleet_report["online"]
        else:
            online = svc.online_stats.as_dict(svc.max_batch)
            if explain:
                explained = svc.explain()
        summary = svc.stats.summary()
        record = {
            "pipeline": cfg.pipeline,
            "description": scenario.description,
            "optimize": cfg.optimize,
            # the resolved values ("auto" resolves at service build)
            "max_batch": getattr(svc, "max_batch", cfg.max_batch),
            "max_wait_ms": getattr(svc, "max_wait_ms", cfg.max_wait_ms),
            "workers": cfg.workers,
            **loop, **summary,
            "online": online,
        }
        if fleet_report is not None:
            record["fleet"] = fleet_report
    finally:
        svc.close()
    if explained is not None:
        record["_explain"] = explained
    if drain and fleet_report is not None:
        record["drained"] = all(c == 0
                                for c in fleet_report["exit_codes"].values())
    return record
