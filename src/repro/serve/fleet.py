"""Multi-process serve fleet: N PipelineService workers, one cache.

``FleetService`` scales the serving layer past one process while
keeping the single-process API: ``submit(qid, query, **extra)`` returns
a future exactly like :class:`~repro.serve.service.PipelineService`,
so the closed-loop generator, the benchmarks and the CLI drive either
interchangeably (``build_service`` picks by ``workers=``).

Topology
--------
The front-end **demux** (this process) owns the client-facing futures
and a duplex ``multiprocessing.Pipe`` per worker.  Each **worker
process** (spawned — never forked: the parent runs jax and executor
threads) rebuilds the scenario from the shared
:class:`~repro.serve.config.ServeConfig`, compiles its own
``PipelineService`` over the *same* cache directory, optionally replays
the expected traffic through the plan (``warm_start`` — all hits over
a warmed dir, so a respawned worker rejoins warm from the PR-6
manifests), then serves requests from its pipe.  Routing follows
``config.routing``: ``"rr"`` (default) round-robins requests over the
live workers so a zipf-hot qid cannot bottleneck one process, while
``"qid"`` hashes the qid stably so repeat traffic for a hot query
keeps hitting the same worker's micro-batcher; either way results
(per-qid frames) are reassembled into the original futures, and
deterministic pipelines make the answers routing-independent.

Sharing the cache is what makes N processes one *fleet* rather than N
cold services: with the ``mmap:<disk>`` read-mostly tier
(``caching/mmap_tier.py``) every worker maps the same packed snapshot,
so cross-process hits take no lock, while misses still compute exactly
once through the disk backend's locked compute-once path.

Fault handling
--------------
A worker death is detected as EOF on its pipe.  The demux then (a)
requeues every accepted request that was in flight on the dead worker
onto survivors — accepted requests are never lost, they are recomputed
(bit-identically: deterministic pipelines) elsewhere; (b) respawns a
replacement, paced by :class:`~repro.distrib.fault.RetryPolicy`
backoff, which warms itself from the manifests before taking traffic.
Per-request requeues are bounded by the same policy; exhausting it
fails that request's future with the underlying error.

``drain()`` is the graceful shutdown: each worker finishes its
in-flight work, flushes, closes its service — which refreshes the
cache manifests (entry counts, access stats) on disk — reports its
stats and exits 0.  ``repro serve --drain`` surfaces the exit codes.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import zlib
from concurrent.futures import Future
from typing import Any, Dict, List, Optional

from ..distrib.fault import RetryPolicy
from .config import ServeConfig
from .service import ServiceStats

__all__ = ["FleetService", "fleet_worker_main"]


def _qid_slot(qid: str, n: int) -> int:
    """Stable (cross-process, cross-run) qid → worker slot hash."""
    return zlib.crc32(str(qid).encode("utf-8")) % max(1, n)


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------

def fleet_worker_main(conn, cfg: ServeConfig, worker_id: int) -> None:
    """Entry point of one worker process (module-level: spawn pickles
    it by reference).  Protocol, parent → worker::

        ("req", rid, row)   serve one row; reply ("res", rid, frame)
                            or ("err", rid, repr)
        ("drain",)          finish in-flight work, close the service
                            (refreshing manifests), reply
                            ("drained", wid, stats), exit 0
        ("stop",)           close immediately, exit 0

    and worker → parent additionally ``("ready", wid, warm_info)`` once
    the local service is built (and warmed)."""
    from .config import build_service
    from .registry import warming_frame

    cfg = cfg.single()
    scenario = cfg.build_scenario()
    svc = build_service(cfg, scenario=scenario)
    warm_info: Dict[str, Any] = {}
    if cfg.warm_start and cfg.cache_dir:
        t0 = time.perf_counter()
        frame = warming_frame(scenario, budget=cfg.warm_budget,
                              seed=cfg.seed)
        stats = svc.plan.warm(frame)
        warm_info = {"queries_warmed": int(len(frame)),
                     "warm_hits": int(stats.cache_hits),
                     "warm_misses": int(stats.cache_misses),
                     "warm_wall_s": round(time.perf_counter() - t0, 4)}
    send_lock = threading.Lock()
    outstanding = [0]
    done_cv = threading.Condition()
    conn.send(("ready", worker_id, warm_info))

    def _reply(payload) -> None:
        try:
            with send_lock:
                conn.send(payload)
        except (BrokenPipeError, OSError):
            pass                         # parent gone; nothing to tell

    def _on_done(fut: Future, rid: int) -> None:
        try:
            _reply(("res", rid, fut.result()))
        except BaseException as e:       # noqa: BLE001 - relay verbatim
            _reply(("err", rid, repr(e)))
        with done_cv:
            outstanding[0] -= 1
            done_cv.notify_all()

    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):      # parent died: nothing to serve
            svc.close()
            return
        kind = msg[0]
        if kind == "req":
            rid, row = msg[1], dict(msg[2])
            qid = row.pop("qid")
            query = row.pop("query")
            with done_cv:
                outstanding[0] += 1
            try:
                fut = svc.submit(qid, query, **row)
            except BaseException as e:   # noqa: BLE001 - relay verbatim
                with done_cv:
                    outstanding[0] -= 1
                    done_cv.notify_all()
                _reply(("err", rid, repr(e)))
                continue
            fut.add_done_callback(lambda f, rid=rid: _on_done(f, rid))
        elif kind == "drain":
            svc.flush()
            with done_cv:
                done_cv.wait_for(lambda: outstanding[0] == 0, timeout=60.0)
            stats = {"worker": worker_id,
                     **svc.stats.summary(),
                     "online": svc.online_stats.as_dict(svc.max_batch),
                     **warm_info}
            svc.close()                  # refreshes manifests on disk
            _reply(("drained", worker_id, stats))
            conn.close()
            return                       # process exit code 0
        elif kind == "stop":
            svc.close()
            conn.close()
            return


# ---------------------------------------------------------------------------
# demux (parent) side
# ---------------------------------------------------------------------------

class _Worker:
    """Parent-side handle of one worker process."""

    __slots__ = ("id", "proc", "conn", "send_lock", "ready", "drained",
                 "alive", "drain_stats", "warm_info", "exit_code")

    def __init__(self, wid: int, proc, conn):
        self.id = wid
        self.proc = proc
        self.conn = conn
        self.send_lock = threading.Lock()
        self.ready = threading.Event()
        self.drained = threading.Event()
        self.alive = True
        self.drain_stats: Optional[Dict[str, Any]] = None
        self.warm_info: Dict[str, Any] = {}
        self.exit_code: Optional[int] = None

    def send(self, payload) -> None:
        with self.send_lock:
            self.conn.send(payload)


class FleetService:
    """Demux over N spawned ``PipelineService`` worker processes.

    Implements the service surface the closed-loop generator relies on
    (``submit`` → future, ``stats``, ``flush``, ``close``) plus the
    fleet lifecycle: ``drain()`` for graceful shutdown with refreshed
    manifests, ``kill_worker()`` as the chaos hook the fault tests and
    the CI fleet-smoke job use.
    """

    def __init__(self, config: Any = None, *,
                 retry: Optional[RetryPolicy] = None,
                 start_timeout: float = 300.0,
                 reservoir_capacity: int = 4096,
                 **overrides: Any):
        self.config = ServeConfig.coerce(config)
        if overrides:
            self.config = dataclasses.replace(self.config, **overrides)
        self.retry = retry or RetryPolicy(max_retries=3, base_delay_s=0.05)
        self.stats = ServiceStats(reservoir_capacity)
        self._lock = threading.RLock()
        self._rids = itertools.count()
        self._wids = itertools.count()
        self._rr = itertools.count()
        #: rid -> {"row", "future", "worker", "attempts", "t0"}
        self._inflight: Dict[int, Dict[str, Any]] = {}
        self._workers: Dict[int, _Worker] = {}
        self._readers: List[threading.Thread] = []
        self.respawns = 0
        self.requeued = 0
        self._max_respawns = self.config.workers * (self.retry.max_retries + 1)
        self._draining = False
        self._closed = False
        self._drain_report: Optional[Dict[str, Any]] = None
        import multiprocessing as mp
        self._ctx = mp.get_context("spawn")
        for _ in range(self.config.workers):
            self._spawn()
        self._wait_ready(start_timeout)

    # -- worker lifecycle ----------------------------------------------------
    def _spawn(self) -> "_Worker":
        wid = next(self._wids)
        parent_conn, child_conn = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=fleet_worker_main,
            args=(child_conn, self.config, wid),
            name=f"fleet-worker-{wid}", daemon=True)
        proc.start()
        child_conn.close()               # parent keeps its end only
        w = _Worker(wid, proc, parent_conn)
        with self._lock:
            self._workers[wid] = w
        t = threading.Thread(target=self._reader, args=(w,),
                             name=f"fleet-reader-{wid}", daemon=True)
        self._readers.append(t)
        t.start()
        return w

    def _wait_ready(self, timeout: float) -> None:
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                pending = [w for w in self._workers.values()
                           if w.alive and not w.ready.is_set()]
                n_alive = sum(w.alive for w in self._workers.values())
            if n_alive == 0:
                raise RuntimeError(
                    "fleet startup failed: every worker process exited "
                    "before becoming ready (respawn budget exhausted)")
            if not pending:
                return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"fleet startup timed out after {timeout}s waiting for "
                    f"workers {[w.id for w in pending]}")
            pending[0].ready.wait(0.2)

    def _reader(self, w: _Worker) -> None:
        while True:
            try:
                msg = w.conn.recv()
            except (EOFError, OSError):
                break
            kind = msg[0]
            if kind == "ready":
                w.warm_info = msg[2]
                w.ready.set()
            elif kind == "res":
                self._resolve(msg[1], msg[2], None)
            elif kind == "err":
                self._resolve(msg[1], None, RuntimeError(msg[2]))
            elif kind == "drained":
                w.drain_stats = msg[2]
                w.drained.set()
        self._on_worker_exit(w)

    def _on_worker_exit(self, w: _Worker) -> None:
        with self._lock:
            w.alive = False
            self._workers.pop(w.id, None)
            orphaned = [rid for rid, e in self._inflight.items()
                        if e["worker"] == w.id]
        w.proc.join(timeout=10.0)
        w.exit_code = w.proc.exitcode
        if self._draining or self._closed or w.drained.is_set():
            return
        # unexpected death: respawn warm (bounded), requeue the
        # orphaned accepted requests onto survivors
        with self._lock:
            may_respawn = self.respawns < self._max_respawns
            if may_respawn:
                self.respawns += 1
                attempt = self.respawns
        if may_respawn:
            time.sleep(self.retry.delay(attempt))
            if not (self._draining or self._closed):
                self._spawn()
        for rid in orphaned:
            self.requeued += 1
            self._dispatch(rid)

    # -- request path --------------------------------------------------------
    def submit(self, qid: Any, query: str, **extra: Any) -> Future:
        """Asynchronously serve one query through the fleet; resolves
        to the per-qid result frame, exactly like
        ``PipelineService.submit``.  Once accepted (this method
        returned), the request survives worker deaths — it is requeued
        to a surviving worker and recomputed bit-identically."""
        if self._closed or self._draining:
            raise RuntimeError("FleetService is closed")
        row = {"qid": str(qid), "query": query, **extra}
        fut: Future = Future()
        rid = next(self._rids)
        with self._lock:
            self._inflight[rid] = {"row": row, "future": fut,
                                   "worker": None, "attempts": 0,
                                   "t0": time.perf_counter()}
        self._dispatch(rid)
        return fut

    def _dispatch(self, rid: int) -> None:
        while True:
            with self._lock:
                entry = self._inflight.get(rid)
                if entry is None:        # already resolved (late requeue)
                    return
                entry["attempts"] += 1
                if entry["attempts"] > self.retry.max_retries + 1:
                    self._inflight.pop(rid, None)
                    entry["future"].set_exception(RuntimeError(
                        f"request {entry['row'].get('qid')!r} failed after "
                        f"{entry['attempts'] - 1} dispatch attempts "
                        f"(workers kept dying)"))
                    return
                live = [w for w in self._workers.values() if w.alive]
                if not live:
                    self._inflight.pop(rid, None)
                    entry["future"].set_exception(RuntimeError(
                        "no live fleet workers to dispatch to"))
                    return
                if self.config.routing == "qid":
                    slot = _qid_slot(entry["row"]["qid"], len(live))
                else:
                    slot = next(self._rr) % len(live)
                w = live[slot]
                entry["worker"] = w.id
            try:
                w.send(("req", rid, entry["row"]))
                return
            except (BrokenPipeError, OSError):
                # raced a death the reader has not processed yet; the
                # loop re-picks among the remaining workers
                with self._lock:
                    w.alive = False

    def _resolve(self, rid: int, frame, error) -> None:
        with self._lock:
            entry = self._inflight.pop(rid, None)
        if entry is None:                # duplicate/late reply
            return
        dt_ms = (time.perf_counter() - entry["t0"]) * 1000.0
        self.stats.record_batch(n_requests=1, latencies_ms=[dt_ms])
        if error is not None:
            entry["future"].set_exception(error)
        else:
            entry["future"].set_result(frame)

    def flush(self) -> None:
        """No-op at the demux: each worker's streaming executor flushes
        on its own ``max_batch``/``max_wait_ms`` window."""

    # -- introspection -------------------------------------------------------
    @property
    def worker_ids(self) -> List[int]:
        with self._lock:
            return sorted(w.id for w in self._workers.values() if w.alive)

    @property
    def warm_info(self) -> Dict[int, Dict[str, Any]]:
        with self._lock:
            return {w.id: dict(w.warm_info)
                    for w in self._workers.values()}

    def kill_worker(self, worker_id: Optional[int] = None) -> int:
        """Chaos hook: SIGKILL one live worker (the lowest id by
        default) and return its id.  The demux requeues its in-flight
        requests and respawns a warm replacement — the fault-tolerance
        path the fleet tests and the CI fleet-smoke job exercise."""
        with self._lock:
            live = sorted((w.id, w) for w in self._workers.values()
                          if w.alive)
            if not live:
                raise RuntimeError("no live workers to kill")
            wid, w = live[0] if worker_id is None else \
                (worker_id, self._workers[worker_id])
        w.proc.kill()
        return wid

    # -- lifecycle -----------------------------------------------------------
    def drain(self, timeout: float = 120.0) -> Dict[str, Any]:
        """Graceful shutdown: every worker finishes in-flight work,
        closes its service — refreshing the cache manifests on disk —
        reports stats and exits 0.  Returns the fleet report
        (per-worker stats, exit codes, respawn/requeue counters,
        aggregated cache totals); idempotent."""
        if self._drain_report is not None:
            return self._drain_report
        with self._lock:
            self._draining = True
            workers = [w for w in self._workers.values() if w.alive]
        for w in workers:
            try:
                w.send(("drain",))
            except (BrokenPipeError, OSError):
                pass
        deadline = time.monotonic() + timeout
        for w in workers:
            w.drained.wait(max(0.0, deadline - time.monotonic()))
            w.proc.join(timeout=max(0.1, deadline - time.monotonic()))
            if w.proc.is_alive():        # refuse to hang: escalate
                w.proc.terminate()
                w.proc.join(timeout=5.0)
            w.exit_code = w.proc.exitcode
        per_worker = [w.drain_stats for w in workers
                      if w.drain_stats is not None]
        hits = sum(int(s["online"]["cache_hits"]) for s in per_worker)
        misses = sum(int(s["online"]["cache_misses"]) for s in per_worker)
        self.stats.add_cache_counts(hits, misses)
        batches = sum(int(s.get("batches", 0)) for s in per_worker)
        occ = (sum(float(s["online"]["batch_occupancy"])
                   * int(s.get("batches", 0)) for s in per_worker)
               / batches) if batches else 0.0
        self._drain_report = {
            "workers": [dict(s) for s in per_worker],
            "exit_codes": {w.id: w.exit_code for w in workers},
            "respawns": self.respawns,
            "requeued": self.requeued,
            "online": {"cache_hits": hits, "cache_misses": misses,
                       "batches": batches,
                       "batch_occupancy": round(occ, 4)},
        }
        return self._drain_report

    def close(self, drain: bool = True) -> None:
        if self._closed:
            return
        if drain and not self._draining:
            try:
                self.drain()
            except Exception:
                pass
        self._closed = True
        with self._lock:
            workers = list(self._workers.values())
            pending = list(self._inflight.values())
            self._inflight.clear()
        for e in pending:
            if not e["future"].done():
                e["future"].set_exception(
                    RuntimeError("FleetService closed"))
        for w in workers:
            try:
                w.send(("stop",))
            except (BrokenPipeError, OSError):
                pass
        for w in workers:
            w.proc.join(timeout=5.0)
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=5.0)
            try:
                w.conn.close()
            except OSError:
                pass

    def __enter__(self) -> "FleetService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
