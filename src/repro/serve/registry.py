"""Named serving scenarios for ``repro serve`` and the serve benchmark.

A *scenario* bundles a pipeline expression with the synthetic corpus /
topic set it runs against, so the CLI, the launch driver and
``benchmarks/serve_bench.py`` stand up the same workloads by name:

* ``"bm25"``       — first-stage retrieval only (``bm25 % cutoff``);
* ``"bm25-mono"``  — the paper's §4.2 two-stage composition
  (``bm25 % cutoff >> text_loader >> mono_scorer``);
* ``"mono"``       — the bare pointwise scorer (requests carry their
  own text);
* ``"dense"``      — neural first-stage retrieval over the Pallas
  ``dense_topk`` stage (``dense % cutoff``, cutoff fused into the
  kernel's per-block k by the optimizer);
* ``"hybrid"``     — sparse+dense candidate union reranked by the mono
  scorer (``(bm25 % cutoff | dense % cutoff) >> text_loader >> mono``);
* ``"bm25-sim"``   — bm25 retrieval followed by a fixed per-row
  simulated device latency (``cacheable=False``, so it always
  executes): a GIL-releasing stand-in for an accelerator-bound
  reranker, which is what makes fleet throughput scaling measurable
  on any host (sleeps overlap across worker processes even on one
  core — same device-latency convention as ``benchmarks/plan_bench``).

``run_closed_loop`` is the shared traffic generator: N closed-loop
client threads, each submitting one query at a time and waiting for its
result — the canonical serving-latency measurement loop.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..core.frame import ColFrame
from ..core.pipeline import Transformer

__all__ = ["ServeScenario", "SERVE_PIPELINES", "SimulatedLatency",
           "build_scenario", "run_closed_loop", "warming_frame"]


@dataclass
class ServeScenario:
    """A servable pipeline plus the topics that generate its traffic."""
    name: str
    pipeline: Transformer
    topics: ColFrame                     # Q(qid, query) request pool
    description: str = ""
    #: extra per-request row columns keyed by qid (e.g. doc text for
    #: scorer-only scenarios); empty for whole-pipeline serving
    request_extra: Dict[str, Dict[str, Any]] = field(default_factory=dict)


def _encoder():
    from ..models.cross_encoder import EncoderConfig, MonoScorer
    return MonoScorer(EncoderConfig(n_layers=2, d_model=64, n_heads=4,
                                    d_ff=128, vocab_size=8192, max_len=32))


def _build_bm25(*, scale: float, cutoff: int, num_results: int,
                seed: int) -> ServeScenario:
    from ..ir import InvertedIndex, msmarco_like
    corpus = msmarco_like(1, scale=scale, seed=seed)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    return ServeScenario(
        name="bm25",
        pipeline=index.bm25(num_results=num_results) % cutoff,
        topics=corpus.get_topics(),
        description=f"BM25 retrieval, top-{cutoff} "
                    f"(num_results={num_results}, pushdown fuses the cutoff)")


def _build_bm25_mono(*, scale: float, cutoff: int, num_results: int,
                     seed: int) -> ServeScenario:
    from ..ir import InvertedIndex, TextLoader, msmarco_like
    corpus = msmarco_like(1, scale=scale, seed=seed)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    pipeline = (index.bm25(num_results=num_results) % cutoff
                >> TextLoader(corpus.text_map()) >> _encoder())
    return ServeScenario(
        name="bm25-mono",
        pipeline=pipeline,
        topics=corpus.get_topics(),
        description=f"two-stage retrieve-and-rerank: bm25 % {cutoff} "
                    f">> text_loader >> mono scorer")


def _build_mono(*, scale: float, cutoff: int, num_results: int,
                seed: int) -> ServeScenario:
    from ..ir import msmarco_like
    corpus = msmarco_like(1, scale=scale, seed=seed)
    docs = corpus.docs
    rng = np.random.default_rng(seed)
    topics = corpus.get_topics()
    extra: Dict[str, Dict[str, Any]] = {}
    n = min(len(docs), 200)
    for qid in topics["qid"].tolist():
        d = int(rng.integers(0, n))
        extra[str(qid)] = {"docno": str(docs["docno"][d]),
                           "text": str(docs["text"][d])}
    return ServeScenario(
        name="mono",
        pipeline=_encoder(),
        topics=topics,
        description="bare pointwise scorer (requests carry doc text)",
        request_extra=extra)


def _dense_retriever(corpus, *, num_results: int, seed: int):
    from ..ir.dense import DenseEncoder, DenseIndex
    from ..models.cross_encoder import EncoderConfig
    cfg = EncoderConfig(name="dense-serve", n_layers=1, d_model=32,
                        n_heads=2, d_ff=64, vocab_size=2048, max_len=16)
    index = DenseIndex(DenseEncoder(cfg, seed=seed + 7)).index(
        corpus.get_corpus_iter())
    return index.retriever(num_results=num_results)


def _build_dense(*, scale: float, cutoff: int, num_results: int,
                 seed: int) -> ServeScenario:
    from ..ir import msmarco_like
    corpus = msmarco_like(1, scale=scale, seed=seed)
    dense = _dense_retriever(corpus, num_results=num_results, seed=seed)
    return ServeScenario(
        name="dense",
        pipeline=dense % cutoff,
        topics=corpus.get_topics(),
        description=f"dense retrieval over the fused dense_topk stage, "
                    f"top-{cutoff} (num_results={num_results}, pushdown "
                    f"fuses the cutoff into the kernel's per-block k)")


def _build_hybrid(*, scale: float, cutoff: int, num_results: int,
                  seed: int) -> ServeScenario:
    from ..ir import InvertedIndex, TextLoader, msmarco_like
    corpus = msmarco_like(1, scale=scale, seed=seed)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    dense = _dense_retriever(corpus, num_results=num_results, seed=seed)
    pipeline = ((index.bm25(num_results=num_results) % cutoff
                 | dense % cutoff)
                >> TextLoader(corpus.text_map()) >> _encoder())
    return ServeScenario(
        name="hybrid",
        pipeline=pipeline,
        topics=corpus.get_topics(),
        description=f"sparse+dense candidate union reranked by the mono "
                    f"scorer: (bm25 % {cutoff} | dense % {cutoff}) "
                    f">> text_loader >> mono")


class SimulatedLatency(Transformer):
    """Identity stage that sleeps ``per_row_ms`` per input row.

    Models an accelerator-bound stage whose cost is proportional to the
    candidate set (a cross-encoder scoring pass): ``time.sleep``
    releases the GIL exactly like a device dispatch, so N worker
    *processes* overlap N requests' latencies even on a single CPU
    core.  ``cacheable=False`` keeps the planner from memoizing it —
    the work must happen on every request, warm cache or not, or the
    fleet benchmark would measure cache lookups instead of serving
    capacity.  ``augment_only`` stays False for the same reason: the
    cache-prune pass may defer exclusive augment-only chains behind
    warm stores, which would skip the simulated work on hits.
    """

    cacheable = False
    rank_preserving = True

    def __init__(self, per_row_ms: float = 2.0):
        self.per_row_ms = float(per_row_ms)

    def transform(self, inp: ColFrame) -> ColFrame:
        time.sleep(self.per_row_ms * 1e-3 * max(1, len(inp)))
        return inp

    def signature(self):
        return ("SimulatedLatency", self.per_row_ms)


def _build_bm25_sim(*, scale: float, cutoff: int, num_results: int,
                    seed: int) -> ServeScenario:
    from ..ir import InvertedIndex, msmarco_like
    corpus = msmarco_like(1, scale=scale, seed=seed)
    index = InvertedIndex.build(corpus.get_corpus_iter())
    pipeline = (index.bm25(num_results=num_results) % cutoff
                >> SimulatedLatency())
    return ServeScenario(
        name="bm25-sim",
        pipeline=pipeline,
        topics=corpus.get_topics(),
        description=f"bm25 % {cutoff} >> simulated per-row device latency "
                    f"(uncacheable; the fleet-scaling workload)")


SERVE_PIPELINES: Dict[str, Callable[..., ServeScenario]] = {
    "bm25": _build_bm25,
    "bm25-mono": _build_bm25_mono,
    "mono": _build_mono,
    "dense": _build_dense,
    "hybrid": _build_hybrid,
    "bm25-sim": _build_bm25_sim,
}


def build_scenario(name: str, *, scale: float = 0.05, cutoff: int = 10,
                   num_results: int = 100, seed: int = 0) -> ServeScenario:
    """Construct a named serving scenario (see ``SERVE_PIPELINES``)."""
    try:
        builder = SERVE_PIPELINES[name]
    except KeyError:
        raise KeyError(f"unknown serving pipeline {name!r}; known: "
                       f"{sorted(SERVE_PIPELINES)}") from None
    return builder(scale=scale, cutoff=cutoff, num_results=num_results,
                   seed=seed)


def warming_frame(scenario: ServeScenario, *,
                  budget: Optional[int] = None,
                  n_requests: int = 512, n_clients: int = 4,
                  seed: int = 0) -> ColFrame:
    """The scenario's expected traffic as a query frame for offline
    cache warming (``repro cache warm`` / ``ExecutionPlan.warm``).

    Simulates the *exact* per-client zipf draws of ``run_closed_loop``
    (same rng seeding, same index formula) to rank topics by expected
    request frequency, then appends the never-drawn tail in topic
    order — so ``budget=None`` covers the whole pool (a subsequent
    serve epoch with matching ``seed``/``scale`` has zero misses) and
    ``budget=N`` precomputes the N most valuable queries first.
    Request-extra columns (e.g. the doc text of scorer-only scenarios)
    are merged per qid, mirroring what ``run_closed_loop`` submits.
    """
    qids = [str(q) for q in scenario.topics["qid"].tolist()]
    queries = scenario.topics["query"].tolist()
    n_topics = len(qids)
    counts = np.zeros(n_topics, dtype=np.int64)
    n_clients = max(1, n_clients)
    per_client = [n_requests // n_clients
                  + (1 if c < n_requests % n_clients else 0)
                  for c in range(n_clients)]
    for cid in range(n_clients):
        rng = np.random.default_rng(seed * 1009 + cid)
        for _ in range(per_client[cid]):
            i = int(min(rng.zipf(1.3) - 1, n_topics - 1))
            counts[i] += 1
    # hottest first; zero-count tail keeps topic order (stable sort on
    # -count), so the full-pool warm is deterministic
    order = np.argsort(-counts, kind="stable")
    if budget is not None:
        order = order[:max(0, int(budget))]
    rows: List[Dict[str, Any]] = []
    for i in order.tolist():
        row = {"qid": qids[i], "query": queries[i]}
        row.update(scenario.request_extra.get(qids[i], {}))
        rows.append(row)
    return ColFrame.from_dicts(rows)


def run_closed_loop(service, scenario: ServeScenario, *,
                    n_requests: int, n_clients: int = 4,
                    seed: int = 0,
                    timeout: Optional[float] = 120.0) -> Dict[str, float]:
    """Closed-loop request stream: ``n_clients`` threads each submit
    one query at a time (drawn from the scenario's topic pool with a
    skew toward popular queries) and wait for the result before
    submitting the next — so concurrency equals the client count and
    the service's micro-batching does the coalescing.

    Returns wall-clock throughput and request counts; latency
    percentiles live in ``service.stats``.
    """
    qids = scenario.topics["qid"].tolist()
    queries = scenario.topics["query"].tolist()
    n_topics = len(qids)
    n_clients = max(1, n_clients)
    # distribute the remainder so exactly n_requests are issued
    per_client = [n_requests // n_clients
                  + (1 if c < n_requests % n_clients else 0)
                  for c in range(n_clients)]
    errors: List[BaseException] = []
    done = [0]
    lock = threading.Lock()

    def client(cid: int) -> None:
        rng = np.random.default_rng(seed * 1009 + cid)
        for _ in range(per_client[cid]):
            # zipf-ish skew: repeat traffic is what caching pays for
            i = int(min(rng.zipf(1.3) - 1, n_topics - 1))
            qid = str(qids[i])
            extra = scenario.request_extra.get(qid, {})
            try:
                fut = service.submit(qid, queries[i], **extra)
                fut.result(timeout)
                with lock:
                    done[0] += 1
            except BaseException as e:   # surface, don't hang the loop
                with lock:
                    errors.append(e)
                return

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return {"requests": done[0], "clients": n_clients,
            "wall_s": round(wall_s, 4),
            "throughput_rps": round(done[0] / wall_s, 2) if wall_s else 0.0}
