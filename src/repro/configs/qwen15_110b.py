"""qwen1.5-110b [hf:Qwen/Qwen1.5-110B; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
"""
import jax.numpy as jnp
from ..models.lm import LMConfig
from .base import lm_arch

CONFIG = LMConfig(
    name="qwen1.5-110b", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=49152, vocab_size=152064, qkv_bias=True,
    dtype=jnp.bfloat16)

ARCH = lm_arch("qwen1.5-110b", CONFIG, source="hf:Qwen/Qwen1.5-110B",
               notes="largest assigned arch (~111B params); memory posture "
                     "relies on FSDP(d_model->data) x TP(d_ff/heads->model)")
