"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
"""
import jax.numpy as jnp
from ..models.lm import LMConfig
from .base import lm_arch

CONFIG = LMConfig(
    name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=6400, vocab_size=32064, n_experts=16, top_k=2,
    dtype=jnp.bfloat16)

ARCH = lm_arch("phi3.5-moe-42b-a6.6b", CONFIG,
               source="hf:microsoft/Phi-3.5-MoE-instruct",
               notes="16 experts == 16-way model axis -> full EP")
