"""mind [arXiv:1904.08030; unverified].

embed_dim=64, 4 interests, 3 capsule-routing iterations, multi-interest
interaction; item vocabulary 1M (paper uses industrial-scale billions).
"""
from ..models.recsys import RecsysConfig
from .base import recsys_arch

CONFIG = RecsysConfig(
    name="mind", kind="mind", embed_dim=64, n_interests=4,
    capsule_iters=3, hist_len=50, item_vocab=1_000_000)

ARCH = recsys_arch("mind", CONFIG, source="arXiv:1904.08030",
                   notes="B2I dynamic-routing capsules; in-batch sampled "
                         "softmax training")
