"""smollm-360m [hf:HuggingFaceTB/SmolLM-360M; hf].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152 (llama-arch small).
head_dim = 960/15 = 64.
"""
import jax.numpy as jnp
from ..models.lm import LMConfig
from .base import lm_arch

CONFIG = LMConfig(
    name="smollm-360m", n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab_size=49152, dtype=jnp.bfloat16)

ARCH = lm_arch("smollm-360m", CONFIG, source="hf:HuggingFaceTB/SmolLM-360M",
               notes="15 heads / d_model 960: indivisible by 16 -> heads & "
                     "d_model pruning exercises the fallback rules hardest")
