"""qwen3-14b [hf:Qwen/Qwen3-14B; hf].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, qk_norm,
head_dim=128.
"""
import jax.numpy as jnp
from ..models.lm import LMConfig
from .base import lm_arch

CONFIG = LMConfig(
    name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_head=128, d_ff=17408, vocab_size=151936, qk_norm=True,
    dtype=jnp.bfloat16)

ARCH = lm_arch("qwen3-14b", CONFIG, source="hf:Qwen/Qwen3-14B",
               notes="40 heads indivisible by 16 -> attention weights "
                     "replicated over model axis; TP carried by d_ff/vocab")
