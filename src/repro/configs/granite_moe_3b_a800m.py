"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-3b-a800m-base; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40 experts
top-8.  NOTE: the assignment bracket text says "32 experts"; the primary
config string says 40e — we implement 40 (matches granite-3.0-3b-a800m;
32 belongs to 1b-a400m). head_dim = 1536/24 = 64.
"""
import jax.numpy as jnp
from ..models.lm import LMConfig
from .base import lm_arch

CONFIG = LMConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab_size=49155, n_experts=40, top_k=8,
    dtype=jnp.bfloat16)

ARCH = lm_arch("granite-moe-3b-a800m", CONFIG,
               source="hf:ibm-granite/granite-3.0-3b-a800m-base",
               notes="40 experts indivisible by 16-way model axis -> "
                     "experts pruned to FSDP, d_ff sharded instead")
