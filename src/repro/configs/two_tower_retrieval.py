"""two-tower-retrieval [RecSys'19 (YouTube); unverified].

embed_dim=256, tower MLP 1024-512-256, dot interaction, sampled-softmax
retrieval; retrieval_cand scores 1 query against 1M candidates as one
batched matmul.
"""
from ..models.recsys import RecsysConfig
from .base import recsys_arch

CONFIG = RecsysConfig(
    name="two-tower-retrieval", kind="two_tower", embed_dim=256,
    tower_mlp=(1024, 512, 256), item_vocab=1_000_000, user_vocab=2_000_000)

ARCH = recsys_arch("two-tower-retrieval", CONFIG,
                   source="RecSys'19 (YouTube)",
                   notes="in-batch sampled softmax with logQ-style scaling")
