"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict, List

from .base import ArchDef
from . import (granite_moe_3b_a800m, phi35_moe_42b_a66b, qwen3_14b,
               smollm_360m, qwen15_110b, gcn_cora, dlrm_rm2, mind, dcn_v2,
               two_tower_retrieval)

_MODULES = [granite_moe_3b_a800m, phi35_moe_42b_a66b, qwen3_14b,
            smollm_360m, qwen15_110b, gcn_cora, dlrm_rm2, mind, dcn_v2,
            two_tower_retrieval]

ARCHS: Dict[str, ArchDef] = {m.ARCH.name: m.ARCH for m in _MODULES}


def get_arch(name: str) -> ArchDef:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells() -> List[tuple]:
    """Every assigned (arch, shape) pair — the 40 dry-run cells."""
    out = []
    for a in ARCHS.values():
        for s in a.shape_names():
            out.append((a.name, s))
    return out
