"""dcn-v2 [arXiv:2008.13535; paper].

13 dense + 26 sparse, embed_dim=16, 3 full-matrix cross layers, deep MLP
1024-1024-512, cross interaction; Criteo-Kaggle vocabularies.
"""
from ..models.recsys import RecsysConfig, CRITEO_VOCABS
from .base import recsys_arch

CONFIG = RecsysConfig(
    name="dcn-v2", kind="dcn", embed_dim=16, n_dense=13,
    vocab_sizes=CRITEO_VOCABS, n_cross_layers=3, deep_mlp=(1024, 1024, 512))

ARCH = recsys_arch("dcn-v2", CONFIG, source="arXiv:2008.13535")
