"""dlrm-rm2 [arXiv:1906.00091; paper].

13 dense + 26 sparse features, embed_dim=64, bottom MLP 13-512-256-64,
top MLP 512-512-256-1, dot interaction.  Sparse vocabularies use the
public Criteo-Kaggle cardinalities.
"""
from ..models.recsys import RecsysConfig, CRITEO_VOCABS
from .base import recsys_arch

CONFIG = RecsysConfig(
    name="dlrm-rm2", kind="dlrm", embed_dim=64, n_dense=13,
    vocab_sizes=CRITEO_VOCABS, bot_mlp=(512, 256, 64),
    top_mlp=(512, 512, 256, 1))

ARCH = recsys_arch("dlrm-rm2", CONFIG, source="arXiv:1906.00091",
                   notes="embedding tables row-sharded over (data, model); "
                         "lookup = jnp.take + GSPMD gather collectives")
