"""gcn-cora [arXiv:1609.02907; paper].

2 layers, d_hidden=16, mean (symmetric-normalized) aggregation.
d_feat / n_classes vary per assigned shape (cora 1433/7; ogbn-products
100/47; reddit-minibatch 602/41; molecule 64/10).
"""
from ..models.gcn import GCNConfig
from .base import gnn_arch

CONFIG = GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16, n_classes=7,
                   d_feat=1433, aggregator="mean", fanouts=(15, 10))

ARCH = gnn_arch("gcn-cora", CONFIG, source="arXiv:1609.02907",
                notes="message passing via segment_sum over edge lists "
                      "(JAX has no CSR SpMM); minibatch shape uses the "
                      "real fanout NeighborSampler")
