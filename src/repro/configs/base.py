"""Arch/shape cell builders for the dry-run and smoke tests.

Every assigned architecture is an ``ArchDef``; every (arch × shape)
pair builds a ``Cell``: a step function + abstract (ShapeDtypeStruct)
inputs + input/output shardings for a given mesh.  Lowering a Cell on
the production mesh IS the multi-pod dry-run.

Shape semantics per the assignment:
* LM ``train_*``   -> train_step (fwd+bwd+AdamW)
* LM ``prefill_*`` -> prefill (forward, builds KV cache)
* LM ``decode_*`` / ``long_*`` -> decode_step (1 token vs KV cache)
* GNN / recsys ``train*`` -> train_step; ``serve*``/``retrieval*`` ->
  forward-only serving step.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distrib.shardings import ShardingRules, batch_axes
from ..models import lm as LM
from ..models import gcn as GCN
from ..models import recsys as RS
from ..models.common import ParamSpec, abstract_params, init_params
from ..train.optimizer import AdamWConfig, adamw_state_specs
from ..train.loop import make_train_step

__all__ = ["ArchDef", "Cell", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES",
           "lm_arch", "gnn_arch", "recsys_arch"]


def _sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclass
class Cell:
    """One dry-run cell: arch × shape, ready to lower on a mesh."""
    arch: str
    shape: str
    kind: str                                  # train|prefill|decode|serve
    fn: Callable
    abstract_args: Tuple[Any, ...]
    #: per-arg: either a ParamSpec pytree (resolved via rules) or a
    #: callable (mesh, rules) -> sharding pytree, or None (replicated)
    arg_spec_trees: Tuple[Any, ...]
    out_spec_trees: Optional[Tuple[Any, ...]] = None
    donate_argnums: Tuple[int, ...] = ()
    notes: str = ""

    def shardings(self, mesh: Mesh, rules: ShardingRules):
        def resolve(tree, args_abs):
            if tree is None:
                return jax.tree.map(
                    lambda _: NamedSharding(mesh, P()), args_abs)
            if callable(tree):
                return tree(mesh, rules)
            return rules.tree_shardings(tree, mesh)
        ins = tuple(resolve(t, a) for t, a in
                    zip(self.arg_spec_trees, self.abstract_args))
        outs = None
        if self.out_spec_trees is not None:
            outs = tuple(resolve(t, None) if not callable(t) and t is not None
                         else (t(mesh, rules) if callable(t) else None)
                         for t in self.out_spec_trees)
        return ins, outs

    def lower(self, mesh: Mesh, rules: Optional[ShardingRules] = None):
        from ..models.common import activation_sharding
        rules = rules or ShardingRules()
        in_sh, out_sh = self.shardings(mesh, rules)
        jit_kwargs: Dict[str, Any] = {"in_shardings": in_sh}
        if out_sh is not None:
            jit_kwargs["out_shardings"] = out_sh
        if self.donate_argnums:
            jit_kwargs["donate_argnums"] = self.donate_argnums
        with mesh, activation_sharding(mesh, rules.spec_for):
            jitted = jax.jit(self.fn, **jit_kwargs)
            return jitted.lower(*self.abstract_args)


@dataclass
class ArchDef:
    name: str
    family: str                    # lm | gnn | recsys
    config: Any
    source: str = ""
    notes: str = ""
    cell_builder: Optional[Callable] = None
    smoke_builder: Optional[Callable] = None

    def shape_names(self) -> List[str]:
        return list({"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                     "recsys": RECSYS_SHAPES}[self.family])

    def cell(self, shape_name: str, **overrides) -> Cell:
        return self.cell_builder(self, shape_name, **overrides)

    def smoke(self):
        """(reduced config, callable() -> dict of output arrays)."""
        return self.smoke_builder(self)


# ---------------------------------------------------------------------------
# shape tables (from the assignment)
# ---------------------------------------------------------------------------

LM_SHAPES: Dict[str, Dict] = {
    "train_4k":    dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k":  dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524288, global_batch=1, kind="decode",
                        window=8192),
}

GNN_SHAPES: Dict[str, Dict] = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg":  dict(kind="train_sampled", n_nodes=232965,
                          n_edges=114615892, batch_nodes=1024,
                          fanouts=(15, 10), d_feat=602, n_classes=41),
    "ogb_products":  dict(kind="train", n_nodes=2449029, n_edges=61859140,
                          d_feat=100, n_classes=47),
    "molecule":      dict(kind="train_mol", n_nodes=30, n_edges=64,
                          batch=128, d_feat=64, n_classes=10),
}

RECSYS_SHAPES: Dict[str, Dict] = {
    "train_batch":    dict(kind="train", batch=65536),
    "serve_p99":      dict(kind="serve", batch=512),
    "serve_bulk":     dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000),
}


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _batch_sharding_fn(ndim: int, dim0: Optional[int] = None):
    """Shard dim0 over the batch mesh axes, pruning on indivisibility
    (long_500k has global_batch=1: batch stays replicated)."""
    def f(mesh, rules):
        ax = list(batch_axes(mesh))
        if dim0 is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            while ax and dim0 % int(np.prod([sizes[a] for a in ax])):
                ax.pop()
        spec = P(tuple(ax) if len(ax) > 1 else (ax[0] if ax else None),
                 *([None] * (ndim - 1)))
        return NamedSharding(mesh, spec)
    return f


def _batch_tree_fn(tree_shapes: Dict[str, int]):
    """dict field -> ndim; shards dim0 on batch axes (if divisible)."""
    def f(mesh, rules):
        ax = batch_axes(mesh)
        out = {}
        for k, meta in tree_shapes.items():
            ndim, dim0 = meta
            n = int(np.prod([dict(zip(mesh.axis_names,
                                      mesh.devices.shape))[a] for a in ax])) \
                if ax else 1
            use = ax if (n and dim0 % max(n, 1) == 0) else ()
            spec = P(use if len(use) > 1 else (use[0] if use else None),
                     *([None] * (ndim - 1)))
            out[k] = NamedSharding(mesh, spec)
        return out
    return f


def _lm_cell(arch: "ArchDef", shape_name: str, *,
             rules: Optional[ShardingRules] = None,
             cfg_overrides: Optional[Dict] = None,
             opt_cfg: Optional[AdamWConfig] = None) -> Cell:
    sh = LM_SHAPES[shape_name]
    cfg: LM.LMConfig = arch.config
    S, B, kind = sh["seq_len"], sh["global_batch"], sh["kind"]
    if "window" in sh:
        cfg = replace(cfg, attn_window=sh["window"])
    if cfg_overrides:
        cfg = replace(cfg, **cfg_overrides)
    opt_cfg = opt_cfg or AdamWConfig()
    specs = LM.param_specs(cfg)
    params_abs = abstract_params(specs)

    if kind == "train":
        loss = lambda p, b: LM.causal_lm_loss(p, b, cfg)
        step_fn, _ = make_train_step(loss, opt_cfg)
        opt_specs = {"adam": adamw_state_specs(specs,
                                               opt_cfg.moment_dtype)}
        opt_abs = abstract_params(opt_specs)
        batch_abs = {"tokens": _sds((B, S)), "labels": _sds((B, S))}
        batch_fn = _batch_tree_fn({"tokens": (2, B), "labels": (2, B)})
        return Cell(arch.name, shape_name, kind, step_fn,
                    (params_abs, opt_abs, batch_abs),
                    (specs, opt_specs, batch_fn),
                    out_spec_trees=(specs, opt_specs, None),
                    donate_argnums=(0, 1))

    if kind == "prefill":
        fn = lambda p, t: LM.prefill(p, t, cfg)
        return Cell(arch.name, shape_name, kind, fn,
                    (params_abs, _sds((B, S))),
                    (specs, _batch_sharding_fn(2, B)))

    # decode
    cache_specs = LM.init_cache_specs(cfg, B, S)
    cache_abs = abstract_params(cache_specs)
    fn = lambda p, c, t, pos: LM.decode_one(p, c, t, pos, cfg)
    return Cell(arch.name, shape_name, "decode", fn,
                (params_abs, cache_abs, _sds((B,)),
                 jax.ShapeDtypeStruct((), jnp.int32)),
                (specs, cache_specs, _batch_sharding_fn(1, B), None),
                donate_argnums=(1,),
                notes=("windowed-attention variant (published config is "
                       "full attention; see DESIGN.md §long-context)"
                       if "window" in sh else ""))


def _strip_layer_dim(s: ParamSpec) -> ParamSpec:
    return ParamSpec(s.shape[1:], s.logical_axes[1:], s.dtype, init=s.init)


def lm_layer_probe(arch: "ArchDef", shape_name: str,
                   cfg_overrides: Optional[Dict] = None) -> Cell:
    """Single-layer probe cell for while-body cost correction.

    XLA cost_analysis counts a while (scan) body once regardless of trip
    count, so the full scanned module under-reports per-layer FLOPs /
    bytes / collective traffic by ×L.  The dry-run compiles this probe —
    one transformer block at the cell's exact activation shapes and
    shardings (chunk loop unrolled) — and corrects:

        total ≈ scanned_module + (L - 1) × probe
    """
    sh = LM_SHAPES[shape_name]
    cfg: LM.LMConfig = arch.config
    S, B, kind = sh["seq_len"], sh["global_batch"], sh["kind"]
    if "window" in sh:
        cfg = replace(cfg, attn_window=sh["window"])
    if cfg_overrides:
        cfg = replace(cfg, **cfg_overrides)
    cfg = replace(cfg, scan_layers=False)   # unroll the chunk loop
    layer_specs = jax.tree.map(
        _strip_layer_dim, LM.param_specs(cfg)["layers"],
        is_leaf=lambda x: isinstance(x, ParamSpec))
    layer_abs = abstract_params(layer_specs)
    D = cfg.d_model

    if kind in ("train", "prefill"):
        x_abs = jax.ShapeDtypeStruct((B, S, D), cfg.dtype)
        if kind == "train":
            def fn(x, layer):
                def proxy(args):
                    out, aux, _ = LM.layer_forward(args[0], args[1], cfg)
                    return jnp.sum(out.astype(jnp.float32)) + aux
                body = jax.checkpoint(
                    proxy, policy=jax.checkpoint_policies.nothing_saveable) \
                    if cfg.remat == "full" else proxy
                return jax.grad(body)((x, layer))
        else:
            def fn(x, layer):
                out, _, kv = LM.layer_forward(x, layer, cfg, collect_kv=True)
                return out, kv
        return Cell(arch.name, shape_name, f"probe_{kind}", fn,
                    (x_abs, layer_abs),
                    (_batch_sharding_fn(3, B), layer_specs))

    # decode probe
    K, hd = cfg.n_kv_heads, cfg.head_dim
    x_abs = jax.ShapeDtypeStruct((B, D), cfg.dtype)
    cache_spec = ParamSpec((B, S, K, hd),
                           ("batch", "kv_seq", "kv_heads", "head_dim"),
                           cfg.dtype, init="zeros")
    cache_abs = jax.ShapeDtypeStruct((B, S, K, hd), cfg.dtype)

    def fn(x, layer, kc, vc, pos):
        return LM.layer_decode(x, layer, kc, vc, pos, cfg)

    return Cell(arch.name, shape_name, "probe_decode", fn,
                (x_abs, layer_abs, cache_abs, cache_abs,
                 jax.ShapeDtypeStruct((), jnp.int32)),
                (_batch_sharding_fn(2, B), layer_specs, cache_spec,
                 cache_spec, None))


def _lm_smoke(arch: "ArchDef"):
    cfg: LM.LMConfig = arch.config
    small = replace(cfg, n_layers=2,
                    d_model=max(64, cfg.head_dim * min(cfg.n_heads, 4)),
                    n_heads=min(cfg.n_heads, 4),
                    n_kv_heads=min(cfg.n_kv_heads,
                                   max(1, min(cfg.n_heads, 4) // 2)),
                    d_head=min(cfg.head_dim, 32), d_ff=128,
                    vocab_size=512, vocab_pad_multiple=128,
                    n_experts=min(cfg.n_experts, 4) if cfg.is_moe else 0,
                    top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
                    dtype=jnp.float32, remat="none")

    def run():
        params = init_params(LM.param_specs(small), jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                  small.vocab_size)
        logits, _ = LM.forward(params, toks, small)
        loss = LM.causal_lm_loss(params, {"tokens": toks, "labels": toks},
                                 small)
        lg, cache = LM.prefill(params, toks, small)
        cache = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))),
            cache)
        lg2, _ = LM.decode_one(params, cache, toks[:, -1], jnp.int32(16),
                               small)
        return {"logits": logits, "loss": loss, "prefill_logits": lg,
                "decode_logits": lg2}

    return small, run


def lm_arch(name: str, cfg: LM.LMConfig, source: str = "",
            notes: str = "") -> ArchDef:
    return ArchDef(name, "lm", cfg, source, notes,
                   cell_builder=_lm_cell, smoke_builder=_lm_smoke)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cell(arch: "ArchDef", shape_name: str) -> Cell:
    sh = GNN_SHAPES[shape_name]
    cfg: GCN.GCNConfig = replace(arch.config, d_feat=sh["d_feat"],
                                 n_classes=sh["n_classes"]) \
        if shape_name != "molecule" else \
        replace(arch.config, d_feat=sh["d_feat"], n_classes=sh["n_classes"])
    specs = GCN.gcn_param_specs(cfg)
    params_abs = abstract_params(specs)
    opt_specs = {"adam": adamw_state_specs(specs)}
    opt_abs = abstract_params(opt_specs)

    if sh["kind"] == "train":
        Np = _pad_to(sh["n_nodes"], 512)
        Ep = _pad_to(sh["n_edges"], 512)
        loss = lambda p, b: GCN.gcn_full_graph_loss(p, b, cfg)
        step_fn, _ = make_train_step(loss, AdamWConfig())
        batch_abs = {"feats": _sds((Np, cfg.d_feat), jnp.float32),
                     "src": _sds((Ep,)), "dst": _sds((Ep,)),
                     "deg": _sds((Np,), jnp.float32),
                     "labels": _sds((Np,)),
                     "label_mask": _sds((Np,), jnp.float32)}

        def bsh(mesh, rules):
            node = NamedSharding(mesh, rules.spec_for(
                (Np,), ("nodes",), mesh))
            node2 = NamedSharding(mesh, rules.spec_for(
                (Np, cfg.d_feat), ("nodes", None), mesh))
            edge = NamedSharding(mesh, rules.spec_for(
                (Ep,), ("edges",), mesh))
            return {"feats": node2, "src": edge, "dst": edge, "deg": node,
                    "labels": node, "label_mask": node}

        return Cell(arch.name, shape_name, "train", step_fn,
                    (params_abs, opt_abs, batch_abs),
                    (specs, opt_specs, bsh),
                    out_spec_trees=(specs, opt_specs, None),
                    donate_argnums=(0, 1))

    if sh["kind"] == "train_sampled":
        B = sh["batch_nodes"]
        f1, f2 = sh["fanouts"]
        loss = lambda p, b: GCN.gcn_sampled_loss(p, b, cfg)
        step_fn, _ = make_train_step(loss, AdamWConfig())
        F = cfg.d_feat
        batch_abs = {"feats_hop0": _sds((B, F), jnp.float32),
                     "feats_hop1": _sds((B, f1, F), jnp.float32),
                     "feats_hop2": _sds((B, f1, f2, F), jnp.float32),
                     "labels": _sds((B,))}
        batch_fn = _batch_tree_fn({k: (len(s.shape), B) for k, s in
                                   batch_abs.items()})
        return Cell(arch.name, shape_name, "train", step_fn,
                    (params_abs, opt_abs, batch_abs),
                    (specs, opt_specs, batch_fn),
                    out_spec_trees=(specs, opt_specs, None),
                    donate_argnums=(0, 1))

    # molecule: batched small graphs
    G, N, E = sh["batch"], sh["n_nodes"], sh["n_edges"]
    loss = lambda p, b: GCN.gcn_molecule_loss(p, b, cfg)
    step_fn, _ = make_train_step(loss, AdamWConfig())
    batch_abs = {"feats": _sds((G, N, cfg.d_feat), jnp.float32),
                 "src": _sds((G, E)), "dst": _sds((G, E)),
                 "deg": _sds((G, N), jnp.float32), "labels": _sds((G,))}
    batch_fn = _batch_tree_fn({k: (len(s.shape), G)
                               for k, s in batch_abs.items()})
    return Cell(arch.name, shape_name, "train", step_fn,
                (params_abs, opt_abs, batch_abs),
                (specs, opt_specs, batch_fn),
                out_spec_trees=(specs, opt_specs, None),
                donate_argnums=(0, 1))


def _gnn_smoke(arch: "ArchDef"):
    cfg = replace(arch.config, d_feat=32, n_classes=7)

    def run():
        rng = np.random.default_rng(0)
        params = init_params(GCN.gcn_param_specs(cfg), jax.random.key(0))
        N, E = 64, 256
        src = jnp.array(rng.integers(0, N, E), jnp.int32)
        dst = jnp.array(rng.integers(0, N, E), jnp.int32)
        batch = {"feats": jnp.array(rng.normal(size=(N, 32)), jnp.float32),
                 "src": src, "dst": dst,
                 "deg": jnp.array(np.bincount(np.asarray(dst),
                                              minlength=N) + 1, jnp.float32),
                 "labels": jnp.array(rng.integers(0, 7, N), jnp.int32),
                 "label_mask": jnp.ones(N, jnp.float32)}
        loss = GCN.gcn_full_graph_loss(params, batch, cfg)
        logits = GCN.gcn_full_graph_logits(
            params, batch["feats"], src, dst, batch["deg"], cfg)
        return {"loss": loss, "logits": logits}

    return cfg, run


def gnn_arch(name: str, cfg: GCN.GCNConfig, source: str = "",
             notes: str = "") -> ArchDef:
    return ArchDef(name, "gnn", cfg, source, notes,
                   cell_builder=_gnn_cell, smoke_builder=_gnn_smoke)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch_abs(cfg: RS.RecsysConfig, B: int) -> Dict:
    if cfg.kind in ("dlrm", "dcn"):
        return {"dense": _sds((B, cfg.n_dense), jnp.float32),
                "sparse": _sds((B, cfg.n_sparse)),
                "labels": _sds((B,))}
    if cfg.kind == "mind":
        return {"hist_ids": _sds((B, cfg.hist_len)),
                "hist_mask": _sds((B, cfg.hist_len), jnp.float32),
                "target_ids": _sds((B,))}
    if cfg.kind == "two_tower":
        return {"user_ids": _sds((B,)), "item_ids": _sds((B,))}
    raise ValueError(cfg.kind)


def _recsys_cell(arch: "ArchDef", shape_name: str) -> Cell:
    sh = RECSYS_SHAPES[shape_name]
    cfg: RS.RecsysConfig = arch.config
    specs = RS.recsys_param_specs(cfg)
    params_abs = abstract_params(specs)

    if sh["kind"] == "train":
        B = sh["batch"]
        loss = lambda p, b: RS.recsys_train_loss(p, b, cfg)
        step_fn, _ = make_train_step(loss, AdamWConfig())
        opt_specs = {"adam": adamw_state_specs(specs)}
        batch_abs = _recsys_batch_abs(cfg, B)
        batch_fn = _batch_tree_fn({k: (len(s.shape), B)
                                   for k, s in batch_abs.items()})
        return Cell(arch.name, shape_name, "train", step_fn,
                    (params_abs, abstract_params(opt_specs), batch_abs),
                    (specs, opt_specs, batch_fn),
                    out_spec_trees=(specs, opt_specs, None),
                    donate_argnums=(0, 1))

    if sh["kind"] == "serve":
        B = sh["batch"]
        fn = lambda p, b: RS.recsys_serve(p, b, cfg)
        batch_abs = _recsys_batch_abs(cfg, B)
        if cfg.kind == "two_tower":   # score user against the paired item
            batch_abs = {"user_ids": _sds((B,)), "cand_ids": _sds((B,))}
            fn = lambda p, b: RS.two_tower_retrieval_scores(p, b, cfg)
        batch_fn = _batch_tree_fn({k: (len(s.shape), s.shape[0])
                                   for k, s in batch_abs.items()})
        return Cell(arch.name, shape_name, "serve", fn,
                    (params_abs, batch_abs), (specs, batch_fn))

    # retrieval_cand: one query scored against n_candidates
    N = sh["n_candidates"]
    if cfg.kind == "two_tower":
        batch_abs = {"user_ids": _sds((1,)), "cand_ids": _sds((N,))}
        fn = lambda p, b: RS.two_tower_retrieval_scores(p, b, cfg)
    elif cfg.kind == "mind":
        batch_abs = {"hist_ids": _sds((1, cfg.hist_len)),
                     "hist_mask": _sds((1, cfg.hist_len), jnp.float32),
                     "target_ids": _sds((N,))}

        def fn(p, b, _cfg=cfg):
            u = RS.mind_interests(p, b["hist_ids"], b["hist_mask"], _cfg)
            t = jnp.take(p["item_embed"], b["target_ids"], axis=0,
                         mode="clip")
            return jnp.einsum("qkd,nd->qkn", u, t).max(axis=1)
    else:   # dlrm/dcn: broadcast one user over N candidate rows
        batch_abs = _recsys_batch_abs(cfg, N)
        batch_abs.pop("labels")
        fn = (lambda p, b: RS.recsys_serve(
            p, {**b, "labels": None}, cfg)) if False else \
            (lambda p, b: jax.nn.sigmoid(
                (RS.dlrm_forward if cfg.kind == "dlrm" else RS.dcn_forward)(
                    p, b, cfg)))
    batch_fn = _batch_tree_fn({k: (len(s.shape), s.shape[0])
                               for k, s in batch_abs.items()})
    return Cell(arch.name, shape_name, "serve", fn,
                (params_abs, batch_abs), (specs, batch_fn))


def _recsys_smoke(arch: "ArchDef"):
    cfg: RS.RecsysConfig = arch.config
    embed_small = min(cfg.embed_dim, 8)
    small = replace(
        cfg,
        vocab_sizes=tuple(min(v, 64) for v in cfg.vocab_sizes),
        embed_dim=embed_small,
        # DLRM invariant: bottom-MLP output dim == embed_dim
        bot_mlp=(tuple(min(x, 16) for x in cfg.bot_mlp[:-1])
                 + (embed_small,)) if cfg.bot_mlp else (),
        top_mlp=tuple(min(x, 16) for x in cfg.top_mlp),
        deep_mlp=tuple(min(x, 16) for x in cfg.deep_mlp),
        tower_mlp=tuple(min(x, 16) for x in cfg.tower_mlp),
        item_vocab=min(cfg.item_vocab, 128),
        user_vocab=min(cfg.user_vocab, 128),
        hist_len=min(cfg.hist_len, 8))

    def run():
        rng = np.random.default_rng(0)
        params = init_params(RS.recsys_param_specs(small), jax.random.key(0))
        B = 16
        if small.kind in ("dlrm", "dcn"):
            batch = {"dense": jnp.array(rng.normal(size=(B, small.n_dense)),
                                        jnp.float32),
                     "sparse": jnp.array(
                         rng.integers(0, min(small.vocab_sizes),
                                      (B, small.n_sparse)), jnp.int32),
                     "labels": jnp.array(rng.integers(0, 2, B), jnp.int32)}
        elif small.kind == "mind":
            batch = {"hist_ids": jnp.array(
                rng.integers(0, small.item_vocab, (B, small.hist_len)),
                jnp.int32),
                "hist_mask": jnp.ones((B, small.hist_len), jnp.float32),
                "target_ids": jnp.array(
                    rng.integers(0, small.item_vocab, B), jnp.int32)}
        else:
            batch = {"user_ids": jnp.array(
                rng.integers(0, small.user_vocab, B), jnp.int32),
                "item_ids": jnp.array(
                    rng.integers(0, small.item_vocab, B), jnp.int32)}
        loss = RS.recsys_train_loss(params, batch, small)
        if small.kind == "two_tower":
            serve = RS.recsys_serve(params, {
                "user_ids": batch["user_ids"][:1],
                "cand_ids": batch["item_ids"]}, small)
        else:
            serve = RS.recsys_serve(params, batch, small)
        return {"loss": loss, "serve": serve}

    return small, run


def recsys_arch(name: str, cfg: RS.RecsysConfig, source: str = "",
                notes: str = "") -> ArchDef:
    return ArchDef(name, "recsys", cfg, source, notes,
                   cell_builder=_recsys_cell, smoke_builder=_recsys_smoke)
