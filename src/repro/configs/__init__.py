from .registry import ARCHS, get_arch, all_cells
from .base import ArchDef, Cell, LM_SHAPES, GNN_SHAPES, RECSYS_SHAPES

__all__ = ["ARCHS", "get_arch", "all_cells", "ArchDef", "Cell",
           "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES"]
