"""Inverted index + BM25 first-stage retrieval (Q → R).

The index is a plain term→postings map (doc ids + term frequencies in
numpy arrays).  Scoring walks the query-term postings and accumulates
BM25 into a dense per-doc array — the standard TAAT strategy, vectorized
per term.  A blocked JAX formulation of the same arithmetic lives in
``repro.kernels.bm25_block`` (the TPU-targeted version of this loop);
the two are cross-validated in tests.
"""
from __future__ import annotations

import json
import os
import pickle
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from ..core.frame import ColFrame
from ..core.pipeline import Indexer, Transformer, add_ranks
from .tokenizer import WordTokenizer

__all__ = ["InvertedIndex", "BM25Retriever", "TextLoader", "QueryExpander"]


class InvertedIndex:
    """Term → (doc_ids int32[], tf float32[]) postings."""

    def __init__(self):
        self.postings: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        self.doc_len: Optional[np.ndarray] = None
        self.docnos: List[str] = []
        self.avg_dl: float = 0.0
        self.tokenizer = WordTokenizer()

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, corpus_iter: Iterable[dict],
              tokenizer: Optional[WordTokenizer] = None) -> "InvertedIndex":
        idx = cls()
        if tokenizer is not None:
            idx.tokenizer = tokenizer
        tmp: Dict[str, Dict[int, int]] = {}
        doc_lens: List[int] = []
        for i, doc in enumerate(corpus_iter):
            toks = idx.tokenizer.tokenize(doc["text"])
            idx.docnos.append(str(doc["docno"]))
            doc_lens.append(len(toks))
            counts: Dict[str, int] = {}
            for t in toks:
                counts[t] = counts.get(t, 0) + 1
            for t, c in counts.items():
                tmp.setdefault(t, {})[i] = c
        idx.doc_len = np.asarray(doc_lens, dtype=np.float32)
        idx.avg_dl = float(idx.doc_len.mean()) if len(doc_lens) else 0.0
        for t, post in tmp.items():
            ids = np.fromiter(post.keys(), dtype=np.int32, count=len(post))
            tfs = np.fromiter(post.values(), dtype=np.float32, count=len(post))
            order = np.argsort(ids)
            idx.postings[t] = (ids[order], tfs[order])
        return idx

    @property
    def n_docs(self) -> int:
        return len(self.docnos)

    def idf(self, term: str) -> float:
        post = self.postings.get(term)
        df = len(post[0]) if post is not None else 0
        n = max(self.n_docs, 1)
        return float(np.log(1.0 + (n - df + 0.5) / (df + 0.5)))

    # -- persistence (Artifact-compatible directory layout) ----------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, "postings.pkl"), "wb") as f:
            pickle.dump(self.postings, f, protocol=pickle.HIGHEST_PROTOCOL)
        np.save(os.path.join(path, "doc_len.npy"), self.doc_len)
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump({"docnos": self.docnos, "avg_dl": self.avg_dl}, f)

    @classmethod
    def load(cls, path: str) -> "InvertedIndex":
        idx = cls()
        with open(os.path.join(path, "postings.pkl"), "rb") as f:
            idx.postings = pickle.load(f)
        idx.doc_len = np.load(os.path.join(path, "doc_len.npy"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        idx.docnos = meta["docnos"]
        idx.avg_dl = meta["avg_dl"]
        return idx

    # -- pipeline stage factories ------------------------------------------
    def bm25(self, *, k1: float = 1.2, b: float = 0.75,
             num_results: int = 1000) -> "BM25Retriever":
        return BM25Retriever(self, k1=k1, b=b, num_results=num_results)

    def indexer(self) -> "_IndexBuilder":
        return _IndexBuilder(self)


class _IndexBuilder(Indexer):
    """Terminal D→∅ stage that (re)builds an InvertedIndex in place."""

    def __init__(self, target: InvertedIndex):
        self.target = target

    def index(self, corpus_iter: Iterable[dict]) -> InvertedIndex:
        built = InvertedIndex.build(corpus_iter, self.target.tokenizer)
        self.target.__dict__.update(built.__dict__)
        return self.target

    def signature(self):
        return ("_IndexBuilder", id(self.target))


class BM25Retriever(Transformer):
    """Q → R: classic BM25 with TAAT accumulation."""

    input_columns = frozenset({"qid", "query"})
    output_columns = frozenset({"qid", "query", "docno", "score", "rank"})
    key_columns = ("qid", "query")
    one_to_many = True

    def __init__(self, index: InvertedIndex, *, k1: float = 1.2,
                 b: float = 0.75, num_results: int = 1000,
                 name: str = "bm25"):
        self.index = index
        self.k1 = float(k1)
        self.b = float(b)
        self.num_results = int(num_results)
        self.name = name

    def signature(self):
        return ("BM25Retriever", self.name, self.k1, self.b,
                self.num_results, self.index.n_docs)

    def with_cutoff(self, k: int) -> "BM25Retriever":
        """Absorb a downstream ``RankCutoff(k)`` into the retrieval
        depth (the optimizer's pushdown pass, ``core/rewrite.py``).
        Sound because truncation is prefix-closed: the top-k of the
        top-``num_results`` equals the global top-k for ``k <=
        num_results`` — ``score_query`` resolves boundary score ties
        deterministically by doc index, the same order ``lexsort``
        imposes inside the returned ranking."""
        if int(k) >= self.num_results:
            return self                  # already at most k results
        return BM25Retriever(self.index, k1=self.k1, b=self.b,
                             num_results=int(k), name=self.name)

    def score_query(self, query: str) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (doc_indices, scores) of the top-num_results docs."""
        idx = self.index
        acc = np.zeros(idx.n_docs, dtype=np.float32)
        dl_norm = self.k1 * (1.0 - self.b + self.b * idx.doc_len
                             / max(idx.avg_dl, 1e-9))
        for term in idx.tokenizer.tokenize(query):
            post = idx.postings.get(term)
            if post is None:
                continue
            ids, tfs = post
            w = idx.idf(term) * tfs * (self.k1 + 1.0) / (tfs + dl_norm[ids])
            acc[ids] += w
        nz = np.nonzero(acc)[0]
        if len(nz) > self.num_results:
            k = self.num_results
            part = np.argpartition(-acc[nz], k - 1)
            kth = acc[nz[part[k - 1]]]
            # deterministic boundary: keep everything strictly above the
            # k-th score, then the smallest doc indices among its ties —
            # matching the lexsort tie order below, so top-k is a prefix
            # of top-n for any n >= k (required by `% k` pushdown)
            above = nz[acc[nz] > kth]
            ties = np.sort(nz[acc[nz] == kth])
            nz = np.concatenate([above, ties[:k - len(above)]])
        order = np.lexsort((nz, -acc[nz]))
        nz = nz[order]
        return nz, acc[nz]

    def transform(self, inp: ColFrame) -> ColFrame:
        qids, docnos, scores, ranks, queries = [], [], [], [], []
        for qid, query in zip(inp["qid"].tolist(), inp["query"].tolist()):
            ids, sc = self.score_query(query)
            qids.extend([qid] * len(ids))
            queries.extend([query] * len(ids))
            docnos.extend(self.index.docnos[i] for i in ids)
            scores.extend(sc.tolist())
            ranks.extend(range(len(ids)))
        return ColFrame({"qid": qids, "query": queries, "docno": docnos,
                         "score": np.asarray(scores, dtype=np.float64),
                         "rank": np.asarray(ranks, dtype=np.int64)})


class TextLoader(Transformer):
    """R → R: attach the document text column (paper's text_loader())."""

    input_columns = frozenset({"qid", "docno"})
    key_columns = ("docno",)
    value_columns = ("text",)
    #: per-row column append: rows, order and existing columns untouched
    augment_only = True
    rank_preserving = True

    def __init__(self, text_map: Dict[str, str], name: str = "text_loader"):
        self.text_map = text_map
        self.name = name

    def transform(self, inp: ColFrame) -> ColFrame:
        texts = np.empty(len(inp), dtype=object)
        texts[:] = [self.text_map.get(str(d), "") for d in
                    inp["docno"].tolist()]
        return inp.assign(text=texts)

    def signature(self):
        return ("TextLoader", self.name, len(self.text_map))


class QueryExpander(Transformer):
    """Q → Q: deterministic pseudo query rewriter (doubles salient terms).

    Stands in for Doc2Query/RM3-style rewriters in tests of
    KeyValueCache (Q→Q caching family)."""

    input_columns = frozenset({"qid", "query"})
    key_columns = ("qid", "query")
    value_columns = ("query",)

    def __init__(self, repeat: int = 2):
        self.repeat = int(repeat)

    def transform(self, inp: ColFrame) -> ColFrame:
        new_q = np.empty(len(inp), dtype=object)
        for i, q in enumerate(inp["query"].tolist()):
            toks = q.split()
            new_q[i] = " ".join(toks + toks[:1] * (self.repeat - 1))
        return inp.assign(query=new_q)

    def signature(self):
        return ("QueryExpander", self.repeat)
