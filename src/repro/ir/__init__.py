# IR substrate: tokenization, synthetic corpora, inverted index + BM25.
from .tokenizer import WordTokenizer, HashTokenizer, fnv1a32
from .corpus import SyntheticCorpus, make_corpus, msmarco_like
from .index import InvertedIndex, BM25Retriever, TextLoader, QueryExpander
from .dense import DenseEncoder, DenseIndex, DenseRetriever

__all__ = ["WordTokenizer", "HashTokenizer", "fnv1a32", "SyntheticCorpus",
           "make_corpus", "msmarco_like", "InvertedIndex", "BM25Retriever",
           "TextLoader", "QueryExpander", "DenseEncoder", "DenseIndex",
           "DenseRetriever"]
