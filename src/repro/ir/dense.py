"""Dense (neural) first-stage retrieval as a pipeline stage (Q → R).

The paper's RetrieverCache wraps *any* retriever; this is the neural
one: encode the corpus once (offline, cacheable via IndexerCache),
encode queries online, brute-force top-k over the embedding matrix —
exactly the `retrieval_cand` pattern of the two-tower arch, surfaced as
an IR pipeline transformer.

Embeddings come from the shared cross-encoder tower in single-text mode
(mean-pooled), so the whole stack — tokenizer, encoder, jit — reuses
the framework substrate.  Scoring is one jitted matmul per query batch;
on TPU the embedding matrix is row-sharded like a recsys table.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..caching.compile_cache import default_compile_cache
from ..core.frame import ColFrame
from ..core.pipeline import Transformer
from ..models.common import init_params, rms_norm

# NOTE: cross_encoder is imported lazily inside DenseEncoder.__init__ —
# cross_encoder itself imports repro.ir.tokenizer, so a module-level
# import here would close an import cycle through repro.ir.__init__.

__all__ = ["DenseEncoder", "DenseIndex", "DenseRetriever"]

EncoderConfig = Any   # type alias; see lazy-import note above


class DenseEncoder:
    """Text -> embedding via the shared encoder backbone (mean pool)."""

    def __init__(self, cfg, seed: int = 7):
        from ..models.cross_encoder import encoder_param_specs
        from .tokenizer import HashTokenizer
        self.cfg = cfg
        self.seed = seed
        self.params = init_params(encoder_param_specs(cfg),
                                  jax.random.key(seed))
        self.tokenizer = HashTokenizer(cfg.vocab_size)

    def _embed_fn(self, tokens: jnp.ndarray) -> jnp.ndarray:
        p, cfg = self.params, self.cfg
        mask = (tokens != 0)
        x = jnp.take(p["embed"], tokens, axis=0, mode="clip")
        x = x + p["pos"][None, :tokens.shape[1]]

        def layer_body(x, layer):
            h = rms_norm(x, layer["ln1"])
            q = jnp.einsum("bsd,dnh->bsnh", h, layer["wq"])
            k = jnp.einsum("bsd,dnh->bsnh", h, layer["wk"])
            v = jnp.einsum("bsd,dnh->bsnh", h, layer["wv"])
            s = jnp.einsum("bqnh,bsnh->bnqs", q, k).astype(jnp.float32)
            bias = jnp.where(mask, 0.0, -1e30)[:, None, None, :]
            pr = jax.nn.softmax(s / np.sqrt(cfg.head_dim) + bias,
                                axis=-1).astype(x.dtype)
            a = jnp.einsum("bnqs,bsnh->bqnh", pr, v)
            x = x + jnp.einsum("bqnh,nhd->bqd", a, layer["wo"])
            h2 = rms_norm(x, layer["ln2"])
            ff = jnp.einsum("bsf,fd->bsd",
                            jax.nn.gelu(jnp.einsum("bsd,df->bsf", h2,
                                                   layer["w1"])),
                            layer["w2"])
            return x + ff, None

        x, _ = jax.lax.scan(layer_body, x, p["layers"])
        x = rms_norm(x, p["ln_f"])
        m = mask[..., None].astype(x.dtype)
        pooled = (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
        return pooled / jnp.maximum(
            jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-6)

    def encode(self, texts: Sequence[str], batch: int = 256) -> np.ndarray:
        outs = []
        for lo in range(0, len(texts), batch):
            chunk = texts[lo:lo + batch]
            toks = self.tokenizer.encode_batch(chunk, self.cfg.max_len)
            pad = (-len(chunk)) % 8
            if pad:
                toks = np.concatenate([toks, np.zeros((pad,
                                                       self.cfg.max_len),
                                                      np.int32)])
            emb = default_compile_cache.call(
                f"dense_encode:{self.cfg.name}", self._embed_fn,
                jnp.asarray(toks))
            outs.append(np.asarray(emb)[:len(chunk)])
        return np.concatenate(outs) if outs else \
            np.zeros((0, self.cfg.d_model), np.float32)


class DenseIndex:
    """Corpus embedding matrix + docno map (brute-force top-k)."""

    def __init__(self, encoder: DenseEncoder):
        self.encoder = encoder
        self.docnos: list = []
        self.matrix: Optional[np.ndarray] = None

    def index(self, corpus_iter) -> "DenseIndex":
        rows = list(corpus_iter)
        self.docnos = [str(r["docno"]) for r in rows]
        self.matrix = self.encoder.encode([r["text"] for r in rows])
        return self

    def retriever(self, num_results: int = 100) -> "DenseRetriever":
        return DenseRetriever(self, num_results=num_results)


class DenseRetriever(Transformer):
    """Q → R over a DenseIndex (one batched matmul per query batch)."""

    input_columns = frozenset({"qid", "query"})
    output_columns = frozenset({"qid", "query", "docno", "score", "rank"})
    key_columns = ("qid", "query")
    one_to_many = True

    def __init__(self, index: DenseIndex, num_results: int = 100):
        self.index = index
        self.num_results = int(num_results)

    def signature(self):
        return ("DenseRetriever", self.index.encoder.cfg.name,
                self.index.encoder.seed, len(self.index.docnos),
                self.num_results)

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0 or self.index.matrix is None:
            return ColFrame()
        q_emb = self.index.encoder.encode(
            [str(q) for q in inp["query"].tolist()])
        scores = q_emb @ self.index.matrix.T          # [Q, N]
        k = min(self.num_results, scores.shape[1])
        rows = []
        for i, (qid, query) in enumerate(zip(inp["qid"].tolist(),
                                             inp["query"].tolist())):
            top = np.argpartition(-scores[i], k - 1)[:k]
            top = top[np.argsort(-scores[i][top], kind="stable")]
            for r, j in enumerate(top):
                rows.append({"qid": qid, "query": query,
                             "docno": self.index.docnos[j],
                             "score": float(scores[i, j]), "rank": r})
        return ColFrame.from_dicts(rows)
