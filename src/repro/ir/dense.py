"""Dense (neural) first-stage retrieval as a compiler-native stage (Q → R).

The paper's RetrieverCache wraps *any* retriever; this is the neural
one: encode the corpus once (offline, cacheable via IndexerCache),
encode queries online, top-k over the embedding matrix — the
`retrieval_cand` pattern of the two-tower arch, surfaced as a
first-class plan-compiler node:

* the hot path is the fused ``kernels/dense_topk`` blocked matmul +
  streaming top-k (``backend="pallas"``: compiled Mosaic on TPU,
  interpret-mode fallback on CPU) or the same math through XLA
  (``backend="xla"``, the default off-TPU — ``lax.top_k`` over one
  jitted contraction per corpus shard);
* the corpus embedding matrix is row-sharded across local devices via
  the ``table_rows`` rule of ``distrib/shardings.py``; each device
  computes a partial top-k over its rows and the partials are merged
  on host under the global tie-break (descending score, then ascending
  doc index);
* that deterministic total order is what makes ``with_cutoff`` sound,
  so the optimizer's pushdown pass (``core/rewrite.py``) fuses
  ``RankCutoff`` into the kernel's per-block k exactly as it does for
  ``BM25Retriever.num_results``;
* ``signature()`` / ``fingerprint_extras()`` carry the corpus content
  digest, so planner-inserted caches (``auto_cache`` →
  ``RetrieverCache``; ``one_to_many=True``) invalidate when the
  embedding matrix changes.

Embeddings come from the shared cross-encoder tower in single-text mode
(mean-pooled); query embeddings are memoized per encoder (bounded LRU),
so hybrid plans whose branches survive CSE as distinct nodes — e.g.
``dense % 5`` next to ``dense % 50`` after pushdown — still encode each
unique query once per process.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from functools import partial
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..caching.compile_cache import default_compile_cache
from ..core.frame import ColFrame
from ..core.pipeline import Transformer
from ..kernels.dense_topk import dense_topk_op
from ..models.common import init_params, rms_norm

# NOTE: cross_encoder is imported lazily inside DenseEncoder.__init__ —
# cross_encoder itself imports repro.ir.tokenizer, so a module-level
# import here would close an import cycle through repro.ir.__init__.

__all__ = ["DenseEncoder", "DenseIndex", "DenseRetriever"]

EncoderConfig = Any   # type alias; see lazy-import note above


class DenseEncoder:
    """Text -> embedding via the shared encoder backbone (mean pool)."""

    #: bound on the query-embedding memo (LRU, see ``encode_queries``)
    QUERY_MEMO_MAX = 4096

    def __init__(self, cfg, seed: int = 7):
        from ..models.cross_encoder import encoder_param_specs
        from .tokenizer import HashTokenizer
        self.cfg = cfg
        self.seed = seed
        self.params = init_params(encoder_param_specs(cfg),
                                  jax.random.key(seed))
        self.tokenizer = HashTokenizer(cfg.vocab_size)
        self._query_memo: "OrderedDict[str, np.ndarray]" = OrderedDict()
        #: texts actually pushed through the backbone (memo hits do not
        #: count) — tests assert CSE'd branches encode each query once
        self.encoded_texts = 0

    def _embed_fn(self, tokens: jnp.ndarray) -> jnp.ndarray:
        p, cfg = self.params, self.cfg
        mask = (tokens != 0)
        x = jnp.take(p["embed"], tokens, axis=0, mode="clip")
        x = x + p["pos"][None, :tokens.shape[1]]

        def layer_body(x, layer):
            h = rms_norm(x, layer["ln1"])
            q = jnp.einsum("bsd,dnh->bsnh", h, layer["wq"])
            k = jnp.einsum("bsd,dnh->bsnh", h, layer["wk"])
            v = jnp.einsum("bsd,dnh->bsnh", h, layer["wv"])
            s = jnp.einsum("bqnh,bsnh->bnqs", q, k).astype(jnp.float32)
            bias = jnp.where(mask, 0.0, -1e30)[:, None, None, :]
            pr = jax.nn.softmax(s / np.sqrt(cfg.head_dim) + bias,
                                axis=-1).astype(x.dtype)
            a = jnp.einsum("bnqs,bsnh->bqnh", pr, v)
            x = x + jnp.einsum("bqnh,nhd->bqd", a, layer["wo"])
            h2 = rms_norm(x, layer["ln2"])
            ff = jnp.einsum("bsf,fd->bsd",
                            jax.nn.gelu(jnp.einsum("bsd,df->bsf", h2,
                                                   layer["w1"])),
                            layer["w2"])
            return x + ff, None

        x, _ = jax.lax.scan(layer_body, x, p["layers"])
        x = rms_norm(x, p["ln_f"])
        m = mask[..., None].astype(x.dtype)
        pooled = (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
        return pooled / jnp.maximum(
            jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-6)

    def encode(self, texts: Sequence[str], batch: int = 256) -> np.ndarray:
        outs = []
        for lo in range(0, len(texts), batch):
            chunk = texts[lo:lo + batch]
            toks = self.tokenizer.encode_batch(chunk, self.cfg.max_len)
            pad = (-len(chunk)) % 8
            if pad:
                toks = np.concatenate([toks, np.zeros((pad,
                                                       self.cfg.max_len),
                                                      np.int32)])
            emb = default_compile_cache.call(
                f"dense_encode:{self.cfg.name}", self._embed_fn,
                jnp.asarray(toks))
            outs.append(np.asarray(emb)[:len(chunk)])
            self.encoded_texts += len(chunk)
        return np.concatenate(outs) if outs else \
            np.zeros((0, self.cfg.d_model), np.float32)

    def encode_queries(self, texts: Sequence[str]) -> np.ndarray:
        """``encode`` behind a bounded per-encoder LRU memo.

        Encoder params are a pure function of ``(cfg, seed)``, so the
        text → embedding map is immutable for this instance; distinct
        plan nodes sharing the encoder (CSE'd hybrid branches, repeated
        serve traffic) therefore encode each unique text once.  Corpus
        indexing bypasses the memo (``encode``) — only the online query
        stream is worth pinning.
        """
        out = np.empty((len(texts), self.cfg.d_model), np.float32)
        fresh: List[str] = []
        for t in texts:
            hit = self._query_memo.get(t)
            if hit is None:
                if t not in fresh:
                    fresh.append(t)
            else:
                self._query_memo.move_to_end(t)
        if fresh:
            emb = self.encode(fresh)
            for t, e in zip(fresh, emb):
                self._query_memo[t] = e
            while len(self._query_memo) > self.QUERY_MEMO_MAX:
                self._query_memo.popitem(last=False)
        for i, t in enumerate(texts):
            out[i] = self._query_memo[t]
        return out


@partial(jax.jit, static_argnames=("k",))
def _xla_chunk_topk(q_emb: jnp.ndarray, chunk: jnp.ndarray, k: int):
    """Per-shard fused scoring on the XLA path (same math as
    ``kernels/dense_topk/ref.py``, kept inline so each corpus shard
    jits against its resident device buffer)."""
    s = jax.lax.dot_general(q_emb, chunk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    vals, idxs = jax.lax.top_k(s, k)
    return vals, idxs.astype(jnp.int32)


class DenseIndex:
    """Corpus embedding matrix + docno map, row-sharded across devices."""

    def __init__(self, encoder: DenseEncoder):
        self.encoder = encoder
        self.docnos: list = []
        self.matrix: Optional[np.ndarray] = None
        self._digest: Optional[str] = None
        self._chunks: Optional[List[Tuple[int, jnp.ndarray]]] = None
        self.sharding_spec = None        # recorded table_rows decision

    def index(self, corpus_iter) -> "DenseIndex":
        rows = list(corpus_iter)
        self.docnos = [str(r["docno"]) for r in rows]
        self.matrix = self.encoder.encode([r["text"] for r in rows])
        self._digest = None
        self._chunks = None
        return self

    def content_digest(self) -> str:
        """Stable digest of the docno map + embedding matrix bytes —
        the provenance token ``DenseRetriever.fingerprint_extras``
        folds in, so caches invalidate when the corpus is re-encoded."""
        if self._digest is None:
            h = hashlib.sha256()
            h.update(repr(self.docnos).encode())
            if self.matrix is not None:
                h.update(np.ascontiguousarray(self.matrix).tobytes())
            self._digest = h.hexdigest()[:16]
        return self._digest

    def device_chunks(self) -> List[Tuple[int, jnp.ndarray]]:
        """Row-shard the corpus matrix across local devices: the
        ``table_rows`` logical-axis rule of ``distrib/shardings.py``
        (rows over the data axis, feature dim replicated), realized as
        one contiguous ``(row_offset, resident chunk)`` per device.
        Chunks are independent — each device computes a partial top-k,
        merged on host — so ragged splits are fine even where the SPMD
        rule engine would prune for indivisibility.
        """
        if self._chunks is None:
            # deferred: distrib pulls in the model zoo, whose
            # cross-encoder imports back through repro.ir — importing
            # at module scope would close that cycle
            from ..distrib.shardings import ShardingRules
            assert self.matrix is not None, "index() before device_chunks()"
            devs = jax.devices()
            mesh = jax.sharding.Mesh(np.asarray(devs), ("data",))
            self.sharding_spec = ShardingRules().spec_for(
                self.matrix.shape, ("table_rows", "table_dim"), mesh)
            n_rows = self.matrix.shape[0]
            n = len(devs) if (len(self.sharding_spec) and
                              self.sharding_spec[0] is not None) else 1
            n = max(1, min(n, n_rows))
            bounds = [(n_rows * i) // n for i in range(n + 1)]
            self._chunks = [
                (lo, jax.device_put(jnp.asarray(self.matrix[lo:hi]),
                                    devs[i]))
                for i, (lo, hi) in enumerate(zip(bounds, bounds[1:]))
                if hi > lo]
        return self._chunks

    def topk(self, q_emb: np.ndarray, k: int, *,
             backend: str = "xla") -> Tuple[np.ndarray, np.ndarray]:
        """Global top-k over the sharded corpus: per-device partial
        top-k (fused kernel or XLA), then a host merge under the total
        order (score desc, doc index asc) — deterministic ties, so
        top-k is a prefix of top-n and cutoff fusion is sound."""
        k = int(min(k, len(self.docnos)))
        parts_v, parts_i = [], []
        qj = jnp.asarray(q_emb, jnp.float32)
        for lo, chunk in self.device_chunks():
            kk = min(k, int(chunk.shape[0]))
            if backend == "pallas":
                v, i = dense_topk_op(qj, chunk, k=kk)
            else:
                v, i = _xla_chunk_topk(qj, chunk, kk)
            parts_v.append(np.asarray(v))
            parts_i.append(np.asarray(i) + lo)
        vals = np.concatenate(parts_v, axis=1)
        idxs = np.concatenate(parts_i, axis=1)
        out_v = np.empty((len(q_emb), k), np.float32)
        out_i = np.empty((len(q_emb), k), np.int64)
        for r in range(len(q_emb)):
            order = np.lexsort((idxs[r], -vals[r]))[:k]
            out_v[r] = vals[r][order]
            out_i[r] = idxs[r][order]
        return out_v, out_i

    def retriever(self, num_results: int = 100, *,
                  backend: str = "xla") -> "DenseRetriever":
        return DenseRetriever(self, num_results=num_results,
                              backend=backend)


class DenseRetriever(Transformer):
    """Q → R over a DenseIndex via the fused blocked-matmul top-k."""

    input_columns = frozenset({"qid", "query"})
    output_columns = frozenset({"qid", "query", "docno", "score", "rank"})
    key_columns = ("qid", "query")
    one_to_many = True
    shardable = True                     # row-local per qid

    def __init__(self, index: DenseIndex, num_results: int = 100, *,
                 backend: str = "xla"):
        assert backend in ("xla", "pallas"), backend
        self.index = index
        self.num_results = int(num_results)
        self.backend = backend

    def signature(self):
        return ("DenseRetriever", self.index.encoder.cfg.name,
                self.index.encoder.seed, len(self.index.docnos),
                self.num_results)

    def fingerprint_extras(self) -> Tuple:
        """Corpus content + scoring backend: re-encoding the corpus or
        switching the kernel path (whose reductions may round
        differently) must invalidate planner-inserted caches even
        though the structural ``signature()`` is unchanged."""
        return ("corpus", self.index.content_digest(),
                "backend", self.backend)

    def with_cutoff(self, k: int) -> "DenseRetriever":
        """Absorb a downstream ``RankCutoff(k)`` into the kernel's
        per-block k (the optimizer's pushdown pass, ``core/rewrite.py``).
        Sound because ``DenseIndex.topk`` resolves score ties by
        ascending doc index — a total order, so the top-k of the
        top-``num_results`` equals the global top-k for ``k <=
        num_results``."""
        if int(k) >= self.num_results:
            return self                  # already at most k results
        return DenseRetriever(self.index, num_results=int(k),
                              backend=self.backend)

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0 or self.index.matrix is None:
            return ColFrame()
        q_emb = self.index.encoder.encode_queries(
            [str(q) for q in inp["query"].tolist()])
        k = min(self.num_results, len(self.index.docnos))
        vals, idxs = self.index.topk(q_emb, k, backend=self.backend)
        rows = []
        for i, (qid, query) in enumerate(zip(inp["qid"].tolist(),
                                             inp["query"].tolist())):
            for r in range(k):
                rows.append({"qid": qid, "query": query,
                             "docno": self.index.docnos[int(idxs[i, r])],
                             "score": float(vals[i, r]), "rank": r})
        return ColFrame.from_dicts(rows)
