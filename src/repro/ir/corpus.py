"""Synthetic corpora + topics + qrels at controlled scales.

MSMARCO v1/v2 are not available offline, so the demonstration
experiments (paper §5, Table 2) run on synthetic Zipfian corpora whose
*relative* scales match (v2 ≈ 4.4× v1 documents; 43 vs 53 queries).
Documents are drawn from a Zipf-distributed vocabulary; each query is
seeded from a "topic" term set so BM25 produces non-degenerate rankings
and qrels are planted with graded labels.

Everything is deterministic given the seed — a property the caching
layer's verification mode relies on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..core.frame import ColFrame

__all__ = ["SyntheticCorpus", "make_corpus", "msmarco_like"]


@dataclass
class SyntheticCorpus:
    """A corpus + topic set + graded qrels."""
    name: str
    docs: ColFrame        # D(docno, text)
    topics: ColFrame      # Q(qid, query)
    qrels: ColFrame       # RA(qid, docno, label)

    def get_corpus_iter(self) -> Iterator[dict]:
        for row in self.docs.to_dicts():
            yield row

    def get_topics(self) -> ColFrame:
        return self.topics

    def get_qrels(self) -> ColFrame:
        return self.qrels

    def text_map(self) -> Dict[str, str]:
        return dict(zip(self.docs["docno"].tolist(),
                        self.docs["text"].tolist()))


def _zipf_terms(rng: np.random.Generator, vocab: int, n: int) -> np.ndarray:
    # Zipf(s≈1.1) truncated to the vocabulary, 0-indexed term ids.
    ranks = rng.zipf(1.1, size=n)
    return np.minimum(ranks - 1, vocab - 1)


def make_corpus(name: str, *, n_docs: int, n_queries: int,
                vocab: int = 5000, doc_len: Tuple[int, int] = (30, 80),
                rels_per_query: int = 8, seed: int = 0) -> SyntheticCorpus:
    rng = np.random.default_rng(seed)
    words = np.array([f"w{i}" for i in range(vocab)], dtype=object)

    # topic nuclei: distinct mid-frequency term groups per query
    topic_terms = rng.choice(np.arange(50, vocab // 2), size=(n_queries, 6),
                             replace=False if n_queries * 6 < vocab // 2 - 50
                             else True)

    docnos = np.array([f"{name}_d{i}" for i in range(n_docs)], dtype=object)
    texts = np.empty(n_docs, dtype=object)
    lengths = rng.integers(doc_len[0], doc_len[1] + 1, size=n_docs)

    # plant relevant docs: for query q, docs q*rels..q*rels+rels are seeded
    planted: Dict[int, List[int]] = {}
    for q in range(n_queries):
        ids = rng.choice(n_docs, size=rels_per_query, replace=False)
        planted[q] = list(ids)

    plant_for_doc: Dict[int, List[int]] = {}
    for q, ids in planted.items():
        for d in ids:
            plant_for_doc.setdefault(d, []).append(q)

    for i in range(n_docs):
        terms = list(_zipf_terms(rng, vocab, lengths[i]))
        for q in plant_for_doc.get(i, []):
            boost = rng.integers(3, 9)
            terms.extend(rng.choice(topic_terms[q], size=boost).tolist())
        rng.shuffle(terms)
        texts[i] = " ".join(words[t] for t in terms)

    qids = np.array([f"{name}_q{j}" for j in range(n_queries)], dtype=object)
    queries = np.empty(n_queries, dtype=object)
    for q in range(n_queries):
        sel = rng.choice(topic_terms[q], size=3, replace=False)
        queries[q] = " ".join(words[t] for t in sel)

    rq, rd, rl = [], [], []
    for q, ids in planted.items():
        for rank_i, d in enumerate(ids):
            rq.append(str(qids[q]))
            rd.append(str(docnos[d]))
            rl.append(int(3 - min(rank_i // 3, 2)))   # graded 3/2/1
    qrels = ColFrame({"qid": rq, "docno": rd, "label": rl})

    return SyntheticCorpus(
        name=name,
        docs=ColFrame({"docno": docnos, "text": texts}),
        topics=ColFrame({"qid": qids, "query": queries}),
        qrels=qrels)


def msmarco_like(version: int = 1, scale: float = 1.0,
                 seed: int = 0) -> SyntheticCorpus:
    """Synthetic stand-ins for MSMARCO v1/v2 passage at reduced scale.

    Keeps the paper's *ratios*: v2 has ≈4.4× the documents of v1, and the
    TREC-DL 2019/2021 query counts (43 / 53).
    """
    if version == 1:
        return make_corpus("msv1", n_docs=int(9000 * scale), n_queries=43,
                           seed=seed)
    if version == 2:
        return make_corpus("msv2", n_docs=int(39600 * scale), n_queries=53,
                           seed=seed + 1)
    raise ValueError("version must be 1 or 2")
