"""Tokenization for the IR substrate.

Two tokenizers:

* ``WordTokenizer`` — whitespace/punctuation split + lowercase + optional
  stopword removal; produces string terms for the inverted index.
* ``HashTokenizer`` — maps terms to integer ids in a fixed vocabulary via
  a stable FNV-1a hash (no vocab file needed).  Used by the neural
  scorers: deterministic, dependency-free, and identical across hosts —
  a requirement for the caching layer's determinism assumptions.
"""
from __future__ import annotations

import re
from typing import Iterable, List, Sequence

import numpy as np

__all__ = ["WordTokenizer", "HashTokenizer", "fnv1a32"]

_TOKEN_RE = re.compile(r"[a-z0-9]+")

_STOPWORDS = frozenset("""
a an and are as at be by for from has he in is it its of on that the to was
were will with
""".split())


def fnv1a32(data: bytes) -> int:
    """32-bit FNV-1a (stable across runs/hosts, unlike hash())."""
    h = 0x811C9DC5
    for b in data:
        h ^= b
        h = (h * 0x01000193) & 0xFFFFFFFF
    return h


class WordTokenizer:
    def __init__(self, remove_stopwords: bool = True):
        self.remove_stopwords = remove_stopwords

    def tokenize(self, text: str) -> List[str]:
        toks = _TOKEN_RE.findall(text.lower())
        if self.remove_stopwords:
            toks = [t for t in toks if t not in _STOPWORDS]
        return toks


class HashTokenizer:
    """term -> stable id in [n_special, vocab); 0 = PAD, 1 = CLS, 2 = SEP."""

    PAD, CLS, SEP = 0, 1, 2
    N_SPECIAL = 3

    def __init__(self, vocab_size: int, remove_stopwords: bool = False):
        if vocab_size <= self.N_SPECIAL:
            raise ValueError("vocab too small")
        self.vocab_size = int(vocab_size)
        self._word = WordTokenizer(remove_stopwords)

    def term_id(self, term: str) -> int:
        return self.N_SPECIAL + fnv1a32(term.encode()) % (
            self.vocab_size - self.N_SPECIAL)

    def encode(self, text: str, max_len: int) -> np.ndarray:
        ids = [self.term_id(t) for t in self._word.tokenize(text)][:max_len]
        out = np.zeros(max_len, dtype=np.int32)
        out[:len(ids)] = ids
        return out

    def encode_pair(self, a: str, b: str, max_len: int) -> np.ndarray:
        """[CLS] a [SEP] b — the cross-encoder input layout."""
        ta = [self.term_id(t) for t in self._word.tokenize(a)]
        tb = [self.term_id(t) for t in self._word.tokenize(b)]
        ids = [self.CLS] + ta[:max_len // 4] + [self.SEP] + tb
        ids = ids[:max_len]
        out = np.zeros(max_len, dtype=np.int32)
        out[:len(ids)] = ids
        return out

    def encode_batch(self, texts: Sequence[str], max_len: int) -> np.ndarray:
        return np.stack([self.encode(t, max_len) for t in texts]) \
            if len(texts) else np.zeros((0, max_len), dtype=np.int32)
