"""Deterministic, resumable, shardable data pipelines.

The fault-tolerance contract (distrib/fault.py) requires batches to be
a pure function of the step index — a restarted run must replay the
exact byte stream.  ``StepKeyedDataset`` packages that contract:

* ``batch(step)`` derives its RNG from ``fold_in(seed, step)`` — O(1)
  random access, no iterator state to checkpoint;
* ``shard(process_index, n_processes)`` gives each host its slice of
  the global batch (multi-host data loading posture) — slices of the
  same step compose to exactly the single-host batch;
* per-arch generators produce the right input trees for every assigned
  family (LM tokens, GCN graphs via the NeighborSampler, recsys
  dense/sparse rows).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

__all__ = ["StepKeyedDataset", "lm_synthetic", "recsys_synthetic",
           "gcn_sampled"]


def _rng(seed: int, step: int) -> np.random.Generator:
    # splitmix-style fold-in: independent stream per (seed, step)
    return np.random.default_rng(
        np.random.SeedSequence([seed, step]).generate_state(4))


@dataclass
class StepKeyedDataset:
    """batch = f(seed, step); optionally sharded across hosts."""

    generator: Callable[[np.random.Generator, int, int], Dict[str, Any]]
    global_batch: int
    seed: int = 0
    process_index: int = 0
    n_processes: int = 1

    def shard(self, process_index: int, n_processes: int
              ) -> "StepKeyedDataset":
        assert self.global_batch % n_processes == 0
        return StepKeyedDataset(self.generator, self.global_batch,
                                self.seed, process_index, n_processes)

    def batch(self, step: int) -> Dict[str, Any]:
        full = self.generator(_rng(self.seed, step), self.global_batch,
                              step)
        if self.n_processes == 1:
            return full
        per = self.global_batch // self.n_processes
        lo = self.process_index * per

        def slice_leaf(x):
            return x[lo:lo + per] if getattr(x, "shape", None) and \
                x.shape and x.shape[0] == self.global_batch else x

        return {k: slice_leaf(v) for k, v in full.items()}

    __call__ = batch


# -- per-family generators -----------------------------------------------------

def lm_synthetic(vocab_size: int, seq_len: int, *, pad_id: int = 0
                 ) -> Callable:
    def gen(rng: np.random.Generator, batch: int, step: int):
        toks = rng.integers(3, vocab_size, (batch, seq_len + 1),
                            dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return gen


def recsys_synthetic(cfg) -> Callable:
    """Matches repro.models.recsys batch schemas (planted CTR signal)."""
    def gen(rng: np.random.Generator, batch: int, step: int):
        if cfg.kind in ("dlrm", "dcn"):
            sparse = np.stack(
                [rng.integers(0, v, batch) for v in cfg.vocab_sizes],
                axis=1).astype(np.int32)
            dense = rng.normal(size=(batch, cfg.n_dense)).astype(np.float32)
            labels = ((sparse[:, 0] + sparse[:, 1]) % 2).astype(np.int32)
            return {"dense": dense, "sparse": sparse, "labels": labels}
        if cfg.kind == "mind":
            return {"hist_ids": rng.integers(
                        0, cfg.item_vocab, (batch, cfg.hist_len)
                    ).astype(np.int32),
                    "hist_mask": np.ones((batch, cfg.hist_len),
                                         np.float32),
                    "target_ids": rng.integers(
                        0, cfg.item_vocab, batch).astype(np.int32)}
        return {"user_ids": rng.integers(0, cfg.user_vocab,
                                         batch).astype(np.int32),
                "item_ids": rng.integers(0, cfg.item_vocab,
                                         batch).astype(np.int32)}
    return gen


def gcn_sampled(sampler, feats: np.ndarray, labels: np.ndarray,
                fanouts: Tuple[int, ...]) -> Callable:
    """Fixed-fanout sampled GCN batches via the real NeighborSampler."""
    n = feats.shape[0]

    def gen(rng: np.random.Generator, batch: int, step: int):
        seeds = rng.integers(0, n, batch).astype(np.int32)
        hops = sampler.sample(seeds, fanouts, seed=int(
            rng.integers(0, 2 ** 31 - 1)))
        f1, f2 = fanouts
        return {"feats_hop0": feats[hops["hop0"]],
                "feats_hop1": feats[hops["hop1"]],
                "feats_hop2": feats[hops["hop2"].reshape(batch, f1, f2)],
                "labels": labels[hops["hop0"]]}
    return gen
