from .pipeline import (StepKeyedDataset, lm_synthetic, recsys_synthetic,
                       gcn_sampled)

__all__ = ["StepKeyedDataset", "lm_synthetic", "recsys_synthetic",
           "gcn_sampled"]
