"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

``compiled.cost_analysis()`` runs *after* SPMD partitioning, so its
flops/bytes are already per-device (global/chips).  Collective bytes are
not in cost_analysis: we parse the post-partitioning HLO text and sum
the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async ``-start`` forms
counted once, ``-done`` skipped).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).

`roofline_fraction` = ideal_model_time / estimated_step_time, where
ideal_model_time assumes the model's *useful* FLOPs (6·N·D style) run at
peak and estimated_step_time = max of the three terms.  This is the
score reported in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Optional, Tuple

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
ICI_BW = 50e9               # bytes/s / link

# host-side roofline priors for the plan compiler's cost model
# (core/cost.py): sustained throughput of the *Python/numpy host path*
# IR stages actually run on, far below chip peak.  Deliberately rough —
# these only seed cost estimates until real measurements replace them.
HOST_PEAK_FLOPS = 2e10      # sustained host FLOP/s (BLAS-ish)
HOST_MEM_BW = 5e9           # bytes/s effective host streaming
#: per-query Python dispatch floor added to every host estimate: frame
#: plumbing and interpreter overhead dominate tiny workloads, and an
#: optimistic prior must never claim a stage is cheaper than a cache
#: round-trip (only *measurements* may justify dropping a cache)
HOST_DISPATCH_OVERHEAD_S = 5e-5

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}\s]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

__all__ = ["PEAK_FLOPS", "HBM_BW", "ICI_BW", "HOST_PEAK_FLOPS",
           "HOST_MEM_BW", "HOST_DISPATCH_OVERHEAD_S",
           "parse_collective_bytes", "RooflineReport",
           "analyze_compiled", "estimate_stage_cost", "lm_model_flops",
           "gnn_model_flops", "recsys_model_flops", "model_flops_for"]


def estimate_stage_cost(stage) -> Optional[float]:
    """Analytic per-query cost prior (seconds) for kernel-backed
    pipeline stages — the plan compiler's cold-start estimate before
    any run has been measured (``core/cost.py``).

    Duck-typed on the stage class name so this module never imports the
    IR layer: a ``DenseRetriever`` costs one row of the blocked matmul
    + top-k against its corpus matrix, a ``BM25Retriever`` one TAAT
    postings traversal.  The figure is
    ``HOST_DISPATCH_OVERHEAD_S + max(flops / HOST_PEAK_FLOPS,
    bytes / HOST_MEM_BW)`` — the host roofline plus the per-query
    Python dispatch floor.  Returns ``None`` for stages with no
    analytic model (generic transformers fall back to the cost model's
    defaults).
    """
    name = type(stage).__name__
    if name == "DenseRetriever":
        matrix = getattr(getattr(stage, "index", None), "matrix", None)
        shape = getattr(matrix, "shape", None)
        if not shape or len(shape) != 2:
            return None
        n_docs, dim = int(shape[0]), int(shape[1])
        itemsize = int(getattr(matrix, "itemsize", 4) or 4)
        k = int(getattr(stage, "num_results", 100))
        flops = 2.0 * n_docs * dim            # one query row × corpus
        byts = float(n_docs * dim * itemsize) # stream the matrix
        topk = float(n_docs) * max(1.0, math.log2(max(2, k)))
        return HOST_DISPATCH_OVERHEAD_S + max(
            (flops + topk) / HOST_PEAK_FLOPS, byts / HOST_MEM_BW)
    if name == "BM25Retriever":
        index = getattr(stage, "index", None)
        n_docs = getattr(index, "n_docs", None)
        if n_docs is None:
            docnos = getattr(index, "docnos", None)
            n_docs = len(docnos) if docnos is not None else None
        if not n_docs:
            return None
        # TAAT: ~q_terms postings lists, each a fraction of the corpus;
        # model ≈ 4 query terms × 10% selectivity × (ids+tfs+score work)
        postings = 4 * 0.1 * float(n_docs)
        flops = 8.0 * postings                # idf/tf saturation per hit
        byts = 12.0 * postings                # int32 id + f32 tf + accum
        k = int(getattr(stage, "num_results", 1000))
        sort = float(n_docs) * max(1.0, math.log2(max(2, min(k, n_docs))))
        return HOST_DISPATCH_OVERHEAD_S + max(
            (flops + sort) / HOST_PEAK_FLOPS, byts / HOST_MEM_BW)
    return None


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective op type (per device)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[op] = out.get(op, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    kind: str
    # raw per-device quantities
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, int] = field(default_factory=dict)
    # memory analysis (bytes per device)
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0
    # derived terms (seconds)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    # useful-work accounting
    model_flops_global: float = 0.0
    useful_ratio: float = 0.0           # model_flops / (hlo_flops × chips)
    roofline_fraction: float = 0.0      # ideal model time / est step time
    est_step_s: float = 0.0
    compile_s: float = 0.0
    notes: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    def summary(self) -> str:
        return (f"{self.arch:24s} {self.shape:14s} {self.mesh:10s} "
                f"compute={self.compute_s:.3e}s memory={self.memory_s:.3e}s "
                f"coll={self.collective_s:.3e}s dom={self.dominant:10s} "
                f"useful={self.useful_ratio:.2f} "
                f"roofline={self.roofline_fraction:.2%}")


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_devices: int, kind: str,
                     model_flops_global: float,
                     compile_s: float = 0.0,
                     notes: str = "") -> RooflineReport:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<0.5 returns [dict] per device
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)

    rep = RooflineReport(arch=arch, shape=shape, mesh=mesh_name,
                         n_devices=n_devices, kind=kind,
                         hlo_flops=flops, hlo_bytes=byts,
                         collective_bytes=float(coll.get("total", 0)),
                         collective_breakdown=coll,
                         model_flops_global=model_flops_global,
                         compile_s=compile_s, notes=notes)
    try:
        ma = compiled.memory_analysis()
        rep.argument_bytes = int(getattr(ma, "argument_size_in_bytes", 0))
        rep.output_bytes = int(getattr(ma, "output_size_in_bytes", 0))
        rep.temp_bytes = int(getattr(ma, "temp_size_in_bytes", 0))
        rep.peak_bytes = rep.argument_bytes + rep.temp_bytes
    except Exception:
        pass

    derive_terms(rep)
    return rep


def derive_terms(rep: "RooflineReport") -> "RooflineReport":
    """(Re-)derive the three terms + fractions from the raw quantities."""
    rep.compute_s = rep.hlo_flops / PEAK_FLOPS
    rep.memory_s = rep.hlo_bytes / HBM_BW
    rep.collective_s = rep.collective_bytes / ICI_BW
    terms = {"compute": rep.compute_s, "memory": rep.memory_s,
             "collective": rep.collective_s}
    rep.dominant = max(terms, key=terms.get)
    rep.est_step_s = max(terms.values())
    total_flops = rep.hlo_flops * rep.n_devices
    rep.useful_ratio = (rep.model_flops_global / total_flops
                        if total_flops else 0.0)
    ideal = rep.model_flops_global / (rep.n_devices * PEAK_FLOPS)
    rep.roofline_fraction = ideal / rep.est_step_s if rep.est_step_s else 0.0
    return rep


def apply_layer_correction(rep: "RooflineReport", probe: "RooflineReport",
                           n_layers: int) -> "RooflineReport":
    """total ≈ scanned_module + (L-1) × single-layer probe.

    XLA cost_analysis counts while bodies once; the scanned module holds
    one layer's worth of FLOPs/bytes/collectives, the probe supplies the
    remaining L-1.  Memory figures stay those of the scanned module
    (while-loop buffer liveness is the honest one).
    """
    rep.hlo_flops += (n_layers - 1) * probe.hlo_flops
    rep.hlo_bytes += (n_layers - 1) * probe.hlo_bytes
    rep.collective_bytes += (n_layers - 1) * probe.collective_bytes
    for k, v in probe.collective_breakdown.items():
        rep.collective_breakdown[k] = rep.collective_breakdown.get(k, 0) \
            + (n_layers - 1) * v
    rep.notes = (rep.notes + " " if rep.notes else "") + \
        f"[layer-corrected: +{n_layers - 1}x probe]"
    return derive_terms(rep)


# ---------------------------------------------------------------------------
# useful-FLOPs models (the 6·N·D convention + family-specific variants)
# ---------------------------------------------------------------------------

def lm_model_flops(cfg, seq_len: int, global_batch: int, kind: str) -> float:
    from ..models.lm import active_params
    n_active = active_params(cfg)
    tokens = global_batch * seq_len
    if kind == "train":
        return 6.0 * n_active * tokens
    if kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    attn = (2.0 * 2.0 * cfg.n_layers * global_batch * seq_len
            * cfg.n_heads * cfg.head_dim)
    return 2.0 * n_active * global_batch + attn


def gnn_model_flops(cfg, sh: Dict) -> float:
    """2·(matmul flops) ×3 for training (fwd+bwd)."""
    mult = 3.0 if sh["kind"].startswith("train") else 1.0
    F, H, C = sh["d_feat"], cfg.d_hidden, sh["n_classes"]
    if "batch_nodes" in sh:         # sampled: count gathered node compute
        f1, f2 = sh["fanouts"]
        n_eff = sh["batch_nodes"] * (1 + f1 + f1 * f2)
        dense = 2.0 * n_eff * F * H + 2.0 * sh["batch_nodes"] * H * C
        return mult * dense
    if "batch" in sh:               # molecules
        n = sh["batch"] * sh["n_nodes"]
        e = sh["batch"] * sh["n_edges"]
    else:
        n, e = sh["n_nodes"], sh["n_edges"]
    dense = 2.0 * n * F * H + 2.0 * n * H * C
    agg = 2.0 * e * (H + C)
    return mult * (dense + agg)


def recsys_model_flops(cfg, sh: Dict) -> float:
    mult = 6.0 if sh["kind"] == "train" else 2.0
    B = sh.get("batch", 1)
    if sh["kind"] == "retrieval":
        B = sh["n_candidates"]

    def mlp_flops(dims, d0):
        f, prev = 0.0, d0
        for d in dims:
            f += prev * d
            prev = d
        return f

    if cfg.kind == "dlrm":
        per_row = (mlp_flops(cfg.bot_mlp, cfg.n_dense)
                   + mlp_flops(cfg.top_mlp,
                               (cfg.n_sparse + 1) * cfg.n_sparse // 2
                               + cfg.bot_mlp[-1])
                   + (cfg.n_sparse + 1) ** 2 * cfg.embed_dim)
    elif cfg.kind == "dcn":
        d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
        per_row = (cfg.n_cross_layers * d0 * d0
                   + mlp_flops(cfg.deep_mlp, d0) + d0 + cfg.deep_mlp[-1])
    elif cfg.kind == "mind":
        d = cfg.embed_dim
        per_row = (cfg.hist_len * d * d                       # bilinear S
                   + cfg.capsule_iters * 2 * cfg.n_interests
                   * cfg.hist_len * d
                   + cfg.n_interests * (2 * d * d + d * d))   # interest MLP
        if sh["kind"] == "retrieval":
            return mult * (per_row + B * cfg.n_interests * d)
    else:  # two_tower
        d = cfg.embed_dim
        per_row = 2 * mlp_flops(cfg.tower_mlp, d)             # both towers
        if sh["kind"] == "retrieval":
            return mult * (mlp_flops(cfg.tower_mlp, d)
                           + B * (mlp_flops(cfg.tower_mlp, d)
                                  + cfg.tower_mlp[-1]))
    return mult * B * per_row


def model_flops_for(arch_def, shape_name: str) -> float:
    from ..configs.base import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES
    if arch_def.family == "lm":
        sh = LM_SHAPES[shape_name]
        return lm_model_flops(arch_def.config, sh["seq_len"],
                              sh["global_batch"], sh["kind"])
    if arch_def.family == "gnn":
        return gnn_model_flops(arch_def.config, GNN_SHAPES[shape_name])
    return recsys_model_flops(arch_def.config, RECSYS_SHAPES[shape_name])
