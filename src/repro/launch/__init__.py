# Launch layer. NOTE: do not import .dryrun here — it sets XLA_FLAGS for
# 512 placeholder devices and must only run as __main__.
from .mesh import make_production_mesh, make_mesh, mesh_info

__all__ = ["make_production_mesh", "make_mesh", "mesh_info"]
