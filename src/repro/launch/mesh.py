"""Production mesh factory.

Defined as a function (never a module-level constant) so importing this
module does not touch JAX device state — the dry-run sets
``xla_force_host_platform_device_count`` *before* first JAX init.

Topology (TPU v5e posture):
* single pod:  (16, 16)        axes ("data", "model") — 256 chips
* multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") — 512 chips

The factory generalizes to (n_pods, d, m) for elastic scaling: the
checkpoint manifest is mesh-agnostic, so restarts may change n_pods.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType, Mesh

__all__ = ["make_production_mesh", "make_mesh", "mesh_info"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Elastic variant: any (n_pods, data, model) factorization."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def mesh_info(mesh: Mesh) -> dict:
    return {"axis_names": list(mesh.axis_names),
            "shape": [int(s) for s in mesh.devices.shape],
            "n_devices": int(mesh.devices.size)}
