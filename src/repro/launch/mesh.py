"""Production mesh factory.

Defined as a function (never a module-level constant) so importing this
module does not touch JAX device state — the dry-run sets
``xla_force_host_platform_device_count`` *before* first JAX init.

Topology (TPU v5e posture):
* single pod:  (16, 16)        axes ("data", "model") — 256 chips
* multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") — 512 chips

The factory generalizes to (n_pods, d, m) for elastic scaling: the
checkpoint manifest is mesh-agnostic, so restarts may change n_pods.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 exposes explicit axis types; older releases do not
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

__all__ = ["make_production_mesh", "make_mesh", "mesh_info"]


def _make(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Elastic variant: any (n_pods, data, model) factorization."""
    return _make(tuple(shape), tuple(axes))


def mesh_info(mesh: Mesh) -> dict:
    return {"axis_names": list(mesh.axis_names),
            "shape": [int(s) for s in mesh.devices.shape],
            "n_devices": int(mesh.devices.size)}
