import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: JAX locks the device count on first
init, and the production meshes below need 512 placeholder host devices.
(Do NOT import this module from tests/benchmarks — they must see 1
device; the flag is process-local by design.)

Usage:
    python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod both \
        --out results/dryrun.jsonl

For every cell this prints ``compiled.memory_analysis()`` (fits?) and
``compiled.cost_analysis()`` (FLOPs/bytes → §Roofline), parses
per-device collective bytes from the partitioned HLO, and emits one
JSON record per (cell × mesh).
"""
import argparse
import json
import sys
import time
import traceback

import jax


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             rules=None, verbose: bool = True, layer_correct: bool = True,
             cfg_overrides=None, opt_cfg=None, mesh_shape=None):
    from ..configs import get_arch
    from ..configs.base import lm_layer_probe
    from ..distrib.shardings import ShardingRules
    from .mesh import make_mesh, make_production_mesh, mesh_info
    from .roofline import (analyze_compiled, apply_layer_correction,
                           model_flops_for)

    arch = get_arch(arch_name)
    kw = {}
    if cfg_overrides and arch.family == "lm":
        kw["cfg_overrides"] = cfg_overrides
    if opt_cfg is not None and arch.family == "lm":
        kw["opt_cfg"] = opt_cfg
    cell = arch.cell(shape_name, **kw)
    if mesh_shape is not None:
        # elastic factorization, e.g. (4, 8, 16) or (8, 32)
        axes = ("pod", "data", "model")[-len(mesh_shape):]
        mesh = make_mesh(tuple(mesh_shape), axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rules = rules or ShardingRules()

    t0 = time.perf_counter()
    lowered = cell.lower(mesh, rules)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    if verbose:
        print(f"--- {arch_name} × {shape_name} on {mesh_name} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(compiled.memory_analysis())      # proves it fits
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed")
               if k in ca})                    # FLOPs/bytes for §Roofline

    rep = analyze_compiled(
        compiled, arch=arch_name, shape=shape_name, mesh_name=mesh_name,
        n_devices=mesh.devices.size, kind=cell.kind,
        model_flops_global=model_flops_for(arch, shape_name),
        compile_s=t_lower + t_compile, notes=cell.notes)

    # LM models scan over layers; correct while-body-once cost accounting
    # with a single-layer probe compile at identical shapes/shardings.
    if layer_correct and arch.family == "lm" and arch.config.scan_layers:
        t0 = time.perf_counter()
        probe_cell = lm_layer_probe(arch, shape_name,
                                    cfg_overrides=cfg_overrides)
        probe = probe_cell.lower(mesh, rules).compile()
        probe_rep = analyze_compiled(
            probe, arch=arch_name, shape=shape_name, mesh_name=mesh_name,
            n_devices=mesh.devices.size, kind=probe_cell.kind,
            model_flops_global=0.0)
        rep = apply_layer_correction(rep, probe_rep, arch.config.n_layers)
        rep.compile_s += time.perf_counter() - t0
    if verbose:
        print(rep.summary())
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch × shape) cell")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--mesh", default=None,
                    help="elastic mesh factorization, e.g. 4x8x16 "
                         "(pods x data x model); overrides --multi-pod")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    from ..configs import all_cells

    if args.all:
        cells = all_cells()
    elif args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    elif args.arch:
        from ..configs import get_arch
        cells = [(args.arch, s) for s in get_arch(args.arch).shape_names()]
    else:
        ap.error("need --arch [--shape] or --all")

    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]

    out_f = open(args.out, "a") if args.out else None
    failures = []
    for arch_name, shape_name in cells:
        for mp in pods:
            try:
                mesh_shape = tuple(int(x) for x in args.mesh.split("x")) \
                    if args.mesh else None
                rep = run_cell(arch_name, shape_name, mp,
                               verbose=not args.quiet,
                               mesh_shape=mesh_shape)
                if out_f:
                    out_f.write(json.dumps(rep.to_dict()) + "\n")
                    out_f.flush()
            except Exception as e:
                traceback.print_exc()
                failures.append((arch_name, shape_name, mp, repr(e)))
    if out_f:
        out_f.close()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print(f"\nall {len(cells) * len(pods)} cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
