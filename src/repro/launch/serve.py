"""Serving driver: batched neural scoring with ScorerCache.

    PYTHONPATH=src python -m repro.launch.serve --requests 500

Simulates a request stream against the ScoringService (the paper's
``index.bm25() >> cached_scorer`` composition as a long-lived service)
and prints latency/hit-rate statistics — the request-level view of the
paper's Table-2 mechanism.
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--n-queries", type=int, default=20)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--no-cache", action="store_true")
    args = ap.parse_args(argv)

    from ..ir import InvertedIndex, msmarco_like
    from ..models.cross_encoder import EncoderConfig, MonoScorer
    from ..serve import ScoringService

    corpus = msmarco_like(1, scale=0.05)
    scorer = MonoScorer(EncoderConfig(n_layers=2, d_model=64, n_heads=4,
                                      d_ff=128, vocab_size=8192,
                                      max_len=32))
    svc = ScoringService(scorer, max_batch=args.max_batch,
                         use_cache=not args.no_cache)
    rng = np.random.default_rng(0)
    docs = corpus.docs
    for i in range(args.requests):
        q = int(rng.integers(0, args.n_queries))
        d = int(rng.integers(0, min(len(docs), 200)))
        svc.submit(f"q{q}", f"query about topic {q}",
                   str(docs["docno"][d]), str(docs["text"][d]))
        if (i + 1) % args.max_batch == 0:
            svc.flush()
    svc.flush()
    print(svc.stats.summary())
    svc.close()
    return svc.stats


if __name__ == "__main__":
    main()
