"""Serving driver — thin wrapper over ``repro serve``.

    PYTHONPATH=src python -m repro.launch.serve --requests 500

Stands up a :class:`~repro.serve.PipelineService` over a registry
pipeline (default: the two-stage ``bm25-mono`` retrieve-and-rerank
composition) and drives it with a closed-loop synthetic request stream
— the request-level view of the paper's Table-2 mechanism, now through
the full plan compiler instead of a single scorer stage.  All the real
logic lives in the unified serving surface (``repro.serve.ServeConfig``
+ ``drive_closed_loop``); this module only keeps the legacy flag
surface (``--requests`` / ``--max-batch`` / ``--no-cache``).
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--pipeline", default="bm25-mono")
    ap.add_argument("--n-queries", type=int, default=20,
                    help="(legacy, ignored — the registry scenario "
                         "defines the topic pool)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--no-cache", action="store_true")
    args = ap.parse_args(argv)

    from ..serve import ServeConfig, drive_closed_loop

    cfg = ServeConfig(
        pipeline=args.pipeline, scale=args.scale, cutoff=10,
        num_results=100, max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms, exec_workers=4, cache_dir=None,
        backend=None if args.no_cache else "memory")
    record = drive_closed_loop(cfg, requests=args.requests,
                               clients=args.clients)
    print({k: record[k] for k in ("requests", "batches", "hit_rate",
                                  "p50_ms", "p99_ms", "throughput_rps")})
    return record


if __name__ == "__main__":
    main()
