"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 200 --preset tiny --ckpt-dir /tmp/ckpt

Runs a real training loop (synthetic LM data / planted recsys labels /
random graphs) with checkpoint/restart supervision.  ``--preset tiny``
shrinks the arch (same family/flags) so a few hundred steps run on CPU;
``--preset full`` uses the published config (requires a real pod).

On a cluster this process runs once per slice under the scheduler; the
RestartableLoop + mesh-agnostic checkpoints provide preemption recovery
and elastic restarts (see repro/distrib).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_lm_batch(cfg, batch: int, seq: int, step: int):
    rng = np.random.default_rng(step)            # step-keyed (resumable)
    toks = rng.integers(3, cfg.vocab_size, (batch, seq + 1), dtype=np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--preset", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", choices=["none", "int8", "topk"],
                    default="none")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    from ..configs import get_arch
    from ..distrib import (Checkpointer, CompressionConfig, RestartableLoop)
    from ..models import lm as LM
    from ..models.common import init_params
    from ..train import AdamWConfig, linear_warmup_cosine, make_train_step

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for "
                         "gnn/recsys training")
    cfg = arch.smoke()[0] if args.preset == "tiny" else arch.config

    specs = LM.param_specs(cfg)
    params = init_params(specs, jax.random.key(0))
    loss_fn = lambda p, b: LM.causal_lm_loss(p, b, cfg)
    step_fn, init_opt = make_train_step(
        loss_fn, AdamWConfig(lr=args.lr),
        lr_schedule=lambda s: linear_warmup_cosine(
            s, warmup=20, total=args.steps),
        microbatches=args.microbatches,
        compression=CompressionConfig(method=args.compress))
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    def sfn(state, batch):
        p, o = state
        p, o, m = jitted(p, o, batch)
        return (p, o), m

    batch_fn = lambda s: synthetic_lm_batch(cfg, args.batch, args.seq, s)
    state = (params, init_opt(params))

    if args.ckpt_dir:
        loop = RestartableLoop(sfn, batch_fn,
                               Checkpointer(args.ckpt_dir, keep=3),
                               ckpt_every=args.ckpt_every)
        state = loop.run(state, args.steps)
        log = loop.metrics_log
    else:
        log = []
        t0 = time.perf_counter()
        for s in range(args.steps):
            state, m = sfn(state, batch_fn(s))
            if s % 20 == 0 or s == args.steps - 1:
                entry = {"step": s,
                         **{k: float(v) for k, v in m.items()}}
                log.append(entry)
                print(entry)
        print(f"[{args.steps} steps in {time.perf_counter() - t0:.1f}s]")
    if log:
        first = next((e for e in log if "loss" in e), None)
        last = next((e for e in reversed(log) if "loss" in e), None)
        if first and last:
            print(f"loss {first['loss']:.3f} -> {last['loss']:.3f}")
    return state


if __name__ == "__main__":
    main()
