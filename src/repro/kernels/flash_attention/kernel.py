"""Flash attention as a Pallas TPU kernel (forward).

TPU-native adaptation of the FlashAttention schedule (arXiv:2205.14135):

* grid ``(B, H, Sq/bq, Sk/bk)`` — the KV axis is innermost so the
  (m, l, acc) online-softmax state lives in VMEM scratch across KV steps
  and the output block is written once on the last step;
* BlockSpecs stream 128-aligned ``[bq, hd]`` / ``[bk, hd]`` tiles
  HBM→VMEM; the MXU sees ``[bq, bk]`` and ``[bq, hd]`` matmuls
  (bq/bk multiples of 128 keep the systolic array full);
* GQA without materializing repeated KV heads: the K/V BlockSpec
  index_map divides the head index (``h // group``) — indirection in the
  *index map*, not the data;
* causal masking via block-level iota comparison (fully-masked blocks
  short-circuit to a no-op through ``@pl.when``).

Validated in interpret mode against ``ref.attention_ref`` (the container
is CPU-only; TPU is the compile target).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, bq: int, bk: int, n_kv: int,
            q_offset: int, sk_valid: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: this KV block starts after the last query row
    q_last = (qi + 1) * bq - 1 + q_offset        # global kv-pos of last q
    k_first = ki * bk
    run = jnp.logical_or(jnp.logical_not(causal), k_first <= q_last)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                     # [bq, bk]
        kpos = ki * bk + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 1)
        valid = kpos < sk_valid                    # mask padded KV rows
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0) + q_offset
            valid = jnp.logical_and(valid, kpos <= qpos)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]                               # [bq, 1]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                   # [bq, 1]
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    sk_valid: int | None = None, q_offset: int | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """q [B,H,Sq,hd]; k/v [B,K,Sk,hd]. Returns [B,H,Sq,hd].

    Sq/Sk must be multiples of block_q/block_k (ops.py pads;
    ``sk_valid`` marks the unpadded KV length — padded rows are masked
    in-kernel).  ``interpret=True`` runs the kernel body in Python on
    CPU — the container has no TPU; flip to False on real hardware.
    """
    B, H, Sq, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    assert H % K == 0, "GQA requires H % K == 0"
    group = H // K
    assert Sq % block_q == 0 and Sk % block_k == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    n_q, n_kv = Sq // block_q, Sk // block_k

    sk_valid = Sk if sk_valid is None else sk_valid
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, bq=block_q, bk=block_k,
        n_kv=n_kv, q_offset=(sk_valid - Sq if q_offset is None
                             else q_offset),
        sk_valid=sk_valid)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
