"""Jitted public wrapper for the flash-attention kernel.

Handles sequence padding to block multiples and exposes the same
signature as the oracle ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .kernel import flash_attention

__all__ = ["flash_attention_op"]


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, block_q: int = 128,
                       block_k: int = 128, interpret: bool = True):
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention(qp, kp, vp, causal=causal, block_q=bq, block_k=bk,
                          sk_valid=Sk, q_offset=Sk - Sq,
                          interpret=interpret)
    return out[:, :, :Sq]
