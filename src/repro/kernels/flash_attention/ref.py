"""Pure-jnp oracle for the flash-attention kernel (GQA, causal)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True,
                  scale: float | None = None) -> jnp.ndarray:
    """q [B,H,Sq,hd]; k/v [B,K,Sk,hd]; H % K == 0. Returns [B,H,Sq,hd]."""
    B, H, Sq, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, Sq, hd)
    scores = jnp.einsum("bkgqh,bksh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * scale
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None] + (Sk - Sq)
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, v)
    return out.reshape(B, H, Sq, hd)
