# Pallas TPU kernels for the perf-critical compute layers, each with a
# pure-jnp oracle (ref.py) and a jitted wrapper (ops.py).  Validated in
# interpret mode on CPU; TPU is the compilation target.
from . import (flash_attention, embedding_bag, cachekey_hash, bm25_block,
               dense_topk)

__all__ = ["flash_attention", "embedding_bag", "cachekey_hash",
           "bm25_block", "dense_topk"]
