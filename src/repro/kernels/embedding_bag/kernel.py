"""EmbeddingBag as a Pallas TPU kernel.

JAX has no native EmbeddingBag (taxonomy §B.6); the jnp path is
gather + masked sum, materializing [B, L, d].  The TPU-native version
never materializes the gathered bag:

* the bag ids are a **scalar-prefetch** operand
  (``PrefetchScalarGridSpec``) — on TPU they land in SMEM before the
  grid starts, and the *table* BlockSpec's index_map reads them to pick
  which table row block to DMA next: the gather happens in the
  **index stream**, not in compute;
* grid ``(B, L)`` with L innermost: the output block for bag ``b`` stays
  resident in VMEM across the L steps and accumulates
  ``weight[b,l] × table[ids[b,l]]``; it is zero-initialized at l==0;
* rows are streamed as ``[1, d]`` blocks (d padded to a lane multiple of
  128 by ops.py).

This is the classic TPU embedding pattern (sparsecore-less variant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_bag"]


def _kernel(ids_ref, w_ref, table_ref, out_ref, *, L: int):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[0, 0].astype(jnp.float32)
    out_ref[...] += table_ref[...].astype(jnp.float32) * w


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  weights: jnp.ndarray | None = None, *,
                  interpret: bool = True) -> jnp.ndarray:
    """table [V,d]; ids [B,L]; weights [B,L] -> [B,d] (sum combiner)."""
    V, d = table.shape
    B, L = ids.shape
    if weights is None:
        weights = jnp.ones((B, L), table.dtype)
    weights = weights.astype(table.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,            # ids -> SMEM
        grid=(B, L),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, l, ids: (b, l)),      # weights
            # the gather: table block row chosen by the prefetched ids
            pl.BlockSpec((1, d), lambda b, l, ids: (ids[b, l], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, l, ids: (b, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),  # f32 accum
        interpret=interpret,
    )(ids, weights, table)
    return out.astype(table.dtype)
