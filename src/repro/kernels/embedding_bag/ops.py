"""Jitted public wrapper for the embedding_bag kernel (padding + mean)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import embedding_bag

__all__ = ["embedding_bag_op"]


@functools.partial(jax.jit, static_argnames=("combiner", "interpret"))
def embedding_bag_op(table, ids, weights=None, *, combiner: str = "sum",
                     interpret: bool = True):
    V, d = table.shape
    B, L = ids.shape
    pad_d = (-d) % 128                     # lane alignment for the MXU/VPU
    tp = jnp.pad(table, ((0, 0), (0, pad_d)))
    out = embedding_bag(tp, ids, weights, interpret=interpret)[:, :d]
    if combiner == "mean":
        denom = (weights.sum(axis=1, keepdims=True) if weights is not None
                 else jnp.full((1, 1), float(L)))
        out = out / jnp.maximum(denom.astype(out.dtype), 1e-9)
    return out
