"""Pure-jnp oracle for the embedding_bag kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["embedding_bag_ref"]


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray,
                      weights: jnp.ndarray | None = None,
                      combiner: str = "sum") -> jnp.ndarray:
    """table [V,d]; ids [B,L] int32; weights [B,L] (None = all ones).

    Returns [B,d]: per-bag weighted sum (or mean) of table rows.
    """
    emb = jnp.take(table, ids, axis=0, mode="clip")     # [B,L,d]
    if weights is not None:
        emb = emb * weights[..., None].astype(emb.dtype)
    out = emb.sum(axis=1)
    if combiner == "mean":
        denom = (weights.sum(axis=1, keepdims=True) if weights is not None
                 else jnp.full((1, 1), float(ids.shape[1])))
        out = out / jnp.maximum(denom.astype(out.dtype), 1e-9)
    return out
