"""Jitted wrapper for bm25_block (padding to tile multiples)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import bm25_block

__all__ = ["bm25_block_op"]


@functools.partial(jax.jit, static_argnames=("k1", "b", "avg_dl",
                                             "interpret"))
def bm25_block_op(tf, idf, doc_len, *, k1: float = 1.2, b: float = 0.75,
                  avg_dl: float = 1.0, interpret: bool = True):
    T, D = tf.shape
    pad_t = (-T) % 8
    pad_d = (-D) % 128
    tfp = jnp.pad(tf, ((0, pad_t), (0, pad_d)))
    idfp = jnp.pad(idf, (0, pad_t))
    dlp = jnp.pad(doc_len, (0, pad_d), constant_values=1.0)
    out = bm25_block(tfp, idfp, dlp, k1=k1, b=b, avg_dl=avg_dl,
                     interpret=interpret)
    return out[:D]
