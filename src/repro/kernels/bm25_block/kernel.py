"""Blocked BM25 scoring as a Pallas TPU kernel.

The first-stage retrieval inner loop, restructured for the TPU memory
hierarchy: CPU BM25 walks per-term postings lists (pointer-chasing —
hostile to the VPU).  The TPU-native formulation processes a dense
(terms × docs) term-frequency tile per grid step:

* grid ``(docs/bd, terms/bt)`` with terms innermost: the per-doc score
  accumulator block stays in VMEM across term tiles;
* each step: load ``tf [bt, bd]``, apply the BM25 saturation
  elementwise on the VPU, then a ``[1,bt]×[bt,bd]`` idf contraction on
  the MXU; accumulate into ``scores [1, bd]``;
* tiles are (8×128)-aligned; zero tf contributes exactly 0, so the
  sparse→dense padding does not change scores.

The postings→tile densification is done host-side per query-term batch
(the tile is the *unit of transfer*, matching how one would stream
posting blocks through VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bm25_block"]


def _kernel(tf_ref, idf_ref, dl_ref, o_ref, *, k1: float, b: float,
            avg_dl: float, n_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tf = tf_ref[...].astype(jnp.float32)          # [bt, bd]
    dl = dl_ref[...].astype(jnp.float32)          # [1, bd]
    idf = idf_ref[...].astype(jnp.float32)        # [1, bt]
    dl_norm = k1 * (1.0 - b + b * dl / avg_dl)    # [1, bd]
    sat = tf * (k1 + 1.0) / (tf + dl_norm)        # [bt, bd]
    sat = jnp.where(tf > 0, sat, 0.0)
    o_ref[...] += jax.lax.dot_general(
        idf, sat, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # [1, bd]


def bm25_block(tf: jnp.ndarray, idf: jnp.ndarray, doc_len: jnp.ndarray, *,
               k1: float = 1.2, b: float = 0.75, avg_dl: float = 1.0,
               block_t: int = 8, block_d: int = 128,
               interpret: bool = True) -> jnp.ndarray:
    """tf [T,D]; idf [T]; doc_len [D] -> scores [D]."""
    T, D = tf.shape
    assert T % block_t == 0 and D % block_d == 0
    idf2 = idf[None, :]                            # [1, T]
    dl2 = doc_len[None, :]                         # [1, D]
    out = pl.pallas_call(
        functools.partial(_kernel, k1=k1, b=b, avg_dl=avg_dl,
                          n_t=T // block_t),
        grid=(D // block_d, T // block_t),
        in_specs=[
            pl.BlockSpec((block_t, block_d), lambda di, ti: (ti, di)),
            pl.BlockSpec((1, block_t), lambda di, ti: (0, ti)),
            pl.BlockSpec((1, block_d), lambda di, ti: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda di, ti: (0, di)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=interpret,
    )(tf, idf2, dl2)
    return out[0]
