"""Pure-jnp oracle for the bm25_block kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["bm25_block_ref"]


def bm25_block_ref(tf: jnp.ndarray, idf: jnp.ndarray, doc_len: jnp.ndarray,
                   *, k1: float = 1.2, b: float = 0.75,
                   avg_dl: float = 1.0) -> jnp.ndarray:
    """tf [T, D] term-frequency tile; idf [T]; doc_len [D] -> scores [D].

    score(d) = Σ_t idf[t] · tf·(k1+1) / (tf + k1·(1-b+b·dl/avgdl))
    """
    dl_norm = k1 * (1.0 - b + b * doc_len / avg_dl)       # [D]
    sat = tf * (k1 + 1.0) / (tf + dl_norm[None, :])
    sat = jnp.where(tf > 0, sat, 0.0)
    return jnp.einsum("t,td->d", idf, sat)
