from .ops import *
from .ref import *
