"""Jitted wrapper for cachekey_hash (padding + host-compatible digest)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import cachekey_hash
from .ref import FNV_OFFSET, FNV_PRIME, LANE2_OFFSET

__all__ = ["cachekey_hash_op", "host_cachekey"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cachekey_hash_op(tokens, *, interpret: bool = True):
    N, L = tokens.shape
    bn = 256 if N >= 256 else max(8, N)
    pad = (-N) % bn
    tp = jnp.pad(tokens, ((0, pad), (0, 0)))
    return cachekey_hash(tp, block_n=bn, interpret=interpret)[:N]


def host_cachekey(token_row: np.ndarray) -> bytes:
    """Host-side digest identical to the kernel (shared cache entries)."""
    h0 = int(FNV_OFFSET)
    h1 = int(LANE2_OFFSET)
    prime = int(FNV_PRIME)
    for b in np.asarray(token_row, dtype=np.uint32).tobytes():
        h0 = ((h0 ^ b) * prime) & 0xFFFFFFFF
        h1 = ((h1 ^ b) * prime) & 0xFFFFFFFF
    return h0.to_bytes(4, "little") + h1.to_bytes(4, "little")
