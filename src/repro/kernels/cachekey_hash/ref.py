"""Pure-jnp/numpy oracle for the cachekey_hash kernel.

Dual-lane 32-bit FNV-1a over int32 token rows.  Lane 0 uses the
standard FNV offset/prime; lane 1 uses an independent offset (decimal
digits of pi) with the same prime — together they form an effectively
64-bit cache key with a host-verifiable reference.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["FNV_OFFSET", "FNV_PRIME", "LANE2_OFFSET", "cachekey_hash_ref"]

FNV_OFFSET = np.uint32(0x811C9DC5)
FNV_PRIME = np.uint32(0x01000193)
LANE2_OFFSET = np.uint32(0x31415927)


def cachekey_hash_ref(tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens [N, L] int32 -> [N, 2] uint32 (two FNV-1a lanes).

    Each int32 token is mixed as 4 little-endian bytes, matching a host
    hashing the raw token buffer.
    """
    t = jnp.asarray(tokens).astype(jnp.uint32)
    N, L = t.shape
    prime = jnp.uint32(FNV_PRIME)
    h0 = jnp.full((N,), jnp.uint32(FNV_OFFSET))
    h1 = jnp.full((N,), jnp.uint32(LANE2_OFFSET))
    for i in range(L):
        word = t[:, i]
        for shift in (0, 8, 16, 24):
            byte = (word >> shift) & jnp.uint32(0xFF)
            h0 = (h0 ^ byte) * prime
            h1 = (h1 ^ byte) * prime
    return jnp.stack([h0, h1], axis=1)
