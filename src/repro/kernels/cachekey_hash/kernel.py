"""Cache-key hashing as a Pallas TPU kernel.

Why a kernel: the paper's RetrieverCache keys are SHA256 over pickled
rows — a measurable *host* cost when an experiment touches 10⁵–10⁶
(query, doc) rows.  On TPU the token rows are already on-device for the
neural scorer; hashing them **on device, alongside scoring** removes the
host round-trip entirely.  SHA256's 64-bit adds/rotates are hostile to
the TPU VPU, so the TPU-native design is a dual-lane 32-bit FNV-1a mix —
pure 32-bit xor/multiply, perfectly lane-parallel over rows, one pass
over the token block; collision resistance for cache keys comes from the
2×32-bit independent lanes (verified against the host oracle bit-for-
bit, so host and device caches can share entries).

grid: (N / block_n,); each step hashes a [block_n, L] VMEM tile with a
fori_loop over the L tokens (4 byte-mixes per token).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FNV_OFFSET, FNV_PRIME, LANE2_OFFSET

__all__ = ["cachekey_hash"]


def _kernel(t_ref, o_ref, *, L: int):
    t = t_ref[...].astype(jnp.uint32)              # [bn, L]
    bn = t.shape[0]
    prime = jnp.uint32(FNV_PRIME)

    def token_step(i, carry):
        h0, h1 = carry
        word = jax.lax.dynamic_slice_in_dim(t, i, 1, axis=1)[:, 0]

        def byte_mix(shift, hh):
            h0_, h1_ = hh
            byte = (word >> jnp.uint32(shift)) & jnp.uint32(0xFF)
            return ((h0_ ^ byte) * prime, (h1_ ^ byte) * prime)

        for shift in (0, 8, 16, 24):
            h0, h1 = byte_mix(shift, (h0, h1))
        return (h0, h1)

    h0 = jnp.full((bn,), jnp.uint32(FNV_OFFSET))
    h1 = jnp.full((bn,), jnp.uint32(LANE2_OFFSET))
    h0, h1 = jax.lax.fori_loop(0, L, token_step, (h0, h1))
    o_ref[...] = jnp.stack([h0, h1], axis=1)


def cachekey_hash(tokens: jnp.ndarray, *, block_n: int = 256,
                  interpret: bool = True) -> jnp.ndarray:
    """tokens [N, L] int32 -> [N, 2] uint32; N % block_n == 0 (ops pads)."""
    N, L = tokens.shape
    assert N % block_n == 0
    return pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid=(N // block_n,),
        in_specs=[pl.BlockSpec((block_n, L), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 2), jnp.uint32),
        interpret=interpret,
    )(tokens)
