"""Blocked query×corpus matmul with a fused streaming top-k.

The dense-retrieval inner loop (PLAID's lesson, arXiv:2205.09707):
latency is won by pruning candidates *inside* the scoring kernel
instead of materializing the full [Q, N] score matrix and sorting it
on the host.  TPU-native formulation, combining the bm25_block layout
with flash_attention's streaming-state schedule:

* grid ``(Q/bq, N/bd)`` with the doc axis innermost: the per-query
  running top-k state ``(vals [bq,k], idxs [bq,k])`` lives in VMEM
  scratch across doc tiles and the output block is written once on the
  last step — the corpus streams through VMEM exactly once;
* each step: a ``[bq,d]×[d,bd]`` contraction on the MXU, then a k-pass
  selection merge of the fresh tile into the running state on the VPU
  (max + masked-min index per pass — no sort primitive needed);
* tie-break is total and deterministic: descending score, then
  ascending global doc index — the same rule ``ref.dense_topk_ref``
  (``lax.top_k``) and the host merge in ``ir/dense.py`` apply, which
  is what makes ``RankCutoff`` fusion sound (top-k is a prefix of
  top-n);
* padded doc rows are masked by block-level iota comparison against
  ``nd_valid`` (score → −∞, index → sentinel), so ops.py's tile
  padding never surfaces in results.

Validated in interpret mode against ``ref.dense_topk_ref`` (the
container is CPU-only; TPU is the compile target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dense_topk", "NEG_INF", "IDX_PAD"]

NEG_INF = -1e30
IDX_PAD = 2 ** 30          # > any real doc index; sorts last on ties


def _kernel(q_ref, c_ref, v_ref, i_ref, vals_scr, idxs_scr, *,
            k: int, bd: int, n_d: int, nd_valid: int):
    di = pl.program_id(1)

    @pl.when(di == 0)
    def _init():
        vals_scr[...] = jnp.full_like(vals_scr, NEG_INF)
        idxs_scr[...] = jnp.full_like(idxs_scr, IDX_PAD)

    q = q_ref[...].astype(jnp.float32)               # [bq, d]
    c = c_ref[...].astype(jnp.float32)               # [bd, d]
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bd]
    bq = s.shape[0]
    dpos = di * bd + jax.lax.broadcasted_iota(jnp.int32, (bq, bd), 1)
    valid = dpos < nd_valid                   # mask padded doc rows
    s = jnp.where(valid, s, NEG_INF)
    dpos = jnp.where(valid, dpos, IDX_PAD)

    # merge the fresh tile into the running state: top-k of the k+bd
    # candidates by k selection passes (each: row max, then min index
    # among the maxima — indices are unique per row, so exactly one
    # real candidate is retired per pass)
    cv = jnp.concatenate([vals_scr[...], s], axis=1)       # [bq, k+bd]
    ci = jnp.concatenate([idxs_scr[...], dpos], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, vals_scr.shape, 1)

    def select(j, carry):
        cv, ci, ov, oi = carry
        m = jnp.max(cv, axis=1, keepdims=True)             # [bq, 1]
        hit = cv >= m
        pick = jnp.min(jnp.where(hit, ci, IDX_PAD), axis=1,
                       keepdims=True)
        chosen = hit & (ci == pick)
        cv = jnp.where(chosen, NEG_INF, cv)
        ci = jnp.where(chosen, IDX_PAD, ci)
        ov = jnp.where(col == j, m, ov)
        oi = jnp.where(col == j, pick, oi)
        return cv, ci, ov, oi

    _, _, ov, oi = jax.lax.fori_loop(
        0, k, select,
        (cv, ci, jnp.full_like(vals_scr, NEG_INF),
         jnp.full_like(idxs_scr, IDX_PAD)))
    vals_scr[...] = ov
    idxs_scr[...] = oi

    @pl.when(di == n_d - 1)
    def _finalize():
        v_ref[...] = vals_scr[...]
        i_ref[...] = idxs_scr[...]


def dense_topk(q: jnp.ndarray, c: jnp.ndarray, *, k: int,
               nd_valid: int | None = None, block_q: int = 8,
               block_d: int = 128, interpret: bool = True):
    """q [Q, d] query embeddings; c [N, d] corpus matrix.

    Returns ``(vals [Q, k] f32, idxs [Q, k] i32)`` — the top-k inner
    products per query with global doc indices, ordered by descending
    score then ascending index.  Q/N must be multiples of
    block_q/block_d (ops.py pads; ``nd_valid`` marks the unpadded doc
    count).  On hardware the output lane dim wants ``k % 128 == 0``
    (ops.py rounds up when compiling); interpret mode takes any k.
    """
    Q, d = q.shape
    N = c.shape[0]
    assert Q % block_q == 0 and N % block_d == 0
    assert 1 <= k
    nd_valid = N if nd_valid is None else nd_valid
    n_d = N // block_d
    kernel = functools.partial(_kernel, k=k, bd=block_d, n_d=n_d,
                               nd_valid=nd_valid)
    return pl.pallas_call(
        kernel,
        grid=(Q // block_q, n_d),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qi, di: (qi, 0)),
            pl.BlockSpec((block_d, d), lambda qi, di: (di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, di: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, di: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, c)
