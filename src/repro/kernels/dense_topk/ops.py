"""Jitted wrapper for dense_topk: tile padding + interpret dispatch.

The dispatch convention for kernel-backed pipeline stages: callers pass
``interpret=None`` and the wrapper resolves it from the runtime —
compiled Mosaic on TPU, interpret-mode fallback everywhere else — so
the same call site works on the CPU-only CI container and on real
hardware (docs/kernels.md).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import dense_topk

__all__ = ["dense_topk_op"]


@functools.partial(jax.jit, static_argnames=("k", "k_pad", "block_q",
                                             "block_d", "nd_valid",
                                             "interpret"))
def _padded(q, c, *, k: int, k_pad: int, block_q: int, block_d: int,
            nd_valid: int, interpret: bool):
    vals, idxs = dense_topk(q, c, k=k_pad, nd_valid=nd_valid,
                            block_q=block_q, block_d=block_d,
                            interpret=interpret)
    return vals[:, :k], idxs[:, :k]


def dense_topk_op(q, c, *, k: int = 100, block_q: int = 8,
                  block_d: int = 128, interpret: Optional[bool] = None):
    """q [Q, d]; c [N, d] -> (vals [Q, k], idxs [Q, k]).

    Pads Q to the block_q multiple, N to the block_d multiple and d to
    the 128-lane multiple (zero feature columns contribute exactly 0 to
    the inner products; padded doc rows are masked in-kernel via
    ``nd_valid``).  k is clamped to N and, when compiling for hardware,
    rounded up to the lane multiple in-kernel then sliced back.
    """
    q = jnp.asarray(q)
    c = jnp.asarray(c)
    Q, d = q.shape
    N = c.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = int(min(max(1, k), N)) if N else 0
    if Q == 0 or N == 0:
        return (jnp.zeros((Q, k), jnp.float32),
                jnp.zeros((Q, k), jnp.int32))
    # rank k only needs lane alignment when Mosaic lays out the block
    k_pad = k if interpret else k + ((-k) % 128)
    pad_q = (-Q) % block_q
    pad_n = (-N) % block_d
    pad_f = (-d) % 128
    qp = jnp.pad(q, ((0, pad_q), (0, pad_f)))
    cp = jnp.pad(c, ((0, pad_n), (0, pad_f)))
    vals, idxs = _padded(qp, cp, k=k, k_pad=k_pad, block_q=block_q,
                         block_d=block_d, nd_valid=N,
                         interpret=interpret)
    return vals[:Q], idxs[:Q]
