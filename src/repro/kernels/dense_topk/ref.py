"""Pure-jnp oracle for the dense_topk kernel.

``lax.top_k`` breaks score ties by ascending index — the same total
order the kernel's masked-min selection applies — so kernel and oracle
agree on indices, not just values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense_topk_ref"]


def dense_topk_ref(q: jnp.ndarray, c: jnp.ndarray, *, k: int):
    """q [Q, d]; c [N, d] -> (vals [Q, k] f32, idxs [Q, k] i32)."""
    s = jax.lax.dot_general(
        q.astype(jnp.float32), c.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    vals, idxs = jax.lax.top_k(s, k)
    return vals, idxs.astype(jnp.int32)
