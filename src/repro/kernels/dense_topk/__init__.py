from .kernel import dense_topk
from .ops import dense_topk_op
from .ref import dense_topk_ref

__all__ = ["dense_topk", "dense_topk_op", "dense_topk_ref"]
