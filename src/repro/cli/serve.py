"""``repro serve`` — stand up a serving service (or fleet) and drive it.

Builds a named pipeline from the serving registry
(``repro.serve.registry``), compiles it once through the plan compiler,
and runs a closed-loop synthetic request stream against it with N
concurrent client threads — the online analogue of the offline
benchmarks:

* ``repro serve --pipeline bm25-mono --requests 400 --clients 4``
* ``repro serve --pipeline bm25 --cache-dir .cache --explain``
* ``repro serve --pipeline bm25-sim --workers 3 --drain --json stats.json``

Everything routes through the unified serving surface
(``repro.serve.ServeConfig`` + ``build_service`` — see
``docs/serving.md``): ``--workers 1`` (default) serves in-process,
``--workers N`` launches a multi-process fleet over the same cache
directory, and ``--drain`` finishes in-flight work, refreshes the cache
manifests on disk and asserts every worker exited 0.

With ``--cache-dir`` the planner inserts the §4 cache families per node
(provenance manifests are validated once, at service start) so a second
invocation against the same directory starts warm; ``--backend``
accepts any ``caching.select_backend`` selector — ``memory`` alone
enables in-process memoization, ``mmap:sqlite`` gives fleet workers
lock-free shared hits.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, Optional, Union

__all__ = ["register", "cmd_serve", "serve_and_drive"]


def _int_or_auto(value: str) -> Union[int, str]:
    if value == "auto":
        return "auto"
    return int(value)


def _float_or_auto(value: str) -> Union[float, str]:
    if value == "auto":
        return "auto"
    return float(value)


def register(subparsers) -> None:
    p = subparsers.add_parser(
        "serve", help="serve a registry pipeline with micro-batching",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--pipeline", default="bm25-mono",
                   help="serving pipeline name (see repro.serve.registry; "
                        "default: bm25-mono)")
    p.add_argument("--scale", type=float, default=0.05,
                   help="synthetic corpus scale (default 0.05)")
    p.add_argument("--cutoff", type=int, default=10,
                   help="rank cutoff of the retrieval stage")
    p.add_argument("--num-results", type=int, default=100,
                   help="retriever depth before the cutoff (pushdown "
                        "fuses the two)")
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--clients", type=int, default=4,
                   help="closed-loop client threads")
    p.add_argument("--max-batch", type=_int_or_auto, default=16,
                   help="micro-batch flush threshold, or 'auto' to use "
                        "the plan's autotuned value (from the manifest's "
                        "measured occupancy history; needs --cache-dir)")
    p.add_argument("--max-wait-ms", type=_float_or_auto, default=2.0,
                   help="micro-batch flush timeout (ms), or 'auto'")
    p.add_argument("--workers", type=int, default=1,
                   help="worker PROCESSES (1 = in-process service, N>1 = "
                        "multi-process fleet over the shared cache dir)")
    p.add_argument("--exec-workers", type=int, default=4,
                   help="executor thread-pool size per service")
    p.add_argument("--cache-dir", default=None,
                   help="planner cache root (persists across runs; "
                        "shared by all fleet workers)")
    p.add_argument("--backend", default=None,
                   help="cache backend selector (caching.select_backend: "
                        "memory/pickle/dbm/sqlite, tiered:<disk>, "
                        "mmap:<disk>)")
    p.add_argument("--no-optimize", action="store_true",
                   help="serve the naive lowered plan (baseline)")
    p.add_argument("--no-warm-start", action="store_true",
                   help="fleet workers skip replaying expected traffic "
                        "through their plan on start")
    p.add_argument("--drain", action="store_true",
                   help="gracefully drain on shutdown: finish in-flight "
                        "work, refresh manifests, assert workers exit 0")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--explain", action="store_true",
                   help="print the compiled plan with online latency "
                        "annotations after the run (workers=1 only)")
    p.add_argument("--json", default=None, metavar="PATH", dest="json_out",
                   help="write run statistics as JSON")
    p.set_defaults(func=cmd_serve)


def serve_and_drive(*, pipeline: str, scale: float, cutoff: int,
                    num_results: int, requests: int, clients: int,
                    max_batch: Union[int, str],
                    max_wait_ms: Union[float, str], workers: int = 1,
                    exec_workers: int = 4,
                    cache_dir: Optional[str] = None,
                    backend: Optional[str] = None,
                    optimize: str = "all", seed: int = 0,
                    explain: bool = False, drain: bool = False,
                    warm_start: bool = True) -> Dict[str, Any]:
    """Build the scenario, stand the service (or fleet) up, run the
    closed loop, return a JSON-able stats record.  Thin kwargs shim
    over :func:`repro.serve.drive_closed_loop` kept for callers of the
    historical flat signature; ``workers`` now counts worker
    *processes* (``exec_workers`` is the per-service thread pool)."""
    from ..serve import ServeConfig, drive_closed_loop

    cfg = ServeConfig(pipeline=pipeline, scale=scale, cutoff=cutoff,
                      num_results=num_results, seed=seed,
                      cache_dir=cache_dir, backend=backend,
                      optimize=optimize, max_batch=max_batch,
                      max_wait_ms=max_wait_ms, exec_workers=exec_workers,
                      workers=workers, warm_start=warm_start)
    return drive_closed_loop(cfg, requests=requests, clients=clients,
                             explain=explain, drain=drain)


def cmd_serve(args) -> int:
    from ..caching import select_backend

    if args.backend is not None:
        select_backend(args.backend)     # fail fast on a bad selector
    record = serve_and_drive(
        pipeline=args.pipeline, scale=args.scale, cutoff=args.cutoff,
        num_results=args.num_results, requests=args.requests,
        clients=args.clients, max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms, workers=args.workers,
        exec_workers=args.exec_workers,
        cache_dir=args.cache_dir, backend=args.backend,
        optimize="none" if args.no_optimize else "all",
        seed=args.seed, explain=args.explain, drain=args.drain,
        warm_start=not args.no_warm_start)
    explained = record.pop("_explain", None)
    print(f"served {record['requests']} requests from "
          f"{record['clients']} clients in {record['wall_s']}s "
          f"({record['throughput_rps']} req/s, "
          f"workers={record['workers']})")
    print(f"p50={record['p50_ms']:.2f}ms p99={record['p99_ms']:.2f}ms "
          f"hit_rate={record['hit_rate']:.3f} "
          f"occupancy={record['online']['batch_occupancy']:.2f}")
    if "fleet" in record:
        fl = record["fleet"]
        codes = fl["exit_codes"]
        print(f"fleet: respawns={fl['respawns']} "
              f"requeued={fl['requeued']} exit_codes="
              f"{[codes[k] for k in sorted(codes)]}")
        if args.drain and any(c != 0 for c in codes.values()):
            print("drain FAILED: nonzero worker exit code")
            return 1
    if explained is not None:
        print()
        print(explained)
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {args.json_out}")
    return 0
