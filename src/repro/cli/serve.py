"""``repro serve`` — stand up a PipelineService and drive it.

Builds a named pipeline from the serving registry
(``repro.serve.registry``), compiles it once through the plan compiler,
and runs a closed-loop synthetic request stream against it with N
concurrent client threads — the online analogue of the offline
benchmarks:

* ``repro serve --pipeline bm25-mono --requests 400 --clients 4``
* ``repro serve --pipeline bm25 --cache-dir .cache --explain``
* ``repro serve --pipeline bm25-mono --json stats.json``

With ``--cache-dir`` the planner inserts the §4 cache families per node
(provenance manifests are validated once, at service start) so a second
invocation against the same directory starts warm; ``--backend memory``
alone enables in-process memoization for the run.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, Optional

__all__ = ["register", "cmd_serve", "serve_and_drive"]


def register(subparsers) -> None:
    p = subparsers.add_parser(
        "serve", help="serve a registry pipeline with micro-batching",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--pipeline", default="bm25-mono",
                   help="serving pipeline name (see repro.serve.registry; "
                        "default: bm25-mono)")
    p.add_argument("--scale", type=float, default=0.05,
                   help="synthetic corpus scale (default 0.05)")
    p.add_argument("--cutoff", type=int, default=10,
                   help="rank cutoff of the retrieval stage")
    p.add_argument("--num-results", type=int, default=100,
                   help="retriever depth before the cutoff (pushdown "
                        "fuses the two)")
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--clients", type=int, default=4,
                   help="closed-loop client threads")
    p.add_argument("--max-batch", type=int, default=16,
                   help="micro-batch flush threshold")
    p.add_argument("--max-wait-ms", type=float, default=2.0,
                   help="micro-batch flush timeout")
    p.add_argument("--workers", type=int, default=4,
                   help="executor thread-pool size")
    p.add_argument("--cache-dir", default=None,
                   help="planner cache root (persists across runs)")
    p.add_argument("--backend", default=None,
                   help="cache backend registry name (memory/pickle/"
                        "dbm/sqlite)")
    p.add_argument("--no-optimize", action="store_true",
                   help="serve the naive lowered plan (baseline)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--explain", action="store_true",
                   help="print the compiled plan with online latency "
                        "annotations after the run")
    p.add_argument("--json", default=None, metavar="PATH", dest="json_out",
                   help="write run statistics as JSON")
    p.set_defaults(func=cmd_serve)


def serve_and_drive(*, pipeline: str, scale: float, cutoff: int,
                    num_results: int, requests: int, clients: int,
                    max_batch: int, max_wait_ms: float, workers: int,
                    cache_dir: Optional[str] = None,
                    backend: Optional[str] = None,
                    optimize: str = "all", seed: int = 0,
                    explain: bool = False) -> Dict[str, Any]:
    """Build the scenario, stand the service up, run the closed loop,
    return a JSON-able stats record.  Shared by the CLI and the launch
    driver."""
    from ..serve import PipelineService, build_scenario, run_closed_loop

    scenario = build_scenario(pipeline, scale=scale, cutoff=cutoff,
                              num_results=num_results, seed=seed)
    svc = PipelineService(scenario.pipeline, cache_dir=cache_dir,
                          cache_backend=backend, optimize=optimize,
                          max_batch=max_batch, max_wait_ms=max_wait_ms,
                          max_workers=workers)
    try:
        loop = run_closed_loop(svc, scenario, n_requests=requests,
                               n_clients=clients, seed=seed)
        summary = svc.stats.summary()
        record = {
            "pipeline": pipeline,
            "description": scenario.description,
            "optimize": optimize,
            "max_batch": max_batch,
            "max_wait_ms": max_wait_ms,
            **loop, **summary,
            "online": svc.online_stats.as_dict(svc.max_batch),
        }
        explained = svc.explain() if explain else None
    finally:
        svc.close()
    if explained is not None:
        record["_explain"] = explained
    return record


def cmd_serve(args) -> int:
    record = serve_and_drive(
        pipeline=args.pipeline, scale=args.scale, cutoff=args.cutoff,
        num_results=args.num_results, requests=args.requests,
        clients=args.clients, max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms, workers=args.workers,
        cache_dir=args.cache_dir, backend=args.backend,
        optimize="none" if args.no_optimize else "all",
        seed=args.seed, explain=args.explain)
    explained = record.pop("_explain", None)
    print(f"served {record['requests']} requests from "
          f"{record['clients']} clients in {record['wall_s']}s "
          f"({record['throughput_rps']} req/s)")
    print(f"p50={record['p50_ms']:.2f}ms p99={record['p99_ms']:.2f}ms "
          f"hit_rate={record['hit_rate']:.3f} "
          f"occupancy={record['online']['batch_occupancy']:.2f}")
    if explained is not None:
        print()
        print(explained)
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {args.json_out}")
    return 0
