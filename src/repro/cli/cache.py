"""``repro cache`` — manage provenance-aware cache directories.

Every cache directory carries a checksummed ``manifest.json``
(``caching/provenance.py``) and planner-managed roots additionally
carry per-plan manifests under ``plans/``; this tool consumes both:

* ``ls ROOT``        — list cache dirs (family, backend, entries,
  budgets + utilization, fingerprint, last use) and the plans that
  reference them; ``--sort size|age|hits`` orders the listing,
  ``--json`` emits the same record machine-readably;
* ``verify ROOT``    — integrity check: manifest checksums, format
  versions, store presence, recorded-vs-actual entry counts, and
  plan-manifest ↔ dir-manifest fingerprint consistency (exit 1 on any
  failure — a hand-edited manifest is detected by its checksum);
* ``warm SCENARIO``  — speculative precomputation: compile the named
  serving scenario through the plan stack and precompute its caches
  offline over the expected traffic distribution (``--queries F`` for
  an explicit qid/query log, ``--budget N`` for the N hottest), so a
  later ``repro serve`` over the same ``--cache-dir`` starts warm;
* ``evict ROOT``     — enforce per-family budgets: TTL-expired entries
  first, then least-recently-used, until every dir is within
  ``--budget`` entries / ``--max-bytes`` / ``--ttl``; ``--record``
  writes the budget into the manifests so ``close()`` re-enforces it
  automatically;
* ``gc ROOT``        — prune dirs unused for ``--older-than`` and/or
  ``--orphaned`` dirs no plan manifest references (dry-run unless
  ``--yes``);
* ``export DIR OUT`` — package one node's entries as a portable
  artifact: backends that can enumerate entries export them
  backend-agnostically (re-importable into *any* registry backend at
  any compatible pipeline position), others export raw store files;
* ``import ART DEST``— materialize an artifact into a cache dir;
  fingerprint mismatches with an existing destination manifest are
  refused without ``--force``.

Import only artifacts you trust — entries are pickled blobs, the same
trust model as the shared result files the source paper discusses.
"""
from __future__ import annotations

import argparse
import io
import json
import os
import pickle
import shutil
import tarfile
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

from ..caching.backends import (BACKENDS, backend_store_exists,
                                split_tiered)
from ..caching.provenance import (MANIFEST_NAME, PLAN_MANIFEST_VERSION,
                                  CacheManifest, ManifestError,
                                  iter_plan_manifests, manifest_path)

__all__ = ["register", "cmd_ls", "cmd_verify", "cmd_warm", "cmd_evict",
           "cmd_gc", "cmd_export", "cmd_import"]

EXPORT_FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# registration
# ---------------------------------------------------------------------------

def register(subparsers) -> None:
    p = subparsers.add_parser(
        "cache", help="inspect / verify / prune / share cache directories",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = p.add_subparsers(dest="cache_command", required=True)

    ls = sub.add_parser("ls", help="list cache dirs and plan manifests")
    ls.add_argument("root", help="cache root (a planner cache_dir) or "
                                 "a single cache directory")
    ls.add_argument("--sort", choices=("name", "size", "age", "hits"),
                    default="name",
                    help="order dirs by store size (desc), last use "
                         "(oldest first) or recorded hits (desc); "
                         "default: name")
    ls.add_argument("--json", action="store_true", dest="as_json")
    ls.set_defaults(func=cmd_ls)

    vf = sub.add_parser("verify", help="integrity-check manifests and stores")
    vf.add_argument("root")
    vf.add_argument("--json", action="store_true", dest="as_json")
    vf.set_defaults(func=cmd_verify)

    wm = sub.add_parser(
        "warm", help="speculatively precompute a serving scenario's caches")
    wm.add_argument("scenario",
                    help="serving scenario name (see `repro serve "
                         "--list-pipelines`): bm25, bm25-mono, mono")
    wm.add_argument("--cache-dir", required=True,
                    help="cache root to precompute into (pass the same "
                         "directory to `repro serve` later)")
    wm.add_argument("--queries", default=None, metavar="FILE",
                    help="explicit warming log: TSV 'qid<TAB>query' lines "
                         "or a .json list of row objects; default is the "
                         "scenario's expected traffic distribution")
    wm.add_argument("--budget", type=int, default=None, metavar="N",
                    help="warm only the N most-expected queries")
    wm.add_argument("--backend", default=None,
                    help="cache backend selector (e.g. sqlite, "
                         "tiered:sqlite); default: per-family defaults")
    wm.add_argument("--requests", type=int, default=512,
                    help="simulated request count for the traffic "
                         "distribution (default 512)")
    wm.add_argument("--clients", type=int, default=4,
                    help="simulated closed-loop clients (default 4; match "
                         "the serve invocation)")
    wm.add_argument("--scale", type=float, default=0.05)
    wm.add_argument("--cutoff", type=int, default=10)
    wm.add_argument("--num-results", type=int, default=100)
    wm.add_argument("--seed", type=int, default=0)
    wm.add_argument("--batch-size", type=int, default=None)
    wm.add_argument("--chunk-rows", type=int, default=None,
                    help="warm in qid-aligned chunks of at most this many "
                         "rows (bounded memory for large logs)")
    wm.add_argument("--json", action="store_true", dest="as_json")
    wm.set_defaults(func=cmd_warm)

    ev = sub.add_parser(
        "evict", help="enforce entry/size/TTL budgets (LRU eviction)")
    ev.add_argument("root", help="cache root or a single cache directory")
    ev.add_argument("--budget", type=int, default=None, metavar="N",
                    help="max entries per cache dir")
    ev.add_argument("--max-bytes", default=None, metavar="SIZE",
                    help="max store bytes per dir (K/M/G suffixes ok)")
    ev.add_argument("--ttl", default=None, metavar="AGE",
                    help="evict entries unused for AGE (e.g. 30s, 12h, 7d)")
    ev.add_argument("--record", action="store_true",
                    help="also record this budget in each dir's manifest "
                         "so close() re-enforces it automatically")
    ev.add_argument("--json", action="store_true", dest="as_json")
    ev.set_defaults(func=cmd_evict)

    gc = sub.add_parser("gc", help="prune stale / orphaned cache dirs")
    gc.add_argument("root")
    gc.add_argument("--older-than", metavar="AGE", default=None,
                    help="remove dirs last used more than AGE ago "
                         "(e.g. 30s, 12h, 7d; bare numbers are seconds)")
    gc.add_argument("--orphaned", action="store_true",
                    help="remove dirs referenced by no plan manifest")
    gc.add_argument("--yes", action="store_true",
                    help="actually delete (default is a dry run)")
    gc.set_defaults(func=cmd_gc)

    ex = sub.add_parser("export", help="package one cache dir as a "
                                       "portable artifact")
    ex.add_argument("cache_dir")
    ex.add_argument("out", help="output artifact path (.tar)")
    ex.set_defaults(func=cmd_export)

    im = sub.add_parser("import", help="materialize an artifact into a "
                                       "cache dir")
    im.add_argument("artifact")
    im.add_argument("dest", help="destination cache directory (e.g. the "
                                 "planner node dir shown by `repro cache "
                                 "ls`)")
    im.add_argument("--backend", default=None,
                    help="store entry-mode artifacts in this backend "
                         "instead of the recorded one")
    im.add_argument("--force", action="store_true",
                    help="overwrite despite fingerprint mismatch / "
                         "non-empty destination")
    im.set_defaults(func=cmd_import)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _cache_dirs(root: str) -> List[str]:
    """Directories holding a ``manifest.json``: the root itself, or its
    immediate children (a planner ``cache_dir`` layout)."""
    root = os.path.abspath(root)
    if os.path.exists(manifest_path(root)):
        return [root]
    if not os.path.isdir(root):
        return []
    out = []
    for name in sorted(os.listdir(root)):
        d = os.path.join(root, name)
        if os.path.isdir(d) and os.path.exists(manifest_path(d)):
            out.append(d)
    return out


def _disk_name(backend: Optional[str]) -> Optional[str]:
    """Resolve a ``tiered[:<disk>]`` selector to its disk tier name;
    pass plain registry names through; ``None`` for anything else."""
    try:
        disk = split_tiered(backend) if isinstance(backend, str) else None
    except ValueError:
        return None
    if disk is not None:
        return disk
    return backend if backend in BACKENDS else None


def _store_exists(dirpath: str, backend: Optional[str]) -> bool:
    if backend == "dense":               # DenseScorerCache layout
        return os.path.exists(os.path.join(dirpath, "scores.npy"))
    if backend == "log":                 # IndexerCache layout
        return os.path.exists(os.path.join(dirpath, "offsets.npy"))
    # registry backends (incl. tiered:<disk>) know their own files
    return backend_store_exists(backend, dirpath)


def _actual_entries(dirpath: str, backend: Optional[str]) -> Optional[int]:
    """Count the entries actually present in a directory's store;
    ``None`` when the backend cannot be counted offline.  Tiered
    selectors count their disk tier (the source of truth)."""
    disk = _disk_name(backend)
    if backend == "memory":
        return None                      # in-process only; nothing on disk
    if disk is None and backend not in ("dense", "log"):
        return None                      # selector unknown to this build
    if not _store_exists(dirpath, backend):
        return 0
    if disk is not None:
        b = BACKENDS[disk](dirpath)
        try:
            return len(b)
        finally:
            b.close()
    if backend == "dense":
        import numpy as np
        qpath = os.path.join(dirpath, "queries.json")
        if not os.path.exists(qpath):
            return 0
        with open(qpath) as f:
            rows = sorted(json.load(f).values())
        if not rows:
            return 0
        mat = np.lib.format.open_memmap(
            os.path.join(dirpath, "scores.npy"), mode="r")
        return int(np.sum(~np.isnan(mat[rows])))
    if backend == "log":
        import numpy as np
        return int(np.load(os.path.join(dirpath, "offsets.npy")).shape[0])
    return None


def _dir_size(dirpath: str) -> int:
    total = 0
    for base, _, files in os.walk(dirpath):
        for f in files:
            try:
                total += os.path.getsize(os.path.join(base, f))
            except OSError:
                pass
    return total


def _fmt_time(ts: float) -> str:
    if not ts:
        return "-"
    return time.strftime("%Y-%m-%d %H:%M:%S", time.localtime(ts))


def _parse_age(text: str) -> float:
    text = text.strip().lower()
    units = {"s": 1.0, "m": 60.0, "h": 3600.0, "d": 86400.0, "w": 604800.0}
    mult = 1.0
    if text and text[-1] in units:
        mult = units[text[-1]]
        text = text[:-1]
    try:
        return float(text) * mult
    except ValueError:
        raise SystemExit(f"repro cache: invalid age {text!r} "
                         f"(expected e.g. 30s, 12h, 7d)")


def _parse_size(text: str) -> int:
    text = text.strip().lower()
    units = {"k": 1024, "m": 1024 ** 2, "g": 1024 ** 3}
    mult = 1
    if text and text[-1] in units:
        mult = units[text[-1]]
        text = text[:-1]
    try:
        return int(float(text) * mult)
    except ValueError:
        raise SystemExit(f"repro cache: invalid size {text!r} "
                         f"(expected e.g. 4096, 64K, 2M, 1G)")


def _load_manifest_doc(dirpath: str) -> Tuple[Optional[CacheManifest],
                                              Optional[str]]:
    try:
        return CacheManifest.load(dirpath), None
    except ManifestError as e:
        return None, str(e)


# ---------------------------------------------------------------------------
# ls
# ---------------------------------------------------------------------------

def _access_hits(dirpath: str) -> int:
    """Total recorded hits from the dir's access-stats sidecar."""
    from ..caching.economics import AccessStats
    return AccessStats.load(dirpath).total_hits()


def _budget_utilization(m: CacheManifest,
                        size_bytes: int) -> Optional[Dict[str, Any]]:
    """Fraction of each recorded budget in use (``None`` when the dir
    has no budget).  ``entries`` is manifest count / ``max_entries``;
    ``bytes`` is on-disk size / ``max_bytes``."""
    if not m.has_budget():
        return None
    out: Dict[str, Any] = {}
    if m.max_entries is not None:
        out["entries"] = round(m.entry_count / m.max_entries, 4) \
            if m.max_entries > 0 else None
    if m.max_bytes is not None:
        out["bytes"] = round(size_bytes / m.max_bytes, 4) \
            if m.max_bytes > 0 else None
    return out


def _sort_dirs(dirs: List[Dict[str, Any]], key: str) -> List[Dict[str, Any]]:
    if key == "size":
        return sorted(dirs, key=lambda r: (-r.get("size_bytes", 0),
                                           r["dir"]))
    if key == "age":                     # oldest last-use first
        return sorted(dirs, key=lambda r: (r.get("last_used_at", 0.0),
                                           r["dir"]))
    if key == "hits":
        return sorted(dirs, key=lambda r: (-r.get("hits", 0), r["dir"]))
    return dirs                          # "name": _cache_dirs order


def _collect(root: str) -> Dict[str, Any]:
    root = os.path.abspath(root)
    dirs = []
    for d in _cache_dirs(root):
        m, err = _load_manifest_doc(d)
        rec: Dict[str, Any] = {"dir": os.path.relpath(d, root) if d != root
                               else ".", "path": d}
        if err is not None:
            rec["error"] = err
        else:
            size = _dir_size(d)
            rec.update(family=m.family, backend=m.backend,
                       fingerprint=m.fingerprint,
                       transformer=m.transformer,
                       key_columns=m.key_columns,
                       value_columns=m.value_columns,
                       entry_count=m.entry_count,
                       created_at=m.created_at,
                       last_used_at=m.last_used_at,
                       size_bytes=size,
                       max_entries=m.max_entries,
                       max_bytes=m.max_bytes,
                       ttl_seconds=m.ttl_seconds,
                       hits=_access_hits(d),
                       budget_utilization=_budget_utilization(m, size))
        dirs.append(rec)
    plans = []
    for path, doc, err in iter_plan_manifests(root):
        rec = {"path": path}
        if err is not None:
            rec["error"] = err
        else:
            rec.update(plan_id=doc.get("plan_id"),
                       created_at=doc.get("created_at"),
                       pipelines=doc.get("pipelines", []),
                       n_nodes=len(doc.get("nodes", [])),
                       n_runs=len(doc.get("runs", [])))
        plans.append(rec)
    return {"root": root, "dirs": dirs, "plans": plans}


def cmd_ls(args) -> int:
    info = _collect(args.root)
    info["dirs"] = _sort_dirs(info["dirs"], getattr(args, "sort", "name"))
    if args.as_json:
        print(json.dumps(info, indent=2, sort_keys=True))
        return 0
    if not info["dirs"]:
        print(f"no cache directories under {info['root']}")
    for rec in info["dirs"]:
        if "error" in rec:
            print(f"{rec['dir']}: UNREADABLE ({rec['error']})")
            continue
        fp = rec["fingerprint"] or "-"
        budget = ""
        util = rec.get("budget_utilization")
        if util:
            parts = [f"{k}={v:.0%}" for k, v in sorted(util.items())
                     if v is not None]
            budget = f" budget[{' '.join(parts)}]" if parts else ""
        print(f"{rec['dir']}: {rec['family']}[{rec['backend']}] "
              f"entries={rec['entry_count']} "
              f"size={rec['size_bytes'] / 1024:.1f}KiB "
              f"hits={rec.get('hits', 0)}{budget} fp={fp} "
              f"last_used={_fmt_time(rec['last_used_at'])}")
        if rec.get("transformer"):
            print(f"    transformer: {rec['transformer']}")
    for rec in info["plans"]:
        if "error" in rec:
            print(f"plan {os.path.basename(rec['path'])}: UNREADABLE "
                  f"({rec['error']})")
            continue
        print(f"plan {rec['plan_id']}: {len(rec['pipelines'])} pipeline(s), "
              f"{rec['n_nodes']} node(s), {rec['n_runs']} recorded run(s), "
              f"created={_fmt_time(rec['created_at'] or 0)}")
    return 0


# ---------------------------------------------------------------------------
# verify
# ---------------------------------------------------------------------------

def cmd_verify(args) -> int:
    root = os.path.abspath(args.root)
    report: List[Dict[str, Any]] = []
    manifests: Dict[str, Optional[CacheManifest]] = {}

    for d in _cache_dirs(root):
        rel = os.path.relpath(d, root) if d != root else "."
        problems: List[str] = []
        m, err = _load_manifest_doc(d)
        manifests[os.path.basename(d)] = m
        if err is not None:
            problems.append(err)
        else:
            actual = _actual_entries(d, m.backend)
            if actual is not None and actual != m.entry_count:
                problems.append(
                    f"entry count mismatch: store holds {actual}, "
                    f"manifest records {m.entry_count}")
        report.append({"dir": rel, "problems": problems})

    for path, doc, err in iter_plan_manifests(root):
        name = f"plan:{os.path.basename(path)}"
        problems = []
        if err is not None:
            problems.append(err)
        else:
            ver = doc.get("format_version")
            if not isinstance(ver, int) or ver > PLAN_MANIFEST_VERSION:
                problems.append(f"unsupported plan format_version {ver!r}")
            for node in doc.get("nodes", []):
                nd = node.get("dir")
                if not nd:
                    continue
                m = manifests.get(nd)
                if m is None:
                    if not os.path.isdir(os.path.join(root, nd)):
                        problems.append(
                            f"node {node.get('label')!r} references missing "
                            f"dir {nd!r} (gc'd or never populated)")
                    continue
                if m.fingerprint and node.get("fingerprint") \
                        and m.fingerprint != node["fingerprint"]:
                    problems.append(
                        f"node {node.get('label')!r}: plan fingerprint "
                        f"{node['fingerprint']} != dir manifest "
                        f"{m.fingerprint}")
        report.append({"dir": name, "problems": problems})

    failed = [r for r in report if r["problems"]]
    if args.as_json:
        print(json.dumps({"root": root, "checked": len(report),
                          "failed": len(failed), "report": report},
                         indent=2, sort_keys=True))
    else:
        for r in report:
            if r["problems"]:
                print(f"FAIL {r['dir']}")
                for p in r["problems"]:
                    print(f"    {p}")
            else:
                print(f"OK   {r['dir']}")
        print(f"verified {len(report)} item(s), {len(failed)} failure(s)")
    return 1 if failed else 0


# ---------------------------------------------------------------------------
# warm (speculative precomputation)
# ---------------------------------------------------------------------------

def _load_queries_file(path: str) -> List[Dict[str, Any]]:
    """Rows for an explicit warming log: a ``.json`` list of row
    objects, or TSV ``qid<TAB>query`` lines."""
    if path.endswith(".json"):
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
        rows = doc if isinstance(doc, list) else doc.get("rows")
        if not isinstance(rows, list):
            raise SystemExit(f"repro cache warm: {path!r} must hold a JSON "
                             f"list of row objects (or {{'rows': [...]}})")
        return rows
    rows = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.rstrip("\n")
            if not line:
                continue
            qid, sep, query = line.partition("\t")
            if not sep:
                raise SystemExit(f"repro cache warm: {path!r} line "
                                 f"{line!r} is not 'qid<TAB>query'")
            rows.append({"qid": qid, "query": query})
    return rows


def cmd_warm(args) -> int:
    from ..caching.warming import warm_scenario
    queries = _load_queries_file(args.queries) if args.queries else None
    rep = warm_scenario(
        args.scenario, os.path.abspath(args.cache_dir),
        queries=queries, budget=args.budget, backend=args.backend,
        requests=args.requests, clients=args.clients, scale=args.scale,
        cutoff=args.cutoff, num_results=args.num_results, seed=args.seed,
        batch_size=args.batch_size, chunk_rows=args.chunk_rows)
    if args.as_json:
        print(json.dumps(rep, indent=2, sort_keys=True))
    else:
        print(f"warmed {rep['queries_warmed']} query(s) for scenario "
              f"{rep['scenario']!r} into {rep['cache_dir']} "
              f"(precomputed={rep['cache_misses']} "
              f"already-cached={rep['cache_hits']}, "
              f"{rep['wall_s']:.2f}s)")
    return 0


# ---------------------------------------------------------------------------
# evict (budget enforcement)
# ---------------------------------------------------------------------------

def cmd_evict(args) -> int:
    from ..caching.economics import CacheBudget, enforce_dir
    root = os.path.abspath(args.root)
    budget = CacheBudget(
        max_entries=args.budget,
        max_bytes=_parse_size(args.max_bytes)
        if args.max_bytes is not None else None,
        ttl_seconds=_parse_age(args.ttl)
        if args.ttl is not None else None)
    dirs = _cache_dirs(root)
    if not dirs:
        print(f"no cache directories under {root}")
        return 0
    report = []
    for d in dirs:
        rel = os.path.relpath(d, root) if d != root else "."
        if args.record and not budget.empty():
            m, err = _load_manifest_doc(d)
            if m is not None and budget.record_in(m):
                m.save(d)
        rep = enforce_dir(d, None if budget.empty() else budget)
        report.append({"dir": rel, **rep})
    if args.as_json:
        print(json.dumps({"root": root, "dirs": report},
                         indent=2, sort_keys=True))
        return 0
    for rec in report:
        if "skipped" in rec:
            print(f"{rec['dir']}: skipped ({rec['skipped']})")
            continue
        print(f"{rec['dir']}: evicted {rec['evicted']} "
              f"({rec['expired']} expired), {rec['entries_before']} -> "
              f"{rec['entries_after']} entrie(s), "
              f"{rec['evicted_bytes'] / 1024:.1f}KiB freed"
              + (f", {rec['unevictable']} unevictable"
                 if rec.get("unevictable") else ""))
    return 0


# ---------------------------------------------------------------------------
# gc
# ---------------------------------------------------------------------------

def cmd_gc(args) -> int:
    root = os.path.abspath(args.root)
    if args.older_than is None and not args.orphaned:
        raise SystemExit("repro cache gc: nothing selected — pass "
                         "--older-than and/or --orphaned")
    dirs = [d for d in _cache_dirs(root) if d != root]
    victims: Dict[str, str] = {}

    if args.older_than is not None:
        cutoff = time.time() - _parse_age(args.older_than)
        for d in dirs:
            m, err = _load_manifest_doc(d)
            if m is None:
                continue                 # unreadable: verify's business
            last = m.last_used_at or m.created_at
            if last <= cutoff:
                victims[d] = (f"last used {_fmt_time(last)}, older than "
                              f"{args.older_than}")

    if args.orphaned:
        referenced = set()
        for _, doc, _err in iter_plan_manifests(root):
            if doc:
                referenced.update(n.get("dir") for n in doc.get("nodes", [])
                                  if n.get("dir"))
        for d in dirs:
            if os.path.basename(d) not in referenced:
                victims.setdefault(d, "referenced by no plan manifest")

    if not victims:
        print("nothing to collect")
        return 0
    freed = 0
    for d in sorted(victims):
        size = _dir_size(d)
        freed += size
        verb = "removing" if args.yes else "would remove"
        print(f"{verb} {d} ({victims[d]}; {size / 1024:.1f}KiB)")
        if args.yes:
            shutil.rmtree(d, ignore_errors=True)
    action = "freed" if args.yes else "would free"
    print(f"{action} {freed / 1024:.1f}KiB across {len(victims)} dir(s)"
          + ("" if args.yes else " — re-run with --yes to delete"))
    return 0


# ---------------------------------------------------------------------------
# export / import
# ---------------------------------------------------------------------------

def _add_bytes(tar: tarfile.TarFile, name: str, data: bytes) -> None:
    info = tarfile.TarInfo(name)
    info.size = len(data)
    info.mtime = int(time.time())
    tar.addfile(info, io.BytesIO(data))


def _safe_extractall(tar: tarfile.TarFile, dest: str, members=None) -> None:
    # the extraction ``filter=`` kwarg is absent on 3.10.<12 / 3.11.<4
    if hasattr(tarfile, "data_filter"):
        tar.extractall(dest, members=members, filter="data")
    else:                                # pragma: no cover - old stdlib
        tar.extractall(dest, members=members)


def cmd_export(args) -> int:
    src = os.path.abspath(args.cache_dir)
    m, err = _load_manifest_doc(src)
    if err is not None:
        raise SystemExit(f"repro cache export: {err}")
    if m is None:
        raise SystemExit(f"repro cache export: {src!r} has no "
                         f"{MANIFEST_NAME} — not a provenance-aware cache "
                         f"directory")
    entries: Optional[List[Tuple[bytes, bytes]]] = None
    if m.backend in BACKENDS and m.backend != "memory" \
            and _store_exists(src, m.backend):
        backend = BACKENDS[m.backend](src)
        try:
            entries = backend.items()
        except NotImplementedError:
            entries = None               # e.g. pickle: raw-file export
        finally:
            backend.close()
    mode = "entries" if entries is not None else "raw"
    meta = {"format_version": EXPORT_FORMAT_VERSION, "mode": mode,
            "exported_at": time.time(),
            "n_entries": len(entries) if entries is not None
            else m.entry_count}
    with tarfile.open(args.out, "w") as tar:
        _add_bytes(tar, "export.json",
                   json.dumps(meta, indent=2, sort_keys=True).encode())
        with open(manifest_path(src), "rb") as f:
            _add_bytes(tar, MANIFEST_NAME, f.read())
        if mode == "entries":
            _add_bytes(tar, "entries.pkl", pickle.dumps(
                entries, protocol=pickle.HIGHEST_PROTOCOL))
        else:
            for base, _, files in os.walk(src):
                for fname in files:
                    full = os.path.join(base, fname)
                    rel = os.path.relpath(full, src)
                    if rel == MANIFEST_NAME:
                        continue
                    tar.add(full, arcname=os.path.join("raw", rel))
    print(f"exported {meta['n_entries']} entrie(s) from {src} "
          f"({mode} mode, fp={m.fingerprint or '-'}) -> {args.out}")
    return 0


def _read_member(tar: tarfile.TarFile, name: str) -> bytes:
    f = tar.extractfile(name)
    if f is None:
        raise SystemExit(f"repro cache import: artifact is missing {name!r}")
    return f.read()


def cmd_import(args) -> int:
    dest = os.path.abspath(args.dest)
    with tarfile.open(args.artifact) as tar:
        meta = json.loads(_read_member(tar, "export.json"))
        if meta.get("format_version", 0) > EXPORT_FORMAT_VERSION:
            raise SystemExit("repro cache import: artifact written by a "
                             "newer exporter")
        man_bytes = _read_member(tar, MANIFEST_NAME)
        with tempfile.TemporaryDirectory() as td:
            with open(manifest_path(td), "wb") as f:
                f.write(man_bytes)
            try:
                imported = CacheManifest.load(td)
            except ManifestError as e:
                raise SystemExit(f"repro cache import: {e}")

        existing, err = (None, None)
        if os.path.isdir(dest):
            existing, err = _load_manifest_doc(dest)
            if err is not None and not args.force:
                raise SystemExit(f"repro cache import: destination has a "
                                 f"corrupted manifest ({err}); pass --force "
                                 f"to overwrite")
        if existing is not None and existing.fingerprint \
                and imported.fingerprint \
                and existing.fingerprint != imported.fingerprint \
                and not args.force:
            raise SystemExit(
                f"repro cache import: fingerprint mismatch — destination "
                f"records {existing.fingerprint}, artifact carries "
                f"{imported.fingerprint}; this is not the same pipeline "
                f"position (pass --force to import anyway)")

        if meta["mode"] == "entries":
            backend_name = args.backend or imported.backend
            if backend_name not in BACKENDS:
                raise SystemExit(f"repro cache import: unknown backend "
                                 f"{backend_name!r}; registered: "
                                 f"{', '.join(sorted(BACKENDS))}")
            entries = pickle.loads(_read_member(tar, "entries.pkl"))
            os.makedirs(dest, exist_ok=True)
            backend = BACKENDS[backend_name](dest)
            try:
                backend.put_many(entries)
                n = len(backend)
            finally:
                backend.close()
            imported.backend = backend_name
            imported.entry_count = int(n)
            imported.last_used_at = time.time()
            imported.save(dest)
        else:
            if os.path.isdir(dest) and os.listdir(dest) and not args.force:
                raise SystemExit(f"repro cache import: destination {dest!r} "
                                 f"is not empty (pass --force)")
            os.makedirs(dest, exist_ok=True)
            members = [m_ for m_ in tar.getmembers()
                       if m_.name.startswith("raw/")]
            for m_ in members:
                m_.name = os.path.relpath(m_.name, "raw")
            _safe_extractall(tar, dest, members=members)
            imported.last_used_at = time.time()
            imported.save(dest)

    print(f"imported {meta['n_entries']} entrie(s) into {dest} "
          f"({meta['mode']} mode, fp={imported.fingerprint or '-'})")
    return 0
