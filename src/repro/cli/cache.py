"""``repro cache`` — manage provenance-aware cache directories.

Every cache directory carries a checksummed ``manifest.json``
(``caching/provenance.py``) and planner-managed roots additionally
carry per-plan manifests under ``plans/``; this tool consumes both:

* ``ls ROOT``        — list cache dirs (family, backend, entries,
  fingerprint, last use) and the plans that reference them;
* ``verify ROOT``    — integrity check: manifest checksums, format
  versions, store presence, recorded-vs-actual entry counts, and
  plan-manifest ↔ dir-manifest fingerprint consistency (exit 1 on any
  failure — a hand-edited manifest is detected by its checksum);
* ``gc ROOT``        — prune dirs unused for ``--older-than`` and/or
  ``--orphaned`` dirs no plan manifest references (dry-run unless
  ``--yes``);
* ``export DIR OUT`` — package one node's entries as a portable
  artifact: backends that can enumerate entries export them
  backend-agnostically (re-importable into *any* registry backend at
  any compatible pipeline position), others export raw store files;
* ``import ART DEST``— materialize an artifact into a cache dir;
  fingerprint mismatches with an existing destination manifest are
  refused without ``--force``.

Import only artifacts you trust — entries are pickled blobs, the same
trust model as the shared result files the source paper discusses.
"""
from __future__ import annotations

import argparse
import io
import json
import os
import pickle
import shutil
import tarfile
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

from ..caching.backends import BACKENDS
from ..caching.provenance import (MANIFEST_NAME, PLAN_MANIFEST_VERSION,
                                  CacheManifest, ManifestError,
                                  iter_plan_manifests, manifest_path)

__all__ = ["register", "cmd_ls", "cmd_verify", "cmd_gc", "cmd_export",
           "cmd_import"]

EXPORT_FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# registration
# ---------------------------------------------------------------------------

def register(subparsers) -> None:
    p = subparsers.add_parser(
        "cache", help="inspect / verify / prune / share cache directories",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = p.add_subparsers(dest="cache_command", required=True)

    ls = sub.add_parser("ls", help="list cache dirs and plan manifests")
    ls.add_argument("root", help="cache root (a planner cache_dir) or "
                                 "a single cache directory")
    ls.add_argument("--json", action="store_true", dest="as_json")
    ls.set_defaults(func=cmd_ls)

    vf = sub.add_parser("verify", help="integrity-check manifests and stores")
    vf.add_argument("root")
    vf.add_argument("--json", action="store_true", dest="as_json")
    vf.set_defaults(func=cmd_verify)

    gc = sub.add_parser("gc", help="prune stale / orphaned cache dirs")
    gc.add_argument("root")
    gc.add_argument("--older-than", metavar="AGE", default=None,
                    help="remove dirs last used more than AGE ago "
                         "(e.g. 30s, 12h, 7d; bare numbers are seconds)")
    gc.add_argument("--orphaned", action="store_true",
                    help="remove dirs referenced by no plan manifest")
    gc.add_argument("--yes", action="store_true",
                    help="actually delete (default is a dry run)")
    gc.set_defaults(func=cmd_gc)

    ex = sub.add_parser("export", help="package one cache dir as a "
                                       "portable artifact")
    ex.add_argument("cache_dir")
    ex.add_argument("out", help="output artifact path (.tar)")
    ex.set_defaults(func=cmd_export)

    im = sub.add_parser("import", help="materialize an artifact into a "
                                       "cache dir")
    im.add_argument("artifact")
    im.add_argument("dest", help="destination cache directory (e.g. the "
                                 "planner node dir shown by `repro cache "
                                 "ls`)")
    im.add_argument("--backend", default=None,
                    help="store entry-mode artifacts in this backend "
                         "instead of the recorded one")
    im.add_argument("--force", action="store_true",
                    help="overwrite despite fingerprint mismatch / "
                         "non-empty destination")
    im.set_defaults(func=cmd_import)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _cache_dirs(root: str) -> List[str]:
    """Directories holding a ``manifest.json``: the root itself, or its
    immediate children (a planner ``cache_dir`` layout)."""
    root = os.path.abspath(root)
    if os.path.exists(manifest_path(root)):
        return [root]
    if not os.path.isdir(root):
        return []
    out = []
    for name in sorted(os.listdir(root)):
        d = os.path.join(root, name)
        if os.path.isdir(d) and os.path.exists(manifest_path(d)):
            out.append(d)
    return out


def _store_exists(dirpath: str, backend: Optional[str]) -> bool:
    if backend in BACKENDS:              # registry backends know their files
        return BACKENDS[backend].store_exists(dirpath)
    if backend == "dense":               # DenseScorerCache layout
        return os.path.exists(os.path.join(dirpath, "scores.npy"))
    if backend == "log":                 # IndexerCache layout
        return os.path.exists(os.path.join(dirpath, "offsets.npy"))
    return False


def _actual_entries(dirpath: str, backend: Optional[str]) -> Optional[int]:
    """Count the entries actually present in a directory's store;
    ``None`` when the backend cannot be counted offline."""
    if backend == "memory":
        return None                      # in-process only; nothing on disk
    if not _store_exists(dirpath, backend):
        return 0
    if backend in BACKENDS:
        b = BACKENDS[backend](dirpath)
        try:
            return len(b)
        finally:
            b.close()
    if backend == "dense":
        import numpy as np
        qpath = os.path.join(dirpath, "queries.json")
        if not os.path.exists(qpath):
            return 0
        with open(qpath) as f:
            rows = sorted(json.load(f).values())
        if not rows:
            return 0
        mat = np.lib.format.open_memmap(
            os.path.join(dirpath, "scores.npy"), mode="r")
        return int(np.sum(~np.isnan(mat[rows])))
    if backend == "log":
        import numpy as np
        return int(np.load(os.path.join(dirpath, "offsets.npy")).shape[0])
    return None


def _dir_size(dirpath: str) -> int:
    total = 0
    for base, _, files in os.walk(dirpath):
        for f in files:
            try:
                total += os.path.getsize(os.path.join(base, f))
            except OSError:
                pass
    return total


def _fmt_time(ts: float) -> str:
    if not ts:
        return "-"
    return time.strftime("%Y-%m-%d %H:%M:%S", time.localtime(ts))


def _parse_age(text: str) -> float:
    text = text.strip().lower()
    units = {"s": 1.0, "m": 60.0, "h": 3600.0, "d": 86400.0, "w": 604800.0}
    mult = 1.0
    if text and text[-1] in units:
        mult = units[text[-1]]
        text = text[:-1]
    try:
        return float(text) * mult
    except ValueError:
        raise SystemExit(f"repro cache: invalid age {text!r} "
                         f"(expected e.g. 30s, 12h, 7d)")


def _load_manifest_doc(dirpath: str) -> Tuple[Optional[CacheManifest],
                                              Optional[str]]:
    try:
        return CacheManifest.load(dirpath), None
    except ManifestError as e:
        return None, str(e)


# ---------------------------------------------------------------------------
# ls
# ---------------------------------------------------------------------------

def _collect(root: str) -> Dict[str, Any]:
    root = os.path.abspath(root)
    dirs = []
    for d in _cache_dirs(root):
        m, err = _load_manifest_doc(d)
        rec: Dict[str, Any] = {"dir": os.path.relpath(d, root) if d != root
                               else ".", "path": d}
        if err is not None:
            rec["error"] = err
        else:
            rec.update(family=m.family, backend=m.backend,
                       fingerprint=m.fingerprint,
                       transformer=m.transformer,
                       key_columns=m.key_columns,
                       value_columns=m.value_columns,
                       entry_count=m.entry_count,
                       created_at=m.created_at,
                       last_used_at=m.last_used_at,
                       size_bytes=_dir_size(d))
        dirs.append(rec)
    plans = []
    for path, doc, err in iter_plan_manifests(root):
        rec = {"path": path}
        if err is not None:
            rec["error"] = err
        else:
            rec.update(plan_id=doc.get("plan_id"),
                       created_at=doc.get("created_at"),
                       pipelines=doc.get("pipelines", []),
                       n_nodes=len(doc.get("nodes", [])),
                       n_runs=len(doc.get("runs", [])))
        plans.append(rec)
    return {"root": root, "dirs": dirs, "plans": plans}


def cmd_ls(args) -> int:
    info = _collect(args.root)
    if args.as_json:
        print(json.dumps(info, indent=2, sort_keys=True))
        return 0
    if not info["dirs"]:
        print(f"no cache directories under {info['root']}")
    for rec in info["dirs"]:
        if "error" in rec:
            print(f"{rec['dir']}: UNREADABLE ({rec['error']})")
            continue
        fp = rec["fingerprint"] or "-"
        print(f"{rec['dir']}: {rec['family']}[{rec['backend']}] "
              f"entries={rec['entry_count']} "
              f"size={rec['size_bytes'] / 1024:.1f}KiB fp={fp} "
              f"last_used={_fmt_time(rec['last_used_at'])}")
        if rec.get("transformer"):
            print(f"    transformer: {rec['transformer']}")
    for rec in info["plans"]:
        if "error" in rec:
            print(f"plan {os.path.basename(rec['path'])}: UNREADABLE "
                  f"({rec['error']})")
            continue
        print(f"plan {rec['plan_id']}: {len(rec['pipelines'])} pipeline(s), "
              f"{rec['n_nodes']} node(s), {rec['n_runs']} recorded run(s), "
              f"created={_fmt_time(rec['created_at'] or 0)}")
    return 0


# ---------------------------------------------------------------------------
# verify
# ---------------------------------------------------------------------------

def cmd_verify(args) -> int:
    root = os.path.abspath(args.root)
    report: List[Dict[str, Any]] = []
    manifests: Dict[str, Optional[CacheManifest]] = {}

    for d in _cache_dirs(root):
        rel = os.path.relpath(d, root) if d != root else "."
        problems: List[str] = []
        m, err = _load_manifest_doc(d)
        manifests[os.path.basename(d)] = m
        if err is not None:
            problems.append(err)
        else:
            actual = _actual_entries(d, m.backend)
            if actual is not None and actual != m.entry_count:
                problems.append(
                    f"entry count mismatch: store holds {actual}, "
                    f"manifest records {m.entry_count}")
        report.append({"dir": rel, "problems": problems})

    for path, doc, err in iter_plan_manifests(root):
        name = f"plan:{os.path.basename(path)}"
        problems = []
        if err is not None:
            problems.append(err)
        else:
            ver = doc.get("format_version")
            if not isinstance(ver, int) or ver > PLAN_MANIFEST_VERSION:
                problems.append(f"unsupported plan format_version {ver!r}")
            for node in doc.get("nodes", []):
                nd = node.get("dir")
                if not nd:
                    continue
                m = manifests.get(nd)
                if m is None:
                    if not os.path.isdir(os.path.join(root, nd)):
                        problems.append(
                            f"node {node.get('label')!r} references missing "
                            f"dir {nd!r} (gc'd or never populated)")
                    continue
                if m.fingerprint and node.get("fingerprint") \
                        and m.fingerprint != node["fingerprint"]:
                    problems.append(
                        f"node {node.get('label')!r}: plan fingerprint "
                        f"{node['fingerprint']} != dir manifest "
                        f"{m.fingerprint}")
        report.append({"dir": name, "problems": problems})

    failed = [r for r in report if r["problems"]]
    if args.as_json:
        print(json.dumps({"root": root, "checked": len(report),
                          "failed": len(failed), "report": report},
                         indent=2, sort_keys=True))
    else:
        for r in report:
            if r["problems"]:
                print(f"FAIL {r['dir']}")
                for p in r["problems"]:
                    print(f"    {p}")
            else:
                print(f"OK   {r['dir']}")
        print(f"verified {len(report)} item(s), {len(failed)} failure(s)")
    return 1 if failed else 0


# ---------------------------------------------------------------------------
# gc
# ---------------------------------------------------------------------------

def cmd_gc(args) -> int:
    root = os.path.abspath(args.root)
    if args.older_than is None and not args.orphaned:
        raise SystemExit("repro cache gc: nothing selected — pass "
                         "--older-than and/or --orphaned")
    dirs = [d for d in _cache_dirs(root) if d != root]
    victims: Dict[str, str] = {}

    if args.older_than is not None:
        cutoff = time.time() - _parse_age(args.older_than)
        for d in dirs:
            m, err = _load_manifest_doc(d)
            if m is None:
                continue                 # unreadable: verify's business
            last = m.last_used_at or m.created_at
            if last <= cutoff:
                victims[d] = (f"last used {_fmt_time(last)}, older than "
                              f"{args.older_than}")

    if args.orphaned:
        referenced = set()
        for _, doc, _err in iter_plan_manifests(root):
            if doc:
                referenced.update(n.get("dir") for n in doc.get("nodes", [])
                                  if n.get("dir"))
        for d in dirs:
            if os.path.basename(d) not in referenced:
                victims.setdefault(d, "referenced by no plan manifest")

    if not victims:
        print("nothing to collect")
        return 0
    freed = 0
    for d in sorted(victims):
        size = _dir_size(d)
        freed += size
        verb = "removing" if args.yes else "would remove"
        print(f"{verb} {d} ({victims[d]}; {size / 1024:.1f}KiB)")
        if args.yes:
            shutil.rmtree(d, ignore_errors=True)
    action = "freed" if args.yes else "would free"
    print(f"{action} {freed / 1024:.1f}KiB across {len(victims)} dir(s)"
          + ("" if args.yes else " — re-run with --yes to delete"))
    return 0


# ---------------------------------------------------------------------------
# export / import
# ---------------------------------------------------------------------------

def _add_bytes(tar: tarfile.TarFile, name: str, data: bytes) -> None:
    info = tarfile.TarInfo(name)
    info.size = len(data)
    info.mtime = int(time.time())
    tar.addfile(info, io.BytesIO(data))


def _safe_extractall(tar: tarfile.TarFile, dest: str, members=None) -> None:
    # the extraction ``filter=`` kwarg is absent on 3.10.<12 / 3.11.<4
    if hasattr(tarfile, "data_filter"):
        tar.extractall(dest, members=members, filter="data")
    else:                                # pragma: no cover - old stdlib
        tar.extractall(dest, members=members)


def cmd_export(args) -> int:
    src = os.path.abspath(args.cache_dir)
    m, err = _load_manifest_doc(src)
    if err is not None:
        raise SystemExit(f"repro cache export: {err}")
    if m is None:
        raise SystemExit(f"repro cache export: {src!r} has no "
                         f"{MANIFEST_NAME} — not a provenance-aware cache "
                         f"directory")
    entries: Optional[List[Tuple[bytes, bytes]]] = None
    if m.backend in BACKENDS and m.backend != "memory" \
            and _store_exists(src, m.backend):
        backend = BACKENDS[m.backend](src)
        try:
            entries = backend.items()
        except NotImplementedError:
            entries = None               # e.g. pickle: raw-file export
        finally:
            backend.close()
    mode = "entries" if entries is not None else "raw"
    meta = {"format_version": EXPORT_FORMAT_VERSION, "mode": mode,
            "exported_at": time.time(),
            "n_entries": len(entries) if entries is not None
            else m.entry_count}
    with tarfile.open(args.out, "w") as tar:
        _add_bytes(tar, "export.json",
                   json.dumps(meta, indent=2, sort_keys=True).encode())
        with open(manifest_path(src), "rb") as f:
            _add_bytes(tar, MANIFEST_NAME, f.read())
        if mode == "entries":
            _add_bytes(tar, "entries.pkl", pickle.dumps(
                entries, protocol=pickle.HIGHEST_PROTOCOL))
        else:
            for base, _, files in os.walk(src):
                for fname in files:
                    full = os.path.join(base, fname)
                    rel = os.path.relpath(full, src)
                    if rel == MANIFEST_NAME:
                        continue
                    tar.add(full, arcname=os.path.join("raw", rel))
    print(f"exported {meta['n_entries']} entrie(s) from {src} "
          f"({mode} mode, fp={m.fingerprint or '-'}) -> {args.out}")
    return 0


def _read_member(tar: tarfile.TarFile, name: str) -> bytes:
    f = tar.extractfile(name)
    if f is None:
        raise SystemExit(f"repro cache import: artifact is missing {name!r}")
    return f.read()


def cmd_import(args) -> int:
    dest = os.path.abspath(args.dest)
    with tarfile.open(args.artifact) as tar:
        meta = json.loads(_read_member(tar, "export.json"))
        if meta.get("format_version", 0) > EXPORT_FORMAT_VERSION:
            raise SystemExit("repro cache import: artifact written by a "
                             "newer exporter")
        man_bytes = _read_member(tar, MANIFEST_NAME)
        with tempfile.TemporaryDirectory() as td:
            with open(manifest_path(td), "wb") as f:
                f.write(man_bytes)
            try:
                imported = CacheManifest.load(td)
            except ManifestError as e:
                raise SystemExit(f"repro cache import: {e}")

        existing, err = (None, None)
        if os.path.isdir(dest):
            existing, err = _load_manifest_doc(dest)
            if err is not None and not args.force:
                raise SystemExit(f"repro cache import: destination has a "
                                 f"corrupted manifest ({err}); pass --force "
                                 f"to overwrite")
        if existing is not None and existing.fingerprint \
                and imported.fingerprint \
                and existing.fingerprint != imported.fingerprint \
                and not args.force:
            raise SystemExit(
                f"repro cache import: fingerprint mismatch — destination "
                f"records {existing.fingerprint}, artifact carries "
                f"{imported.fingerprint}; this is not the same pipeline "
                f"position (pass --force to import anyway)")

        if meta["mode"] == "entries":
            backend_name = args.backend or imported.backend
            if backend_name not in BACKENDS:
                raise SystemExit(f"repro cache import: unknown backend "
                                 f"{backend_name!r}; registered: "
                                 f"{', '.join(sorted(BACKENDS))}")
            entries = pickle.loads(_read_member(tar, "entries.pkl"))
            os.makedirs(dest, exist_ok=True)
            backend = BACKENDS[backend_name](dest)
            try:
                backend.put_many(entries)
                n = len(backend)
            finally:
                backend.close()
            imported.backend = backend_name
            imported.entry_count = int(n)
            imported.last_used_at = time.time()
            imported.save(dest)
        else:
            if os.path.isdir(dest) and os.listdir(dest) and not args.force:
                raise SystemExit(f"repro cache import: destination {dest!r} "
                                 f"is not empty (pass --force)")
            os.makedirs(dest, exist_ok=True)
            members = [m_ for m_ in tar.getmembers()
                       if m_.name.startswith("raw/")]
            for m_ in members:
                m_.name = os.path.relpath(m_.name, "raw")
            _safe_extractall(tar, dest, members=members)
            imported.last_used_at = time.time()
            imported.save(dest)

    print(f"imported {meta['n_entries']} entrie(s) into {dest} "
          f"({meta['mode']} mode, fp={imported.fingerprint or '-'})")
    return 0
