"""``repro plan`` — inspect recorded execution plans.

A planner ``cache_dir`` records every plan that used it under
``plans/<plan_id>.json`` (``core/plan.py``).  ``repro plan explain``
renders those records with the *same* renderer as
``ExecutionPlan.explain()`` (``repro.core.ir.render_explain``), so the
CLI output round-trips the in-process one byte-for-byte:

* ``explain ROOT``             — render every recorded plan;
* ``explain ROOT --plan ID``   — render one plan (id prefix accepted);
* ``explain ROOT --json``      — emit the raw record(s) as JSON
  (stable key order) for scripting.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Tuple

from ..caching.provenance import iter_plan_manifests
from ..core.ir import render_explain

__all__ = ["register", "cmd_explain"]


def register(subparsers) -> None:
    p = subparsers.add_parser(
        "plan", help="inspect recorded execution plans",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = p.add_subparsers(dest="plan_command", required=True)

    ex = sub.add_parser(
        "explain", help="render a recorded plan as the explain() tree")
    ex.add_argument("root", help="planner cache_dir (holding plans/*.json)")
    ex.add_argument("--plan", default=None, metavar="ID",
                    help="plan id to render (prefix accepted); "
                         "default: every recorded plan")
    ex.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the raw plan record(s) as JSON")
    ex.set_defaults(func=cmd_explain)


def _load_plans(root: str) -> List[Tuple[str, Dict[str, Any]]]:
    out = []
    for path, doc, err in iter_plan_manifests(os.path.abspath(root)):
        if err is not None:
            raise SystemExit(f"repro plan explain: {err} ({path})")
        out.append((path, doc))
    return out


def cmd_explain(args) -> int:
    plans = _load_plans(args.root)
    if args.plan is not None:
        plans = [(p, d) for p, d in plans
                 if str(d.get("plan_id", "")).startswith(args.plan)]
    if not plans:
        sel = f" matching {args.plan!r}" if args.plan is not None else ""
        msg = (f"no recorded plan manifests{sel} under {args.root} "
               f"(plans are recorded when ExecutionPlan is given a "
               f"cache_dir)")
        if args.as_json:
            print("[]")                  # stdout stays pure JSON
            print(msg, file=sys.stderr)
        else:
            print(msg)
        return 1
    if args.as_json:
        print(json.dumps([d for _, d in plans], indent=2, sort_keys=True))
        return 0
    for i, (_, doc) in enumerate(plans):
        if i:
            print()
        print(render_explain(doc))
    return 0
