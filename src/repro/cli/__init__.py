"""``repro`` command line (also invocable as ``python -m repro.cli``).

Subcommands register themselves on the top-level parser:

* ``repro cache`` (``cli/cache.py``) — inspection, verification,
  garbage collection and export/import of cache directories built on
  the provenance manifests of ``caching/provenance.py``;
* ``repro plan`` (``cli/plan.py``) — render recorded execution plans
  with the same ASCII tree as ``ExecutionPlan.explain()``;
* ``repro serve`` (``cli/serve.py``) — stand up a ``PipelineService``
  over a registry pipeline and drive it with a closed-loop request
  stream (micro-batching, planner caches, online latency stats).
"""
from __future__ import annotations

import argparse
from typing import List, Optional

__all__ = ["main", "build_parser"]


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro",
        description="Precomputation & caching in IR experiments — tooling")
    sub = ap.add_subparsers(dest="command", required=True)
    from . import cache as _cache
    from . import plan as _plan
    from . import serve as _serve
    _cache.register(sub)
    _plan.register(sub)
    _serve.register(sub)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return int(args.func(args) or 0)
