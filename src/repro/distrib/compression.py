"""Gradient compression with error feedback (distributed-optimization).

Two compressors, both with EF (error-feedback) accumulators so the
quantization error is re-injected next step (Karimireddy et al.,
arXiv:1901.09847 — EF-SGD; 1-bit Adam lineage):

* ``int8``  — per-tensor symmetric int8 quantization (32→8 bits on the
  wire: 4× reduce-scatter volume);
* ``topk``  — magnitude top-k sparsification (k fraction kept).

The compress/decompress pair is applied *around the collective*: in a
real deployment the int8 payload is what crosses ICI/DCN.  In this
repo's single-process runs the arithmetic (and its effect on training)
is exercised end-to-end; tests assert convergence parity within
tolerance and exact EF bookkeeping.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "init_ef_state", "compress_grads",
           "wire_bytes"]


@dataclass(frozen=True)
class CompressionConfig:
    method: str = "none"        # none | int8 | topk
    topk_fraction: float = 0.01
    error_feedback: bool = True


def init_ef_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                        params)


def _int8_roundtrip(g: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(g: jnp.ndarray, frac: float) -> jnp.ndarray:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_grads(grads, ef_state, cfg: CompressionConfig):
    """Returns (decompressed grads as seen post-collective, new EF state)."""
    if cfg.method == "none":
        return grads, ef_state

    def one(g, e):
        g32 = g.astype(jnp.float32)
        target = g32 + (e if cfg.error_feedback else 0.0)
        if cfg.method == "int8":
            sent = _int8_roundtrip(target)
        elif cfg.method == "topk":
            sent = _topk_roundtrip(target, cfg.topk_fraction)
        else:
            raise ValueError(cfg.method)
        new_e = target - sent if cfg.error_feedback else e
        return sent.astype(g.dtype), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(ef_state)[0]
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        sg, se = one(g, e)
        out_g.append(sg)
        out_e.append(se)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def wire_bytes(params, cfg: CompressionConfig) -> int:
    """Bytes a gradient all-reduce would move per step under cfg."""
    n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    if cfg.method == "int8":
        return n            # 1 byte/elem
    if cfg.method == "topk":
        keep = int(n * cfg.topk_fraction)
        return keep * 8     # value fp32 + index int32
    return n * 4
