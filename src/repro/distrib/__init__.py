# Distribution runtime: shardings, checkpointing, compression, fault tol.
from .shardings import (ShardingRules, DEFAULT_RULES, spec_for,
                        tree_shardings, batch_axes, describe_tree_shardings)
from .checkpoint import (Checkpointer, save_checkpoint, restore_checkpoint,
                         latest_step)
from .compression import CompressionConfig, init_ef_state, compress_grads, \
    wire_bytes
from .fault import (Preemption, RestartableLoop, RetryPolicy,
                    StragglerPolicy)

__all__ = ["ShardingRules", "DEFAULT_RULES", "spec_for", "tree_shardings",
           "batch_axes", "describe_tree_shardings", "Checkpointer",
           "save_checkpoint", "restore_checkpoint", "latest_step",
           "CompressionConfig", "init_ef_state", "compress_grads",
           "wire_bytes", "RestartableLoop", "RetryPolicy", "StragglerPolicy",
           "Preemption"]
