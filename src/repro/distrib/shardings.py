"""Logical-axis sharding rules with divisibility pruning.

MaxText-style: every parameter/activation dim carries a *logical* axis
name; a rule table maps logical axes to mesh axes.  One rule set must
compile **all 10 architectures × 4 shapes × 2 meshes**, so the engine
prunes infeasible assignments instead of failing:

* a mesh axis is used at most once per array (PartitionSpec constraint);
  first dim (in rule priority order) wins, later dims fall back;
* if a dim is not divisible by its mesh-axis product, trailing mesh axes
  are dropped until it divides (e.g. 40 attention heads on a 16-way
  model axis ⇒ heads replicated, TP falls back to the d_ff dim);
* unknown logical axes replicate.

This is what turns "qwen3 has 40 heads" from a crash into a recorded
sharding decision the roofline analysis can then criticise.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import ParamSpec, logical_axes_tree

__all__ = ["ShardingRules", "DEFAULT_RULES", "spec_for", "tree_shardings",
           "batch_axes", "describe_tree_shardings"]


#: rule table: logical axis -> tuple of mesh axes (joint sharding).
#: tuple order = preference; trailing axes pruned on indivisibility.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # LM params
    "vocab": ("model",),
    "d_ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "moe_groups": ("data",),
    "moe_capacity": ("model",),   # fallback TP when experts indivisible
    "d_model": ("data",),          # FSDP / ZeRO-3 style in-dim shard
    "d_model_out": ("data",),
    # activations
    "batch": ("pod", "data"),      # "pod" silently skipped on 2D meshes
    "seq": (),
    "kv_seq": ("model",),          # split-K decode
    # recsys
    "table_rows": ("data", "model"),
    "table_dim": (),
    "mlp_in": ("data",),
    "mlp_out": ("model",),
    # gnn
    "gnn_in": (),
    "gnn_out": (),
    "nodes": ("data", "model"),
    "edges": ("data", "model"),
    # never sharded
    "layers": (),
    "norm": (),
    "head_dim": (),
}


@dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, Tuple[str, ...]] = field(
        default_factory=lambda: dict(DEFAULT_RULES))

    def override(self, **kv: Tuple[str, ...]) -> "ShardingRules":
        new = dict(self.rules)
        new.update(kv)
        return ShardingRules(new)

    # -- core resolution ---------------------------------------------------
    def spec_for(self, shape: Sequence[int],
                 logical_axes: Sequence[Optional[str]],
                 mesh: Mesh) -> P:
        mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        used: set = set()
        parts: List[Any] = []
        for dim, lax in zip(shape, logical_axes):
            if lax is None:
                parts.append(None)
                continue
            cand = [a for a in self.rules.get(lax, ())
                    if a in mesh_sizes and a not in used]
            # divisibility pruning: drop trailing axes until dim divides
            while cand and dim % int(np.prod([mesh_sizes[a] for a in cand])):
                cand.pop()
            if not cand:
                parts.append(None)
            else:
                used.update(cand)
                parts.append(tuple(cand) if len(cand) > 1 else cand[0])
        # strip trailing Nones for a tidy spec
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding_for(self, spec_or_shape, logical_axes=None,
                     mesh: Optional[Mesh] = None) -> NamedSharding:
        if isinstance(spec_or_shape, ParamSpec):
            shape, axes = spec_or_shape.shape, spec_or_shape.logical_axes
        else:
            shape, axes = spec_or_shape, logical_axes
        return NamedSharding(mesh, self.spec_for(shape, axes, mesh))

    def tree_shardings(self, specs, mesh: Mesh):
        """pytree[ParamSpec] -> pytree[NamedSharding]."""
        return jax.tree.map(
            lambda s: self.sharding_for(s, mesh=mesh), specs,
            is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_for(shape, logical_axes, mesh, rules: Optional[ShardingRules] = None):
    return (rules or ShardingRules()).spec_for(shape, logical_axes, mesh)


def tree_shardings(specs, mesh, rules: Optional[ShardingRules] = None):
    return (rules or ShardingRules()).tree_shardings(specs, mesh)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that jointly shard the global batch."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def describe_tree_shardings(specs, mesh,
                            rules: Optional[ShardingRules] = None
                            ) -> List[str]:
    """Human-readable sharding table (DESIGN/EXPERIMENTS reporting)."""
    rules = rules or ShardingRules()
    lines = []

    def visit(path, s):
        spec = rules.spec_for(s.shape, s.logical_axes, mesh)
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        lines.append(f"{name:40s} {str(s.shape):24s} {spec}")

    leaves = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]
    for path, s in leaves:
        visit(path, s)
    return lines
