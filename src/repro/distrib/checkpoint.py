"""Sharded, atomic, async, *elastic* checkpointing.

Design (1000+-node posture, dimensioned down to this container):

* **content**: every leaf of (params, opt_state, extras) is saved as an
  ``.npy`` under a flat path derived from its pytree path, plus a JSON
  manifest (step, leaf index, shapes, dtypes).  The manifest is
  mesh-agnostic: restore re-shards onto *any* mesh ("elastic restore"
  — scale from 256 to 512 chips between runs without conversion).
* **atomicity**: writes go to ``<dir>/.tmp-<step>`` and are committed
  with a single ``os.replace`` to ``<dir>/step_<k>`` — a crash mid-save
  never corrupts the latest checkpoint; ``latest()`` only sees
  committed directories.
* **async**: ``save_async`` snapshots leaves to host memory then writes
  on a background thread, returning control to the train loop (the
  standard MaxText/Orbax overlap); ``wait()`` joins before the next
  save.
* **retention**: keep the newest ``keep`` checkpoints, delete older.

On a real multi-host pod each process would save only its addressable
shards; here the single process owns everything, which keeps the commit
protocol identical.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Checkpointer", "save_checkpoint", "restore_checkpoint",
           "latest_step"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = ".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name or "leaf", leaf))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}-{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": [], "time": time.time(),
                "format_version": 1}
    for i, (name, leaf) in enumerate(_flatten_with_names(tree)):
        arr = np.asarray(leaf)          # device->host gather if sharded
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
            # exotic dtypes (bfloat16, fp8) round-trip via float32
            arr = arr.astype(np.float32)
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": logical_dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)              # atomic commit
    return final


def restore_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings (same structure) —
    the elastic path: leaves are device_put onto the *current* mesh
    regardless of the mesh that saved them.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names_like = [n for n, _ in _flatten_with_names(like)]
    by_name = {l["name"]: l for l in manifest["leaves"]}
    missing = [n for n in names_like if n not in by_name]
    if missing:
        raise ValueError(f"checkpoint at step {step} missing leaves "
                         f"{missing[:5]}...")
    leaves, treedef = jax.tree_util.tree_flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for name, leaf, shd in zip(names_like, leaves, shard_leaves):
        arr = np.load(os.path.join(d, by_name[name]["file"]))
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        if str(arr.dtype) != str(want_dtype):
            # jnp handles ml_dtypes casts (bfloat16 etc.) that numpy lacks
            arr = np.asarray(jnp.asarray(arr).astype(want_dtype))
        if shd is not None:
            arr = jax.device_put(arr, shd)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step


class Checkpointer:
    """Async checkpoint manager with retention."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saves = 0

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        # snapshot to host synchronously (cheap vs. disk) so the train
        # loop can mutate its arrays immediately afterwards
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self.saves += 1

    def save(self, step: int, tree: Any) -> str:
        self.wait()
        path = save_checkpoint(self.ckpt_dir, step, tree)
        self.saves += 1
        self._gc()
        return path

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        self.wait()
        return restore_checkpoint(self.ckpt_dir, like, step, shardings)

    def latest(self) -> Optional[int]:
        return latest_step(self.ckpt_dir)

    def _gc(self) -> None:
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(int(m.group(1)) for d in os.listdir(self.ckpt_dir)
                       if (m := _STEP_RE.match(d)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)
