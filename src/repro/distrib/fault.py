"""Fault tolerance: restart driver + straggler mitigation policy.

``RestartableLoop`` is the generic supervisor a cluster scheduler would
run per slice: execute the step function, checkpoint every
``ckpt_every`` steps, and on *any* failure restore the last committed
checkpoint and resume.  Determinism contract: the data pipeline is
step-keyed (``batch_fn(step)``), so a restarted run replays the exact
byte stream — tests assert bit-equal final params between an
uninterrupted run and a run with injected preemptions.

``StragglerPolicy`` is the deadline-barrier policy used at scale:
per-step durations feed an EWMA; a step exceeding
``deadline_factor × ewma`` is flagged, and after ``evict_after``
consecutive flags the (simulated) worker is marked for eviction —
which in a real deployment triggers an elastic restart on the reduced
mesh (the checkpoint layer's mesh-agnostic manifest is what makes that
restart possible).

``RetryPolicy`` is the shared retry/backoff envelope: a bounded
attempt count with exponentially growing (capped) delays.  The serve
fleet (``serve/fleet.py``) uses it both to pace worker respawns and to
bound how often an accepted request may be requeued onto survivors.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from .checkpoint import Checkpointer

__all__ = ["RestartableLoop", "RetryPolicy", "StragglerPolicy",
           "Preemption"]


class Preemption(RuntimeError):
    """Simulated node failure."""


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with capped exponential backoff.

    ``max_retries`` counts *retries*, not attempts: a policy with
    ``max_retries=3`` allows 4 total attempts.  ``delay(attempt)`` is
    the pause before retry number ``attempt`` (1-based), growing as
    ``base_delay_s * multiplier**(attempt-1)`` up to ``max_delay_s``.
    """

    max_retries: int = 3
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 2.0

    def delay(self, attempt: int) -> float:
        if attempt <= 0:
            return 0.0
        d = self.base_delay_s * self.multiplier ** (attempt - 1)
        return min(d, self.max_delay_s)

    def allows(self, attempt: int) -> bool:
        """Whether retry number ``attempt`` (1-based) is still within
        budget."""
        return attempt <= self.max_retries

    def call(self, fn: Callable[[], Any], *,
             retry_on: Tuple[type, ...] = (Exception,),
             sleep: Callable[[float], None] = time.sleep) -> Any:
        """Run ``fn`` under this policy: on a ``retry_on`` exception,
        back off and retry; re-raise once the budget is exhausted."""
        attempt = 0
        while True:
            try:
                return fn()
            except retry_on:
                attempt += 1
                if not self.allows(attempt):
                    raise
                sleep(self.delay(attempt))


@dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0
    evict_after: int = 3
    ewma_alpha: float = 0.2
    _ewma: Optional[float] = None
    flags: int = 0
    flagged_steps: List[int] = field(default_factory=list)
    evicted: bool = False

    def observe(self, step: int, duration_s: float) -> str:
        """Returns 'ok' | 'straggle' | 'evict'."""
        if self._ewma is None:
            self._ewma = duration_s
            return "ok"
        verdict = "ok"
        if duration_s > self.deadline_factor * self._ewma:
            self.flags += 1
            self.flagged_steps.append(step)
            verdict = "straggle"
            if self.flags >= self.evict_after:
                self.evicted = True
                verdict = "evict"
        else:
            self.flags = 0
            # only healthy steps update the baseline
            self._ewma = (1 - self.ewma_alpha) * self._ewma \
                + self.ewma_alpha * duration_s
        return verdict


class RestartableLoop:
    """Checkpoint/restart supervisor around a step function."""

    def __init__(self, step_fn: Callable, batch_fn: Callable[[int], Any],
                 ckpt: Checkpointer, *, ckpt_every: int = 10,
                 max_restarts: int = 10,
                 straggler: Optional[StragglerPolicy] = None):
        self.step_fn = step_fn            # (state, batch) -> state, metrics
        self.batch_fn = batch_fn          # step -> batch (deterministic!)
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerPolicy()
        self.restarts = 0
        self.metrics_log: List[Dict] = []

    def run(self, state: Any, n_steps: int,
            fail_at: Optional[Dict[int, int]] = None) -> Any:
        """Run to n_steps; ``fail_at`` maps step->restart_ordinal for
        injected preemptions (test hook)."""
        fail_at = fail_at or {}
        step = 0
        while step < n_steps:
            try:
                while step < n_steps:
                    if step in fail_at and fail_at[step] == self.restarts:
                        raise Preemption(f"injected failure at step {step}")
                    t0 = time.perf_counter()
                    batch = self.batch_fn(step)
                    state, metrics = self.step_fn(state, batch)
                    dt = time.perf_counter() - t0
                    verdict = self.straggler.observe(step, dt)
                    self.metrics_log.append(
                        {"step": step, "dt": dt, "verdict": verdict,
                         **{k: float(v) for k, v in (metrics or {}).items()}})
                    step += 1
                    if step % self.ckpt_every == 0 or step == n_steps:
                        self.ckpt.wait()
                        self.ckpt.save(step, state)
            except Preemption:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                last = self.ckpt.latest()
                if last is None:
                    step = 0        # restart from scratch
                    continue
                state, step = self.ckpt.restore(state)
        return state
